"""End-to-end driver: train a ~100M-parameter DiT for a few hundred steps.

Synthetic class-conditioned latent dataset (data/synthetic.py), AdamW with
warmup+cosine, fault-tolerant checkpointing (auto-resume on restart). The
resulting checkpoint is picked up by the benchmark suite for quality
studies closer to the paper's trained-model setting.

    PYTHONPATH=src python examples/train_dit.py --steps 300
"""
import argparse
import os
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.dit_xl_512 import TRAIN_100M
from repro.data import synthetic
from repro.models import dit as dit_lib
from repro.optim.adamw import OptimConfig
from repro.train import steps as steps_lib

CKPT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                    "dit_train_ckpt")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=2e-4)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = TRAIN_100M
    n = dit_lib.param_count(cfg)
    print(f"[train_dit] {cfg.name}: {n/1e6:.1f}M params, "
          f"latent {cfg.latent_size}x{cfg.latent_size}")

    ocfg = OptimConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    dcfg = synthetic.for_model(cfg, args.batch, seed=7)
    state = steps_lib.init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(CKPT, keep_last=2)
    start = 0
    got = mgr.restore_latest(state)
    if got is not None:
        start, state, _ = got
        print(f"[train_dit] resumed at step {start}")

    step_fn = jax.jit(steps_lib.make_train_step(cfg, ocfg),
                      donate_argnums=(0,))
    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = synthetic.batch_at(dcfg, step)
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"({(time.time()-t0)/max(step-start+1,1):.2f}s/step)",
                  flush=True)
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            mgr.save(step + 1, state.params)
            print(f"[ckpt] saved params at step {step+1}", flush=True)

    first = np.mean(losses[:20])
    last = np.mean(losses[-20:])
    print(f"[train_dit] loss {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'no decrease'})")


if __name__ == "__main__":
    main()
