"""Quickstart: DRIFT in ~40 lines.

Samples images from a small DiT three ways -- clean, aggressive-DVFS
unprotected, aggressive-DVFS with DRIFT (fine-grained schedule +
rollback-ABFT) -- and prints the fixed-seed quality comparison.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import dvfs, metrics
from repro.core.exec_ctx import DriftSystemConfig
from repro.diffusion import sampler
from repro.train import steps as steps_lib

ARCH, STEPS, BATCH = "dit-xl-512", 10, 2


def main():
    cfg = configs.get_config(ARCH, smoke=True)
    key = jax.random.PRNGKey(0)
    params = steps_lib.init_model_params(cfg, key)
    # random init: perturb the adaLN-Zero weights so outputs are non-trivial
    params["blocks"]["adaln_w"] = 0.1 * jax.random.normal(
        jax.random.fold_in(key, 1), params["blocks"]["adaln_w"].shape)
    params["final_w"] = 0.2 * jax.random.normal(
        jax.random.fold_in(key, 2), params["final_w"].shape)

    lat0 = jax.random.normal(jax.random.fold_in(key, 3),
                             (BATCH, cfg.latent_size, cfg.latent_size,
                              cfg.latent_channels))
    cond = jnp.array([1, 2])
    sched = dvfs.fine_grained_schedule(STEPS, dvfs.UNDERVOLT,
                                       nominal_steps=2)

    def run(mode, schedule):
        scfg = sampler.SamplerConfig(num_sample_steps=STEPS,
                                     drift=DriftSystemConfig(mode=mode),
                                     schedule=schedule)
        return jax.jit(lambda p, l: sampler.sample(
            cfg, p, key, l, cond, None, scfg))(params, lat0)

    clean = run("clean", None)
    faulty = run("faulty", sched)
    drift = run("drift", sched)

    img = lambda o: jnp.clip(o.latents, -1, 1)
    print(f"operating point: {dvfs.UNDERVOLT.voltage}V @ "
          f"{dvfs.UNDERVOLT.freq_ghz}GHz -> BER "
          f"{dvfs.ber_of(dvfs.UNDERVOLT):.1e}")
    print(f"unprotected  lpips-proxy vs clean: "
          f"{float(metrics.lpips_proxy(img(faulty), img(clean))):.4f}")
    print(f"DRIFT        lpips-proxy vs clean: "
          f"{float(metrics.lpips_proxy(img(drift), img(clean))):.4f} "
          f"(corrected {int(drift.total_corrected)} elements)")


if __name__ == "__main__":
    main()
