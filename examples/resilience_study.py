"""Resilience characterization probes (paper Sec 4) on a small DiT.

    PYTHONPATH=src python examples/resilience_study.py --probe similarity
    PYTHONPATH=src python examples/resilience_study.py --probe bits
    PYTHONPATH=src python examples/resilience_study.py --probe steps
    PYTHONPATH=src python examples/resilience_study.py --probe selfheal
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np


def probe_similarity():
    """Fig 2(b): cosine similarity of activations across adjacent steps --
    the property rollback-ABFT exploits."""
    from benchmarks.common import tiny_model, sample_inputs
    from repro.diffusion import sampler as sampler_lib, schedule as sched_lib
    from repro.core.exec_ctx import DriftSystemConfig

    cfg, params = tiny_model("dit-xl-512")
    lat0, cond, text = sample_inputs(cfg)
    scfg = sampler_lib.SamplerConfig(num_sample_steps=10,
                                     drift=DriftSystemConfig(mode="clean"))
    sched = sched_lib.DdpmSchedule.default(1000)
    ts = sched_lib.ddim_timesteps(1000, 10)
    from repro.models import dit as dit_lib
    lat = lat0
    prev_eps = None
    print("step_pair,cos_similarity(eps)")
    for i, t in enumerate(ts):
        eps, _, _ = dit_lib.forward(cfg, params, lat,
                                    jnp.full((lat.shape[0],), float(t)),
                                    cond, text=text)
        if prev_eps is not None:
            num = float(jnp.sum(eps * prev_eps))
            den = float(jnp.linalg.norm(eps) * jnp.linalg.norm(prev_eps))
            print(f"{i-1}->{i},{num/den:.4f}")
        prev_eps = eps
        t_next = int(ts[i + 1]) if i + 1 < len(ts) else -1
        lat = sched.ddim_step(lat, eps, int(t), t_next)


def probe_bits():
    from benchmarks import fig4_bitlevel
    fig4_bitlevel.main()


def probe_steps():
    from benchmarks import fig5_timestep
    fig5_timestep.main()


def probe_blocks():
    from benchmarks import fig6_block
    fig6_block.main()


def probe_selfheal():
    from benchmarks import fig7_selfcorrection
    fig7_selfcorrection.main()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--probe", default="similarity",
                    choices=["similarity", "bits", "steps", "blocks",
                             "selfheal"])
    args = ap.parse_args()
    {"similarity": probe_similarity, "bits": probe_bits,
     "steps": probe_steps, "blocks": probe_blocks,
     "selfheal": probe_selfheal}[args.probe]()


if __name__ == "__main__":
    main()
