"""Serve a stream of generation requests with mixed DVFS operating points,
priorities, and deadlines through one DRIFT serving engine.

``--arch`` picks any registered model: diffusion archs run the DRIFT
denoiser (mode ``drift``), autoregressive archs run token decoding with
statistical ABFT + KV-window rollback (mode ``stat_abft``) -- same
engine, queue, DVFS ladder, and monitor either way (docs/servable.md):

    PYTHONPATH=src python examples/drift_serve.py --arch olmo-1b \
        --requests 2 --batch 2 --steps 8

Each request picks its own operating point (``--op`` is a comma-separated
list cycled across requests; ``auto`` defers to the engine's BER-monitor
ladder, ``core.dvfs.OP_LADDER``) and scheduling class (``--priority`` is
cycled the same way). The engine buckets same-configuration requests into
fixed-size micro-batches, jits each configuration exactly once, reuses the
cached clean reference for quality metrics, and carries the BER monitor
across batches. Per-request energy/latency comes from
``perfmodel.energy.per_request_cost`` (the bucket's cost split across its
live requests).

    PYTHONPATH=src python examples/drift_serve.py --requests 6 --batch 2 \
        --op undervolt,overclock

``--deadline`` (a cycled list like ``--op``; ``none`` = no deadline, with
optional ``--step-budget``) routes submissions through the deadline-aware
scheduler: admission control projects each request's completion on the
engine's virtual (perfmodel) clock, escalates urgent work to overclock or
trims its denoising steps, and rejects hopeless requests -- see
docs/scheduler.md. ``--stream K`` yields latent previews every K
denoising steps ahead of the final results (final latents bit-identical
to the unstreamed path):

    PYTHONPATH=src python examples/drift_serve.py --requests 2 --batch 1 \
        --steps 6 --op undervolt --priority interactive,background \
        --deadline 0.055,none --stream 2

``--energy-budget`` / ``--quality-floor`` state a compute-optimal
objective: admission resolves against the joint (steps x precision x
TaylorSeer x DVFS) Pareto frontier -- minimum energy meeting the
deadline, fastest point at or above the quality floor, or best quality
inside the budget -- and rewrites all four knobs (docs/frontier.md):

    PYTHONPATH=src python examples/drift_serve.py --requests 2 --batch 2 \
        --steps 8 --op auto --quality-floor 0.9

``--sharded`` runs the same stream through ``ShardedDriftServeEngine``,
spreading every micro-batch over the local (data, model) device mesh --
on one device it degrades to the plain engine, and on a data-parallel
mesh the latents are bit-identical either way:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/drift_serve.py --requests 8 \
        --batch 8 --sharded

``--metrics-port PORT`` exposes the run's telemetry over HTTP
(``/metrics`` Prometheus text, ``/healthz``, SSE ``/events``; 0 =
ephemeral); ``--no-telemetry`` switches the subsystem -- metrics,
learned latency estimates, adaptive BER guardband -- off entirely.
Workloads naming explicit operating points serve bit-identically either
way; ``auto`` requests lose the guardband floor. See docs/telemetry.md.
"""
import argparse
import contextlib

from repro.core import dvfs as dvfs_lib
from repro.core.rollback import DEFAULT_INTERVAL
from repro.launch.serve import (arch_family_help, default_mode_for,
                                rollback_interval_arg)
from repro.serving import (DeadlineScheduler, DriftServeEngine,
                           EngineTelemetry, OffloadConfig, PreviewEvent,
                           ShardedDriftServeEngine, make_engine,
                           paradigm_for, serve_telemetry)
from repro.serving.request import REQUEST_PRIORITIES

OP_LADDER_HELP = " -> ".join(p.name for p in dvfs_lib.OP_LADDER)


def build_parser():
    ap = argparse.ArgumentParser(
        description="Mixed-op / mixed-priority DRIFT serving demo.",
        epilog=f"The op 'auto' walks core.dvfs.OP_LADDER "
               f"({OP_LADDER_HELP}) via the engine's BER monitor.")
    ap.add_argument("--arch", default="dit-xl-512",
                    help="model to serve; paradigm comes from the "
                         f"ServableModel registry -- {arch_family_help()}")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--op", default="undervolt,overclock",
                    help="comma-separated operating points, cycled per "
                         "request (nominal/undervolt/overclock/auto; "
                         f"'auto' walks the ladder {OP_LADDER_HELP})")
    ap.add_argument("--priority", default="standard",
                    help="comma-separated scheduling classes "
                         f"({'/'.join(REQUEST_PRIORITIES)}), cycled per "
                         "request; non-standard classes enable the "
                         "deadline-aware scheduler")
    ap.add_argument("--deadline", default=None, metavar="SEC[,SEC|none...]",
                    help="comma-separated relative deadlines (engine "
                         "virtual seconds; 'none' = no deadline), cycled "
                         "per request; enables admission control with "
                         "op-escalation / step-trimming")
    ap.add_argument("--step-budget", type=int, default=None, metavar="N",
                    help="per-request cap on denoising steps")
    ap.add_argument("--energy-budget", type=float, default=None,
                    metavar="J",
                    help="per-request energy budget in Joules; admission "
                         "resolves against the compute-optimal (steps x "
                         "precision x TaylorSeer x DVFS) frontier "
                         "(docs/frontier.md)")
    ap.add_argument("--quality-floor", type=float, default=None,
                    metavar="Q",
                    help="minimum quality proxy in (0, 1]; the frontier "
                         "picks the fastest point at or above it "
                         "(docs/frontier.md)")
    ap.add_argument("--stream", type=int, default=0, metavar="K",
                    help="yield latent previews every K denoising steps "
                         "(0 = off)")
    ap.add_argument("--rollback-interval", type=rollback_interval_arg,
                    default=DEFAULT_INTERVAL, metavar="N|auto",
                    dest="rollback_interval",
                    help="rollback checkpoint-refresh interval "
                         f"(default: {DEFAULT_INTERVAL}, from "
                         "core.rollback.DEFAULT_INTERVAL); 'auto' = the "
                         "offload planner's per-configuration choice")
    ap.add_argument("--offload", action="store_true",
                    help="async host offload of rollback checkpoints, "
                         "overlapped with the next window (docs/offload.md)")
    ap.add_argument("--sharded", action="store_true",
                    help="spread micro-batches across the device mesh")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve /metrics, /healthz, and SSE /events over "
                         "HTTP for this run (0 = ephemeral port)")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable metrics + learned latency estimates + "
                         "the adaptive BER guardband (explicit-op serving "
                         "is bit-identical; auto loses the floor)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="write the flight recorder as Chrome/Perfetto "
                         "trace JSON to DIR/flight.json after the run "
                         "(docs/tracing.md)")
    return ap


def main():
    args = build_parser().parse_args()

    ops = [o.strip() for o in args.op.split(",") if o.strip()]
    priorities = [p.strip() for p in args.priority.split(",") if p.strip()]
    deadlines = [None if d.strip().lower() == "none" else float(d)
                 for d in args.deadline.split(",") if d.strip()] \
        if args.deadline is not None else [None]
    if not ops or not priorities or not deadlines:
        raise SystemExit("--op/--priority/--deadline need at least one "
                         "non-empty entry")
    if args.stream and paradigm_for(args.arch) != "diffusion":
        raise SystemExit("--stream previews are latent images; "
                         f"{args.arch} serves autoregressively (tokens "
                         "come back in the final results)")
    telemetry = EngineTelemetry(enabled=not args.no_telemetry)
    offload = OffloadConfig() if args.offload else None
    if args.sharded:
        engine = make_engine(arch=args.arch, smoke=True,
                             bucket=args.batch,
                             model_parallel=args.model_parallel,
                             telemetry=telemetry, offload=offload)
    else:
        if args.model_parallel != 1:
            raise SystemExit("--model-parallel requires --sharded")
        engine = DriftServeEngine(arch=args.arch, smoke=True,
                                  bucket=args.batch, telemetry=telemetry,
                                  offload=offload)
    server = None
    if args.metrics_port is not None:
        server = serve_telemetry(engine, port=args.metrics_port)
        print(f"[drift_serve] telemetry at {server.url}")
    try:
        _drive(args, engine, server, ops, priorities, deadlines)
    finally:
        # never leak the bound port / server thread when the drain or
        # one of the self-asserts below raises
        if server is not None:
            server.close()


def _drive(args, engine, server, ops, priorities, deadlines):
    use_scheduler = (args.deadline is not None
                     or args.step_budget is not None
                     or args.energy_budget is not None
                     or args.quality_floor is not None
                     or any(p != "standard" for p in priorities))
    sched = DeadlineScheduler(engine) if use_scheduler else None
    previews = 0
    # Hold the server's engine lock from first submission through the
    # drain so a concurrent /events client 503s instead of interleaving
    # batches -- or draining the queue we just filled.
    drain_lock = server.engine_lock if server is not None \
        else contextlib.nullcontext()
    mode = default_mode_for(args.arch)
    with drain_lock:
        for i in range(args.requests):
            fields = dict(arch=args.arch, steps=args.steps, mode=mode,
                          op=ops[i % len(ops)], seed=i,
                          rollback_interval=args.rollback_interval)
            if sched is not None:
                adm = sched.submit(priority=priorities[i % len(priorities)],
                                   deadline_s=deadlines[i % len(deadlines)],
                                   step_budget=args.step_budget,
                                   energy_budget_j=args.energy_budget,
                                   quality_floor=args.quality_floor,
                                   **fields)
                frontier = (f" precision={adm.precision} "
                            f"taylorseer={adm.taylorseer} "
                            f"quality={adm.quality:.3f}"
                            if adm.action == "frontier" else "")
                print(f"[admission] {adm.action}: op={adm.op} "
                      f"steps={adm.steps}{frontier}"
                      + (f" ({adm.reason})" if adm.reason else ""))
            else:
                engine.submit(**fields)

        mesh = (dict(engine.mesh.shape)
                if isinstance(engine, ShardedDriftServeEngine)
                else "1 device")
        print(f"[drift_serve] {args.requests} requests, "
              f"bucket={args.batch}, ops={ops}, mesh={mesh}")

        if args.stream:
            results = []
            for ev in engine.run_stream(args.stream):
                if isinstance(ev, PreviewEvent):
                    previews += 1
                else:
                    results.append(ev)
            results.sort(key=lambda r: r.request_id)
            print(f"[drift_serve] {previews} preview events streamed")
        else:
            results = engine.run()

    for r in results:
        miss = " MISSED-DEADLINE" if r.deadline_missed else ""
        if r.tokens is not None:
            quality = (f"{len(r.tokens)} tokens "
                       f"match-vs-clean {r.token_match_vs_clean:.3f} "
                       f"abft-detections {r.ar_detections} "
                       f"kv-rollbacks {r.ar_rollbacks} "
                       f"evals {r.n_model_evals}")
        else:
            quality = (f"lpips={r.lpips_vs_clean:.4f} "
                       f"psnr={r.psnr_vs_clean_db:.1f}dB "
                       f"corrected(batch)={r.batch_corrected_elems}")
        print(f"req {r.request_id}: op={r.op} steps={r.steps} "
              f"prio={r.priority} batch={r.batch_index} {quality} "
              f"energy={r.energy_j:.2f}J (baseline {r.baseline_energy_j:.2f}J) "
              f"monitor_ber={r.monitor_ber:.2e}{miss}")

    # precision and taylorseer are SamplerKey dimensions too (the frontier
    # may assign them per request), so they discriminate traced configs
    distinct = len({(r.op, r.mode, r.steps, r.precision, r.taylorseer)
                    for r in results})
    # Diffusion one-shot: one trace per distinct config; streamed OR
    # offloaded (offload runs the windowed sampler with the refresh
    # interval as the window): a window plus possibly a remainder window
    # per config -> at most two traces per distinct config. Clean
    # references are keyed by step count (the scheduler may trim steps per
    # request), one one-shot trace each. Autoregressive configs compile
    # exactly two functions (prefill + decode step) -- both the served
    # config and its clean reference.
    ar = paradigm_for(args.arch) == "autoregressive"
    per_config = 2 if (ar or args.stream or args.offload) else 1
    per_clean = 2 if ar else 1
    clean_configs = len({r.steps for r in results})
    expected_traces = distinct * per_config + clean_configs * per_clean
    print(f"engine: {engine.stats.batches} batches, {engine.cache.traces} "
          f"traces for {distinct} drift configs (+{clean_configs} clean), "
          f"{engine.cache.hits} cache hits; clock {engine.clock_s:.3f}s, "
          f"{engine.stats.deadline_misses} deadline misses")
    if sched is not None:
        print(f"scheduler: {sched.stats}")
    # The whole point of the engine: after the first batch of a
    # configuration, every later batch must hit the compiled-sampler cache
    # instead of re-jitting. (Skip when admission rejected everything --
    # zero batches means nothing to assert about.)
    assert engine.cache.traces <= expected_traces, \
        (engine.cache.traces, expected_traces)
    if results and engine.stats.batches > engine.cache.compiles - 1:
        assert engine.cache.hits > 0, "expected sampler-cache hits"
    if args.stream and any(r.steps > args.stream for r in results):
        assert previews >= 1, "streaming produced no previews"
    print("sampler cache verified: no recompiles after first batch per config")
    if engine.telemetry.enabled and results:
        est = engine.telemetry.estimator
        print(f"telemetry: {est.total_observations} latency observations "
              f"over {len(est)} configs; guardband floor "
              f"{engine.telemetry.controller.guard_index}")
        ledger, slo = engine.telemetry.ledger, engine.telemetry.slo
        if ledger is not None and ledger.batches:
            top = sorted(ledger.shares().items(), key=lambda kv: -kv[1])[:3]
            burning = slo.breached_objectives()
            print(f"energy: {ledger.energy_per_request_j():.2f} J/request ("
                  + ", ".join(f"{c} {s:.0%}" for c, s in top)
                  + "); slo breached: "
                  + (", ".join(burning) if burning else "none"))
    if engine.offload_store is not None:
        ost = engine.offload_store.stats
        print(f"offload: {ost.commits} commits, "
              f"{ost.bytes_offloaded / 1e6:.2f} MB offloaded, "
              f"{ost.restores} restores")
    if args.trace_dir is not None:
        import os

        from repro.serving.trace import write_chrome_trace
        os.makedirs(args.trace_dir, exist_ok=True)
        path = os.path.join(args.trace_dir, "flight.json")
        write_chrome_trace(path, engine.tracer.spans())
        print(f"trace: {len(engine.tracer)} spans -> {path}")


if __name__ == "__main__":
    main()
