"""Serve a stream of diffusion requests with mixed DVFS operating points,
priorities, and deadlines through one DRIFT serving engine.

Each request picks its own operating point (``--op`` is a comma-separated
list cycled across requests; ``auto`` defers to the engine's BER-monitor
ladder, ``core.dvfs.OP_LADDER``) and scheduling class (``--priority`` is
cycled the same way). The engine buckets same-configuration requests into
fixed-size micro-batches, jits each configuration exactly once, reuses the
cached clean reference for quality metrics, and carries the BER monitor
across batches. Per-request energy/latency comes from
``perfmodel.energy.per_request_cost`` (the bucket's cost split across its
live requests).

    PYTHONPATH=src python examples/drift_serve.py --requests 6 --batch 2 \
        --op undervolt,overclock

``--deadline`` (a cycled list like ``--op``; ``none`` = no deadline, with
optional ``--step-budget``) routes submissions through the deadline-aware
scheduler: admission control projects each request's completion on the
engine's virtual (perfmodel) clock, escalates urgent work to overclock or
trims its denoising steps, and rejects hopeless requests -- see
docs/scheduler.md. ``--stream K`` yields latent previews every K
denoising steps ahead of the final results (final latents bit-identical
to the unstreamed path):

    PYTHONPATH=src python examples/drift_serve.py --requests 2 --batch 1 \
        --steps 6 --op undervolt --priority interactive,background \
        --deadline 0.055,none --stream 2

``--sharded`` runs the same stream through ``ShardedDriftServeEngine``,
spreading every micro-batch over the local (data, model) device mesh --
on one device it degrades to the plain engine, and on a data-parallel
mesh the latents are bit-identical either way:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/drift_serve.py --requests 8 \
        --batch 8 --sharded
"""
import argparse

from repro.core import dvfs as dvfs_lib
from repro.serving import (DeadlineScheduler, DriftServeEngine, PreviewEvent,
                           ShardedDriftServeEngine, make_engine)
from repro.serving.request import REQUEST_PRIORITIES

OP_LADDER_HELP = " -> ".join(p.name for p in dvfs_lib.OP_LADDER)


def build_parser():
    ap = argparse.ArgumentParser(
        description="Mixed-op / mixed-priority DRIFT serving demo.",
        epilog=f"The op 'auto' walks core.dvfs.OP_LADDER "
               f"({OP_LADDER_HELP}) via the engine's BER monitor.")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--op", default="undervolt,overclock",
                    help="comma-separated operating points, cycled per "
                         "request (nominal/undervolt/overclock/auto; "
                         f"'auto' walks the ladder {OP_LADDER_HELP})")
    ap.add_argument("--priority", default="standard",
                    help="comma-separated scheduling classes "
                         f"({'/'.join(REQUEST_PRIORITIES)}), cycled per "
                         "request; non-standard classes enable the "
                         "deadline-aware scheduler")
    ap.add_argument("--deadline", default=None, metavar="SEC[,SEC|none...]",
                    help="comma-separated relative deadlines (engine "
                         "virtual seconds; 'none' = no deadline), cycled "
                         "per request; enables admission control with "
                         "op-escalation / step-trimming")
    ap.add_argument("--step-budget", type=int, default=None, metavar="N",
                    help="per-request cap on denoising steps")
    ap.add_argument("--stream", type=int, default=0, metavar="K",
                    help="yield latent previews every K denoising steps "
                         "(0 = off)")
    ap.add_argument("--sharded", action="store_true",
                    help="spread micro-batches across the device mesh")
    ap.add_argument("--model-parallel", type=int, default=1)
    return ap


def main():
    args = build_parser().parse_args()

    ops = [o.strip() for o in args.op.split(",") if o.strip()]
    priorities = [p.strip() for p in args.priority.split(",") if p.strip()]
    deadlines = [None if d.strip().lower() == "none" else float(d)
                 for d in args.deadline.split(",") if d.strip()] \
        if args.deadline is not None else [None]
    if args.sharded:
        engine = make_engine(arch="dit-xl-512", smoke=True,
                             bucket=args.batch,
                             model_parallel=args.model_parallel)
    else:
        if args.model_parallel != 1:
            raise SystemExit("--model-parallel requires --sharded")
        engine = DriftServeEngine(arch="dit-xl-512", smoke=True,
                                  bucket=args.batch)

    use_scheduler = (args.deadline is not None
                     or args.step_budget is not None
                     or any(p != "standard" for p in priorities))
    sched = DeadlineScheduler(engine) if use_scheduler else None
    rejected = 0
    for i in range(args.requests):
        fields = dict(steps=args.steps, mode="drift", op=ops[i % len(ops)],
                      seed=i)
        if sched is not None:
            adm = sched.submit(priority=priorities[i % len(priorities)],
                               deadline_s=deadlines[i % len(deadlines)],
                               step_budget=args.step_budget, **fields)
            rejected += not adm.admitted
            print(f"[admission] {adm.action}: op={adm.op} steps={adm.steps}"
                  + (f" ({adm.reason})" if adm.reason else ""))
        else:
            engine.submit(**fields)

    mesh = (dict(engine.mesh.shape)
            if isinstance(engine, ShardedDriftServeEngine) else "1 device")
    print(f"[drift_serve] {args.requests} requests, bucket={args.batch}, "
          f"ops={ops}, mesh={mesh}")

    previews = 0
    if args.stream:
        results = []
        for ev in engine.run_stream(args.stream):
            if isinstance(ev, PreviewEvent):
                previews += 1
            else:
                results.append(ev)
        results.sort(key=lambda r: r.request_id)
        print(f"[drift_serve] {previews} preview events streamed")
    else:
        results = engine.run()

    for r in results:
        miss = " MISSED-DEADLINE" if r.deadline_missed else ""
        print(f"req {r.request_id}: op={r.op} steps={r.steps} "
              f"prio={r.priority} batch={r.batch_index} "
              f"lpips={r.lpips_vs_clean:.4f} psnr={r.psnr_vs_clean_db:.1f}dB "
              f"corrected(batch)={r.batch_corrected_elems} "
              f"energy={r.energy_j:.2f}J (baseline {r.baseline_energy_j:.2f}J) "
              f"monitor_ber={r.monitor_ber:.2e}{miss}")

    distinct = len({(r.op, r.mode, r.steps) for r in results})
    # one-shot: one trace per distinct config; streamed: a window plus
    # possibly a remainder window per config -> at most two traces per
    # distinct config. Clean references are keyed by step count (the
    # scheduler may trim steps per request), one one-shot trace each.
    per_config = 2 if args.stream else 1
    clean_configs = len({r.steps for r in results})
    expected_traces = distinct * per_config + clean_configs
    print(f"engine: {engine.stats.batches} batches, {engine.cache.traces} "
          f"traces for {distinct} drift configs (+{clean_configs} clean), "
          f"{engine.cache.hits} cache hits; clock {engine.clock_s:.3f}s, "
          f"{engine.stats.deadline_misses} deadline misses")
    if sched is not None:
        print(f"scheduler: {sched.stats}")
    # The whole point of the engine: after the first batch of a
    # configuration, every later batch must hit the compiled-sampler cache
    # instead of re-jitting. (Skip when admission rejected everything --
    # zero batches means nothing to assert about.)
    assert engine.cache.traces <= expected_traces, \
        (engine.cache.traces, expected_traces)
    if results and engine.stats.batches > engine.cache.compiles - 1:
        assert engine.cache.hits > 0, "expected sampler-cache hits"
    if args.stream and any(r.steps > args.stream for r in results):
        assert previews >= 1, "streaming produced no previews"
    print("sampler cache verified: no recompiles after first batch per config")


if __name__ == "__main__":
    main()
