"""Serve a stream of diffusion requests with mixed DVFS operating points
through one DRIFT serving engine.

Each request picks its own operating point (``--op`` is a comma-separated
list cycled across requests; ``auto`` defers to the engine's BER-monitor
ladder, ``core.dvfs.OP_LADDER``). The engine buckets same-configuration
requests into fixed-size micro-batches, jits each configuration exactly
once, reuses the cached clean reference for quality metrics, and carries
the BER monitor across batches. Per-request energy/latency comes from
``perfmodel.energy.per_request_cost`` (the bucket's cost split across its
live requests).

    PYTHONPATH=src python examples/drift_serve.py --requests 6 --batch 2 \
        --op undervolt,overclock

``--sharded`` runs the same stream through ``ShardedDriftServeEngine``,
spreading every micro-batch over the local (data, model) device mesh --
on one device it degrades to the plain engine, and on a data-parallel
mesh the latents are bit-identical either way:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/drift_serve.py --requests 8 \
        --batch 8 --sharded
"""
import argparse

from repro.serving import DriftServeEngine
from repro.serving.sharded import ShardedDriftServeEngine, make_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--op", default="undervolt,overclock",
                    help="comma-separated operating points, cycled per "
                         "request (nominal/undervolt/overclock/auto)")
    ap.add_argument("--sharded", action="store_true",
                    help="spread micro-batches across the device mesh")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    ops = [o.strip() for o in args.op.split(",") if o.strip()]
    if args.sharded:
        engine = make_engine(arch="dit-xl-512", smoke=True,
                             bucket=args.batch,
                             model_parallel=args.model_parallel)
    else:
        if args.model_parallel != 1:
            raise SystemExit("--model-parallel requires --sharded")
        engine = DriftServeEngine(arch="dit-xl-512", smoke=True,
                                  bucket=args.batch)
    for i in range(args.requests):
        engine.submit(steps=args.steps, mode="drift", op=ops[i % len(ops)],
                      seed=i)
    mesh = (dict(engine.mesh.shape)
            if isinstance(engine, ShardedDriftServeEngine) else "1 device")
    print(f"[drift_serve] {args.requests} requests, bucket={args.batch}, "
          f"ops={ops}, mesh={mesh}")
    results = engine.run()

    for r in results:
        print(f"req {r.request_id}: op={r.op} batch={r.batch_index} "
              f"lpips={r.lpips_vs_clean:.4f} psnr={r.psnr_vs_clean_db:.1f}dB "
              f"corrected(batch)={r.batch_corrected_elems} "
              f"energy={r.energy_j:.2f}J (baseline {r.baseline_energy_j:.2f}J) "
              f"monitor_ber={r.monitor_ber:.2e}")

    distinct = len({(r.op, r.mode, r.steps) for r in results})
    expected_traces = distinct + 1          # + the shared clean reference
    print(f"engine: {engine.stats.batches} batches, {engine.cache.traces} "
          f"traces for {distinct} drift configs (+1 clean), "
          f"{engine.cache.hits} cache hits")
    # The whole point of the engine: after the first batch of a
    # configuration, every later batch must hit the compiled-sampler cache
    # instead of re-jitting.
    assert engine.cache.traces <= expected_traces, \
        (engine.cache.traces, expected_traces)
    if engine.stats.batches > engine.cache.compiles - 1:
        assert engine.cache.hits > 0, "expected sampler-cache hits"
    print("sampler cache verified: no recompiles after first batch per config")


if __name__ == "__main__":
    main()
