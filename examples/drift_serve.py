"""Serve a small diffusion model with batched requests under DRIFT.

Thin driver over repro.launch.serve: processes a queue of generation
requests, batching them per sampler invocation, with the undervolt
operating point + rollback-ABFT, and reports per-batch quality/energy.

    PYTHONPATH=src python examples/drift_serve.py --requests 6 --batch 2
"""
import argparse
import sys

from repro.launch import serve as serve_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--op", default="undervolt")
    args = ap.parse_args()
    n_batches = -(-args.requests // args.batch)
    print(f"[drift_serve] {args.requests} requests -> {n_batches} batches "
          f"of {args.batch}")
    for i in range(n_batches):
        print(f"--- batch {i} ---")
        sys.argv = ["serve", "--arch", "dit-xl-512", "--smoke",
                    "--batch", str(args.batch), "--steps", "10",
                    "--mode", "drift", "--op", args.op, "--seed", str(i)]
        serve_lib.main()


if __name__ == "__main__":
    main()
