#!/usr/bin/env python
"""Assert docs/telemetry.md's metric catalog covers every registered metric.

Binds a real ``EngineTelemetry`` (plus the serving-layer hooks that
register lazily through ``registry.counter(...)`` idempotence: a bound
engine registers everything in one place, ``EngineTelemetry.bind``),
walks the registry, and fails if any metric family name is missing a
``| `name` |`` row in the catalog table — the docs drift this script
exists to catch. The same assertion runs as a tier-1 test
(tests/test_trace.py::test_metrics_catalog_covers_registry), so a PR
cannot pass tests locally and still break the docs job.

Run from the repo root (CI does: the docs job in
.github/workflows/ci.yml):

    PYTHONPATH=src python tools/check_metrics_catalog.py
"""
import sys

sys.path.insert(0, "src")
from repro.serving.telemetry import EngineTelemetry  # noqa: E402

DOC = "docs/telemetry.md"


def registered_metric_names():
    """Every metric family a bound engine telemetry registers."""
    tele = EngineTelemetry().bind(target_ber=3e-3)
    return sorted(tele.registry._metrics)


def missing_from_catalog(doc_text, names):
    return [n for n in names if f"`{n}`" not in doc_text]


def main() -> int:
    with open(DOC, encoding="utf-8") as fh:
        doc = fh.read()
    names = registered_metric_names()
    missing = missing_from_catalog(doc, names)
    if missing:
        print(f"FAIL: {DOC} catalog is missing {len(missing)} registered "
              f"metric(s): {missing}", file=sys.stderr)
        return 1
    print(f"ok: all {len(names)} registered metric families have a "
          f"catalog row in {DOC}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
