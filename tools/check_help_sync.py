#!/usr/bin/env python
"""Assert the serving CLIs' --help stays in sync with the code.

Checks, for both ``python -m repro.launch.serve`` and
``examples/drift_serve.py``:

* every operating point in ``core.dvfs.OP_LADDER`` is named in the help
  text (the CLIs derive it from the ladder programmatically -- this guard
  catches someone replacing that with a stale literal);
* every scheduling/streaming flag the docs advertise is present.

Run from the repo root (CI does: the docs job in
.github/workflows/ci.yml):

    PYTHONPATH=src python tools/check_help_sync.py
"""
import subprocess
import sys

sys.path.insert(0, "src")
from repro.core.dvfs import OP_LADDER  # noqa: E402

CLIS = (
    [sys.executable, "-m", "repro.launch.serve", "--help"],
    [sys.executable, "examples/drift_serve.py", "--help"],
)
REQUIRED_FLAGS = ("--op", "--priority", "--deadline", "--step-budget",
                  "--stream", "--batch", "--steps",
                  "--metrics-port", "--no-telemetry")


def main() -> int:
    failures = []
    for cmd in CLIS:
        out = subprocess.run(cmd, capture_output=True, text=True,
                             check=True).stdout
        missing = [p.name for p in OP_LADDER if p.name not in out]
        missing += [f for f in REQUIRED_FLAGS if f not in out]
        if missing:
            failures.append((cmd, missing))
        else:
            print(f"ok: {' '.join(cmd[-2:])} help names the full ladder "
                  f"and all scheduler flags")
    for cmd, missing in failures:
        print(f"FAIL {' '.join(cmd)}: --help missing {missing}",
              file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
