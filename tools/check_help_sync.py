#!/usr/bin/env python
"""Assert the serving CLIs' --help stays in sync with the code.

Checks, for both ``python -m repro.launch.serve`` and
``examples/drift_serve.py``:

* every operating point in ``core.dvfs.OP_LADDER`` is named in the help
  text (the CLIs derive it from the ladder programmatically -- this guard
  catches someone replacing that with a stale literal);
* every scheduling/streaming/offload flag the docs advertise is present;
* the ``--rollback-interval`` help renders its default from
  ``core.rollback.DEFAULT_INTERVAL`` (the single source of truth -- the
  old CLIs duplicated the literal 10 in help strings, which is exactly
  the drift this script exists to catch);
* the ``--arch`` help names every registered config grouped by serving
  paradigm (derived from the ServableModel registry via
  ``launch.serve.arch_family_help`` -- adding a config without wiring its
  family into the registry, or hard-coding a stale arch list, fails here).

Run from the repo root (CI does: the docs job in
.github/workflows/ci.yml):

    PYTHONPATH=src python tools/check_help_sync.py
"""
import subprocess
import sys

sys.path.insert(0, "src")
from repro import configs  # noqa: E402
from repro.core.dvfs import OP_LADDER  # noqa: E402
from repro.core.rollback import DEFAULT_INTERVAL  # noqa: E402

CLIS = (
    [sys.executable, "-m", "repro.launch.serve", "--help"],
    [sys.executable, "examples/drift_serve.py", "--help"],
)
REQUIRED_FLAGS = ("--op", "--priority", "--deadline", "--step-budget",
                  "--stream", "--batch", "--steps", "--arch",
                  "--metrics-port", "--no-telemetry",
                  "--rollback-interval", "--offload",
                  "--energy-budget", "--quality-floor", "--trace-dir")
# --arch help must be registry-derived: every registered config by name,
# plus the paradigm labels the registry groups them under.
PARADIGM_WORDS = ("diffusion", "autoregressive", "unsupported")
# The rendered interval default must come from the one constant (a CLI
# hard-coding the number would go stale the day the constant moves).
INTERVAL_DEFAULT_TEXT = f"default: {DEFAULT_INTERVAL},"


def main() -> int:
    failures = []
    for cmd in CLIS:
        out = subprocess.run(cmd, capture_output=True, text=True,
                             check=True).stdout
        missing = [p.name for p in OP_LADDER if p.name not in out]
        missing += [f for f in REQUIRED_FLAGS if f not in out]
        missing += [a for a in configs.list_archs() if a not in out]
        missing += [w for w in PARADIGM_WORDS if w not in out]
        if INTERVAL_DEFAULT_TEXT not in out:
            missing.append(f"'{INTERVAL_DEFAULT_TEXT}' (rollback-interval "
                           "default derived from rollback.DEFAULT_INTERVAL)")
        if missing:
            failures.append((cmd, missing))
        else:
            print(f"ok: {' '.join(cmd[-2:])} help names the full ladder, "
                  f"all scheduler/offload flags, every registered arch "
                  f"by paradigm, and the DEFAULT_INTERVAL-derived default")
    for cmd, missing in failures:
        print(f"FAIL {' '.join(cmd)}: --help missing {missing}",
              file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
