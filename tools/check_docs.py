#!/usr/bin/env python
"""Execute the ``bash`` code blocks in markdown docs so they can't rot.

Usage (what the CI docs job runs, from the repo root):

    python tools/check_docs.py README.md docs/*.md

Rules:

* only fenced blocks whose info string starts with ``bash`` run; plain
  fences (ASCII diagrams) and other languages (illustrative ``python``)
  are skipped;
* a fence marked ``bash no-run`` is skipped (for genuinely
  environment-specific snippets);
* lines starting with ``pip install`` are stripped before running -- CI
  installs dependencies in its own cached step, and doc checks must not
  hit the network;
* each block runs as one ``bash -euo pipefail`` script with
  ``PYTHONPATH=src`` pre-seeded (blocks usually set it themselves too),
  so multi-line commands with ``\\`` continuations and inline env vars
  (``XLA_FLAGS=... python ...``) work as written.

Exit code is non-zero on the first failing block, with the block and its
output echoed for debugging.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

FENCE = re.compile(r"^```(.*)$")


def extract_blocks(path: str):
    """Yield (info_string, body, start_line) for each fenced block."""
    info, body, start = None, [], 0
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            m = FENCE.match(line.rstrip("\n"))
            if m is None:
                if info is not None:
                    body.append(line)
                continue
            if info is None:
                info, body, start = m.group(1).strip(), [], lineno
            else:
                yield info, "".join(body), start
                info = None
    if info is not None:
        raise SystemExit(f"{path}: unterminated code fence at line {start}")


def runnable(info: str) -> bool:
    parts = info.split()
    return bool(parts) and parts[0] == "bash" and "no-run" not in parts[1:]


def run_block(path: str, body: str, start: int) -> bool:
    script = "\n".join(ln for ln in body.splitlines()
                       if not ln.lstrip().startswith("pip install"))
    if not script.strip():
        return True
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    print(f"--- {path}:{start} ---")
    print(script)
    proc = subprocess.run(["bash", "-euo", "pipefail", "-c", script],
                          env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        print(proc.stdout)
        print(proc.stderr, file=sys.stderr)
        print(f"FAIL {path}:{start} (exit {proc.returncode})",
              file=sys.stderr)
        return False
    tail = proc.stdout.strip().splitlines()[-3:]
    for ln in tail:
        print(f"    {ln}")
    print(f"ok ({path}:{start})")
    return True


def main(argv) -> int:
    if not argv:
        print("usage: check_docs.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    n_run = 0
    for path in argv:
        for info, body, start in extract_blocks(path):
            if not runnable(info):
                continue
            n_run += 1
            if not run_block(path, body, start):
                return 1
    print(f"all {n_run} bash doc blocks ran clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
