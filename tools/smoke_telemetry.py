#!/usr/bin/env python
"""Smoke the telemetry HTTP front-end end to end (the CI telemetry job).

Boots a real (smoke-model) serving engine with the HTTP front-end,
then -- as an external client would --

* curls ``/healthz`` and asserts the JSON liveness payload,
* curls ``/metrics`` and asserts the Prometheus exposition carries the
  core serving series,
* opens the SSE ``/events`` stream and consumes at least one ``preview``
  frame and the terminating ``result``/``end`` frames,

and shuts the server down. Uses the ``curl`` binary when present (the
point of the job is the wire, not the Python client); falls back to
urllib where curl is missing so the script also runs in bare containers.

Run from the repo root (CI: .github/workflows/ci.yml, telemetry job):

    PYTHONPATH=src python tools/smoke_telemetry.py
"""
import json
import shutil
import subprocess
import sys
import urllib.request

sys.path.insert(0, "src")

from repro.serving import DriftServeEngine, serve_telemetry  # noqa: E402

STEPS, PREVIEW_EVERY = 4, 2


def fetch(url: str) -> str:
    # no client timeout shorter than a loaded CI box needs: the SSE drain
    # jits the streaming sampler inside the handler
    if shutil.which("curl"):
        return subprocess.run(["curl", "-sS", "--fail", "--max-time", "600",
                               url],
                              capture_output=True, text=True,
                              check=True).stdout
    with urllib.request.urlopen(url, timeout=600) as resp:
        return resp.read().decode("utf-8")


def parse_sse(payload: str):
    """[(event, data-dict)] from a complete SSE stream body."""
    events = []
    kind = None
    for line in payload.splitlines():
        if line.startswith("event: "):
            kind = line[len("event: "):]
        elif line.startswith("data: "):
            events.append((kind, json.loads(line[len("data: "):])))
    return events


def main() -> int:
    print("[smoke] building engine + serving one warm-up batch")
    engine = DriftServeEngine(arch="dit-xl-512", smoke=True, bucket=1)
    engine.submit(steps=STEPS, mode="drift", op="undervolt", seed=0)
    engine.run()                       # telemetry has real series to expose

    server = serve_telemetry(engine, port=0)
    base = server.url
    print(f"[smoke] telemetry at {base} "
          f"(client: {'curl' if shutil.which('curl') else 'urllib'})")
    try:
        health = json.loads(fetch(f"{base}/healthz"))
        assert health["status"] == "ok", health
        assert health["batches"] >= 1, health
        print(f"[smoke] /healthz ok: clock={health['clock_s']:.4f}s "
              f"batches={health['batches']}")

        metrics = fetch(f"{base}/metrics")
        for series in ("drift_batches_total", "drift_batch_latency_seconds",
                       "drift_monitor_ema_ber", "drift_clock_seconds"):
            assert series in metrics, f"/metrics missing {series}"
        print(f"[smoke] /metrics ok: {len(metrics.splitlines())} lines")

        # a fresh request for the SSE drain to stream
        engine.submit(steps=STEPS, mode="drift", op="undervolt", seed=1)
        events = parse_sse(fetch(f"{base}/events?interval={PREVIEW_EVERY}"))
        kinds = [k for k, _ in events]
        assert kinds.count("preview") >= 1, kinds
        assert kinds.count("result") == 1, kinds
        assert kinds[-1] == "end", kinds
        preview = next(d for k, d in events if k == "preview")
        result = next(d for k, d in events if k == "result")
        assert preview["step"] < preview["total_steps"] == STEPS
        assert len(result["latents_sha256"]) == 64
        print(f"[smoke] /events ok: {kinds.count('preview')} previews, "
              f"1 result, digest {result['latents_sha256'][:12]}…")
    finally:
        server.close()
    print("[smoke] telemetry front-end smoke PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
