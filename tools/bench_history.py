#!/usr/bin/env python
"""Benchmark trajectory gate: BENCH_*.json -> rolling history -> regression
check.

The telemetry-smoke CI job has been emitting per-SHA ``BENCH_*.json``
artifacts since PR 7, but nothing ever *compared* them -- a change could
halve serving throughput or double billed energy and CI would stay green.
This tool closes the loop:

``ingest``
    Flatten every ``BENCH_*.json`` in a directory into dotted scalar
    metrics (``serving.throughput_req_per_virtual_s``, ...) and append
    one ``{sha, metrics}`` entry to a rolling ``BENCH_history.json``
    (bounded to ``--keep`` entries, oldest dropped).

``check``
    Compare the newest entry against the mean of the previous
    ``--baseline-window`` entries, metric by metric, using the
    direction-aware tolerances declared in ``TOLERANCES`` below. A
    tracked metric moving beyond its tolerance in the *bad* direction is
    a regression: the tool prints a delta table and exits 1. Fewer than
    ``--min-baseline`` prior entries (e.g. a fresh history) is a pass --
    a gate with no baseline has nothing to gate. ``--inject
    metric=factor`` multiplies the candidate's metric before comparing:
    the CI job uses it to prove the gate actually fails (acceptance:
    "demonstrably fails on an injected regression").

``self-test``
    Synthesizes a history, verifies the gate passes on a flat trajectory
    and fails on an injected regression, exits accordingly. Cheap enough
    to run on every CI invocation as the gate's own canary.

Untracked numeric metrics ride along in the history (future PRs can
promote them to tracked) but never gate. Only scalars are kept --
nested benchmark detail like per-config estimator tables stays in the
per-SHA artifacts.

Usage (what .github/workflows/ci.yml runs)::

    python tools/bench_history.py ingest --sha $GITHUB_SHA
    python tools/bench_history.py check
    python tools/bench_history.py check --inject \
        serving.throughput_req_per_virtual_s=0.5   # expected to exit 1
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

HISTORY_DEFAULT = "BENCH_history.json"
KEEP_DEFAULT = 50

# Tracked metrics: dotted path -> (good direction, relative tolerance).
# "higher" = bigger is better (regression when the candidate falls more
# than tol below the baseline mean); "lower" = smaller is better
# (regression when it rises more than tol above). Tolerances are loose on
# purpose: CI runners are shared machines, so only virtual-clock and
# modeled-energy numbers get tight gates; wall-clock metrics are recorded
# but untracked.
TOLERANCES: Dict[str, Tuple[str, float]] = {
    # serving trajectory (benchmarks/serving_telemetry.py)
    "serving.throughput_req_per_virtual_s": ("higher", 0.10),
    "serving.queue_wait_p99_s": ("lower", 0.25),
    "serving.estimator.mean_rel_error_vs_perfmodel": ("lower", 0.50),
    "serving.deadline_misses": ("lower", 0.0),
    # energy ledger + SLO trajectory (benchmarks/energy_slo.py)
    "energy.energy_per_request_j": ("lower", 0.10),
    "energy.ledger_residual_j": ("lower", 0.0),   # must stay exactly 0
    # offload overlap (benchmarks/offload_overlap.py)
    "offload.stall_fraction_async": ("lower", 0.25),
    # AR serving (benchmarks/ar_serving.py)
    "ar.throughput_tok_per_virtual_s": ("higher", 0.10),
}


# ------------------------------------------------------------------ flatten
def _flatten(prefix: str, node, out: Dict[str, float]) -> None:
    if isinstance(node, bool):        # bool is an int subclass; skip flags
        return
    if isinstance(node, (int, float)):
        out[prefix] = float(node)
        return
    if isinstance(node, dict):
        for k, v in node.items():
            _flatten(f"{prefix}.{k}" if prefix else str(k), v, out)
    # strings / lists: benchmark detail, not trajectory scalars


def _tag(path: str) -> str:
    """BENCH_serving.json -> 'serving'."""
    name = os.path.basename(path)
    tag = name[len("BENCH_"):] if name.startswith("BENCH_") else name
    return tag[:-len(".json")] if tag.endswith(".json") else tag


def collect_metrics(bench_dir: str) -> Dict[str, float]:
    """Flattened scalar metrics from every BENCH_*.json in ``bench_dir``
    (the history file itself excluded), keys prefixed by file tag."""
    out: Dict[str, float] = {}
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        if os.path.basename(path) == HISTORY_DEFAULT:
            continue
        with open(path, encoding="utf-8") as fh:
            _flatten(_tag(path), json.load(fh), out)
    return out


# ------------------------------------------------------------------ history
def load_history(path: str) -> List[dict]:
    if not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    entries = data.get("entries", [])
    assert isinstance(entries, list), f"malformed history {path}"
    return entries


def save_history(path: str, entries: List[dict]) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"schema": 1, "entries": entries}, fh, indent=2,
                  sort_keys=True)
        fh.write("\n")


def ingest(bench_dir: str, history_path: str, sha: str,
           keep: int = KEEP_DEFAULT) -> dict:
    metrics = collect_metrics(bench_dir)
    if not metrics:
        raise SystemExit(f"no BENCH_*.json found in {bench_dir!r}; "
                         "run the benchmarks first")
    entries = load_history(history_path)
    entry = {"sha": sha, "metrics": metrics}
    entries.append(entry)
    save_history(history_path, entries[-keep:])
    return entry


# -------------------------------------------------------------------- check
def regressions(baseline: List[dict], candidate: dict,
                tolerances: Dict[str, Tuple[str, float]] = None
                ) -> List[dict]:
    """Tracked metrics where the candidate moved beyond tolerance in the
    bad direction vs the baseline-window mean. Metrics absent from either
    side are skipped (a new benchmark has no baseline; a removed one has
    no candidate -- neither is a perf regression)."""
    tolerances = TOLERANCES if tolerances is None else tolerances
    cand = candidate["metrics"]
    out = []
    for metric, (direction, tol) in sorted(tolerances.items()):
        base_vals = [e["metrics"][metric] for e in baseline
                     if metric in e["metrics"]]
        if not base_vals or metric not in cand:
            continue
        base = sum(base_vals) / len(base_vals)
        val = cand[metric]
        if direction == "higher":
            bound = base * (1.0 - tol)
            bad = val < bound - 1e-12
        else:
            bound = base * (1.0 + tol) if base != 0 else tol
            bad = val > bound + 1e-12
        if bad:
            out.append({"metric": metric, "direction": direction,
                        "tolerance": tol, "baseline": base,
                        "candidate": val, "bound": bound})
    return out


def check(history_path: str, baseline_window: int, min_baseline: int,
          inject: Dict[str, float]) -> int:
    entries = load_history(history_path)
    if not entries:
        print(f"bench-history: {history_path} is empty -- nothing to gate")
        return 0
    candidate = dict(entries[-1])
    candidate["metrics"] = dict(candidate["metrics"])
    for metric, factor in inject.items():
        if metric not in candidate["metrics"]:
            raise SystemExit(f"--inject: metric {metric!r} not in the "
                             "candidate entry")
        candidate["metrics"][metric] *= factor
        print(f"bench-history: injected {metric} x{factor:g} "
              f"-> {candidate['metrics'][metric]:.6g}")
    baseline = entries[:-1][-baseline_window:]
    if len(baseline) < min_baseline:
        print(f"bench-history: {len(baseline)} baseline entries "
              f"(< {min_baseline}) -- pass (no baseline to gate against)")
        return 0
    bad = regressions(baseline, candidate)
    n_tracked = sum(1 for m in TOLERANCES
                    if m in candidate["metrics"]
                    and any(m in e["metrics"] for e in baseline))
    print(f"bench-history: candidate {candidate.get('sha', '?')[:12]} vs "
          f"mean of {len(baseline)} entries, {n_tracked} tracked metrics")
    for r in bad:
        arrow = "fell below" if r["direction"] == "higher" else "rose above"
        print(f"  REGRESSION {r['metric']}: {r['candidate']:.6g} {arrow} "
              f"{r['bound']:.6g} (baseline {r['baseline']:.6g}, "
              f"tol {r['tolerance']:.0%})")
    if bad:
        return 1
    print("bench-history: no tolerance-exceeding regressions")
    return 0


# ---------------------------------------------------------------- self-test
def self_test() -> int:
    """The gate's canary: a flat synthetic trajectory must pass, an
    injected 2x-worse candidate must fail. Exercises the same
    ``regressions`` core the CI check runs."""
    flat = {"serving.throughput_req_per_virtual_s": 20.0,
            "energy.energy_per_request_j": 0.2,
            "energy.ledger_residual_j": 0.0}
    baseline = [{"sha": f"base{i}", "metrics": dict(flat)} for i in range(5)]
    ok = regressions(baseline, {"sha": "cand", "metrics": dict(flat)})
    assert ok == [], f"flat trajectory flagged: {ok}"
    worse = dict(flat)
    worse["serving.throughput_req_per_virtual_s"] *= 0.5     # -50% >> 10%
    worse["energy.energy_per_request_j"] *= 2.0              # +100% >> 10%
    bad = regressions(baseline, {"sha": "cand", "metrics": worse})
    got = {r["metric"] for r in bad}
    assert got == {"serving.throughput_req_per_virtual_s",
                   "energy.energy_per_request_j"}, got
    # zero-tolerance metric: ANY nonzero residual is a regression
    leak = dict(flat)
    leak["energy.ledger_residual_j"] = 1e-9
    bad = regressions(baseline, {"sha": "cand", "metrics": leak})
    assert any(r["metric"] == "energy.ledger_residual_j" for r in bad), bad
    print("bench-history self-test: pass on flat, fail on injected -- ok")
    return 0


# ---------------------------------------------------------------------- cli
def _parse_inject(specs: List[str]) -> Dict[str, float]:
    out = {}
    for spec in specs:
        metric, _, factor = spec.partition("=")
        if not factor:
            raise SystemExit(f"--inject wants metric=factor, got {spec!r}")
        out[metric] = float(factor)
    return out


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Rolling BENCH_*.json trajectory + regression gate")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_in = sub.add_parser("ingest", help="fold BENCH_*.json into history")
    p_in.add_argument("--sha", required=True,
                      help="commit SHA to stamp the entry with")
    p_in.add_argument("--dir", default=".",
                      help="directory holding BENCH_*.json (default: .)")
    p_in.add_argument("--history", default=HISTORY_DEFAULT)
    p_in.add_argument("--keep", type=int, default=KEEP_DEFAULT,
                      help=f"rolling entry cap (default {KEEP_DEFAULT})")

    p_ck = sub.add_parser("check", help="gate the newest entry")
    p_ck.add_argument("--history", default=HISTORY_DEFAULT)
    p_ck.add_argument("--baseline-window", type=int, default=5,
                      help="prior entries averaged as baseline (default 5)")
    p_ck.add_argument("--min-baseline", type=int, default=1,
                      help="prior entries required to gate at all "
                           "(default 1; fewer = automatic pass)")
    p_ck.add_argument("--inject", action="append", default=[],
                      metavar="METRIC=FACTOR",
                      help="multiply a candidate metric before comparing "
                           "(CI uses it to prove the gate fires)")

    sub.add_parser("self-test", help="verify the gate logic itself")

    args = ap.parse_args(argv)
    if args.cmd == "ingest":
        entry = ingest(args.dir, args.history, args.sha, args.keep)
        print(f"bench-history: ingested {len(entry['metrics'])} metrics "
              f"for {args.sha[:12]} into {args.history}")
        return 0
    if args.cmd == "check":
        return check(args.history, args.baseline_window, args.min_baseline,
                     _parse_inject(args.inject))
    return self_test()


if __name__ == "__main__":
    sys.exit(main())
