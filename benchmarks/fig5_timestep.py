"""Fig 5: timestep-level resilience -- inject at one denoising step.

Expected reproduction: EARLY steps are substantially more sensitive (they
build global structure); late-step faults wash out as texture noise.
"""
from benchmarks.common import N_STEPS, csv, quality_vs_clean, run_sampler, \
    schedule_single_step, timer

BER = 1e-3


def main():
    print("# fig5: inject_step,lpips,psnr")
    for step in range(0, N_STEPS, 2):
        out, dt = timer(run_sampler, "dit-xl-512", "faulty",
                        schedule_single_step(BER, step))
        q = quality_vs_clean(out)
        csv(f"fig5_step{step}", dt * 1e6,
            f"lpips={q['lpips']:.4f} psnr={q['psnr']:.2f}")


if __name__ == "__main__":
    main()
