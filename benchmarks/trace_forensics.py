"""Flight-recorder forensics benchmark: span coverage, heatmap mass,
recorder overhead.

Drives one streamed + offloaded diffusion stream and one statistical-ABFT
autoregressive stream through the serving engine with the flight recorder
on, and emits ``BENCH_trace.json``:

* **span coverage** -- every jitted streaming window, every offload
  commit, and (AR) every rollback replay must appear as a span in the
  recorder, counted against the expected numbers derived from the run
  shape (windows = ceil(steps / stream) per batch, commits from the
  offload store's own counters, replays from the served results). A
  forensics trace with holes is worse than none;
* **heatmap mass** -- the per-request resilience heatmap
  (``RequestResult.detect_heatmap``, the live analogue of DRIFT
  Figs 5-6) split into protected vs unprotected timestep mass: the
  engine protects the first ``nominal_steps`` denoising steps at
  nominal voltage, so detection mass should concentrate in the
  *unprotected* tail -- the paper's Fig 5 structure, checked live;
* **recorder overhead** -- host microseconds per ``record()`` call on a
  full ring buffer, measured over ``OVERHEAD_RECORDS`` timed records and
  asserted under ``OVERHEAD_BOUND_US``. The recorder sits on the batch
  boundary of every serve, so its cost budget is part of the contract
  (zero-perturbation covers *what* is computed; this bounds *how long*
  the bookkeeping takes).

Run from the repo root:

    PYTHONPATH=src python -m benchmarks.trace_forensics

Also registered in ``benchmarks.run``. Output lands in ./BENCH_trace.json.
"""
from __future__ import annotations

import json
import time

from repro.serving import DriftServeEngine, OffloadConfig
from repro.serving.scheduler import DeadlineScheduler
from repro.serving.trace import FlightRecorder, N_STEP_BINS

DIFF_ARCH, DIFF_STEPS, STREAM, BUCKET, N_REQ = "dit-xl-512", 8, 2, 2, 4
AR_ARCH, AR_STEPS = "olmo-1b", 8
OVERHEAD_RECORDS = 10_000
OVERHEAD_BOUND_US = 200.0        # per record(), lock + deque append


def _span_counts(tracer):
    counts = {}
    for s in tracer.spans():
        counts[s.kind] = counts.get(s.kind, 0) + 1
    return counts


def _diffusion_leg():
    engine = DriftServeEngine(arch=DIFF_ARCH, smoke=True, bucket=BUCKET,
                              offload=OffloadConfig())
    sched = DeadlineScheduler(engine)
    for i in range(N_REQ):
        sched.submit(steps=DIFF_STEPS, mode="drift", op="undervolt",
                     seed=i)
    from repro.serving import PreviewEvent
    results = [r for r in engine.run_stream(preview_interval=STREAM)
               if not isinstance(r, PreviewEvent)]
    counts = _span_counts(engine.tracer)
    batches = engine.stats.batches
    # offload windows the refresh interval; the engine streams with
    # window = stream, so each batch runs ceil(steps / stream) windows
    windows_expected = batches * -(-DIFF_STEPS // STREAM)
    commits_expected = engine.offload_store.stats.commits
    heat = next((r.detect_heatmap for r in results
                 if r.detect_heatmap is not None), None)
    blocks = next((r.detect_heatmap_blocks for r in results
                   if r.detect_heatmap_blocks is not None), None)
    leg = {
        "requests": len(results),
        "batches": batches,
        "spans": counts,
        "windows_expected": windows_expected,
        "windows_recorded": counts.get("window", 0),
        "commits_expected": commits_expected,
        "commits_recorded": counts.get("offload_commit", 0),
        "admissions_recorded": counts.get("admission", 0),
        "detects_recorded": counts.get("detect", 0),
        "coverage_ok": (counts.get("window", 0) == windows_expected
                        and counts.get("offload_commit", 0)
                        == commits_expected
                        and counts.get("admission", 0) == N_REQ
                        and counts.get("detect", 0) == batches),
        "spans_dropped": engine.tracer.dropped,
    }
    return leg, heat, blocks, engine.nominal_steps


def _ar_leg():
    engine = DriftServeEngine(arch=AR_ARCH, smoke=True, bucket=BUCKET)
    for i in range(N_REQ):
        engine.submit(steps=AR_STEPS, mode="stat_abft", op="undervolt",
                      seed=i)
    results = engine.run()
    counts = _span_counts(engine.tracer)
    rollbacks = sum(r.ar_rollbacks for r in results) // BUCKET
    return {
        "requests": len(results),
        "batches": engine.stats.batches,
        "spans": counts,
        "replays_expected": rollbacks,   # per batch: rollbacks are
        "replays_recorded": counts.get("replay", 0),   # batch-level
        "detections": sum(r.ar_detections for r in results),
        "coverage_ok": counts.get("replay", 0) == rollbacks,
        "spans_dropped": engine.tracer.dropped,
    }


def _heatmap_mass(heat, blocks, nominal_steps):
    if heat is None:
        return {"available": False}
    # bin b of N covers steps [b*steps/N, (b+1)*steps/N); the engine
    # pins the first nominal_steps to nominal voltage, so bins fully
    # inside that prefix are the "protected" mass
    per_bin = [sum(row[b] for row in heat) for b in range(len(heat[0]))]
    steps_per_bin = DIFF_STEPS / len(per_bin)
    protected = sum(m for b, m in enumerate(per_bin)
                    if (b + 1) * steps_per_bin <= nominal_steps + 1e-9)
    total = sum(per_bin)
    return {
        "available": True,
        "site_labels": list(blocks),
        "binned": [list(row) for row in heat],
        "step_bins": len(per_bin),
        "nominal_steps_protected": nominal_steps,
        "protected_mass": protected,
        "unprotected_mass": total - protected,
        "total_mass": total,
        "protected_fraction": protected / total if total else 0.0,
    }


def _recorder_overhead():
    rec = FlightRecorder(capacity=4096)
    # pre-fill so every timed record also pays the ring-buffer eviction
    for i in range(4096):
        rec.record("warm", "window", request_ids=(i,), batch_index=0)
    t0 = time.perf_counter()
    for i in range(OVERHEAD_RECORDS):
        rec.record("bench", "window", request_ids=(i,), batch_index=1,
                   from_step=i, done_steps=i + 1)
    us = (time.perf_counter() - t0) * 1e6 / OVERHEAD_RECORDS
    return {
        "records_timed": OVERHEAD_RECORDS,
        "us_per_record": us,
        "bound_us": OVERHEAD_BOUND_US,
        "under_bound": us < OVERHEAD_BOUND_US,
    }


def main() -> None:
    diffusion, heat, blocks, nominal_steps = _diffusion_leg()
    ar = _ar_leg()
    heatmap = _heatmap_mass(heat, blocks, nominal_steps)
    overhead = _recorder_overhead()

    bench = {
        "diffusion": diffusion,
        "autoregressive": ar,
        "heatmap": heatmap,
        "recorder_overhead": overhead,
        "step_bins_default": N_STEP_BINS,
    }
    with open("BENCH_trace.json", "w", encoding="utf-8") as fh:
        json.dump(bench, fh, indent=2, sort_keys=True)
    print(json.dumps(bench, indent=2, sort_keys=True))

    assert diffusion["coverage_ok"], \
        f"diffusion span coverage has holes: {diffusion}"
    assert ar["coverage_ok"], f"AR span coverage has holes: {ar}"
    assert overhead["under_bound"], \
        (f"recorder overhead {overhead['us_per_record']:.1f}us/record "
         f"over the {OVERHEAD_BOUND_US}us bound")
    print("wrote BENCH_trace.json")


if __name__ == "__main__":
    main()
