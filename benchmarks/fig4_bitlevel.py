"""Fig 4: bit-level resilience -- fixed-position flips across bit 0..31.

Expected reproduction: negligible quality loss for low bits, sharp
degradation once flips reach the high-magnitude bits (paper: ~10th bit of
the INT32 accumulator is the damage threshold used for ABFT).
"""
from benchmarks.common import csv, quality_vs_clean, run_sampler, \
    schedule_uniform, timer

BITS = [0, 4, 8, 10, 12, 14, 18, 22, 26, 30]
RATE = 3e-4       # per-word flip rate at the pinned bit


def main():
    print("# fig4: bit,lpips,psnr")
    for bit in BITS:
        out, dt = timer(run_sampler, "dit-xl-512", "faulty",
                        schedule_uniform(RATE), 10, 5, 10, bit)
        q = quality_vs_clean(out)
        csv(f"fig4_bit{bit:02d}", dt * 1e6,
            f"lpips={q['lpips']:.4f} psnr={q['psnr']:.2f}")


if __name__ == "__main__":
    main()
