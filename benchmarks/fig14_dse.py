"""Fig 14: design-space exploration -- ABFT threshold, offload interval,
systolic-array size, and the compute-optimal serving frontier vs the
fixed escalation policy.

fig14d sweeps iso-deadline admission over the joint (steps x precision x
TaylorSeer x DVFS) knob space (``serving.frontier``) against the PR 3
fixed ladder (as-requested -> overclock -> trimmed steps, baseline
precision, TaylorSeer off) and emits ``BENCH_dse.json``: per diffusion
arch, the frontier size, the mean/min energy saved at iso-deadline, and
both policies' deadline-miss rates over the same deadline grid. Pure
perfmodel arithmetic -- no traces, CI-fast.
"""
import json

import jax.numpy as jnp

from benchmarks.common import csv, quality_vs_clean, run_sampler, \
    schedule_uniform, timer
from repro import configs
from repro.core import dvfs
from repro.core.quant import DEFAULT_PLAN
from repro.perfmodel import scalesim
from repro.perfmodel.hw import PaperAccel
from repro.serving.frontier import FrontierBuilder

BER = 3e-3

# fig14d sweep shape: the serving defaults (launch.serve / scheduler).
DSE_ARCHS = ("dit-xl-512", "sd15-unet")
DSE_STEPS, DSE_BUCKET, DSE_MIN_STEPS = 10, 2, 4
N_DEADLINES = 24


def _fixed_policy_pick(builder, cfg, deadline_s):
    """The PR 3 ladder, priced with the same perfmodel: as-requested
    (undervolt, full steps) -> overclock full -> overclock trimmed to
    min_steps; None = miss. Baseline precision, TaylorSeer off."""
    candidates = [("undervolt", DSE_STEPS), ("overclock", DSE_STEPS)]
    candidates += [("overclock", s)
                   for s in range(DSE_STEPS - 1, DSE_MIN_STEPS - 1, -1)]
    by_name = {op.name: op for op in builder.ops}
    for op_name, steps in candidates:
        p = builder.price(cfg, by_name[op_name], steps, DSE_STEPS,
                          DEFAULT_PLAN, False, DSE_BUCKET)
        if p.latency_s <= deadline_s:
            return p
    return None


def _frontier_pick(points, deadline_s):
    """Min-energy frontier point meeting the deadline (the scheduler's
    min-energy objective); None = miss."""
    ok = [p for p in points if p.latency_s <= deadline_s]
    return min(ok, key=lambda p: p.energy_j) if ok else None


def fig14d_frontier_vs_fixed():
    builder = FrontierBuilder(min_steps=DSE_MIN_STEPS)
    bench = {}
    for arch in DSE_ARCHS:
        cfg = configs.get_config(arch)
        full = builder.enumerate(cfg, DSE_STEPS, DSE_BUCKET)
        front = builder.frontier(cfg, DSE_STEPS, DSE_BUCKET)
        # Deadline grid spanning just-below-hopeless to comfortably-slack,
        # anchored on the knob space's own latency range.
        lats = sorted(p.latency_s for p in full)
        lo, hi = 0.9 * lats[0], 1.2 * lats[-1]
        grid = [lo + (hi - lo) * i / (N_DEADLINES - 1)
                for i in range(N_DEADLINES)]
        savings, fixed_misses, frontier_misses = [], 0, 0
        for d in grid:
            fixed = _fixed_policy_pick(builder, cfg, d)
            opt = _frontier_pick(front, d)
            fixed_misses += fixed is None
            frontier_misses += opt is None
            if fixed is not None and opt is not None:
                savings.append(1.0 - opt.energy_j / fixed.energy_j)
        assert savings, f"{arch}: no deadline served by both policies"
        bench[arch] = {
            "enumerated_points": len(full),
            "frontier_points": len(front),
            "deadline_grid": N_DEADLINES,
            "energy_saved_iso_deadline_mean": sum(savings) / len(savings),
            "energy_saved_iso_deadline_min": min(savings),
            "energy_saved_iso_deadline_max": max(savings),
            "fixed_miss_rate": fixed_misses / N_DEADLINES,
            "frontier_miss_rate": frontier_misses / N_DEADLINES,
        }
        csv(f"fig14d_{arch}", 0.0,
            f"frontier={len(front)}/{len(full)} "
            f"energy_saved_mean={bench[arch]['energy_saved_iso_deadline_mean']:.2%} "
            f"miss_fixed={bench[arch]['fixed_miss_rate']:.2f} "
            f"miss_frontier={bench[arch]['frontier_miss_rate']:.2f}")
        # The frontier searches a superset of the ladder's candidates, so
        # at iso-deadline it can never cost more energy or miss more.
        assert bench[arch]["energy_saved_iso_deadline_min"] >= 0.0
        assert (bench[arch]["frontier_miss_rate"]
                <= bench[arch]["fixed_miss_rate"])
    with open("BENCH_dse.json", "w", encoding="utf-8") as fh:
        json.dump(bench, fh, indent=2, sort_keys=True)
    print("wrote BENCH_dse.json")
    return bench


def _fine(ber, n=10):
    t = schedule_uniform(ber, n).ber_table
    t = t.at[:2, :].set(0.0).at[:, dvfs.CLASS_EMBED].set(0.0) \
         .at[:, dvfs.CLASS_FIRST_BLOCK].set(0.0)
    return dvfs.DvfsSchedule(t, dvfs.UNDERVOLT, 2)


def main():
    print("# fig14a: ABFT threshold bit vs quality (fine-grained, BER=3e-3)")
    for bit in [6, 8, 10, 12, 14, 18]:
        out, _ = timer(run_sampler, "dit-xl-512", "drift",
                       _fine(BER), 10, 5, bit)
        csv(f"fig14a_thr{bit}", 0.0,
            f"lpips={quality_vs_clean(out)['lpips']:.4f} "
            f"corrected={int(out.total_corrected)}")
    print("# fig14b: offload interval vs quality")
    for interval in [1, 2, 5, 10, 20]:
        out, _ = timer(run_sampler, "dit-xl-512", "drift",
                       schedule_uniform(BER), 10, interval)
        csv(f"fig14b_interval{interval}", 0.0,
            f"lpips={quality_vs_clean(out)['lpips']:.4f} "
            f"offload_traffic=1/{interval}")
    print("# fig14c: systolic array size (ABFT overhead + utilization)")
    for a in [16, 32, 64, 128]:
        hw = PaperAccel(array_dim=a)
        ovh = scalesim.abft_overhead_ratio(0, 0, 0, hw)
        st = scalesim.gemm(1024, 1152, 1152, hw)
        csv(f"fig14c_array{a}", 0.0,
            f"abft_overhead={ovh:.2%} gemm_util={st.utilization:.2f}")
    print("# fig14d: compute-optimal frontier vs fixed escalation policy")
    fig14d_frontier_vs_fixed()


if __name__ == "__main__":
    import sys

    # CI runs only the arithmetic frontier sweep (BENCH_dse.json); the
    # full figure additionally runs the smoke sampler for fig14a/b.
    if "--frontier-only" in sys.argv:
        fig14d_frontier_vs_fixed()
    else:
        main()
