"""Fig 14: design-space exploration -- ABFT threshold, offload interval,
systolic-array size."""
import jax.numpy as jnp

from benchmarks.common import csv, quality_vs_clean, run_sampler, \
    schedule_uniform, timer
from repro.core import dvfs
from repro.perfmodel import scalesim
from repro.perfmodel.hw import PaperAccel

BER = 3e-3


def _fine(ber, n=10):
    t = schedule_uniform(ber, n).ber_table
    t = t.at[:2, :].set(0.0).at[:, dvfs.CLASS_EMBED].set(0.0) \
         .at[:, dvfs.CLASS_FIRST_BLOCK].set(0.0)
    return dvfs.DvfsSchedule(t, dvfs.UNDERVOLT, 2)


def main():
    print("# fig14a: ABFT threshold bit vs quality (fine-grained, BER=3e-3)")
    for bit in [6, 8, 10, 12, 14, 18]:
        out, _ = timer(run_sampler, "dit-xl-512", "drift",
                       _fine(BER), 10, 5, bit)
        csv(f"fig14a_thr{bit}", 0.0,
            f"lpips={quality_vs_clean(out)['lpips']:.4f} "
            f"corrected={int(out.total_corrected)}")
    print("# fig14b: offload interval vs quality")
    for interval in [1, 2, 5, 10, 20]:
        out, _ = timer(run_sampler, "dit-xl-512", "drift",
                       schedule_uniform(BER), 10, interval)
        csv(f"fig14b_interval{interval}", 0.0,
            f"lpips={quality_vs_clean(out)['lpips']:.4f} "
            f"offload_traffic=1/{interval}")
    print("# fig14c: systolic array size (ABFT overhead + utilization)")
    for a in [16, 32, 64, 128]:
        hw = PaperAccel(array_dim=a)
        ovh = scalesim.abft_overhead_ratio(0, 0, 0, hw)
        st = scalesim.gemm(1024, 1152, 1152, hw)
        csv(f"fig14c_array{a}", 0.0,
            f"abft_overhead={ovh:.2%} gemm_util={st.utilization:.2f}")


if __name__ == "__main__":
    main()
