"""Serving-telemetry benchmark: the perf trajectory's first data point.

Drives a mixed-operating-point request stream through the deadline
scheduler + engine with telemetry on and emits ``BENCH_serving.json``:

* **throughput** -- requests per virtual (modeled-accelerator) second
  (the deterministic number future PRs must not regress), plus three
  wall-clock views that no longer conflate compile with serving: the
  total drain wall, the summed jit/compile wall (flight-recorder
  ``compile`` spans), and the steady-state wall throughput computed
  from batches that compiled nothing -- the number a warmed-up server
  actually sustains;
* **queue wait** -- p50/p99 virtual-clock wait from the telemetry
  histogram (submission -> batch start);
* **estimator vs perfmodel** -- after the stream, the learned latency
  estimate per (arch, op, steps, bucket) against the perfmodel price
  for the same configuration: mean/max relative error. The engine bills
  with per-request overheads (rollback interval, recovery traffic) the
  scheduler's a-priori perfmodel call does not see, so this gap is
  exactly what learned admission estimates buy.

Run from the repo root:

    PYTHONPATH=src python -m benchmarks.serving_telemetry

Also registered in ``benchmarks.run``. Output lands in ./BENCH_serving.json.
"""
from __future__ import annotations

import json
import time

from repro.perfmodel import energy
from repro.serving import (DeadlineScheduler, DriftServeEngine,
                           OP_BY_NAME)

ARCH, STEPS, BUCKET, N_REQ = "dit-xl-512", 4, 2, 8
OPS = ["undervolt", "overclock", "auto"]


def main() -> None:
    engine = DriftServeEngine(arch=ARCH, smoke=True, bucket=BUCKET)
    sched = DeadlineScheduler(engine)
    for i in range(N_REQ):
        sched.submit(steps=STEPS, mode="drift", op=OPS[i % len(OPS)],
                     seed=i)
    t0 = time.time()
    results = sched.run()
    wall_s = time.time() - t0

    # Separate compile wall from serving wall: the first drain of every
    # configuration jits its sampler (plus its clean reference), which
    # used to dominate throughput_req_per_wall_s and made the number a
    # cold-start artifact. The flight recorder already has the split:
    # compile spans carry the jit wall cost, finalize spans bound each
    # batch's wall interval, and a batch whose index owns no compile span
    # ran entirely warm.
    spans = engine.tracer.spans()
    compile_build_wall_s = sum(s.t1_wall_s - s.t0_wall_s
                               for s in spans if s.kind == "compile")
    compiling = {s.batch_index for s in spans if s.kind == "compile"}
    finals = [s for s in spans if s.kind == "finalize"]
    # The factory only *builds* a jitted fn; tracing happens on first
    # call, inside the batch -- so the honest compile bill is the whole
    # wall of every batch that owned a cache miss (warmup batches).
    warmup_wall_s = sum(s.t1_wall_s - s.t0_wall_s
                        for s in finals if s.batch_index in compiling)
    steady = [s for s in finals if s.batch_index not in compiling]
    steady_wall_s = sum(s.t1_wall_s - s.t0_wall_s for s in steady)
    steady_reqs = sum(len(s.request_ids) for s in steady)

    tele = engine.telemetry
    waits = sorted(r.queue_wait_s for r in results)
    pct = lambda q: waits[min(len(waits) - 1,
                              int(round(q / 100 * (len(waits) - 1))))]

    # learned estimate vs the scheduler's a-priori perfmodel price
    # (drift-mode keys only: that is the configuration the perfmodel
    # fallback prices; other modes bill differently by design)
    errors = {}
    em = engine._energy_model_for()
    full = engine._full_cfg(ARCH)
    for key in tele.estimator.keys():
        arch, op, steps, bucket, mode, taylorseer, rollback, precision = key
        if mode != "drift" or taylorseer or precision != "int8":
            continue
        est = tele.estimator.estimate_s(arch, op, steps, bucket, mode=mode,
                                        taylorseer=taylorseer,
                                        rollback_interval=rollback,
                                        precision=precision)
        rc = energy.RunConfig(num_steps=steps,
                              nominal_steps=engine.nominal_steps,
                              aggressive=OP_BY_NAME[op])
        model = energy.run_cost(full, rc, batch=bucket, em=em)["latency_s"]
        errors[f"{arch}/{op}/{steps}/b{bucket}"] = {
            "learned_s": est, "perfmodel_s": model,
            "rel_error": abs(est - model) / model,
        }
    rels = [e["rel_error"] for e in errors.values()]

    bench = {
        "requests": len(results),
        "batches": engine.stats.batches,
        "virtual_s": engine.clock_s,
        "wall_s": wall_s,
        "throughput_req_per_virtual_s": len(results) / engine.clock_s,
        # whole-drain wall rate, compile included -- a cold-start number,
        # kept for continuity with pre-split history entries
        "throughput_req_per_wall_s": len(results) / max(wall_s, 1e-9),
        "compile_build_wall_s": compile_build_wall_s,
        "warmup_wall_s": warmup_wall_s,
        "steady_batches": len(steady),
        "steady_wall_s": steady_wall_s,
        "throughput_req_per_wall_s_steady":
            steady_reqs / steady_wall_s if steady_wall_s > 0 else 0.0,
        "queue_wait_p50_s": pct(50),
        "queue_wait_p99_s": pct(99),
        "estimator": {
            "observations": tele.estimator.total_observations,
            "configs": len(tele.estimator),
            "mean_rel_error_vs_perfmodel": sum(rels) / len(rels),
            "max_rel_error_vs_perfmodel": max(rels),
            "per_config": errors,
        },
        "guardband_floor": tele.controller.guard_index,
        "deadline_misses": engine.stats.deadline_misses,
    }
    with open("BENCH_serving.json", "w", encoding="utf-8") as fh:
        json.dump(bench, fh, indent=2, sort_keys=True)
    print(json.dumps({k: v for k, v in bench.items() if k != "estimator"},
                     indent=2, sort_keys=True))
    print(f"estimator: mean rel err "
          f"{bench['estimator']['mean_rel_error_vs_perfmodel']:.4f} over "
          f"{bench['estimator']['configs']} configs")
    print("wrote BENCH_serving.json")


if __name__ == "__main__":
    main()
