"""Table 2: DRIFT + TaylorSeer composition (orthogonality check).

Paper: interval-3 order-2 TaylorSeer alone 2.82x; DRIFT 1.71x; combined
4.40x at preserved quality. Speedups here = analytic (skipped evals are
free; DVFS scales the computed ones); quality = fixed-seed proxy.
"""
from benchmarks.common import N_STEPS, csv, quality_vs_clean, run_sampler, \
    timer
from repro.core import dvfs
from repro.diffusion import taylorseer as ts_lib


def main():
    from benchmarks import common
    common.TRAINED["use"] = True      # headline table: trained DiT if avail
    sched = dvfs.fine_grained_schedule(N_STEPS, dvfs.OVERCLOCK,
                                       nominal_steps=2)
    ts_cfg = ts_lib.TaylorSeerConfig(interval=3, order=2)
    ts_speed = ts_lib.speedup(N_STEPS, ts_cfg)
    oc_speed = N_STEPS / (2 + (N_STEPS - 2) * (2.0 / 3.5))

    rows = [
        ("baseline", "clean", None, False, 1.0),
        ("taylorseer", "clean", None, True, ts_speed),
        ("drift", "drift", sched, False, oc_speed),
        ("taylorseer+drift", "drift", sched, True, ts_speed * oc_speed),
    ]
    print("# table2: method,lpips,clip,speedup")
    for name, mode, sc, ts, speed in rows:
        out, dt = timer(run_sampler, "dit-xl-512", mode, sc, N_STEPS, 5,
                        10, -1, "union", ts)
        q = quality_vs_clean(out)
        csv(f"table2_{name}", dt * 1e6,
            f"lpips={q['lpips']:.4f} clip={q['clip']:.4f} "
            f"evals={int(out.n_model_evals)} speedup={speed:.2f}x")
    csv("table2_paper_ref", 0.0,
        "paper: taylorseer 2.82x, drift 1.71x, combined 4.40x")
    common.TRAINED["use"] = False


if __name__ == "__main__":
    main()
