"""Shared benchmark harness: tiny non-trivial models + fixed-seed sampling.

The paper's characterization protocol (Sec 4): fix the initial noise seed,
run the sampler clean and under injection, compare perceptual deviation.
Works with random-init weights (the four characterized phenomena are
architecture properties, not training properties); if
``examples/train_dit.py`` has produced a checkpoint it is used instead
(closer to the paper's trained-model setting).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.core import dvfs, metrics
from repro.core.exec_ctx import DriftSystemConfig
from repro.core.rollback import RollbackConfig
from repro.core.abft import AbftConfig
from repro.diffusion import sampler as sampler_lib
from repro.diffusion.taylorseer import TaylorSeerConfig
from repro.train import steps as steps_lib

SEED = 1234
N_STEPS = 10
BATCH = 2
CKPT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dit_train_ckpt")


@functools.lru_cache(maxsize=6)
def tiny_model(arch: str = "dit-xl-512", trained: bool = False
               ) -> Tuple[Any, Any]:
    """(cfg, params): smoke config; with trained=True the in-repo-trained
    ~100M DiT checkpoint is used when available (headline quality tables);
    otherwise random init with the zero-init adaLN/final weights perturbed
    (so outputs are non-trivial)."""
    cfg = configs.get_config(arch, smoke=True)
    key = jax.random.PRNGKey(SEED)
    params = steps_lib.init_model_params(cfg, key)
    if trained and arch == "dit-xl-512" and os.path.isdir(CKPT_DIR):
        try:
            from repro.configs.dit_xl_512 import TRAIN_100M
            tcfg = TRAIN_100M
            tparams = steps_lib.init_model_params(tcfg, key)
            got = CheckpointManager(CKPT_DIR).restore_latest(tparams)
            if got is not None:
                print(f"[bench] using trained DiT checkpoint (step {got[0]})")
                return tcfg, got[1]
        except Exception as e:
            print(f"[bench] trained ckpt unusable ({e}); random init")
    if cfg.family == "dit":
        k1, k2, k3 = jax.random.split(key, 3)
        params["blocks"]["adaln_w"] = 0.1 * jax.random.normal(
            k1, params["blocks"]["adaln_w"].shape)
        params["blocks"]["adaln_b"] = 0.1 * jax.random.normal(
            k2, params["blocks"]["adaln_b"].shape)
        params["final_w"] = 0.2 * jax.random.normal(
            k3, params["final_w"].shape)
    return cfg, params


TRAINED = {"use": False}   # table1/table2 flip this for the trained ckpt


def sample_inputs(cfg, batch: int = BATCH):
    key = jax.random.PRNGKey(SEED + 1)
    lat0 = jax.random.normal(key, (batch, cfg.latent_size, cfg.latent_size,
                                   cfg.latent_channels))
    if cfg.cond_tokens:
        cond = None
        text = 0.1 * jax.random.normal(jax.random.fold_in(key, 1),
                                       (batch, cfg.cond_tokens, cfg.cond_dim))
    else:
        cond = jnp.arange(batch) % max(cfg.num_classes, 1)
        text = None
    return lat0, cond, text


def run_sampler(arch: str = "dit-xl-512", mode: str = "clean",
                schedule: Optional[dvfs.DvfsSchedule] = None,
                n_steps: int = N_STEPS,
                interval: int = 5,
                threshold_bit: int = 10,
                force_bit: int = -1,
                mask_policy: str = "union",
                taylorseer: bool = False,
                layer_gate=None, embed_gate=None,
                batch: int = BATCH) -> sampler_lib.SampleOutput:
    cfg, params = tiny_model(arch, TRAINED["use"])
    lat0, cond, text = sample_inputs(cfg, batch)
    scfg = sampler_lib.SamplerConfig(
        num_sample_steps=n_steps,
        drift=DriftSystemConfig(
            mode=mode,
            abft=AbftConfig(threshold_bit=threshold_bit,
                            mask_policy=mask_policy),
            rollback=RollbackConfig(interval=interval),
            force_bit=force_bit),
        schedule=schedule,
        taylorseer=TaylorSeerConfig(interval=3, order=2, enabled=taylorseer),
        layer_gate=layer_gate, embed_gate=embed_gate)
    key = jax.random.PRNGKey(SEED + 2)
    fn = jax.jit(lambda p, l: sampler_lib.sample(cfg, p, key, l, cond, text,
                                                 scfg))
    return fn(params, lat0)


@functools.lru_cache(maxsize=8)
def _clean_reference(arch: str, n_steps: int, trained: bool):
    return run_sampler(arch, "clean", None, n_steps)


def clean_reference(arch: str = "dit-xl-512", n_steps: int = N_STEPS):
    return _clean_reference(arch, n_steps, TRAINED["use"])


def quality_vs_clean(out: sampler_lib.SampleOutput,
                     arch: str = "dit-xl-512",
                     n_steps: int = N_STEPS) -> Dict[str, float]:
    ref = clean_reference(arch, n_steps)
    a = jnp.clip(out.latents, -1, 1)
    b = jnp.clip(ref.latents, -1, 1)
    cfg, _ = tiny_model(arch, TRAINED["use"])
    cond_dim = max(cfg.d_model, 8)
    cond = jnp.ones((a.shape[0], cond_dim))
    return {
        "lpips": float(metrics.lpips_proxy(a, b)),
        "psnr": float(metrics.psnr(a, b)),
        "ssim": float(metrics.ssim(a, b)),
        "clip": float(metrics.clip_proxy(a, cond)),
    }


def schedule_uniform(ber: float, n_steps: int = N_STEPS) -> dvfs.DvfsSchedule:
    """Flat BER on every class/step (no protection anywhere)."""
    table = jnp.full((n_steps, dvfs.N_CLASSES), ber, jnp.float32)
    return dvfs.DvfsSchedule(table, dvfs.UNDERVOLT, 0)


def schedule_single_step(ber: float, step: int,
                         n_steps: int = N_STEPS) -> dvfs.DvfsSchedule:
    table = np.zeros((n_steps, dvfs.N_CLASSES), np.float32)
    table[step, :] = ber
    return dvfs.DvfsSchedule(jnp.asarray(table), dvfs.UNDERVOLT, 0)


def timer(fn, *args):
    t0 = time.time()
    out = fn(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return out, time.time() - t0


def csv(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
