"""Fig 12: DRIFT vs prior error-mitigation works.

(a)(c) reliability: quality under rising BER for DRIFT vs ThUnderVolt
(zero faulty) and ApproxABFT (zero anomalies) -- zeroing methods collapse at
high BER (excessive neuron pruning).
(b)(d) recovery efficiency: extra compute/DRAM charged by DMR and StatABFT
(recompute on detection) vs DRIFT's sparse checkpoint reads.
"""
import jax.numpy as jnp

from benchmarks.common import csv, quality_vs_clean, run_sampler, \
    schedule_uniform, timer
from repro.perfmodel import energy
from repro import configs

BERS = [1e-5, 1e-4, 1e-3, 3e-3]
MODES = ["drift", "thundervolt", "approx_abft", "dmr", "stat_abft"]


def main():
    print("# fig12ac: mode,ber,lpips")
    for mode in MODES:
        for ber in BERS:
            out, dt = timer(run_sampler, "dit-xl-512", mode,
                            schedule_uniform(ber))
            q = quality_vs_clean(out)
            csv(f"fig12_{mode}_ber{ber:.0e}", dt * 1e6,
                f"lpips={q['lpips']:.4f}")
    # (b)(d) recovery cost: extra work per step at BER 3e-3
    print("# fig12bd: recovery overhead (relative to one model eval)")
    full = configs.get_config("dit-xl-512")
    macs = energy.model_eval_macs(full)
    for mode, extra in [
        ("drift", 0.0),                 # sparse DRAM reads only
        ("stat_abft", 0.15),            # flagged-tile recompute at 3e-3
        ("dmr", 1.0),                   # full duplicate pass
    ]:
        csv(f"fig12_cost_{mode}", 0.0,
            f"extra_compute={extra:.2f}x model eval "
            f"({extra*2*macs:.2e} FLOPs/step)")


if __name__ == "__main__":
    main()
