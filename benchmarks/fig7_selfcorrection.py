"""Fig 7: self-correction -- latent trajectory after a single-step fault.

Expected reproduction: an injected deviation at an intermediate step decays
back toward the clean trajectory over subsequent steps (small errors heal).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BATCH, N_STEPS, csv, run_sampler, \
    schedule_single_step, tiny_model, sample_inputs
from repro.core.exec_ctx import DriftSystemConfig
from repro.diffusion import sampler as sampler_lib
from repro.diffusion import schedule as sched_lib


def trajectory(mode, schedule):
    """Track one latent pixel across all denoising steps."""
    cfg, params = tiny_model("dit-xl-512")
    lat0, cond, text = sample_inputs(cfg)
    scfg = sampler_lib.SamplerConfig(num_sample_steps=N_STEPS,
                                     drift=DriftSystemConfig(mode=mode),
                                     schedule=schedule)
    # re-run the sampler step by step to record the trajectory
    sched = sched_lib.DdpmSchedule.default(scfg.num_train_steps)
    ts = sched_lib.ddim_timesteps(scfg.num_train_steps, N_STEPS)
    key = jax.random.PRNGKey(1234 + 2)
    vals = []
    lat = lat0
    stores = sampler_lib.init_stores(cfg, params, lat0,
                                     jnp.full((BATCH,), float(ts[0])),
                                     cond, text, scfg.drift)
    for i, t in enumerate(ts):
        ber = (schedule.ber_table[i] if schedule is not None
               else jnp.zeros(3))
        eps, stores, _, _ = sampler_lib._model_eval(
            cfg, params, lat, jnp.full((BATCH,), float(t)), cond, text,
            (scfg.drift, jax.random.fold_in(key, i), jnp.int32(i), ber,
             stores, i > 0))
        t_next = ts[i + 1] if i + 1 < len(ts) else -1
        lat = sched.ddim_step(lat, eps, int(t), int(t_next))
        vals.append(float(lat[0, 4, 4, 0]))
    return np.array(vals)


def main():
    print("# fig7: step,clean,small_err,large_err (pixel [0,4,4,0])")
    clean = trajectory("clean", None)
    small = trajectory("faulty", schedule_single_step(3e-5, 3))
    large = trajectory("faulty", schedule_single_step(1e-3, 3))
    for i in range(N_STEPS):
        print(f"fig7,{i},{clean[i]:.4f},{small[i]:.4f},{large[i]:.4f}")
    dev_small = np.abs(small - clean)
    dev_large = np.abs(large - clean)
    peak_s, final_s = dev_small[3:].max(), dev_small[-1]
    peak_l, final_l = dev_large[3:].max(), dev_large[-1]
    csv("fig7_small_recovery", 0.0,
        f"peak_dev={peak_s:.4f} final_dev={final_s:.4f} "
        f"healed={final_s < 0.5 * peak_s + 1e-9}")
    csv("fig7_large_recovery", 0.0,
        f"peak_dev={peak_l:.4f} final_dev={final_l:.4f}")


if __name__ == "__main__":
    main()
