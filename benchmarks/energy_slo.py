"""Energy-ledger + SLO benchmark: where the joules go, per DVFS point.

Drives a mixed-operating-point request stream through a telemetry-enabled
engine and emits ``BENCH_energy.json``:

* **breakdown shares per op** -- each ledger component's fraction of the
  billed joules, per operating point that served batches (the live
  analogue of the paper's Fig 11 energy decomposition: compute at the
  aggressive (V, f), checkpoint-refresh DRAM, recovery traffic, static);
* **ledger residual** -- ``max |sum(components) - energy_j|`` over every
  result AND every batch, asserted to be exactly 0.0: the billing
  invariant (serving.telemetry.energy.verify_cost) is re-proved on every
  benchmark run and gated at zero tolerance by tools/bench_history.py;
* **SLO burn-rate trace** -- per drained phase, every objective's
  fast/slow burn rates and breach state on the deterministic virtual
  clock (so two runs of this benchmark emit byte-identical SLO traces).

Run from the repo root:

    PYTHONPATH=src python -m benchmarks.energy_slo

Also registered in ``benchmarks.run``. Output: ./BENCH_energy.json.
"""
from __future__ import annotations

import json

from repro.serving import DriftServeEngine
from repro.serving.telemetry.energy import ledger_total

ARCH, STEPS, BUCKET = "dit-xl-512", 4, 2
# Three drain phases, each a different op mix: the SLO windows see the
# energy-per-request objective move as the mix shifts toward nominal.
PHASES = [
    ["undervolt", "undervolt", "uv-mild", "uv-mild"],
    ["overclock", "overclock", "auto", "auto"],
    ["nominal", "nominal", "near-nominal", "near-nominal"],
]


def main() -> None:
    engine = DriftServeEngine(arch=ARCH, smoke=True, bucket=BUCKET)
    tele = engine.telemetry
    residual = 0.0
    slo_trace = []
    served = 0
    for phase, ops in enumerate(PHASES):
        for i, op in enumerate(ops):
            engine.submit(steps=STEPS, mode="drift", op=op,
                          seed=phase * len(ops) + i)
        for res in engine.run():
            served += 1
            residual = max(residual,
                           abs(ledger_total(res.energy_breakdown)
                               - res.energy_j))
        snap = tele.slo_snapshot()
        slo_trace.append({
            "phase": phase, "ops": ops, "clock_s": snap["clock_s"],
            "objectives": {
                obj: {k: o[k] for k in ("burn_fast", "burn_slow",
                                        "breached")}
                for obj, o in snap["objectives"].items()},
        })
    assert residual == 0.0, \
        f"energy ledger does not reconcile: residual {residual!r}"

    ledger = tele.ledger
    bench = {
        "requests": served,
        "batches": ledger.batches,
        "virtual_s": engine.clock_s,
        "energy_per_request_j": ledger.energy_per_request_j(),
        "ledger_residual_j": residual,
        "total_j": sum(ledger.component_totals().values()),
        "shares": ledger.shares(),
        "shares_by_op": {op: ledger.shares(op) for op in ledger.ops()},
        "slo_trace": slo_trace,
    }
    with open("BENCH_energy.json", "w", encoding="utf-8") as fh:
        json.dump(bench, fh, indent=2, sort_keys=True)
    print(json.dumps({k: v for k, v in bench.items()
                      if k not in ("shares_by_op", "slo_trace")},
                     indent=2, sort_keys=True))
    for op in ledger.ops():
        top = sorted(ledger.shares(op).items(), key=lambda kv: -kv[1])[:3]
        print(f"  {op}: " + ", ".join(f"{c}={s:.1%}" for c, s in top))
    print("wrote BENCH_energy.json")


if __name__ == "__main__":
    main()
