"""Benchmark runner: one module per paper table/figure + roofline summary.

    PYTHONPATH=src python -m benchmarks.run [--only fig4,fig13]

Prints ``name,us_per_call,derived`` CSV lines per benchmark.
"""
import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "fig1_ber",
    "fig4_bitlevel",
    "fig5_timestep",
    "fig6_block",
    "fig7_selfcorrection",
    "table1_quality_efficiency",
    "fig11_tradeoff",
    "fig12_comparison",
    "fig13_ablation",
    "fig14_dse",
    "table2_taylorseer",
    "roofline_summary",
    "serving_telemetry",
    "ar_serving",
    "offload_overlap",
    "trace_forensics",
    "energy_slo",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = [s.strip() for s in args.only.split(",") if s.strip()]

    failures = []
    for name in MODULES:
        if only and not any(name == o or name.startswith(o + "_")
                            for o in only):
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.main()
            print(f"[{name}] done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        sys.exit(1)
    print("\nall benchmarks passed")


if __name__ == "__main__":
    main()
