"""Fig 1(a): BER across voltage/frequency operating points (model surface).

Reproduces the calibrated BER(V, f) surface: anchors at the paper's
(0.9V,2GHz)~error-free, (0.68V,2GHz)~3e-3, (0.88V,3.5GHz)~3e-3, with the
energy/throughput factors that define the efficiency-reliability tradeoff.
"""
from repro.core import dvfs
from benchmarks.common import csv


def main():
    print("# fig1a: voltage,freq_ghz,ber,energy_factor,speed_factor")
    for v in [0.62, 0.65, 0.68, 0.72, 0.76, 0.80, 0.84, 0.88, 0.90]:
        for f in [2.0, 2.5, 3.0, 3.5]:
            op = dvfs.OperatingPoint(v, f)
            print(f"fig1a,{v:.2f},{f:.1f},{dvfs.ber_of(op):.3e},"
                  f"{op.energy_factor:.3f},{op.speed_factor:.3f}")
    for name, op in [("nominal", dvfs.NOMINAL), ("undervolt", dvfs.UNDERVOLT),
                     ("overclock", dvfs.OVERCLOCK)]:
        csv(f"fig1a_anchor_{name}", 0.0,
            f"ber={dvfs.ber_of(op):.2e} (paper: ~3e-3 aggressive)")


if __name__ == "__main__":
    main()
