"""Table 1: generation quality + efficiency, w/ and w/o DRIFT.

Quality: tiny-model fixed-seed simulation (proxy metrics; see DESIGN.md).
Efficiency: calibrated perfmodel on the FULL configs -- the reproduction
targets are the paper's ~36% energy saving (undervolt) and ~1.7x speedup
(overclock) at preserved quality.
"""
from repro import configs
from repro.core import dvfs
from repro.perfmodel import energy

from benchmarks.common import csv, quality_vs_clean, run_sampler, timer

CONFIGS = [("dit-xl-512", 50), ("pixart-alpha", 20), ("sd15-unet", 50)]


def main():
    from benchmarks import common
    common.TRAINED["use"] = True      # headline table: trained DiT if avail
    em = energy.calibrate()
    print("# table1: arch | clean-vs-drift quality | energy | latency")
    saves, speeds = [], []
    for arch, steps in CONFIGS:
        # quality at the undervolt BER with fine-grained protection
        sched = dvfs.fine_grained_schedule(10, dvfs.UNDERVOLT,
                                           nominal_steps=2)
        out, dt = timer(run_sampler, arch, "drift", sched)
        q = quality_vs_clean(out, arch)
        rec_tiles = float(out.total_corrected) / 10 / (32 * 32)

        full = configs.get_config(arch)
        base = energy.run_cost(full, energy.baseline_rc(steps), em=em)
        uv = energy.run_cost(full, energy.RunConfig(
            num_steps=steps, aggressive=dvfs.UNDERVOLT,
            recovery_tiles_per_step=rec_tiles), em=em)
        oc = energy.run_cost(full, energy.RunConfig(
            num_steps=steps, aggressive=dvfs.OVERCLOCK,
            recovery_tiles_per_step=rec_tiles), em=em)
        save = 100 * (1 - uv["energy_j"] / base["energy_j"])
        speed = base["latency_s"] / oc["latency_s"]
        saves.append(save)
        speeds.append(speed)
        csv(f"table1_{arch}", dt * 1e6,
            f"lpips={q['lpips']:.4f} clip={q['clip']:.4f} "
            f"ssim={q['ssim']:.4f} "
            f"E_base={base['energy_j']:.2f}J E_uv={uv['energy_j']:.2f}J "
            f"(-{save:.1f}%) T_base={base['latency_s']:.3f}s "
            f"speedup={speed:.2f}x")
    csv("table1_average", 0.0,
        f"energy_saving={sum(saves)/len(saves):.1f}% (paper 36%) "
        f"speedup={sum(speeds)/len(speeds):.2f}x (paper 1.7x)")
    common.TRAINED["use"] = False


if __name__ == "__main__":
    main()
