"""Autoregressive-serving benchmark: the second paradigm's perf baseline.

Drives identical token-decoding request streams through the shared
serving engine twice -- once with statistical ABFT + KV-window rollback
(``mode=stat_abft``) and once with the same fault injection but no
protection (``mode=faulty``) -- and emits ``BENCH_ar.json``:

* **throughput** -- generated tokens per virtual (modeled-accelerator)
  second and per host wall second, for the protected run (wall numbers
  are a CPU-smoke artifact; virtual numbers are the deterministic ones
  future PRs must not regress);
* **detection rate** -- statistical-ABFT flagged rows per monitored
  decode step and per protected GEMM word, plus KV rollbacks per
  request;
* **rollback overhead** -- what protection costs relative to ABFT off:
  the model-eval ratio (replayed windows charged as extra evals) and the
  virtual-latency ratio between the two runs;
* **quality** -- token match vs the clean reference for both runs: the
  protected stream must match exactly (rollback replays every flagged
  window); the unprotected stream documents what the same fault rate
  does without detection.

Run from the repo root:

    PYTHONPATH=src python -m benchmarks.ar_serving

Also registered in ``benchmarks.run``. Output lands in ./BENCH_ar.json.
"""
from __future__ import annotations

import json
import time

from repro.serving import DriftServeEngine

ARCH, STEPS, BUCKET, N_REQ = "olmo-1b", 8, 2, 4
OP = "undervolt"


def _run(mode: str):
    engine = DriftServeEngine(arch=ARCH, smoke=True, bucket=BUCKET)
    for i in range(N_REQ):
        engine.submit(arch=ARCH, steps=STEPS, mode=mode, op=OP, seed=i)
    t0 = time.time()
    results = engine.run()
    return engine, results, time.time() - t0


def main() -> None:
    eng_p, protected, wall_p = _run("stat_abft")
    eng_u, unprotected, wall_u = _run("faulty")

    tokens = sum(len(r.tokens) for r in protected)
    detections = sum(r.ar_detections for r in protected)
    rollbacks = sum(r.ar_rollbacks for r in protected)
    evals_p = sum(r.n_model_evals for r in protected)
    evals_u = sum(r.n_model_evals for r in unprotected)
    # every request decodes steps-1 monitored tokens after the prefill
    monitored_steps = N_REQ * (STEPS - 1)

    bench = {
        "arch": ARCH, "steps": STEPS, "requests": N_REQ, "op": OP,
        "tokens": tokens,
        "virtual_s": eng_p.clock_s,
        "wall_s": wall_p,
        "tokens_per_virtual_s": tokens / eng_p.clock_s,
        "tokens_per_wall_s": tokens / max(wall_p, 1e-9),
        "detection": {
            "flagged_rows": detections,
            "per_monitored_step": detections / monitored_steps,
            "rollbacks": rollbacks,
            "rollbacks_per_request": rollbacks / N_REQ,
            "monitor_ema_ber": float(eng_p.monitor.ema_ber),
        },
        "rollback_overhead": {
            "model_evals_protected": evals_p,
            "model_evals_unprotected": evals_u,
            "eval_ratio": evals_p / evals_u,
            "virtual_s_unprotected": eng_u.clock_s,
            "latency_ratio": eng_p.clock_s / eng_u.clock_s,
        },
        "quality": {
            "token_match_protected": min(
                r.token_match_vs_clean for r in protected),
            "token_match_unprotected": min(
                r.token_match_vs_clean for r in unprotected),
        },
    }
    assert bench["quality"]["token_match_protected"] == 1.0, (
        "protected decode diverged from the clean reference")
    with open("BENCH_ar.json", "w", encoding="utf-8") as fh:
        json.dump(bench, fh, indent=2, sort_keys=True)
    print(json.dumps(bench, indent=2, sort_keys=True))
    print(f"unprotected wall {wall_u:.1f}s; wrote BENCH_ar.json")


if __name__ == "__main__":
    main()
