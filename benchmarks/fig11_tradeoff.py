"""Fig 11: (a) energy/latency tradeoff across operating points,
(b) energy breakdown under undervolting."""
from repro import configs
from repro.core import dvfs
from repro.perfmodel import energy

from benchmarks.common import csv


def main():
    em = energy.calibrate()
    full = configs.get_config("dit-xl-512")
    base = energy.run_cost(full, energy.baseline_rc(50), em=em)
    print("# fig11a: op(V,GHz),ber,energy_J,latency_s")
    for v, f in [(0.9, 2.0), (0.84, 2.0), (0.76, 2.0), (0.68, 2.0),
                 (0.9, 2.5), (0.9, 3.0), (0.88, 3.5), (0.84, 3.5)]:
        op = dvfs.OperatingPoint(v, f)
        rc = energy.RunConfig(num_steps=50, aggressive=op,
                              recovery_tiles_per_step=100)
        c = energy.run_cost(full, rc, em=em)
        print(f"fig11a,{v:.2f}V@{f:.1f}GHz,{dvfs.ber_of(op):.2e},"
              f"{c['energy_j']:.2f},{c['latency_s']:.3f}")
    uv = energy.run_cost(full, energy.RunConfig(
        num_steps=50, aggressive=dvfs.UNDERVOLT,
        recovery_tiles_per_step=100), em=em)
    tot = uv["energy_j"]
    csv("fig11b_breakdown", 0.0,
        f"die={uv['e_die']/tot:.2%} dram={uv['e_dram']/tot:.2%} "
        f"static={uv['e_static']/tot:.2%} "
        f"drift_mem_overhead={uv['e_drift_mem']/tot:.2%} (paper <3%)")
    csv("fig11_summary", 0.0,
        f"undervolt_saving={1-uv['energy_j']/base['energy_j']:.1%} "
        f"(paper ~35%)")


if __name__ == "__main__":
    main()
