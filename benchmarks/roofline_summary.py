"""Roofline summary from the dry-run artifacts (see launch/roofline.py).

Prints the per-cell three-term roofline for whatever cells have completed;
the full table lands in EXPERIMENTS.md Sec Roofline.
"""
import os

from repro.launch import roofline

DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def main():
    if not os.path.isdir(DIR):
        print("roofline_summary,0.0,no dryrun artifacts yet "
              "(run python -m repro.launch.dryrun)")
        return
    rows = roofline.load_rows(DIR)
    if not rows:
        print("roofline_summary,0.0,no cells recorded yet")
        return
    print("# roofline: arch,shape,mesh,compute_s,memory_s,collective_s,"
          "dominant,frac,useful")
    for r in rows:
        print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
              f"{r['t_collective_s']:.3e},{r['dominant']},"
              f"{r['roofline_fraction']:.2f},{r['useful_flops_ratio']:.2f}")
    n_dom = {}
    for r in rows:
        n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
    print(f"roofline_summary,0.0,cells={len(rows)} dominated_by={n_dom}")


if __name__ == "__main__":
    main()
