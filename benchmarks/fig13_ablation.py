"""Fig 13: (a) ablation of rollback-ABFT + fine-grained DVFS,
(b) data-layout repacking row-activation reduction + overlap check."""
import jax.numpy as jnp

from benchmarks.common import N_STEPS, csv, quality_vs_clean, run_sampler, \
    schedule_uniform, timer
from repro import configs
from repro.core import dvfs
from repro.perfmodel import dram, energy, scalesim
from repro.perfmodel.hw import PAPER_ACCEL

BERS = [1e-8, 1e-6, 1e-5, 1e-4, 1e-3, 3e-3]


def main():
    print("# fig13a: variant,ber,lpips  (quality cliff location)")
    for ber in BERS:
        out, _ = timer(run_sampler, "dit-xl-512", "faulty",
                       schedule_uniform(ber))
        csv(f"fig13a_noprotect_ber{ber:.0e}", 0.0,
            f"lpips={quality_vs_clean(out)['lpips']:.4f}")
    for ber in BERS:
        out, _ = timer(run_sampler, "dit-xl-512", "drift",
                       schedule_uniform(ber))
        csv(f"fig13a_rollback_ber{ber:.0e}", 0.0,
            f"lpips={quality_vs_clean(out)['lpips']:.4f}")
    for ber in BERS:
        sched = dvfs.DvfsSchedule(
            schedule_uniform(ber).ber_table
            .at[:2, :].set(0.0).at[:, dvfs.CLASS_EMBED].set(0.0)
            .at[:, dvfs.CLASS_FIRST_BLOCK].set(0.0),
            dvfs.UNDERVOLT, 2)
        out, _ = timer(run_sampler, "dit-xl-512", "drift", sched)
        csv(f"fig13a_finegrained_ber{ber:.0e}", 0.0,
            f"lpips={quality_vs_clean(out)['lpips']:.4f}")

    # (b) repacking: q_proj of DiT-XL (1024 tokens x 1152)
    full = configs.get_config("dit-xl-512")
    t = (full.latent_size // full.patch_size) ** 2
    red = dram.repack_speedup(32, 32, full.d_model)
    rep = dram.recovery_report(100, 32, 32, full.d_model)
    gemm_t = scalesim.gemm_seconds(t, full.d_model, full.d_model,
                                   PAPER_ACCEL) * 1e6
    csv("fig13b_repack", 0.0,
        f"row_activation_reduction={red:.1f}x (paper 23.4x at their row "
        f"size) retrieval={rep['t_retrieval_repacked_us']:.2f}us "
        f"compute={gemm_t:.1f}us overlapped={rep['t_retrieval_repacked_us'] < gemm_t}")


if __name__ == "__main__":
    main()
