"""Fig 6: block-level resilience -- inject into one block (or embeddings).

Expected reproduction: first block and the embedding/conditioning layers
are the most sensitive; middle/deep blocks degrade least.
"""
import numpy as np

from benchmarks.common import csv, quality_vs_clean, run_sampler, \
    schedule_uniform, timer, tiny_model

BER = 1e-3


def main():
    cfg, _ = tiny_model("dit-xl-512")
    n_layers = cfg.n_layers
    sched = schedule_uniform(BER)
    print("# fig6: site,lpips,psnr")
    # embeddings only
    out, dt = timer(run_sampler, "dit-xl-512", "faulty", sched, 10, 5, 10,
                    -1, "union", False,
                    np.zeros((n_layers,), np.float32), 1.0)
    q = quality_vs_clean(out)
    csv("fig6_embed", dt * 1e6, f"lpips={q['lpips']:.4f}")
    # one block at a time
    for blk in range(n_layers):
        gate = np.zeros((n_layers,), np.float32)
        gate[blk] = 1.0
        out, dt = timer(run_sampler, "dit-xl-512", "faulty", sched, 10, 5,
                        10, -1, "union", False, gate, 0.0)
        q = quality_vs_clean(out)
        csv(f"fig6_block{blk}", dt * 1e6, f"lpips={q['lpips']:.4f}")


if __name__ == "__main__":
    main()
