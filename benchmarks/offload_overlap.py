"""Checkpoint-offload benchmark: serialized vs overlapped refresh, the
per-interval energy/stall sweep, and the multi-engine metrics wire.

Three parts, emitted together as ``BENCH_offload.json``:

1. **Overlap sweep** (modeled, full-size DiT-XL-512): for every candidate
   refresh interval, the planner's serialized stall (refresh blocks the
   scan, the pre-offload behavior) vs the overlapped residual stall
   (refresh rides a background thread under the next window's compute).
   Asserts the overlapped stall is *strictly* lower at every interval --
   the whole point of the subsystem -- and that the planner's chosen
   interval sits on the independently-computed (energy, stall) Pareto
   frontier.

2. **Layout accounting**: the Fig 10(b)/13(b) tile-contiguous story on
   the real smoke checkpoint store -- DRAM row activations for a full
   restore under the repacked vs row-major layouts.

3. **Live engines + aggregated /metrics**: two real smoke engines (one
   offload-enabled, one baseline) serve the same request stream; finals
   are checked bit-identical, and both engines' registries are scraped
   through ONE ``/metrics`` endpoint with an ``engine`` label
   (``TelemetryHTTPServer(engines=...)`` -- the ROADMAP's multi-engine
   aggregation item), over the actual HTTP wire.

Run from the repo root:

    PYTHONPATH=src python -m benchmarks.offload_overlap

Also registered in ``benchmarks.run``. Output lands in ./BENCH_offload.json.
"""
from __future__ import annotations

import json
import time
import urllib.request

import numpy as np

from repro import configs
from repro.core import dvfs as dvfs_lib
from repro.serving import (DriftServeEngine, OffloadConfig, OffloadPlanner,
                           TelemetryHTTPServer)
from repro.serving.offload import layout_report, pareto_frontier

ARCH, STEPS, BUCKET, N_REQ = "dit-xl-512", 4, 2, 4
SWEEP_STEPS = 50                       # full-length chain for the sweep


def overlap_sweep() -> dict:
    cfg = configs.get_config(ARCH)     # full-size arch: real byte volumes
    planner = OffloadPlanner()
    out = {}
    for op in (dvfs_lib.UNDERVOLT, dvfs_lib.OVERCLOCK):
        plans = planner.sweep(cfg, op, SWEEP_STEPS, BUCKET, detect_rate=1.0)
        chosen = planner.plan(cfg, op, SWEEP_STEPS, BUCKET, detect_rate=1.0)
        frontier = pareto_frontier(plans)
        # Acceptance bar 1: overlap strictly beats the serialized refresh
        # at every interval (residual stall < full refresh time as long
        # as the window computes anything at all).
        for p in plans:
            assert p.stall_s < p.stall_serialized_s, (
                f"overlap did not reduce stall at interval {p.interval}: "
                f"{p.stall_s} >= {p.stall_serialized_s}")
        # Acceptance bar 2: the argmin of the summed objective must be
        # Pareto-optimal over (energy, stall) -- checked against the
        # independent frontier, not assumed from the math.
        assert any(p.interval == chosen.interval for p in frontier), (
            f"chosen interval {chosen.interval} off the Pareto frontier "
            f"{[p.interval for p in frontier]}")
        out[op.name] = {
            "chosen_interval": chosen.interval,
            "frontier_intervals": sorted(p.interval for p in frontier),
            "per_interval": [{
                "interval": p.interval,
                "n_refreshes": p.n_refreshes,
                "stall_serialized_s": p.stall_serialized_s,
                "stall_overlapped_s": p.stall_s,
                "refresh_energy_j": p.refresh_energy_j,
                "rollback_penalty_j": p.rollback_penalty_j,
                "total_j": p.total_j,
            } for p in plans],
        }
        mean_red = float(np.mean(
            [1.0 - p.stall_s / max(p.stall_serialized_s, 1e-30)
             for p in plans]))
        print(f"[{op.name}] chosen interval {chosen.interval}, frontier "
              f"{out[op.name]['frontier_intervals']}, mean stall "
              f"reduction {100 * mean_red:.1f}%")
    return out


def layout_accounting() -> dict:
    """Row activations for a full smoke-store restore, both layouts."""
    import jax
    from repro.core.exec_ctx import DriftSystemConfig
    from repro.diffusion import sampler as sampler_lib
    from repro.train import steps as steps_lib

    cfg = configs.get_config(ARCH, smoke=True)
    params = steps_lib.init_model_params(cfg, jax.random.PRNGKey(0))
    lat = jax.random.normal(jax.random.PRNGKey(1),
                            (1, cfg.latent_size, cfg.latent_size,
                             cfg.latent_channels))
    t = np.zeros((1,), np.float32)
    stores = sampler_lib.init_stores(cfg, params, lat, t, None, None,
                                     DriftSystemConfig(mode="drift"))
    rep = layout_report(stores, tm=8, tn=8)
    print(f"[layout] smoke store: {rep['tiles']:.0f} tiles, restore rows "
          f"{rep['rows_repacked']:.0f} repacked vs "
          f"{rep['rows_rowmajor']:.0f} row-major "
          f"({rep['reduction']:.1f}x)")
    return rep


def live_engines_and_aggregation() -> dict:
    def build(offload):
        return DriftServeEngine(arch=ARCH, smoke=True, bucket=BUCKET,
                                offload=offload)

    runs = {}
    for name, eng in (("offload", build(OffloadConfig())),
                      ("baseline", build(None))):
        for i in range(N_REQ):
            eng.submit(steps=STEPS, mode="drift", op="undervolt", seed=i,
                       rollback_interval=2)
        t0 = time.time()
        results = eng.run()
        runs[name] = (eng, results, time.time() - t0)

    off_eng, off_res, off_wall = runs["offload"]
    base_eng, base_res, base_wall = runs["baseline"]
    for a, b in zip(off_res, base_res):
        assert np.array_equal(np.asarray(a.latents), np.asarray(b.latents)), \
            f"offload changed request {a.request_id}'s latents"
    ost = off_eng.offload_store.stats

    # one /metrics endpoint, both engines, engine-labeled series
    server = TelemetryHTTPServer(off_eng, engines={"offload": off_eng,
                                                   "baseline": base_eng})
    server.start()
    try:
        with urllib.request.urlopen(f"{server.url}/metrics",
                                    timeout=10) as resp:
            payload = resp.read().decode()
    finally:
        server.close()
    assert 'engine="offload"' in payload and 'engine="baseline"' in payload
    assert "drift_offload_commits_total" in payload
    off_line = [l for l in payload.splitlines()
                if l.startswith("drift_offload_commits_total")
                and 'engine="offload"' in l]
    assert off_line and float(off_line[0].rsplit(" ", 1)[1]) >= 1, off_line

    print(f"[live] finals bit-identical; {ost.commits} commits, "
          f"{ost.bytes_offloaded / 1e6:.2f} MB offloaded; aggregated "
          f"/metrics served {len(payload.splitlines())} lines for 2 "
          f"engines")
    return {
        "finals_bit_identical": True,
        "commits": ost.commits,
        "bytes_offloaded": ost.bytes_offloaded,
        "modeled_stall_per_batch_s": off_res[0].latency_s
            - base_res[0].latency_s,
        "virtual_s": {"offload": off_eng.clock_s,
                      "baseline": base_eng.clock_s},
        "wall_s": {"offload": off_wall, "baseline": base_wall},
        "aggregated_metrics_lines": len(payload.splitlines()),
    }


def main() -> None:
    bench = {
        "sweep": overlap_sweep(),
        "layout": layout_accounting(),
        "live": live_engines_and_aggregation(),
    }
    with open("BENCH_offload.json", "w", encoding="utf-8") as fh:
        json.dump(bench, fh, indent=2, sort_keys=True)
    print("wrote BENCH_offload.json")


if __name__ == "__main__":
    main()
