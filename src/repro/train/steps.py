"""Train/serve step factories for every architecture family.

``make_train_step(cfg, optim_cfg)`` returns a pure (state, batch) ->
(state, metrics) function suitable for jit/pjit; ``make_prefill_step`` /
``make_decode_step`` build the serving path. The dry-run lowers exactly
these functions on the production mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import dit as dit_lib
from repro.models import encdec as encdec_lib
from repro.models import transformer as tf_lib
from repro.models import unet as unet_lib
from repro.models.common import ModelConfig
from repro.optim import adamw as optim_lib


class TrainState(NamedTuple):
    params: Any
    opt: optim_lib.OptState
    step: jax.Array
    rng: jax.Array


def init_train_state(cfg: ModelConfig, optim_cfg: optim_lib.OptimConfig,
                     key: jax.Array) -> TrainState:
    params = init_model_params(cfg, key)
    return TrainState(params, optim_lib.init(optim_cfg, params),
                      jnp.int32(0), key)


def init_model_params(cfg: ModelConfig, key: jax.Array) -> Any:
    if cfg.family in ("dense", "moe", "ssm", "hybrid", "vlm"):
        return tf_lib.init_params(cfg, key)
    if cfg.family == "encdec":
        return encdec_lib.init_params(cfg, key)
    if cfg.family == "dit":
        return dit_lib.init_params(cfg, key)
    if cfg.family == "unet":
        return unet_lib.init_params(cfg, key)
    raise ValueError(cfg.family)


# ----------------------------------------------------------------- losses
def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean cross entropy; logits f32 (B, S, V), labels (B, S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ----------------------------------------------------------- train steps
def _lm_loss(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, Dict]:
    tokens = batch["tokens"]
    vis = batch.get("vis_embeds")
    logits, aux = tf_lib.forward(cfg, params, tokens[:, :-1],
                                 vis_embeds=vis)
    labels = tokens[:, 1:]
    if vis is not None:
        # loss only over text positions (the vis prefix predicts nothing)
        logits = logits[:, cfg.vis_tokens:]
    loss = softmax_xent(logits, labels) + 0.01 * aux
    return loss, {"aux_loss": aux}


def _encdec_loss(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, Dict]:
    memory = encdec_lib.encode(cfg, params, batch["frames"])
    logits = encdec_lib.decode_train(cfg, params, batch["tokens"][:, :-1],
                                     memory)
    return softmax_xent(logits, batch["tokens"][:, 1:]), {}


def _diffusion_loss(cfg: ModelConfig, params, batch, rng) -> Tuple[jax.Array, Dict]:
    """Standard DDPM epsilon-prediction MSE."""
    from repro.diffusion import schedule as sched_lib
    latents = batch["latents"]
    b = latents.shape[0]
    k_t, k_eps = jax.random.split(rng)
    sched = sched_lib.DdpmSchedule.default(1000)
    t = jax.random.randint(k_t, (b,), 0, sched.num_steps)
    eps = jax.random.normal(k_eps, latents.shape)
    x_t = sched.q_sample(latents, t, eps)
    if cfg.family == "dit":
        if cfg.cond_tokens:
            pred, _, _ = dit_lib.forward(cfg, params, x_t, t.astype(jnp.float32),
                                         None, text=batch["text"])
        else:
            pred, _, _ = dit_lib.forward(cfg, params, x_t, t.astype(jnp.float32),
                                         batch["labels"])
    else:
        pred = unet_lib.forward(cfg, params, x_t, t.astype(jnp.float32),
                                batch.get("text"))
    return jnp.mean((pred - eps) ** 2), {}


def make_train_step(cfg: ModelConfig, optim_cfg: optim_lib.OptimConfig,
                    microbatches: int = 1
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """Build the train step; ``microbatches > 1`` enables gradient
    accumulation (scan over batch slices), dividing the live-activation
    footprint by the microbatch count -- required to fit the assigned
    65k-token-per-device train cells in 16 GB HBM."""
    def loss_fn(params, batch, rng):
        if cfg.family in ("dense", "moe", "ssm", "hybrid", "vlm"):
            return _lm_loss(cfg, params, batch)
        if cfg.family == "encdec":
            return _encdec_loss(cfg, params, batch)
        if cfg.family in ("dit", "unet"):
            return _diffusion_loss(cfg, params, batch, rng)
        raise ValueError(cfg.family)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        rng = jax.random.fold_in(state.rng, state.step)
        if microbatches <= 1:
            (loss, extras), grads = grad_fn(state.params, batch, rng)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, ex), g = grad_fn(state.params, mb,
                                     jax.random.fold_in(rng, l_acc.astype(
                                         jnp.int32) * 0))
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), ex

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (grads, loss_sum), exs = jax.lax.scan(acc, (g0, jnp.float32(0.0)),
                                                  micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            extras = jax.tree.map(lambda a: a[-1], exs)
        params, opt, om = optim_lib.apply(optim_cfg, state.opt, state.params,
                                          grads)
        metrics = {"loss": loss, **extras, **om}
        return TrainState(params, opt, state.step + 1, state.rng), metrics

    return train_step


# ----------------------------------------------------------- serve steps
def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, batch):
        if cfg.family == "encdec":
            memory = encdec_lib.encode(cfg, params, batch["frames"])
            logits = encdec_lib.decode_train(cfg, params, batch["tokens"],
                                             memory)
            return logits
        logits, cache = tf_lib.prefill(cfg, params, batch["tokens"], max_seq,
                                       vis_embeds=batch.get("vis_embeds"))
        return logits[:, -1], cache
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, tokens):
        if cfg.family == "encdec":
            return encdec_lib.decode_step(cfg, params, cache, tokens)
        logits, cache2, _ = tf_lib.decode_step(cfg, params, cache, tokens)
        return logits, cache2
    return decode_step


def make_denoise_step(cfg: ModelConfig):
    """One diffusion sampling step (the paper's serve unit)."""
    from repro.diffusion import schedule as sched_lib
    sched = sched_lib.DdpmSchedule.default(1000)

    def denoise_step(params, latents, t, cond):
        tt = jnp.full((latents.shape[0],), t, jnp.float32)
        if cfg.family == "dit":
            if cfg.cond_tokens:
                eps, _, _ = dit_lib.forward(cfg, params, latents, tt, None,
                                            text=cond)
            else:
                eps, _, _ = dit_lib.forward(cfg, params, latents, tt, cond)
        else:
            eps = unet_lib.forward(cfg, params, latents, tt, cond)
        return sched.ddim_step(latents, eps, t, t - 1)
    return denoise_step
