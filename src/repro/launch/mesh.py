"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first backend init --
the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(model: int = 2):
    """Tiny mesh for CI-scale sharding tests (requires >=4 host devices)."""
    n = len(jax.devices())
    data = max(n // model, 1)
    return jax.make_mesh((data, model), ("data", "model"))
