"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first backend init --
the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(model: int = 2):
    """Tiny mesh for CI-scale sharding tests (requires >=4 host devices)."""
    n = len(jax.devices())
    data = max(n // model, 1)
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(model_parallel: int = 1, devices=None):
    """(data, model) mesh for the sharded serving engine.

    ``data`` gets every device not claimed by ``model_parallel`` -- the
    serving engine spreads one micro-batch bucket over it, so bucket sizes
    should be multiples of the data-axis size (otherwise the batch stays
    replicated; see ``distributed.sharding.batch_spec``). ``devices``
    restricts the mesh to a subset (tests carve a 4-device mesh out of 8
    fake CPU devices); default is all local devices.
    """
    import numpy as np

    devices = list(jax.devices()) if devices is None else list(devices)
    n = len(devices)
    if model_parallel < 1 or n % model_parallel:
        raise ValueError(
            f"model_parallel={model_parallel} does not divide {n} devices")
    shape = (n // model_parallel, model_parallel)
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices).reshape(shape), ("data", "model"))
