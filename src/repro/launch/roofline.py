"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md Sec Roofline).

Per (arch x shape x mesh) cell, from the compiled dry-run's scan-aware HLO
analysis (launch/hlo_analysis.py):

  compute term    = HLO_FLOPs_per_device / peak_FLOPs        (197 TF bf16)
  memory term     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
  collective term = collective_bytes_per_device / link_bw    (50 GB/s/link)

The dominant term is the bottleneck; roofline fraction = compute term /
max(all terms) (how close the cell runs to compute-bound peak).
MODEL_FLOPS / (HLO_FLOPs x devices) measures how much compiled compute is
"useful" (remat / capacity-factor / padding waste shows up here).

    PYTHONPATH=src python -m repro.launch.roofline --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.perfmodel.hw import TPU_V5E


def roofline_row(rep: Dict) -> Dict:
    peak = TPU_V5E.peak_flops_bf16
    hbm = TPU_V5E.hbm_bytes_per_s
    link = TPU_V5E.ici_bytes_per_s_per_link

    t_comp = (rep["hlo_flops_per_device"] or 0) / peak
    t_mem = (rep["hlo_bytes_per_device"] or 0) / hbm
    t_coll = (rep["collective_bytes_per_device"] or 0) / link
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total_hlo = (rep["hlo_flops_per_device"] or 0) * rep["n_devices"]
    useful = rep["model_flops"] / total_hlo if total_hlo else 0.0
    frac = t_comp / bound if bound > 0 else 0.0
    return {
        "arch": rep["arch"], "shape": rep["shape"],
        "mesh": "x".join(str(m) for m in rep["mesh"]),
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": frac,
        "useful_flops_ratio": useful,
        "model_flops": rep["model_flops"],
        "hlo_flops_per_device": rep["hlo_flops_per_device"],
        "collective_gb": (rep["collective_bytes_per_device"] or 0) / 1e9,
        "compile_s": rep.get("compile_s"),
    }


_ADVICE = {
    "compute": ("drop the remat/useful-FLOPs gap (selective checkpointing) "
                "or cut padded/wasted GEMM work (MoE capacity, head padding)"),
    "memory": ("shrink the working set: bf16 carries, windowed KV "
               "(ring buffers for local layers), fuse elementwise chains"),
    "collective": ("reshard: move the all-gathered operand's axis, overlap "
                   "collectives with the layer scan, or compress payloads"),
}


def advice(row: Dict) -> str:
    return _ADVICE[row["dominant"]]


def load_rows(dir_: str, mesh: str = "") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            rep = json.load(f)
        if mesh and not path.endswith(f"_{mesh}.json"):
            continue
        rows.append(roofline_row(rep))
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | roofline frac | useful FLOPs |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                 f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
                 f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
                 f"| {r['roofline_fraction']:.2f} "
                 f"| {r['useful_flops_ratio']:.2f} |\n")
    return hdr + body


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load_rows(args.dir, args.mesh)
    if args.markdown:
        print(to_markdown(rows))
        return
    for r in rows:
        print(f"{r['arch']:18s} {r['shape']:14s} {r['mesh']:8s} "
              f"C={r['t_compute_s']:.2e} M={r['t_memory_s']:.2e} "
              f"X={r['t_collective_s']:.2e} dom={r['dominant'][:4]} "
              f"frac={r['roofline_fraction']:.2f} "
              f"useful={r['useful_flops_ratio']:.2f}")
        print(f"    -> {advice(r)}")


if __name__ == "__main__":
    main()
