"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production mesh, record memory/cost/collective analysis for the roofline.

MUST be executed as a fresh process (jax locks the device count at first
init):  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \
            --shape train_4k --mesh multi

Writes one JSON per cell to experiments/dryrun/.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
from typing import Any, Dict, Optional, Tuple   # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                     # noqa: E402
from repro.configs import shapes as shapes_lib  # noqa: E402
from repro.distributed import constraints       # noqa: E402
from repro.distributed import sharding as shd   # noqa: E402
from repro.launch import hlo_analysis           # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import transformer as tf_lib      # noqa: E402
from repro.models.common import ModelConfig          # noqa: E402
from repro.optim.adamw import OptimConfig            # noqa: E402
from repro.perfmodel import flops as flops_lib       # noqa: E402
from repro.train import steps as steps_lib           # noqa: E402

# --------------------------------------------------------------- inputs
def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(cfg: ModelConfig, shape: shapes_lib.ShapeSpec, mesh
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        extra = 1 if shape.kind == "train" else 0
        if cfg.family == "encdec":
            out["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model),
                                 jnp.float32, mesh,
                                 shd.batch_spec((b, 1, 1), mesh))
            out["tokens"] = _sds((b, s + extra), jnp.int32, mesh,
                                 shd.batch_spec((b, s), mesh))
        elif cfg.family == "vlm":
            st = s - cfg.vis_tokens
            out["vis_embeds"] = _sds((b, cfg.vis_tokens, cfg.d_model),
                                     jnp.float32, mesh,
                                     shd.batch_spec((b, 1, 1), mesh))
            out["tokens"] = _sds((b, st + extra), jnp.int32, mesh,
                                 shd.batch_spec((b, st), mesh))
        else:
            out["tokens"] = _sds((b, s + extra), jnp.int32, mesh,
                                 shd.batch_spec((b, s), mesh))
        return out
    if shape.kind == "decode":
        out["tokens"] = _sds((b, 1), jnp.int32, mesh,
                             shd.batch_spec((b, 1), mesh))
        return out
    if shape.kind in ("denoise_train", "sample"):
        ls, lc = cfg.latent_size, cfg.latent_channels
        out["latents"] = _sds((b, ls, ls, lc), jnp.float32, mesh,
                              shd.batch_spec((b, ls, ls, lc), mesh))
        if cfg.cond_tokens:
            out["text"] = _sds((b, cfg.cond_tokens, cfg.cond_dim),
                               jnp.float32, mesh,
                               shd.batch_spec((b, 1, 1), mesh))
        else:
            out["labels"] = _sds((b,), jnp.int32, mesh,
                                 shd.batch_spec((b,), mesh))
        return out
    raise ValueError(shape.kind)


def _abstract(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)


def _optim_cfg(cfg: ModelConfig) -> OptimConfig:
    kind = "adafactor" if cfg.name in ("kimi-k2-1t-a32b",) else "adamw"
    return OptimConfig(kind=kind, warmup_steps=100, total_steps=10_000)


def _state_shardings(state_abs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, P()) if not s.shape
        else None, state_abs)


def lower_cell(arch: str, shape_name: str, mesh, drift: bool = False,
               shard_act_dmodel: bool = False, opt: str = ""):
    """Build + lower + compile one (arch, shape) cell. Returns report dict.

    opt: "" (baseline) | "windowed" (ring-buffer local attention)
       | "dp_only" (replicate weights, batch over every mesh axis)
       | "moe_sharded_dispatch" (constrain MoE dispatch shardings)
    """
    cfg = configs.get_config(arch)
    shape = shapes_lib.get_shape(shape_name)
    ocfg = _optim_cfg(cfg)
    key = jax.random.PRNGKey(0)
    dp_only = opt == "dp_only"
    constraints.set_policy(constraints.MeshPolicy(
        mesh, shard_act_dmodel=shard_act_dmodel, dp_over_all=dp_only))
    t0 = time.time()

    if shape.kind in ("train", "denoise_train"):
        state_abs = jax.eval_shape(
            lambda: steps_lib.init_train_state(cfg, ocfg, key))
        if dp_only:   # replicate all weights/optimizer, pure DP
            state_sh = jax.tree.map(
                lambda _: NamedSharding(mesh, P()), state_abs)
        else:
            state_sh = shd.shardings_for(state_abs, mesh)
        batch = input_specs(cfg, shape, mesh)
        if dp_only:
            axes = tuple(mesh.axis_names)
            while axes and shape.global_batch % int(
                    np.prod([mesh.shape[a] for a in axes])):
                axes = axes[1:]
            batch = {k: jax.ShapeDtypeStruct(
                v.shape, v.dtype,
                sharding=NamedSharding(mesh, P(axes, *([None] * (len(v.shape) - 1)))))
                for k, v in batch.items()}
        micro = 8 if opt == "microbatch" else 1
        fn = steps_lib.make_train_step(cfg, ocfg, microbatches=micro)
        jfn = jax.jit(fn,
                      in_shardings=(state_sh, {k: v.sharding
                                               for k, v in batch.items()}),
                      out_shardings=(state_sh, None),
                      donate_argnums=(0,))
        lowered = jfn.lower(state_abs, batch)
        n_params = sum(x.size for x in
                       jax.tree_util.tree_leaves(state_abs.params))

    elif shape.kind == "prefill":
        params_abs = jax.eval_shape(
            lambda: steps_lib.init_model_params(cfg, key))
        params_sh = shd.shardings_for(params_abs, mesh)
        batch = input_specs(cfg, shape, mesh)
        fn = steps_lib.make_prefill_step(cfg, max_seq=shape.seq_len)
        jfn = jax.jit(fn, in_shardings=(params_sh,
                                        {k: v.sharding
                                         for k, v in batch.items()}))
        lowered = jfn.lower(params_abs, batch)
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(params_abs))

    elif shape.kind == "decode" and opt == "windowed":
        # Hillclimb #1: ring-buffer windowed decode for local/global archs
        assert tf_lib.supports_mixed_decode(cfg), cfg.name
        params_abs = jax.eval_shape(
            lambda: steps_lib.init_model_params(cfg, key))
        params_sh = shd.shardings_for(params_abs, mesh)
        b, s = shape.global_batch, shape.seq_len
        cache_abs = jax.eval_shape(
            lambda: tf_lib.init_mixed_cache(cfg, b, s))
        cache_sh = _cache_shardings(cfg, cache_abs, mesh)
        batch = input_specs(cfg, shape, mesh)
        jfn = jax.jit(lambda p, c, t: tf_lib.decode_step_mixed(cfg, p, c, t),
                      in_shardings=(params_sh, cache_sh,
                                    batch["tokens"].sharding),
                      out_shardings=(None, cache_sh),
                      donate_argnums=(1,))
        lowered = jfn.lower(params_abs, cache_abs, batch["tokens"])
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(params_abs))

    elif shape.kind == "decode":
        params_abs = jax.eval_shape(
            lambda: steps_lib.init_model_params(cfg, key))
        params_sh = shd.shardings_for(params_abs, mesh)
        b, s = shape.global_batch, shape.seq_len
        if cfg.family == "encdec":
            from repro.models import encdec as ed
            mem_abs = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                           jnp.bfloat16)
            cache_abs = jax.eval_shape(
                lambda: ed.init_decode_cache(cfg, jax.tree.map(
                    lambda x: jnp.zeros(x.shape, x.dtype), params_abs),
                    jnp.zeros(mem_abs.shape, mem_abs.dtype), s))
        else:
            cache_abs = jax.eval_shape(
                lambda: tf_lib.init_cache(cfg, b, s))
        cache_sh = _cache_shardings(cfg, cache_abs, mesh)
        batch = input_specs(cfg, shape, mesh)
        fn = steps_lib.make_decode_step(cfg)
        jfn = jax.jit(fn, in_shardings=(params_sh, cache_sh,
                                        batch["tokens"].sharding),
                      out_shardings=(None, cache_sh),
                      donate_argnums=(1,))
        lowered = jfn.lower(params_abs, cache_abs, batch["tokens"])
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(params_abs))

    elif shape.kind == "sample" and opt == "drift":
        # The paper's system at pod scale: one denoising step with INT8
        # quant + fault injection + ABFT + tile rollback on every GEMM.
        # Proves DRIFT's scale-out property: rollback stores shard like
        # activations; detection/correction are shard-local.
        from repro.core.exec_ctx import DriftSystemConfig
        from repro.diffusion import schedule as sched_lib
        from repro.models import dit as dit_lib
        import dataclasses as _dc
        params_abs = jax.eval_shape(
            lambda: steps_lib.init_model_params(cfg, key))
        params_sh = shd.shardings_for(params_abs, mesh)
        batch = input_specs(cfg, shape, mesh)
        b = shape.global_batch
        stores_abs = jax.eval_shape(
            lambda: dit_lib.drift_store_spec(cfg, b))
        dp = shd.data_axes(mesh)
        dp = dp if len(dp) > 1 else (dp[0] if dp else None)

        def store_sh(leaf):
            spec = [None] * len(leaf.shape)
            for dim, sz in enumerate(leaf.shape):
                if sz % max(shd.axis_size(mesh, "data"), 1) == 0 and \
                        dim == len(leaf.shape) - 2:
                    spec[dim] = dp
                    break
            return NamedSharding(mesh, P(*spec))
        stores_sh = jax.tree.map(store_sh, stores_abs)
        sched = sched_lib.DdpmSchedule.default(1000)
        scfg = DriftSystemConfig(mode="drift")

        def drift_step(params, latents, t, cond, embed_store, block_store):
            ds = dit_lib.DriftState(
                cfg=scfg, key=jax.random.PRNGKey(0), step=t,
                ber_by_class=jnp.array([0.0, 0.0, 3e-3], jnp.float32),
                embed_store=embed_store, block_store=block_store,
                have_ckpt=True)
            tt = jnp.full((latents.shape[0],), t, jnp.float32)
            if cfg.cond_tokens:
                eps, nds, _ = dit_lib.forward(cfg, params, latents, tt,
                                              None, text=cond, drift=ds)
            else:
                eps, nds, _ = dit_lib.forward(cfg, params, latents, tt,
                                              cond, drift=ds)
            lat2 = sched.ddim_step(latents, eps, t, t - 1)
            return lat2, nds.embed_store, nds.block_store

        cond = batch.get("text", batch.get("labels"))
        jfn = jax.jit(drift_step,
                      in_shardings=(params_sh, batch["latents"].sharding,
                                    NamedSharding(mesh, P()), cond.sharding,
                                    stores_sh[0], stores_sh[1]),
                      out_shardings=(batch["latents"].sharding,
                                     stores_sh[0], stores_sh[1]),
                      donate_argnums=(4, 5))
        lowered = jfn.lower(params_abs, batch["latents"],
                            jax.ShapeDtypeStruct((), jnp.int32), cond,
                            stores_abs[0], stores_abs[1])
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(params_abs))

    elif shape.kind == "sample":
        params_abs = jax.eval_shape(
            lambda: steps_lib.init_model_params(cfg, key))
        params_sh = shd.shardings_for(params_abs, mesh)
        batch = input_specs(cfg, shape, mesh)
        fn = steps_lib.make_denoise_step(cfg)
        cond = batch.get("text", batch.get("labels"))
        jfn = jax.jit(fn, in_shardings=(params_sh, batch["latents"].sharding,
                                        NamedSharding(mesh, P()),
                                        cond.sharding))
        lowered = jfn.lower(params_abs, batch["latents"],
                            jax.ShapeDtypeStruct((), jnp.int32), cond)
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(params_abs))
    else:
        raise ValueError(shape.kind)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {k: int(getattr(mem, k)) for k in
                 ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
                 if hasattr(mem, k)}
    except Exception as e:   # CPU backend may not support it
        mem_d = {"error": str(e)}
    # Scan-aware per-device analysis (cost_analysis counts loop bodies once)
    t1 = time.time()
    hlo_text = compiled.as_text()
    hlo = hlo_analysis.analyze(hlo_text)
    t_analyze = time.time() - t1
    hlo_dir = os.environ.get("DRYRUN_HLO_DIR")
    if hlo_dir:
        import gzip
        os.makedirs(hlo_dir, exist_ok=True)
        mesh_tag = "x".join(str(v) for v in mesh.shape.values())
        with gzip.open(os.path.join(
                hlo_dir, f"{arch}_{shape_name}_{mesh_tag}.hlo.gz"),
                "wt") as f:
            f.write(hlo_text)

    mf = flops_lib.cell_flops(cfg, shape)
    report = {
        "opt": opt,
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh.shape.values()), "axes": list(mesh.axis_names),
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "drift": drift,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "analyze_s": round(t_analyze, 2),
        "hlo_flops_per_device": hlo["flops"],
        "hlo_bytes_per_device": hlo["bytes"],
        "collective_bytes_per_device": hlo["collective_bytes"],
        "collectives": hlo["collectives"],
        "collective_ops_executed": hlo["collective_ops_executed"],
        "xla_cost_flops_body_once": cost.get("flops"),
        "xla_cost_bytes_body_once": cost.get("bytes accessed"),
        "memory_analysis": mem_d,
        "n_params": int(n_params),
        "model_flops": mf["model_flops"],
        "tokens": mf["tokens"],
    }
    return report


def _cache_shardings(cfg: ModelConfig, cache_abs, mesh):
    """NamedTuple fields flatten positionally, so dispatch on rank:
    rank-5 = KV caches (L/N, B, S|W, Hkv, hd) -> cache_spec (seq/head
    sharding with GQA fallbacks); rank-6 = SSD state -> ssm_state_spec;
    anything else -> batch-dim sharding / replicate."""
    def one(path, leaf):
        nd = len(leaf.shape)
        if nd == 5:
            return NamedSharding(mesh, shd.cache_spec(cfg, leaf.shape, mesh))
        if nd >= 4:
            return NamedSharding(mesh,
                                 shd.ssm_state_spec(cfg, leaf.shape, mesh))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(one, cache_abs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opt", default="",
                    help="optimization variant (windowed|dp_only|...)")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    os.makedirs(args.out, exist_ok=True)
    archs = configs.ALL_ARCHS if args.arch == "all" else [args.arch]
    failures = []
    for arch in archs:
        cells = (shapes_lib.cells_for(arch) if args.shape == "all"
                 else [args.shape])
        for cell in cells:
            suffix = f"_{args.opt}" if args.opt else ""
            tag = f"{arch}_{cell}_{args.mesh}{suffix}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                with mesh:
                    rep = lower_cell(arch, cell, mesh, opt=args.opt)
                with open(path, "w") as f:
                    json.dump(rep, f, indent=1)
                print(f"  ok: flops/dev={rep['hlo_flops_per_device']:.3e} "
                      f"compile={rep['compile_s']}s "
                      f"coll_ops={rep['collective_ops_executed']}", flush=True)
            except Exception as e:
                failures.append((tag, str(e)[:200]))
                print(f"  FAIL: {e}", flush=True)
    if failures:
        print("\nFAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("all cells passed")


if __name__ == "__main__":
    main()
