"""DRIFT serving launcher: batched diffusion sampling (or LM decode) under
the fine-grained DVFS schedule with rollback-ABFT protection.

    PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-512 --smoke \
        --batch 2 --steps 10 --mode drift --op undervolt

Prints per-request quality-vs-clean metrics and the perfmodel's
energy/latency accounting for the chosen operating point.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import dvfs, metrics
from repro.core.exec_ctx import DriftSystemConfig
from repro.core.rollback import RollbackConfig
from repro.diffusion import sampler as sampler_lib
from repro.diffusion.taylorseer import TaylorSeerConfig
from repro.perfmodel import energy
from repro.train import steps as steps_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-xl-512")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--mode", default="drift",
                    choices=["clean", "faulty", "drift", "thundervolt",
                             "approx_abft", "dmr", "stat_abft"])
    ap.add_argument("--op", default="undervolt",
                    choices=["nominal", "undervolt", "overclock"])
    ap.add_argument("--interval", type=int, default=10)
    ap.add_argument("--taylorseer", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    if cfg.family not in ("dit", "unet"):
        raise SystemExit("serve.py drives the diffusion archs; "
                         "use launch/train.py for LMs")
    key = jax.random.PRNGKey(args.seed)
    params = steps_lib.init_model_params(cfg, key)

    op = {"nominal": dvfs.NOMINAL, "undervolt": dvfs.UNDERVOLT,
          "overclock": dvfs.OVERCLOCK}[args.op]
    sched = dvfs.fine_grained_schedule(args.steps, op, nominal_steps=2)

    lat0 = jax.random.normal(jax.random.fold_in(key, 7),
                             (args.batch, cfg.latent_size, cfg.latent_size,
                              cfg.latent_channels))
    if cfg.cond_tokens:
        cond = None
        text = 0.1 * jax.random.normal(jax.random.fold_in(key, 8),
                                       (args.batch, cfg.cond_tokens,
                                        cfg.cond_dim))
    else:
        cond = jnp.arange(args.batch) % max(cfg.num_classes, 1)
        text = None

    def run(mode, schedule):
        scfg = sampler_lib.SamplerConfig(
            num_sample_steps=args.steps,
            drift=DriftSystemConfig(
                mode=mode, rollback=RollbackConfig(interval=args.interval)),
            schedule=schedule,
            taylorseer=TaylorSeerConfig(enabled=args.taylorseer))
        t0 = time.time()
        out = jax.jit(lambda p, l: sampler_lib.sample(
            cfg, p, key, l, cond, text, scfg))(params, lat0)
        out.latents.block_until_ready()
        return out, time.time() - t0

    clean, _ = run("clean", None)
    out, wall = run(args.mode, sched)
    img = lambda o: jnp.clip(o.latents, -1, 1)
    print(f"[serve] {cfg.name} mode={args.mode} op={args.op} "
          f"steps={args.steps} wall={wall:.1f}s")
    print(f"  lpips-proxy vs clean: "
          f"{float(metrics.lpips_proxy(img(out), img(clean))):.4f}")
    print(f"  psnr vs clean: {float(metrics.psnr(img(out), img(clean))):.2f} dB")
    print(f"  corrected elems: {int(out.total_corrected)}  "
          f"model evals: {int(out.n_model_evals)}")

    em = energy.calibrate()
    full = configs.get_config(args.arch)   # energy model uses full config
    rc = energy.RunConfig(num_steps=args.steps, aggressive=op,
                          ckpt_interval=args.interval,
                          taylorseer_interval=3 if args.taylorseer else 0,
                          recovery_tiles_per_step=float(out.total_corrected)
                          / max(args.steps, 1) / (32 * 32))
    base = energy.run_cost(full, energy.baseline_rc(args.steps), em=em)
    cost = energy.run_cost(full, rc, em=em)
    print(f"  perfmodel (full {full.name}): baseline "
          f"{base['energy_j']:.2f}J/{base['latency_s']:.3f}s -> "
          f"{cost['energy_j']:.2f}J/{cost['latency_s']:.3f}s "
          f"({100*(1-cost['energy_j']/base['energy_j']):.1f}% energy, "
          f"{base['latency_s']/cost['latency_s']:.2f}x speed)")


if __name__ == "__main__":
    main()
