"""DRIFT serving launcher: thin CLI over ``repro.serving``.

    PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-512 --smoke \
        --batch 2 --steps 10 --mode drift --op undervolt

Submits ``--requests`` generation requests (default: one bucket's worth)
to a single engine instance and prints the structured per-request results:
quality vs the engine's cached clean reference, and the perfmodel's
energy/latency attribution (``perfmodel.energy.per_request_cost``: the
bucket's cost split across live requests, so padding overhead is visible).
The engine jits each (arch, steps, mode, op, bucket, mesh) configuration
once and computes the clean reference once per (configuration, latent
seeds) batch -- repeated invocations of ``main()`` in one process reuse
both caches when given the same engine.

``--op auto`` defers each request's DVFS operating point to the engine's
BER-monitor ladder (``core.dvfs.OP_LADDER``: undervolt -> uv-mild ->
uv-safe -> near-nominal -> nominal), the Sec 5.1 feedback loop carried
across batches.

``--sharded`` spreads each micro-batch across every local device on a
(data, model) mesh (``--model-parallel`` sets the model-axis width) via
``ShardedDriftServeEngine``; with one device it degrades to the plain
engine. See docs/serving.md.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence

from repro.serving import DriftServeEngine
from repro.serving.request import REQUEST_OPS
from repro.serving.sharded import ShardedDriftServeEngine, make_engine


def build_engine(args) -> DriftServeEngine:
    common = dict(arch=args.arch, smoke=args.smoke, bucket=args.batch,
                  base_seed=args.seed)
    if args.sharded:
        return make_engine(model_parallel=args.model_parallel, **common)
    if args.model_parallel != 1:
        raise SystemExit("--model-parallel requires --sharded")
    return DriftServeEngine(**common)


def main(argv: Optional[Sequence[str]] = None,
         engine: Optional[DriftServeEngine] = None) -> list:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-xl-512")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=2,
                    help="micro-batch bucket size")
    ap.add_argument("--requests", type=int, default=0,
                    help="requests to submit (0 = one bucket's worth)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--mode", default="drift",
                    choices=["clean", "faulty", "drift", "thundervolt",
                             "approx_abft", "dmr", "stat_abft"])
    ap.add_argument("--op", default="undervolt", choices=list(REQUEST_OPS),
                    help="DVFS operating point; 'auto' walks "
                         "core.dvfs.OP_LADDER via the BER monitor")
    ap.add_argument("--interval", type=int, default=10,
                    help="rollback checkpoint-refresh interval (steps)")
    ap.add_argument("--taylorseer", action="store_true")
    ap.add_argument("--sharded", action="store_true",
                    help="shard each micro-batch across the local device "
                         "mesh (single device: plain engine)")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="mesh model-axis width for --sharded")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    eng = engine if engine is not None else build_engine(args)
    if isinstance(eng, ShardedDriftServeEngine):
        print(f"[serve] mesh {dict(eng.mesh.shape)}")
    bucket = eng.batcher.bucket        # an injected engine's bucket wins
    n_requests = args.requests or bucket
    for i in range(n_requests):
        eng.submit(arch=args.arch, smoke=args.smoke, steps=args.steps,
                   mode=args.mode, op=args.op, seed=args.seed + i,
                   taylorseer=args.taylorseer,
                   rollback_interval=args.interval)
    t0 = time.time()
    results = eng.run()
    wall = time.time() - t0

    print(f"[serve] {args.arch} mode={args.mode} op={args.op} "
          f"steps={args.steps} requests={n_requests} bucket={bucket} "
          f"wall={wall:.1f}s")
    for r in results:
        print(f"  req {r.request_id} (batch {r.batch_index}, op {r.op}): "
              f"lpips-proxy {r.lpips_vs_clean:.4f}  "
              f"psnr {r.psnr_vs_clean_db:.2f} dB  "
              f"corrected(batch) {r.batch_corrected_elems}  "
              f"evals {r.n_model_evals}")
        print(f"    perfmodel/request: baseline "
              f"{r.baseline_energy_j:.2f}J/{r.baseline_latency_s:.3f}s -> "
              f"{r.energy_j:.2f}J/{r.latency_s:.3f}s "
              f"({100 * (1 - r.energy_j / r.baseline_energy_j):.1f}% energy, "
              f"{r.baseline_latency_s / r.latency_s:.2f}x speed)")
    print(f"  engine: {eng.cache.traces} traces, {eng.cache.hits} cache "
          f"hits, {eng.stats.batches} batches, "
          f"{eng.stats.padded_slots} padded slots; monitor "
          f"ber={float(eng.monitor.ema_ber):.2e} "
          f"ladder={int(eng.monitor.op_index)}")
    return results


if __name__ == "__main__":
    main()
