"""DRIFT serving launcher: thin CLI over ``repro.serving``.

    PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-512 --smoke \
        --batch 2 --steps 10 --mode drift --op undervolt

Submits ``--requests`` generation requests (default: one bucket's worth)
to a single engine instance and prints the structured per-request results:
quality vs the engine's cached clean reference, and the perfmodel's
energy/latency attribution (``perfmodel.energy.per_request_cost``: the
bucket's cost split across live requests, so padding overhead is visible).
The engine jits each (arch, steps, mode, op, bucket, stream, mesh)
configuration once and computes the clean reference once per
(configuration, latent seeds) batch -- repeated invocations of ``main()``
in one process reuse both caches when given the same engine.

``--op auto`` defers each request's DVFS operating point to the engine's
BER-monitor ladder (``core.dvfs.OP_LADDER``), the Sec 5.1 feedback loop
carried across batches.

``--priority`` / ``--deadline`` / ``--step-budget`` route submissions
through ``serving.scheduler.DeadlineScheduler``: admission control
projects each request's completion on the engine's virtual (perfmodel)
clock and jointly picks its (operating point, step count) -- urgent
requests get overclocked or step-trimmed, hopeless ones are rejected,
background ones keep the energy-saving ladder. See docs/scheduler.md.

``--energy-budget`` / ``--quality-floor`` state a compute-optimal
objective: the scheduler resolves the request against the joint
(steps x precision x TaylorSeer x DVFS) Pareto frontier
(``serving.frontier``) and rewrites all four knobs -- min-energy meeting
the deadline, min-latency at/above the floor, or max-quality inside the
budget. See docs/frontier.md.

``--stream K`` streams each batch: a latent preview is yielded for every
live request after each K denoising steps, before the final results --
final latents are bit-identical to the unstreamed path.

``--sharded`` spreads each micro-batch across every local device on a
(data, model) mesh (``--model-parallel`` sets the model-axis width) via
``ShardedDriftServeEngine``; with one device it degrades to the plain
engine. See docs/serving.md.

``--metrics-port PORT`` serves the telemetry HTTP front-end for the run
(``/metrics`` Prometheus text, ``/healthz``, SSE ``/events``; 0 binds an
ephemeral port and prints it). ``--no-telemetry`` disables the whole
telemetry subsystem -- metrics, learned latency estimates, adaptive BER
guardband. Explicit-op workloads serve bit-identically without it;
``op=auto`` loses the guardband floor (that adaptation is the point of
the controller). See docs/telemetry.md.
"""
from __future__ import annotations

import argparse
import contextlib
import os
import time
from typing import Optional, Sequence

from repro import configs
from repro.core import dvfs as dvfs_lib
from repro.core.rollback import DEFAULT_INTERVAL
from repro.serving import (DeadlineScheduler, DriftServeEngine,
                           EngineTelemetry, OffloadConfig, PreviewEvent,
                           ShardedDriftServeEngine, make_engine,
                           serve_telemetry)
from repro.core.quant import PRECISION_PLANS
from repro.serving.request import REQUEST_OPS, REQUEST_PRIORITIES
from repro.serving.servable import PARADIGM_BY_FAMILY, paradigm_for

# Derived from code so --help can never drift out of sync with the ladder
# (tools/check_help_sync.py asserts every name appears in the help text).
OP_LADDER_HELP = " -> ".join(p.name for p in dvfs_lib.OP_LADDER)


def arch_family_help() -> str:
    """--arch help text derived from the ServableModel registry: every
    known arch grouped by serving paradigm, unsupported ones named.
    tools/check_help_sync.py asserts all of it shows up in --help."""
    by_paradigm = {}
    unsupported = []
    for arch in configs.list_archs():
        fam = configs.get_config(arch).family
        paradigm = PARADIGM_BY_FAMILY.get(fam)
        if paradigm is None:
            unsupported.append(arch)
        else:
            by_paradigm.setdefault(paradigm, []).append(arch)
    parts = [f"{p}: {', '.join(archs)}"
             for p, archs in sorted(by_paradigm.items())]
    parts.append(f"unsupported: {', '.join(unsupported)}")
    return "; ".join(parts)


def default_mode_for(arch: str) -> str:
    """Paradigm-appropriate default when --mode is omitted: the DRIFT
    denoiser protection for diffusion archs, statistical ABFT with
    KV-window rollback for autoregressive ones."""
    return "drift" if paradigm_for(arch) == "diffusion" else "stat_abft"


def rollback_interval_arg(value: str):
    """--rollback-interval parser: a positive int or 'auto' (the offload
    planner picks per configuration)."""
    if value.strip().lower() == "auto":
        return "auto"
    iv = int(value)
    if iv < 1:
        raise argparse.ArgumentTypeError(
            f"rollback interval must be >= 1 or 'auto', got {value}")
    return iv


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve",
        description="Serve DRIFT diffusion requests through one "
                    "continuous-batching engine.",
        epilog=f"DVFS ladder (op 'auto', walked by the BER monitor): "
               f"{OP_LADDER_HELP}. Scheduling (--priority/--deadline/"
               f"--step-budget) and streaming (--stream) are documented in "
               f"docs/scheduler.md.")
    ap.add_argument("--arch", default="dit-xl-512",
                    help="model to serve; the engine picks the paradigm "
                         "from the ServableModel registry -- "
                         f"{arch_family_help()}")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=2,
                    help="micro-batch bucket size")
    ap.add_argument("--requests", type=int, default=0,
                    help="requests to submit (0 = one bucket's worth)")
    ap.add_argument("--steps", type=int, default=10,
                    help="denoising steps (diffusion) or tokens to decode "
                         "(autoregressive)")
    ap.add_argument("--mode", default=None,
                    choices=["clean", "faulty", "drift", "thundervolt",
                             "approx_abft", "dmr", "stat_abft"],
                    help="protection mode (default: 'drift' for diffusion "
                         "archs, 'stat_abft' for autoregressive ones; AR "
                         "serving accepts clean/faulty/stat_abft only)")
    ap.add_argument("--op", default="undervolt", choices=list(REQUEST_OPS),
                    help="DVFS operating point; 'auto' walks the BER-monitor "
                         f"ladder core.dvfs.OP_LADDER ({OP_LADDER_HELP})")
    ap.add_argument("--rollback-interval", "--interval",
                    type=rollback_interval_arg, default=DEFAULT_INTERVAL,
                    metavar="N|auto", dest="rollback_interval",
                    help="rollback checkpoint-refresh interval in steps "
                         f"(default: {DEFAULT_INTERVAL}, from "
                         "core.rollback.DEFAULT_INTERVAL); 'auto' lets the "
                         "offload planner pick per (arch, op, steps, "
                         "bucket) from modeled energy+stall and the "
                         "telemetry detection history")
    ap.add_argument("--offload", action="store_true",
                    help="offload rollback checkpoints to a host-side "
                         "double buffer asynchronously, overlapped with "
                         "the next denoising window (tile-contiguous "
                         "layout; finals stay bit-identical -- see "
                         "docs/offload.md)")
    ap.add_argument("--taylorseer", action="store_true")
    ap.add_argument("--precision", default="int8",
                    choices=sorted(PRECISION_PLANS),
                    help="precision plan for the resilient denoiser body "
                         "(core.quant.PRECISION_PLANS); 'int8' is the "
                         "baseline path bit for bit. Usually left to the "
                         "frontier (--energy-budget/--quality-floor) but "
                         "requestable directly like --op")
    ap.add_argument("--priority", default="standard",
                    choices=list(REQUEST_PRIORITIES),
                    help="scheduling class for all submitted requests; "
                         "interactive buckets form before standard before "
                         "background")
    ap.add_argument("--deadline", type=float, default=None, metavar="SEC",
                    help="per-request relative deadline in engine virtual "
                         "(perfmodel) seconds; enables deadline-aware "
                         "admission control -- requests get overclocked or "
                         "step-trimmed to fit, or rejected when hopeless")
    ap.add_argument("--step-budget", type=int, default=None, metavar="N",
                    help="cap denoising steps per request (DiffPro-style "
                         "quality/latency knob; the scheduler may trim "
                         "further for a deadline)")
    ap.add_argument("--energy-budget", type=float, default=None,
                    metavar="J",
                    help="per-request energy budget in Joules (perfmodel "
                         "attribution); routes admission through the "
                         "compute-optimal (steps x precision x TaylorSeer "
                         "x DVFS) frontier -- min-energy meeting the "
                         "deadline, or max-quality inside the budget "
                         "without one (docs/frontier.md)")
    ap.add_argument("--quality-floor", type=float, default=None,
                    metavar="Q",
                    help="minimum acceptable quality proxy in (0, 1] "
                         "(1.0 = as-requested fidelity); frontier "
                         "admission picks the fastest point at or above "
                         "the floor (docs/frontier.md)")
    ap.add_argument("--stream", type=int, default=0, metavar="K",
                    help="stream a latent preview every K denoising steps "
                         "(0 = off); final latents are bit-identical to "
                         "the unstreamed path")
    ap.add_argument("--sharded", action="store_true",
                    help="shard each micro-batch across the local device "
                         "mesh (single device: plain engine)")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="mesh model-axis width for --sharded")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve the telemetry HTTP front-end (/metrics "
                         "Prometheus text, /healthz, SSE /events) on this "
                         "port for the duration of the run (0 = ephemeral, "
                         "printed at startup; omit = no server)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="write the engine's flight-recorder ring buffer "
                         "as Chrome/Perfetto trace-event JSON to "
                         "DIR/flight.json after the drain (load it at "
                         "ui.perfetto.dev; see docs/tracing.md)")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable the telemetry subsystem (metrics, learned "
                         "latency estimates, adaptive BER guardband); "
                         "explicit-op serving is bit-identical, op=auto "
                         "loses the guardband floor")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def build_engine(args) -> DriftServeEngine:
    common = dict(arch=args.arch, smoke=args.smoke, bucket=args.batch,
                  base_seed=args.seed,
                  telemetry=EngineTelemetry(enabled=not args.no_telemetry),
                  offload=OffloadConfig() if args.offload else None)
    if args.sharded:
        return make_engine(model_parallel=args.model_parallel, **common)
    if args.model_parallel != 1:
        raise SystemExit("--model-parallel requires --sharded")
    return DriftServeEngine(**common)


def main(argv: Optional[Sequence[str]] = None,
         engine: Optional[DriftServeEngine] = None) -> list:
    args = build_parser().parse_args(argv)

    eng = engine if engine is not None else build_engine(args)
    if isinstance(eng, ShardedDriftServeEngine):
        print(f"[serve] mesh {dict(eng.mesh.shape)}")
    bucket = eng.batcher.bucket        # an injected engine's bucket wins
    n_requests = args.requests or bucket

    server = None
    if args.metrics_port is not None:
        server = serve_telemetry(eng, port=args.metrics_port)
        print(f"[serve] telemetry at {server.url} "
              f"(/metrics /healthz /events)")
    try:
        return _drive(args, eng, server, n_requests, bucket)
    finally:
        # main() is also called in-process: never leak the bound port /
        # server thread when the drain raises
        if server is not None:
            server.close()


def _drive(args, eng, server, n_requests: int, bucket: int) -> list:
    use_scheduler = (args.deadline is not None
                     or args.priority != "standard"
                     or args.step_budget is not None
                     or args.energy_budget is not None
                     or args.quality_floor is not None)
    sched = DeadlineScheduler(eng) if use_scheduler else None
    mode = args.mode if args.mode is not None else default_mode_for(args.arch)
    fields = dict(arch=args.arch, smoke=args.smoke, steps=args.steps,
                  mode=mode, op=args.op, taylorseer=args.taylorseer,
                  precision=args.precision,
                  rollback_interval=args.rollback_interval)
    # Hold the server's engine lock from first submission through the
    # drain: a concurrent /events client gets a clean 503 instead of
    # interleaving batches -- or stealing the just-submitted queue.
    drain_lock = server.engine_lock if server is not None \
        else contextlib.nullcontext()
    with drain_lock:
        for i in range(n_requests):
            if sched is not None:
                adm = sched.submit(seed=args.seed + i,
                                   priority=args.priority,
                                   deadline_s=args.deadline,
                                   step_budget=args.step_budget,
                                   energy_budget_j=args.energy_budget,
                                   quality_floor=args.quality_floor,
                                   **fields)
                knobs = f"op {adm.op}, {adm.steps} steps"
                if adm.action == "frontier":
                    knobs += (f", {adm.precision}, taylorseer "
                              f"{'on' if adm.taylorseer else 'off'}, "
                              f"quality {adm.quality:.3f}, "
                              f"{adm.projected_energy_j:.2f}J projected")
                print(f"[admission] req {adm.request_id}: {adm.action} "
                      f"({knobs})"
                      + (f" -- {adm.reason}" if adm.reason else ""))
            else:
                eng.submit(seed=args.seed + i, **fields)

        t0 = time.time()
        results = []
        previews = 0
        if args.stream:
            for ev in eng.run_stream(args.stream):
                if isinstance(ev, PreviewEvent):
                    previews += 1
                    print(f"  [preview] req {ev.request_id} step "
                          f"{ev.step}/{ev.total_steps}")
                else:
                    results.append(ev)
            results.sort(key=lambda r: r.request_id)
        else:
            results = eng.run()
    wall = time.time() - t0

    print(f"[serve] {args.arch} mode={mode} op={args.op} "
          f"steps={args.steps} requests={n_requests} bucket={bucket} "
          f"wall={wall:.1f}s"
          + (f" previews={previews}" if args.stream else ""))
    for r in results:
        miss = "  DEADLINE MISSED" if r.deadline_missed else ""
        if r.tokens is not None:
            print(f"  req {r.request_id} (batch {r.batch_index}, op {r.op}, "
                  f"{r.priority}): {len(r.tokens)} tokens  "
                  f"match-vs-clean {r.token_match_vs_clean:.3f}  "
                  f"abft-detections {r.ar_detections}  "
                  f"kv-rollbacks {r.ar_rollbacks}  "
                  f"evals {r.n_model_evals}{miss}")
        else:
            print(f"  req {r.request_id} (batch {r.batch_index}, op {r.op}, "
                  f"{r.priority}): "
                  f"lpips-proxy {r.lpips_vs_clean:.4f}  "
                  f"psnr {r.psnr_vs_clean_db:.2f} dB  "
                  f"corrected(batch) {r.batch_corrected_elems}  "
                  f"evals {r.n_model_evals}{miss}")
        print(f"    perfmodel/request: baseline "
              f"{r.baseline_energy_j:.2f}J/{r.baseline_latency_s:.3f}s -> "
              f"{r.energy_j:.2f}J/{r.latency_s:.3f}s "
              f"({100 * (1 - r.energy_j / r.baseline_energy_j):.1f}% energy, "
              f"{r.baseline_latency_s / r.latency_s:.2f}x speed)")
    print(f"  engine: {eng.cache.traces} traces, {eng.cache.hits} cache "
          f"hits, {eng.stats.batches} batches, "
          f"{eng.stats.padded_slots} padded slots; monitor "
          f"ber={float(eng.monitor.ema_ber):.2e} "
          f"ladder={int(eng.monitor.op_index)}; clock {eng.clock_s:.3f}s, "
          f"{eng.stats.deadline_misses} deadline misses")
    if eng.offload_store is not None:
        ost = eng.offload_store.stats
        print(f"  offload: {ost.commits} commits "
              f"({ost.bytes_offloaded / 1e6:.2f} MB tile-contiguous), "
              f"{ost.skipped} spike-skipped, {ost.restores} restores; "
              f"last committed step {eng.offload_store.committed_step}")
    if sched is not None:
        s = sched.stats
        print(f"  scheduler: {s.admitted}/{s.submitted} admitted "
              f"({s.rejected} rejected, {s.escalated_op} op-escalated, "
              f"{s.trimmed_steps} step-trimmed, {s.frontier_selected} "
              f"frontier-selected, {s.projected_misses} projected misses)")
    tele = eng.telemetry
    if tele.enabled:
        ctrl = tele.controller
        print(f"  telemetry: {tele.estimator.total_observations} latency "
              f"observations over {len(tele.estimator)} configs; guardband "
              f"floor {ctrl.guard_index if ctrl else 0} "
              f"({ctrl.guard_op_name() if ctrl else 'n/a'})")
        if tele.ledger is not None and tele.ledger.batches:
            top = sorted(tele.ledger.shares().items(),
                         key=lambda kv: -kv[1])[:3]
            burning = tele.slo.breached_objectives()
            print(f"  energy: {tele.ledger.energy_per_request_j():.2f} "
                  f"J/request ("
                  + ", ".join(f"{c} {s:.0%}" for c, s in top)
                  + "); slo breached: "
                  + (", ".join(burning) if burning else "none")
                  + (f" -- GET {server.url}/slo" if server is not None
                     else ""))
    if args.trace_dir is not None:
        from repro.serving.trace import write_chrome_trace
        os.makedirs(args.trace_dir, exist_ok=True)
        path = os.path.join(args.trace_dir, "flight.json")
        write_chrome_trace(path, eng.tracer.spans())
        print(f"  trace: {len(eng.tracer)} spans -> {path} "
              f"(ui.perfetto.dev)")
    return results


if __name__ == "__main__":
    main()
