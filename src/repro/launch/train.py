"""Production training launcher: sharded train loop with fault-tolerant
checkpointing, auto-resume, elastic mesh planning and straggler hooks.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b \
        --steps 200 --global-batch 8 --seq 128 --ckpt-dir /tmp/run1

On this CPU container it runs real steps on the 1-device mesh (smoke scale);
on a TPU slice the same script shards over the full (pod, data, model) mesh
-- the mesh is planned from the visible device count (distributed/elastic).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.data import synthetic
from repro.distributed import constraints, elastic
from repro.distributed import sharding as shd
from repro.optim.adamw import OptimConfig
from repro.train import steps as steps_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    n_dev = len(jax.devices())
    mesh_shape, axes = elastic.plan_mesh(n_dev, args.model_parallel)
    mesh = jax.make_mesh(mesh_shape, axes)
    print(f"[train] {cfg.name} on mesh {dict(zip(axes, mesh_shape))}")
    if n_dev > 1:
        constraints.set_policy(constraints.MeshPolicy(mesh))

    ocfg = OptimConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                       total_steps=args.steps)
    dcfg = synthetic.for_model(cfg, args.global_batch, args.seq)
    train_step = steps_lib.make_train_step(cfg, ocfg)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    state = steps_lib.init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    start = 0
    if mgr is not None:
        got = mgr.restore_latest(state)
        if got is not None:
            start, state, extra = got
            print(f"[train] resumed from step {start}")

    with mesh:
        state_sh = shd.shardings_for(state, mesh)
        state = jax.tree.map(jax.device_put, state, state_sh)
        jstep = jax.jit(train_step, donate_argnums=(0,))
        t0 = time.time()
        for step in range(start, args.steps):
            batch = synthetic.batch_at(dcfg, step)
            state, metrics = jstep(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"({(time.time()-t0)/max(step-start+1,1):.2f}s/step)",
                      flush=True)
            if mgr is not None and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, state, extra={"data_step": step + 1})
                print(f"[ckpt] saved step {step+1}")
    print("[train] done")


if __name__ == "__main__":
    main()
