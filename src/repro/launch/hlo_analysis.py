"""Scan-aware HLO analysis: FLOPs / bytes / collective traffic with loop
trip-count attribution.

``compiled.cost_analysis()`` counts a while-loop body ONCE (verified: an
8-iteration scan of 128^3 matmuls reports 4.19e6 flops, not 3.36e7), so for
scan-over-layers models it undercounts by ~n_layers x. This module parses
the post-SPMD HLO text into computations, builds a global symbol table
(op name -> result type; operand types are not inline in compiled HLO),
detects each while loop's trip count from its condition's comparison
constant, propagates multipliers through the call graph (while bodies x
trip, fusion/call/reduce subcomputations x parent, conditional branches x
parent -- both branches counted, i.e. lax.cond upper bound), and sums:

  * flops            -- 2*N_out*K per dot; convs via output x kernel volume
  * bytes            -- per top-level op: operand + result bytes (fusion
                        internals excluded => approximates fused traffic)
  * collective bytes -- result-shape bytes per all-reduce / all-gather /
                        reduce-scatter / all-to-all / collective-permute

All numbers are PER DEVICE (the SPMD-partitioned module is per-device).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)$")


def _shape_dims(types: str) -> List[Tuple[str, List[int]]]:
    return [(m.group(1), [int(d) for d in m.group(2).split(",") if d])
            for m in _SHAPE_RE.finditer(types)]


def _bytes_of(types: str) -> int:
    total = 0
    for dt, dims in _shape_dims(types):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    result_types: str
    kind: str
    rest: str            # operands + attributes (everything after '(')


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool = False
    ops: List[Op] = dataclasses.field(default_factory=list)
    whiles: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    calls: List[str] = dataclasses.field(default_factory=list)
    fusion_subs: List[str] = dataclasses.field(default_factory=list)


def parse_computations(hlo: str
                       ) -> Tuple[Dict[str, Computation], Dict[str, str], str]:
    comps: Dict[str, Computation] = {}
    symtab: Dict[str, str] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        s = line.strip()
        hdr = _COMP_HDR.match(s)
        if hdr and "->" in s:
            cur = Computation(hdr.group(2), is_entry=bool(hdr.group(1)))
            comps[cur.name] = cur
            if cur.is_entry:
                entry = cur.name
            continue
        if cur is None:
            continue
        if s == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, res, kind, rest = m.groups()
        op = Op(name, res.strip(), kind, rest)
        cur.ops.append(op)
        symtab[name] = res.strip()
        if kind == "while":
            body = re.search(r"body=%?([\w.\-]+)", rest)
            cond = re.search(r"condition=%?([\w.\-]+)", rest)
            known = re.search(r'known_trip_count.....n.:.(\d+)', rest)
            if body and cond:
                cur.whiles.append((body.group(1), cond.group(1),
                                   int(known.group(1)) if known else 0))
        for cm in re.finditer(
                r"(?:calls|to_apply)=%?([\w.\-]+)", rest):
            target = cm.group(1)
            cur.calls.append(target)
            if kind == "fusion":
                cur.fusion_subs.append(target)
        bm = re.search(r"branch_computations=\{([^}]*)\}", rest)
        if bm:
            for b in re.split(r",\s*", bm.group(1)):
                cur.calls.append(b.strip().lstrip("%"))
        for key in ("true_computation", "false_computation"):
            km = re.search(key + r"=%?([\w.\-]+)", rest)
            if km:
                cur.calls.append(km.group(1))
    return comps, symtab, entry


def trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Loop bound from the condition computation's constants."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            m = re.match(r"(\d+)\)?", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _multipliers(comps: Dict[str, Computation], entry: str
                 ) -> Tuple[Dict[str, float], set]:
    mult: Dict[str, float] = {entry: 1.0}
    fusion_subs: set = set()
    stack = [entry]
    visited = set()
    while stack:
        name = stack.pop()
        comp = comps.get(name)
        if comp is None:
            continue
        key = (name, mult.get(name, 1.0))
        if key in visited:
            continue
        visited.add(key)
        m = mult.get(name, 1.0)
        for body, cond, known in comp.whiles:
            t = known if known > 0 else trip_count(comps, cond)
            for c in (body, cond):
                if m * t > mult.get(c, 0.0):
                    mult[c] = m * t
                    stack.append(c)
        for callee in comp.calls:
            if m > mult.get(callee, 0.0):
                mult[callee] = m
                stack.append(callee)
        fusion_subs.update(comp.fusion_subs)
    return mult, fusion_subs


def _operand_names(rest: str) -> List[str]:
    """Names inside the top-level parens of 'a, %b), attrs...'."""
    depth = 1
    out = []
    cur = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        cur += ch
    for m in re.finditer(r"%([\w.\-]+)", cur):
        out.append(m.group(1))
    return out


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    opnds = _operand_names(op.rest)
    if not opnds:
        return 0.0
    lhs_t = symtab.get(opnds[0], "")
    lhs = _shape_dims(lhs_t)
    out = _shape_dims(op.result_types)
    if not lhs or not out:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    k = 1
    if m:
        for d in m.group(1).split(","):
            if d:
                k *= lhs[0][1][int(d)]
    n_out = 1
    for d in out[0][1]:
        n_out *= d
    return 2.0 * n_out * k


def _conv_flops(op: Op, symtab: Dict[str, str]) -> float:
    opnds = _operand_names(op.rest)
    out = _shape_dims(op.result_types)
    if len(opnds) < 2 or not out:
        return 0.0
    kern = _shape_dims(symtab.get(opnds[1], ""))
    if not kern:
        return 0.0
    n_out = 1
    for d in out[0][1]:
        n_out *= d
    vol = 1
    for d in kern[0][1]:
        vol *= d
    feat = kern[0][1][-1] if kern[0][1] else 1
    groups = 1
    g = re.search(r"feature_group_count=(\d+)", op.rest)
    if g:
        groups = int(g.group(1))
    return 2.0 * n_out * max(vol // max(feat, 1), 1) / 1.0 / max(groups, 1) \
        * max(groups, 1) / max(groups, 1)


# Byte accounting approximates TPU behaviour where elementwise chains fuse
# into neighbours (CPU HLO leaves them as separate wrapped fusions, which
# would overcount HBM traffic ~10x). We charge only ops that genuinely
# touch HBM-resident tensors:
#   dot/conv          operands + result
#   gather/dyn-slice  result (the read volume; MoE dispatch, embed lookup)
#   dyn-update-slice  update operand only (in-place on TPU; the big buffer
#                     read is charged by its consumer dot)
#   scatter           updates + result write
#   reduce/sort/copy/transpose/concatenate  read + write once
_BYTES_FULL = {"dot", "convolution"}
_BYTES_RESULT = {"gather", "dynamic-slice"}
_BYTES_RW = {"copy", "transpose", "concatenate", "sort", "reverse", "pad"}


def analyze(hlo: str) -> Dict[str, float]:
    comps, symtab, entry = parse_computations(hlo)
    if not entry:
        entry = next(iter(comps), "")
    mult, fusion_subs = _multipliers(comps, entry)

    flops = 0.0
    bytes_ = 0.0
    coll: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_KINDS}
    n_coll = 0.0
    for name, comp in comps.items():
        m = mult.get(name)
        if m is None:
            continue          # unreachable computation
        for op in comp.ops:
            if op.kind == "dot":
                flops += m * _dot_flops(op, symtab)
            elif op.kind == "convolution":
                flops += m * _conv_flops(op, symtab)
            if op.kind in COLLECTIVE_KINDS or \
               op.kind.rstrip("-start") in COLLECTIVE_KINDS:
                kind = op.kind.replace("-start", "")
                if kind in COLLECTIVE_KINDS:
                    b = _bytes_of(op.result_types)
                    coll[kind] += m * b
                    n_coll += m
            opnds = None
            if op.kind in _BYTES_FULL:
                opnds = _operand_names(op.rest)
                b = _bytes_of(op.result_types)
                for o in opnds:
                    b += _bytes_of(symtab.get(o, ""))
                bytes_ += m * b
            elif op.kind in _BYTES_RESULT:
                bytes_ += m * _bytes_of(op.result_types)
            elif op.kind == "dynamic-update-slice":
                opnds = _operand_names(op.rest)
                if len(opnds) >= 2:
                    bytes_ += m * _bytes_of(symtab.get(opnds[1], ""))
            elif op.kind == "scatter":
                opnds = _operand_names(op.rest)
                b = _bytes_of(op.result_types)
                if len(opnds) >= 3:
                    b += _bytes_of(symtab.get(opnds[2], ""))
                bytes_ += m * b
            elif op.kind in _BYTES_RW:
                bytes_ += m * 2 * _bytes_of(op.result_types)
            elif op.kind == "reduce":
                opnds = _operand_names(op.rest)
                b = _bytes_of(op.result_types)
                if opnds:
                    b += _bytes_of(symtab.get(opnds[0], ""))
                bytes_ += m * b
    return {
        "flops": flops,
        "bytes": bytes_,
        "collective_bytes": sum(coll.values()),
        "collectives": coll,
        "collective_ops_executed": n_coll,
    }


def top_ops(hlo: str, n: int = 15, kinds=("dot", "convolution")
            ) -> List[Tuple[float, float, str, str]]:
    """Debug: (total_flops, multiplier, result_type, op_name) heaviest ops."""
    comps, symtab, entry = parse_computations(hlo)
    mult, _ = _multipliers(comps, entry or next(iter(comps), ""))
    rows = []
    for name, comp in comps.items():
        m = mult.get(name)
        if m is None:
            continue
        for op in comp.ops:
            if op.kind not in kinds:
                continue
            f = (_dot_flops(op, symtab) if op.kind == "dot"
                 else _conv_flops(op, symtab))
            rows.append((m * f, m, op.result_types, f"{name}/{op.name}"))
    rows.sort(reverse=True)
    return rows[:n]


def top_collectives(hlo: str, n: int = 15
                    ) -> List[Tuple[float, float, str, str]]:
    comps, symtab, entry = parse_computations(hlo)
    mult, _ = _multipliers(comps, entry or next(iter(comps), ""))
    rows = []
    for name, comp in comps.items():
        m = mult.get(name)
        if m is None:
            continue
        for op in comp.ops:
            kind = op.kind.replace("-start", "")
            if kind in COLLECTIVE_KINDS:
                rows.append((m * _bytes_of(op.result_types), m,
                             op.result_types[:60], f"{kind}:{name}/{op.name}"))
    rows.sort(reverse=True)
    return rows[:n]
