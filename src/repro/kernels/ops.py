"""Jit'd wrappers: the kernel-backed DRIFT GEMM pipeline.

``drift_gemm``: quantize -> fused faulty-ABFT GEMM (Pallas) -> dequantize ->
rollback correction (Pallas). Pure function of (x, w, ckpt, key, ber);
this is the path ExecContext(backend="pallas") dispatches to, and the unit
the kernel tests sweep against the ref.py oracles.

On CPU (this container) the kernels run with interpret=True; on TPU the same
code path compiles to Mosaic. ``interpret`` defaults to True when no TPU is
present.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fault, quant
from repro.kernels import abft_matmul as _abft
from repro.kernels import rollback_correct as _rc


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad2(x: jax.Array, bm: int, bn: int) -> jax.Array:
    m, n = x.shape
    return jnp.pad(x, ((0, (-m) % bm), (0, (-n) % bn)))


class DriftGemmOut(NamedTuple):
    y: jax.Array               # (M, N) f32 corrected output
    n_flagged_tiles: jax.Array  # scalar int32
    row_diff: jax.Array        # (Mp, Ntp) int32 (padded grid)
    col_diff: jax.Array        # (Mtp, Np) int32


@functools.partial(jax.jit,
                   static_argnames=("threshold_bit", "bm", "bn", "bk",
                                    "union", "interpret"))
def drift_gemm(x: jax.Array, w: jax.Array, ckpt: Optional[jax.Array],
               key: jax.Array, ber: jax.Array,
               threshold_bit: int = 10,
               bm: int = 128, bn: int = 128, bk: int = 128,
               union: bool = True,
               interpret: Optional[bool] = None) -> DriftGemmOut:
    """Kernel-backed DRIFT-protected GEMM: x (M,K) f32 @ w (K,N) f32."""
    if interpret is None:
        interpret = _default_interpret()
    m, k = x.shape
    n = w.shape[1]

    xq = quant.quantize(x, axis=None)
    wq = quant.quantize(w, axis=1)
    aq = _pad2(xq.q, bm, bk)
    bq = _pad2(wq.q, bk, bn)
    mp, kp = aq.shape
    np_ = bq.shape[1]

    # Functional DVFS error injection: per-element uint32 xor masks.
    kf, kb = jax.random.split(key)
    p = fault.word_flip_prob(ber)
    flip = jax.random.uniform(kf, (mp, np_)) < p
    pos = jax.random.randint(kb, (mp, np_), 0, 32, dtype=jnp.uint32)
    flips = jnp.where(flip, jnp.left_shift(jnp.uint32(1), pos), jnp.uint32(0))

    c, act_row, exp_row, act_col, exp_col = _abft.abft_matmul(
        aq, bq, flips, bm=bm, bn=bn, bk=bk, interpret=interpret)

    row_diff = act_row - exp_row          # (Mp, Nt)
    col_diff = act_col - exp_col          # (Mt, Np)

    w_scale = wq.scale.reshape(1, -1)
    y_faulty = (c[:m, :n].astype(jnp.float32) * xq.scale * w_scale)
    y_faulty_p = _pad2(y_faulty, bm, bn)
    ckpt_p = (_pad2(ckpt, bm, bn) if ckpt is not None
              else jnp.zeros_like(y_faulty_p))

    corrected, tile_flag = _rc.rollback_correct(
        y_faulty_p, ckpt_p, row_diff, col_diff,
        threshold=1 << threshold_bit, bm=bm, bn=bn, union=union,
        interpret=interpret)
    return DriftGemmOut(corrected[:m, :n], jnp.sum(tile_flag),
                        row_diff, col_diff)
