"""Pallas kernel: standalone bit-flip injection on int32/f32 tiles.

Used when faults must be injected into tensors that do not flow through the
fused ABFT GEMM (e.g. the f32 path of un-quantized layers in
characterization sweeps). Elementwise xor; flip masks are generated
functionally outside (core/fault.py) so injection stays reproducible.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, flip_ref, o_ref):
    bits = jax.lax.bitcast_convert_type(x_ref[...], jnp.uint32)
    o_ref[...] = jax.lax.bitcast_convert_type(
        jax.lax.bitwise_xor(bits, flip_ref[...]), x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def fault_inject(x: jax.Array, flips: jax.Array,
                 bm: int = 128, bn: int = 128,
                 interpret: bool = False) -> jax.Array:
    """x: (M, N) int32 or f32; flips: (M, N) uint32 xor mask."""
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, flips)
