"""Statistical ABFT for float GEMMs (ReaLM-style) + quantized backend.

The classic ABFT checksum test (``kernels/abft_matmul.py``) is exact: the
INT32 row/column sums of the quantized GEMM either match or they don't.
Float GEMMs break that -- the checksum lane accumulates in a different
order than the MXU tiles, so the residual

    r_i = sum_j y[i, j]  -  x_i . (W @ 1)

is nonzero even for a fault-free multiply, with magnitude set by the
rounding noise of the accumulation. ReaLM's observation (PAPERS.md) is
that this is a feature, not a bug: LLM decoding tolerates small numerical
perturbations, so detection only needs to fire for faults whose magnitude
*exceeds* the rounding envelope -- a **statistical** threshold calibrated
from the operands, not an exact test.

This module provides:

  * ``threshold(x, w)`` -- per-row detection threshold
    ``tau_i = alpha * eps * K * (|x_i| . rowsum|W|) + floor``: the standard
    forward-error envelope ``gamma_K * |x||W|`` of K-term accumulation,
    with ``eps`` the unit roundoff of the *accumulation* dtype and
    ``alpha`` a safety factor soaking up order-of-summation variance.
  * ``residuals(x, w, y)`` -- checksum residual of a (possibly faulty)
    product ``y`` against the rank-1 checksum of ``(x, w)``.
  * ``detect(x, w, y)`` -- per-row boolean ``|r_i| > tau_i``. A single
    bit flip of magnitude ``delta`` in ``y`` shifts exactly one residual
    by ``delta``, so flips above the envelope (exponent / high-mantissa
    bits -- the ones that damage decoding) are caught and low-mantissa
    noise sails through undetected, by design.
  * ``stat_abft_matmul(aq, bq, flips, threshold_mag)`` -- the quantized
    backend: wraps the fused Pallas ``abft_matmul`` kernel and applies the
    same magnitude-thresholding to its INT32 row-checksum residuals, for
    callers already on the int8 path (tile-aligned shapes only; the float
    path above is what the decode loop uses, since (batch, 1, d) decode
    GEMMs never tile-align).

All checksum math runs in float32 regardless of the operand dtype; the
threshold uses the coarser of the operand dtypes' unit roundoffs, so bf16
inputs get a bf16-sized envelope.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

#: safety factor on the rounding envelope: the gamma_K bound assumes
#: worst-case error alignment PER TERM but we compare one summed residual;
#: 4x absorbs order-of-summation variance across backends at a measured
#: false-positive rate of ~0 (tests/test_stat_abft.py pins this).
ALPHA = 4.0

#: absolute floor so all-zero (or denormal) rows don't get tau == 0 and
#: flag their own rounding dust.
TAU_FLOOR = 1e-6


def unit_roundoff(dtype) -> float:
    """Unit roundoff of a float dtype (bf16: 2^-9, f32: 2^-24, ...)."""
    return float(jnp.finfo(jnp.dtype(dtype)).eps) / 2.0


def _eps_for(x: jax.Array, w: jax.Array) -> float:
    return max(unit_roundoff(x.dtype), unit_roundoff(w.dtype))


def threshold(x: jax.Array, w: jax.Array) -> jax.Array:
    """Per-row detection threshold tau, shape = x.shape[:-1].

    x: (..., K) activations, w: (K, N) weights.
    """
    k = x.shape[-1]
    eps = _eps_for(x, w)
    absw_rowsum = jnp.sum(jnp.abs(w.astype(jnp.float32)), axis=-1)  # (K,)
    envelope = jnp.abs(x.astype(jnp.float32)) @ absw_rowsum         # (...,)
    return ALPHA * eps * float(k) * envelope + TAU_FLOOR


def residuals(x: jax.Array, w: jax.Array, y: jax.Array) -> jax.Array:
    """Checksum residual r_i = sum_j y_ij - x_i . (W @ 1), shape (...,)."""
    w_colsum = jnp.sum(w.astype(jnp.float32), axis=-1)              # (K,)
    expected = x.astype(jnp.float32) @ w_colsum                     # (...,)
    actual = jnp.sum(y.astype(jnp.float32), axis=-1)                # (...,)
    return actual - expected


def detect(x: jax.Array, w: jax.Array, y: jax.Array) -> jax.Array:
    """Per-row fault flags: |residual| above the statistical threshold."""
    return jnp.abs(residuals(x, w, y)) > threshold(x, w)


def min_detectable_magnitude(x: jax.Array, w: jax.Array) -> jax.Array:
    """Smallest per-row |delta| a single corrupted element must carry to be
    detected no matter where the clean residual sits inside the envelope:
    delta > 2*tau (the clean residual can sit at -tau while the threshold
    test needs |r + delta| > tau). Used by the property tests to pick
    provably-detectable injections."""
    return 2.0 * threshold(x, w)


def stat_abft_matmul(aq: jax.Array, bq: jax.Array, flips: jax.Array,
                     threshold_mag: int,
                     bm: int = 128, bn: int = 128, bk: int = 128,
                     interpret: bool = False
                     ) -> Tuple[jax.Array, jax.Array]:
    """Quantized statistical ABFT via the fused Pallas checksum kernel.

    Runs ``kernels.abft_matmul.abft_matmul`` (int8 GEMM + fused faulty
    row/col checksums) and flags row-tiles whose INT32 row-checksum
    residual magnitude exceeds ``threshold_mag`` -- the integer analogue
    of the float envelope: exact ABFT is ``threshold_mag == 0``; a
    positive threshold ignores low-bit flips the quantized network
    tolerates anyway (ReaLM's magnitude cutoff).

    Returns ``(c_faulty (M, N) int32, detected_rows (M, n_tiles) bool)``.
    Shapes must tile-align (M % bm == N % bn == K % bk == 0).
    """
    from repro.kernels.abft_matmul import abft_matmul
    c_faulty, act_row, exp_row, _, _ = abft_matmul(
        aq, bq, flips, bm=bm, bn=bn, bk=bk, interpret=interpret)
    resid = jnp.abs(act_row - exp_row)
    return c_faulty, resid > jnp.int32(threshold_mag)
