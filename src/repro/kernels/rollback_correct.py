"""Pallas TPU kernel: rollback correction (the recovery scheduler's splice).

Consumes the per-tile checksum differences emitted by abft_matmul, builds the
correction mask in-register (union or cross policy, Fig 10a) and overwrites
masked positions of the dequantized GEMM output with the checkpointed values
from a previous timestep (Sec 5.3 Step 3-4). Elementwise + broadcast only --
the tile is VMEM-resident and the checkpoint tile arrives via its own
BlockSpec stream (on hardware: the DMA the recovery scheduler coalesces).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(c_ref, ckpt_ref, rdiff_ref, cdiff_ref, thr_ref,
            out_ref, flag_ref, *, union: bool):
    thr = thr_ref[0]
    rd = rdiff_ref[...]                      # (bm, 1) int32
    cd = cdiff_ref[...]                      # (1, bn) int32
    rflag = (rd >= thr) | (rd <= -thr)
    cflag = (cd >= thr) | (cd <= -thr)
    mask = (rflag | cflag) if union else (rflag & cflag)
    out_ref[...] = jnp.where(mask, ckpt_ref[...], c_ref[...])
    flag_ref[0, 0] = jnp.any(mask).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "union", "interpret"))
def rollback_correct(c: jax.Array, ckpt: jax.Array,
                     row_diff: jax.Array, col_diff: jax.Array,
                     threshold: int,
                     bm: int = 128, bn: int = 128,
                     union: bool = True,
                     interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """c, ckpt: (M, N) f32; row_diff: (M, Nt) int32; col_diff: (Mt, N) int32.

    Returns (corrected (M, N) f32, tile_flag (Mt, Nt) int32).
    """
    m, n = c.shape
    assert m % bm == 0 and n % bn == 0
    mt, nt = m // bm, n // bn
    thr = jnp.asarray([threshold], jnp.int32)

    return pl.pallas_call(
        functools.partial(_kernel, union=union),
        grid=(mt, nt),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=(
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m, n), c.dtype),
            jax.ShapeDtypeStruct((mt, nt), jnp.int32),
        ),
        interpret=interpret,
    )(c, ckpt, row_diff, col_diff, thr)
