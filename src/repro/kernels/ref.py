"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def abft_matmul_ref(aq: jax.Array, bq: jax.Array, flips: jax.Array,
                    bm: int, bn: int
                    ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Reference for the fused faulty-ABFT GEMM.

    aq: (M, K) int8, bq: (K, N) int8, flips: (M, N) uint32 xor mask applied
    to the int32 accumulator (the DVFS timing-error injection).

    Returns:
      c_faulty : (M, N) int32  -- faulted accumulator
      act_row  : (M, Nt) int32 -- per (row, col-block) sums of c_faulty
      exp_row  : (M, Nt) int32 -- expected sums, A @ blocksum(B)
      act_col  : (Mt, N) int32
      exp_col  : (Mt, N) int32
    All arithmetic wraps mod 2^32 (exact ABFT; see core/abft.py).
    """
    m, k = aq.shape
    n = bq.shape[1]
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    mt, nt = m // bm, n // bn
    a32 = aq.astype(jnp.int32)
    b32 = bq.astype(jnp.int32)
    c = a32 @ b32
    c_faulty = jax.lax.bitcast_convert_type(
        jax.lax.bitwise_xor(jax.lax.bitcast_convert_type(c, jnp.uint32), flips),
        jnp.int32)

    act_row = c_faulty.reshape(m, nt, bn).sum(axis=2)
    exp_row = a32 @ b32.reshape(k, nt, bn).sum(axis=2)
    act_col = c_faulty.reshape(mt, bm, n).sum(axis=1)
    exp_col = a32.reshape(mt, bm, k).sum(axis=1) @ b32
    return c_faulty, act_row, exp_row, act_col, exp_col


def rollback_correct_ref(c: jax.Array, ckpt: jax.Array,
                         row_diff: jax.Array, col_diff: jax.Array,
                         threshold: int, bm: int, bn: int,
                         union: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Reference for the rollback-correction kernel.

    c, ckpt: (M, N) f32; row_diff: (M, Nt) int32; col_diff: (Mt, N) int32.
    Returns (corrected, tile_flag (Mt, Nt) bool).
    """
    m, n = c.shape
    mt, nt = row_diff.shape[1], None
    nt = row_diff.shape[1]
    mt = col_diff.shape[0]
    thr = jnp.int32(threshold)
    rflag = (row_diff >= thr) | (row_diff <= -thr)      # (M, Nt)
    cflag = (col_diff >= thr) | (col_diff <= -thr)      # (Mt, N)
    r_elem = jnp.repeat(rflag, bn, axis=1)              # (M, N)
    c_elem = jnp.repeat(cflag, bm, axis=0)              # (M, N)
    mask = (r_elem | c_elem) if union else (r_elem & c_elem)
    corrected = jnp.where(mask, ckpt, c)
    tile_flag = mask.reshape(mt, bm, nt, bn).any(axis=(1, 3))
    return corrected, tile_flag


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return a @ b
