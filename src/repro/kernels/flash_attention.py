"""Pallas TPU kernel: fused (flash) attention for DiT / LM serve.

The attention score+mix pair is the one non-projection compute hotspot of
the paper's DiT workload; on TPU the win is keeping the (Bq x Bk) score
tile in VMEM through the online-softmax recurrence instead of
materializing (S x S) scores in HBM.

Grid (batch*heads, q_blocks, kv_blocks), kv innermost with running
(m, l, acc) scratch carried across the kv dimension -- the classic flash
recurrence. Supports non-causal (DiT) and causal (LM) masking. Validated
bit-close against ref.flash_attention_ref / models.attention in interpret
mode (tests/test_kernels_flash.py); on TPU the same code compiles to
Mosaic with MXU-aligned (128, 128) default tiles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, n_kv: int, scale: float, causal: bool,
            bq: int, bk: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                              # (bq, d)
    k = k_ref[0]                              # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        q_i = pl.program_id(1)
        rows = q_i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_ref[...]                       # (bq, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                    # (bq, bk) f32
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_cur

    @pl.when(kv_i == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-37)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = False,
                    bq: int = 128, bk: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q, k, v: (BH, S, D) -> (BH, S, D). S % bq == S % bk == 0."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bh, s, d = q.shape
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = d ** -0.5

    return pl.pallas_call(
        functools.partial(_kernel, n_kv=nk, scale=scale, causal=causal,
                          bq=bq, bk=bk),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def mha_flash(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = False, bq: int = 128, bk: int = 128,
              interpret: Optional[bool] = None) -> jax.Array:
    """(B, S, H, D) convenience wrapper (no GQA: repeat KV before calling)."""
    b, s, h, d = q.shape
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    o = flash_attention(fold(q), fold(k), fold(v), causal=causal,
                        bq=bq, bk=bk, interpret=interpret)
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)
