# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Cross-version Pallas compat helpers shared by the TPU kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(*, dimension_semantics=None, **kwargs):
    """Build TPU compiler params across the JAX API rename.

    Newer JAX exposes ``pltpu.CompilerParams``; older releases (<= 0.4.x)
    call the same structure ``pltpu.TPUCompilerParams``. Resolve whichever
    the installed JAX provides so the kernels import everywhere.
    """
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(pltpu, "TPUCompilerParams")
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    return cls(**kwargs)
