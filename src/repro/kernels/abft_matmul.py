"""Pallas TPU kernel: fused faulty INT8 GEMM + ABFT checksums.

This is the paper's "ABFT-wrapping" of the systolic array (Fig 3 / Sec 5.1)
as a TPU kernel: one pass over (M, N, K) tiles computes

  * the INT32 accumulator C = Aq @ Bq (the MXU int8 pass),
  * the simulated DVFS timing-error injection (xor of a precomputed
    per-element flip mask -- the functional analogue of late-latching bits),
  * per-(row, tile-col) and per-(tile-row, col) actual AND expected
    checksums, fused into the same K-loop so the "checksum row/column" of
    the classic ABFT systolic formulation costs one extra MAC lane instead
    of a second GEMM pass.

Block shapes are BlockSpec tiles resident in VMEM; defaults (128, 128, 128)
match MXU granularity (int8 wants >= (32, 128) sublane x lane packing).
Checksum arithmetic is int32 with two's-complement wraparound => bit-exact
against the pure-jnp oracle in ref.py (validated in interpret mode on CPU).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _kernel(a_ref, b_ref, flip_ref,
            c_ref, act_row_ref, exp_row_ref, act_col_ref, exp_col_ref,
            acc_ref, exp_row_acc, exp_col_acc, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        exp_row_acc[...] = jnp.zeros_like(exp_row_acc)
        exp_col_acc[...] = jnp.zeros_like(exp_col_acc)

    a = a_ref[...]                      # (bm, bk) int8
    b = b_ref[...]                      # (bk, bn) int8
    a32 = a.astype(jnp.int32)
    b32 = b.astype(jnp.int32)

    # Main MAC pass (MXU int8 -> int32 on hardware).
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    # Fused checksum lanes: expected row sums need B's block row-sum vector,
    # expected col sums need A's block col-sum vector -- both rank-1, so the
    # extra work is one MAC column + one MAC row per tile (the "+1 lane" of
    # the ABFT-wrapped systolic array).
    b_rowsum = jnp.sum(b32, axis=1, keepdims=True)        # (bk, 1)
    a_colsum = jnp.sum(a32, axis=0, keepdims=True)        # (1, bk)
    exp_row_acc[...] += jax.lax.dot_general(
        a32, b_rowsum, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)                  # (bm, 1)
    exp_col_acc[...] += jax.lax.dot_general(
        a_colsum, b32, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)                  # (1, bn)

    @pl.when(k == n_k - 1)
    def _finalize():
        # DVFS timing errors land on the accumulator as it streams out.
        bits = jax.lax.bitcast_convert_type(acc_ref[...], jnp.uint32)
        c_faulty = jax.lax.bitcast_convert_type(
            jax.lax.bitwise_xor(bits, flip_ref[...]), jnp.int32)
        c_ref[...] = c_faulty
        act_row_ref[...] = jnp.sum(c_faulty, axis=1, keepdims=True)
        act_col_ref[...] = jnp.sum(c_faulty, axis=0, keepdims=True)
        exp_row_ref[...] = exp_row_acc[...]
        exp_col_ref[...] = exp_col_acc[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def abft_matmul(aq: jax.Array, bq: jax.Array, flips: jax.Array,
                bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: bool = False
                ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused faulty-ABFT GEMM. See ref.abft_matmul_ref for semantics.

    aq: (M, K) int8, bq: (K, N) int8, flips: (M, N) uint32.
    M % bm == N % bn == K % bk == 0 (callers pad; ops.py does).
    """
    m, k = aq.shape
    k2, n = bq.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    mt, nt, kt = m // bm, n // bn, k // bk

    grid = (mt, nt, kt)
    out_shapes = (
        jax.ShapeDtypeStruct((m, n), jnp.int32),        # c_faulty
        jax.ShapeDtypeStruct((m, nt), jnp.int32),       # act_row
        jax.ShapeDtypeStruct((m, nt), jnp.int32),       # exp_row
        jax.ShapeDtypeStruct((mt, n), jnp.int32),       # act_col
        jax.ShapeDtypeStruct((mt, n), jnp.int32),       # exp_col
    )
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
    ]
    out_specs = (
        pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        pl.BlockSpec((bm, 1), lambda i, j, kk: (i, j)),
        pl.BlockSpec((bm, 1), lambda i, j, kk: (i, j)),
        pl.BlockSpec((1, bn), lambda i, j, kk: (i, j)),
        pl.BlockSpec((1, bn), lambda i, j, kk: (i, j)),
    )
    scratch = [
        pltpu.VMEM((bm, bn), jnp.int32),
        pltpu.VMEM((bm, 1), jnp.int32),
        pltpu.VMEM((1, bn), jnp.int32),
    ]
    return pl.pallas_call(
        functools.partial(_kernel, n_k=kt),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=scratch,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(aq, bq, flips)
