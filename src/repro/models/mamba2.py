"""Mamba-2 (SSD, state-space duality) blocks: chunked scan + decode recurrence.

Implements the SSD algorithm of Dao & Gu (arXiv:2405.21060): the sequence is
split into chunks; within a chunk the recurrence is evaluated as a masked
attention-like quadratic form (MXU-friendly), across chunks a (cheap) linear
recurrence carries the (H, N, P) state. Decode is the O(1) per-token
recurrence -- which is what makes mamba2/hymba the archs that run the
long_500k cell.

DRIFT note (DESIGN.md Sec 4): in/out projections are GEMMs and get
ABFT+rollback; the SSD scan itself is not a GEMM and carries persistent
state, so it is classified error-sensitive and runs at the nominal operating
point.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import ModelConfig, Params, dense_init


class SsmState(NamedTuple):
    h: jax.Array           # (B, G, Hg, N, P) recurrent state
    conv: jax.Array        # (B, convw-1, conv_ch) causal-conv tail


def conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_ssm_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    d, di, nh = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    cch = conv_channels(cfg)
    proj_out = 2 * di + 2 * cfg.ssm_groups * cfg.ssm_state + nh
    return {
        "in_proj": dense_init(ks[0], d, proj_out, cfg.param_dtype),
        "conv_w": common.trunc_normal(ks[1], (cfg.ssm_conv_width, cch),
                                      cfg.ssm_conv_width ** -0.5,
                                      cfg.param_dtype),
        "conv_b": jnp.zeros((cch,), cfg.param_dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 1e-2, jnp.float32))),
        "norm_scale": jnp.zeros((di,), cfg.param_dtype),
        "out_proj": dense_init(ks[5], di, d, cfg.param_dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal 1-D conv. x: (B, S, C); w: (W, C); tail: (B, W-1, C)."""
    cw, c = w.shape
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = jax.lax.conv_general_dilated(
        xp, w.astype(x.dtype).reshape(cw, 1, c),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c)
    return out + b.astype(x.dtype)


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, gn = cfg.d_inner, cfg.ssm_groups * cfg.ssm_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    return z, xbc, dt


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array) -> jax.Array:
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(var + 1e-6)
            * (1.0 + scale.astype(jnp.float32))).astype(y.dtype)


def ssd_forward(cfg: ModelConfig, p: Params, x: jax.Array,
                return_state: bool = False
                ) -> Tuple[jax.Array, Optional[SsmState]]:
    """Chunked SSD over a full sequence. x: (B, S, d) -> (B, S, d)."""
    b, s, _ = x.shape
    nh, hp, ng, ns = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups,
                      cfg.ssm_state)
    hg = nh // ng
    q = min(cfg.ssm_chunk, s)
    pad = (-s) % q
    di = cfg.d_inner

    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc_conv = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"])
                           .astype(jnp.float32)).astype(x.dtype)
    xs = xbc_conv[..., :di]
    bc = xbc_conv[..., di:]
    b_ssm = bc[..., :ng * ns].reshape(b, s, ng, ns).astype(jnp.float32)
    c_ssm = bc[..., ng * ns:].reshape(b, s, ng, ns).astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                     # (B, S, nh)
    a_neg = -jnp.exp(p["A_log"])                             # (nh,)
    da = dt * a_neg                                          # (B, S, nh) <= 0

    xh = xs.reshape(b, s, nh, hp).astype(jnp.float32)
    xdt = xh * dt[..., None]                                 # (B, S, nh, hp)

    if pad:
        z_pad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        da, xdt = z_pad(da), z_pad(xdt)
        b_ssm, c_ssm = z_pad(b_ssm), z_pad(c_ssm)
    sp = s + pad
    nc = sp // q

    # reshape to chunks, heads grouped (ng, hg)
    da_c = da.reshape(b, nc, q, ng, hg)
    xdt_c = xdt.reshape(b, nc, q, ng, hg, hp)
    b_c = b_ssm.reshape(b, nc, q, ng, ns)
    c_c = c_ssm.reshape(b, nc, q, ng, ns)

    l = jnp.cumsum(da_c, axis=2)                             # inclusive
    l_t = jnp.moveaxis(l, 2, -1)                             # (B,nc,ng,hg,Q)
    l_last = l_t[..., -1:]                                   # (B,nc,ng,hg,1)

    # within-chunk quadratic form
    diff = l_t[..., :, None] - l_t[..., None, :]             # (…,Q_t,Q_s)
    tri = jnp.tril(jnp.ones((q, q), bool))
    m_seg = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    cb = jnp.einsum("bcqgn,bcsgn->bcgqs", c_c, b_c)
    y_intra = jnp.einsum("bcgqs,bcghqs,bcsghp->bcqghp", cb, m_seg, xdt_c)

    # chunk states + linear recurrence across chunks
    decay_to_end = jnp.exp(l_last - l_t)                     # (B,nc,ng,hg,Q)
    state_c = jnp.einsum("bcsgn,bcghs,bcsghp->bcghnp", b_c, decay_to_end,
                         xdt_c)
    chunk_decay = jnp.exp(l_last[..., 0])                    # (B,nc,ng,hg)

    def chunk_step(h, inp):
        dec, st = inp
        h_out = h                                            # state BEFORE chunk
        h = dec[..., None, None] * h + st
        return h, h_out

    h0 = jnp.zeros((b, ng, hg, ns, hp), jnp.float32)
    h_final, h_in = jax.lax.scan(
        chunk_step, h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(state_c, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                          # (B,nc,ng,hg,ns,hp)

    decay_from_start = jnp.exp(l_t)                          # (B,nc,ng,hg,Q)
    y_inter = jnp.einsum("bcqgn,bcghq,bcghnp->bcqghp", c_c, decay_from_start,
                         h_in)

    y = (y_intra + y_inter).reshape(b, sp, nh, hp)[:, :s]
    y = y + p["D"][None, None, :, None] * xh[:, :s]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = _gated_norm(y, z, p["norm_scale"])
    out = y @ p["out_proj"].astype(x.dtype)

    state = None
    if return_state:
        cw = cfg.ssm_conv_width
        tail = jnp.pad(xbc, ((0, 0), (cw - 1, 0), (0, 0)))[:, -(cw - 1):]
        state = SsmState(h=h_final, conv=tail)
    return out, state


def ssd_decode_step(cfg: ModelConfig, p: Params, x: jax.Array,
                    state: SsmState) -> Tuple[jax.Array, SsmState]:
    """One-token recurrence. x: (B, 1, d) -> (B, 1, d)."""
    b = x.shape[0]
    nh, hp, ng, ns = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups,
                      cfg.ssm_state)
    hg = nh // ng
    di = cfg.d_inner
    cw = cfg.ssm_conv_width

    zxbcdt = x @ p["in_proj"].astype(x.dtype)                # (B,1,·)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)

    win = jnp.concatenate([state.conv.astype(x.dtype), xbc], axis=1)  # (B,cw,C)
    conv_out = (jnp.einsum("bwc,wc->bc", win.astype(jnp.float32),
                           p["conv_w"].astype(jnp.float32))
                + p["conv_b"].astype(jnp.float32))
    xbc_t = jax.nn.silu(conv_out)                            # (B, C)
    new_conv = win[:, 1:]

    xs = xbc_t[:, :di].reshape(b, ng, hg, hp)
    b_t = xbc_t[:, di:di + ng * ns].reshape(b, ng, ns)
    c_t = xbc_t[:, di + ng * ns:].reshape(b, ng, ns)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp((dt * -jnp.exp(p["A_log"]))).reshape(b, ng, hg)
    xdt = xs * dt.reshape(b, ng, hg)[..., None]

    h = (a[..., None, None] * state.h
         + jnp.einsum("bgn,bghp->bghnp", b_t, xdt))
    y = jnp.einsum("bgn,bghnp->bghp", c_t, h)
    y = y + p["D"].reshape(ng, hg)[None, :, :, None] * xs
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = _gated_norm(y, z, p["norm_scale"])
    out = y @ p["out_proj"].astype(x.dtype)
    return out, SsmState(h=h, conv=new_conv)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SsmState:
    ng, hg = cfg.ssm_groups, cfg.ssm_heads // cfg.ssm_groups
    return SsmState(
        h=jnp.zeros((batch, ng, hg, cfg.ssm_state, cfg.ssm_head_dim),
                    jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_channels(cfg)),
                       dtype))
