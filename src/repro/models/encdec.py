"""Encoder-decoder transformer (whisper-base backbone).

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings
(B, encoder_seq, d_model). The backbone is faithful: pre-LN transformer
encoder (full self-attention over frames), decoder with causal
self-attention + cross-attention to the encoder output.

DRIFT note: the encoder runs once per request -- there is no previous-
timestep sibling to roll back to, so encoder GEMMs fall back to
StatABFT-style recompute under DRIFT (DESIGN.md Sec 4). The decoder rolls
back across decode steps like the other LMs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain
from repro.models import attention, common
from repro.models.common import (ModelConfig, Params, apply_norm, dense_init,
                                 embed_init, norm_params)


def _init_attn(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd
    return {"wq": dense_init(ks[0], d, h * hd, cfg.param_dtype),
            "wk": dense_init(ks[1], d, hkv * hd, cfg.param_dtype),
            "wv": dense_init(ks[2], d, hkv * hd, cfg.param_dtype),
            "wo": dense_init(ks[3], h * hd, d, cfg.param_dtype)}


def _init_mlp(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 2)
    return {"w_up": dense_init(ks[0], cfg.d_model, cfg.d_ff, cfg.param_dtype),
            "w_down": dense_init(ks[1], cfg.d_ff, cfg.d_model,
                                 cfg.param_dtype)}


def _init_enc_layer(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    return {"ln1": norm_params(cfg, ks[0]), "attn": _init_attn(cfg, ks[1]),
            "ln2": norm_params(cfg, ks[2]), "mlp": _init_mlp(cfg, ks[3])}


def _init_dec_layer(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    return {"ln1": norm_params(cfg, ks[0]), "attn": _init_attn(cfg, ks[1]),
            "ln_x": norm_params(cfg, ks[2]), "xattn": _init_attn(cfg, ks[3]),
            "ln2": norm_params(cfg, ks[4]), "mlp": _init_mlp(cfg, ks[5])}


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.param_dtype),
        "enc_pos": common.trunc_normal(ks[1], (cfg.encoder_seq, cfg.d_model),
                                       0.02, cfg.param_dtype),
        "enc_layers": common.stack_layer_params(
            lambda k: _init_enc_layer(cfg, k), cfg.n_encoder_layers, ks[2]),
        "enc_final": norm_params(cfg, ks[3]),
        "dec_layers": common.stack_layer_params(
            lambda k: _init_dec_layer(cfg, k), cfg.n_layers, ks[4]),
        "dec_final": norm_params(cfg, ks[5]),
    }


def _mha(cfg, p, x, kv_src, *, causal, q_offset=0, cache=None, pos=None):
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    if cache is None:
        k = (kv_src @ p["wk"].astype(x.dtype)).reshape(b, -1, hkv, hd)
        v = (kv_src @ p["wv"].astype(x.dtype)).reshape(b, -1, hkv, hd)
        o = attention.attention_any(q, k, v, causal=causal)
        new_cache = None
    else:
        ck, cv = cache
        if kv_src is not None:        # self-attn decode: append new kv
            k = (kv_src @ p["wk"].astype(x.dtype)).reshape(b, s, hkv, hd)
            v = (kv_src @ p["wv"].astype(x.dtype)).reshape(b, s, hkv, hd)
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, pos, 0, 0))
            o = attention.decode_attention(q, ck, cv, pos=pos)
        else:                          # cross-attn decode: static memory
            o = attention.decode_attention(q, ck, cv, pos=ck.shape[1] - 1)
        new_cache = (ck, cv)
    o = o.reshape(b, s, h * hd)
    return o @ p["wo"].astype(x.dtype), new_cache


def _mlp(cfg, p, x):
    h = jax.nn.gelu((x @ p["w_up"].astype(x.dtype)).astype(jnp.float32))
    return h.astype(x.dtype) @ p["w_down"].astype(x.dtype)


def encode(cfg: ModelConfig, params: Params,
           frames: jax.Array) -> jax.Array:
    """frames: (B, encoder_seq, d_model) stub embeddings -> memory."""
    x = frames.astype(cfg.dtype) + params["enc_pos"].astype(cfg.dtype)

    def body(xc, p_i, _):
        h, _ = _mha(cfg, p_i["attn"], apply_norm(cfg, p_i["ln1"], xc),
                    apply_norm(cfg, p_i["ln1"], xc), causal=False)
        xc = xc + h
        xc = xc + _mlp(cfg, p_i["mlp"], apply_norm(cfg, p_i["ln2"], xc))
        return constrain(xc, "act"), None

    x, _ = common.scan_layers(body, constrain(x, "act"), params["enc_layers"],
                              remat=cfg.remat, unroll=not cfg.scan_layers)
    return apply_norm(cfg, params["enc_final"], x)


def decode_train(cfg: ModelConfig, params: Params, tokens: jax.Array,
                 memory: jax.Array) -> jax.Array:
    """Teacher-forced decoder pass -> logits (B, S, V) f32."""
    x = params["embed"].astype(cfg.dtype)[tokens]
    positions = jnp.arange(tokens.shape[1])
    x = common.apply_rope(x[:, :, None, :], positions,
                          cfg.rope_theta)[:, :, 0, :]

    def body(xc, p_i, _):
        h, _ = _mha(cfg, p_i["attn"], apply_norm(cfg, p_i["ln1"], xc),
                    apply_norm(cfg, p_i["ln1"], xc), causal=True)
        xc = xc + h
        h, _ = _mha(cfg, p_i["xattn"], apply_norm(cfg, p_i["ln_x"], xc),
                    memory, causal=False)
        xc = xc + h
        xc = xc + _mlp(cfg, p_i["mlp"], apply_norm(cfg, p_i["ln2"], xc))
        return constrain(xc, "act"), None

    x, _ = common.scan_layers(body, constrain(x, "act"), params["dec_layers"],
                              remat=cfg.remat, unroll=not cfg.scan_layers)
    x = apply_norm(cfg, params["dec_final"], x)
    logits = x @ params["embed"].astype(x.dtype).T
    return constrain(logits, "logits").astype(jnp.float32)


class EncDecCache(NamedTuple):
    self_k: jax.Array     # (L, B, S_max, Hkv, hd)
    self_v: jax.Array
    cross_k: jax.Array    # (L, B, enc_seq, Hkv, hd)
    cross_v: jax.Array
    pos: jax.Array


def init_decode_cache(cfg: ModelConfig, params: Params, memory: jax.Array,
                      max_seq: int) -> EncDecCache:
    b = memory.shape[0]
    hkv, hd = cfg.kv_heads, cfg.hd
    shape = (cfg.n_layers, b, max_seq, hkv, hd)

    def xk(p_i):
        k = (memory @ p_i["xattn"]["wk"].astype(memory.dtype)
             ).reshape(b, -1, hkv, hd)
        v = (memory @ p_i["xattn"]["wv"].astype(memory.dtype)
             ).reshape(b, -1, hkv, hd)
        return k, v

    ck, cv = jax.vmap(xk)(params["dec_layers"])
    return EncDecCache(jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype),
                       ck.astype(cfg.dtype), cv.astype(cfg.dtype),
                       jnp.int32(0))


def decode_step(cfg: ModelConfig, params: Params, cache: EncDecCache,
                tokens: jax.Array) -> Tuple[jax.Array, EncDecCache]:
    """One decode token. tokens: (B, 1)."""
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = common.apply_rope(x[:, :, None, :],
                          jnp.full((1,), cache.pos, jnp.int32),
                          cfg.rope_theta)[:, :, 0, :]

    def body(xc, p_i, extra):
        sk, sv, xk_, xv_ = extra
        h, new_self = _mha(cfg, p_i["attn"], apply_norm(cfg, p_i["ln1"], xc),
                           apply_norm(cfg, p_i["ln1"], xc),
                           causal=True, cache=(sk, sv), pos=cache.pos)
        xc = xc + h
        h, _ = _mha(cfg, p_i["xattn"], apply_norm(cfg, p_i["ln_x"], xc),
                    None, causal=False, cache=(xk_, xv_), pos=None)
        xc = xc + h
        xc = xc + _mlp(cfg, p_i["mlp"], apply_norm(cfg, p_i["ln2"], xc))
        return xc, new_self

    xs = (cache.self_k, cache.self_v, cache.cross_k, cache.cross_v)
    x, new_self = common.scan_layers(body, x, params["dec_layers"],
                                     xs_extra=xs, remat=False,
                                     unroll=not cfg.scan_layers)
    sk, sv = new_self
    x = apply_norm(cfg, params["dec_final"], x)
    logits = (x @ params["embed"].astype(x.dtype).T).astype(jnp.float32)
    return logits, EncDecCache(sk, sv, cache.cross_k, cache.cross_v,
                               cache.pos + 1)
