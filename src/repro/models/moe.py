"""Mixture-of-Experts FFN: top-k routing, capacity-based dispatch, EP-ready.

Sort-based dispatch (no (T, E) one-hot einsum): token->expert assignments
are ranked inside each expert via argsort + searchsorted, dropped beyond
capacity, scattered into (E, C, d) slots, processed by a dense batched
expert GEMM (honest FLOPs ~= top_k * capacity_factor * T * d * ff, unlike
masked-all-experts implementations), and combined back with router weights.

Sharding: the expert dimension E shards on the 'model' mesh axis (expert
parallelism); the token scatter/gather becomes the dispatch all-to-all under
GSPMD. Covers kimi-k2 (384 routed, top-8) and deepseek-moe (2 shared + 64
routed, top-6) -- shared experts run as a plain dense gated FFN on all
tokens.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain
from repro.models import common
from repro.models.common import ModelConfig, Params, dense_init


def init_moe_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 7)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p: Params = {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router kept f32
        "w_gate": common.trunc_normal(ks[1], (e, d, f), d ** -0.5, cfg.param_dtype),
        "w_up": common.trunc_normal(ks[2], (e, d, f), d ** -0.5, cfg.param_dtype),
        "w_down": common.trunc_normal(ks[3], (e, f, d), f ** -0.5, cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff * cfg.n_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], d, fs, cfg.param_dtype),
            "w_up": dense_init(ks[5], d, fs, cfg.param_dtype),
            "w_down": dense_init(ks[6], fs, d, cfg.param_dtype),
        }
    return p


def moe_ffn(cfg: ModelConfig, p: Params, x: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (T, d) -> (y: (T, d), aux_loss: scalar). Pure function."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    capacity = max(int(cfg.capacity_factor * t * k / e), 1)
    # round capacity so the slot tensor's C dim can shard over the data axis
    capacity = -(-capacity // 64) * 64

    # router matmul in the activation dtype (bf16 MXU pass), f32 softmax:
    # casting x itself to f32 materializes + all-reduces a full-width f32
    # (T, d) tensor per layer (hillclimb #2 iter 4)
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                  # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- rank within expert (sort-based; no T x E one-hot) ----
    flat_e = top_i.reshape(-1)                              # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)                   # (T*k,)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    run_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(t * k) - run_start
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < capacity

    # dropped tokens scatter zeros into slot 0 (safe: .add of zeros), so the
    # slot tensor needs no +1 overflow row and can shard cleanly.
    slot = jnp.where(keep, flat_e * capacity + rank, 0)
    x_rep = constrain(x[flat_t], "tokens2d")                # (T*k, d)
    dispatched = jnp.zeros((e * capacity, d), x.dtype)
    # Anchor BOTH sides of the scatter: tokens stay dp-sharded, the flat
    # slot space is expert-major and shards on 'model' -- GSPMD lowers the
    # scatter into the dispatch all-to-all (hillclimb #2).
    dispatched = constrain(dispatched, "slots2d")
    dispatched = dispatched.at[slot].add(x_rep * keep[:, None].astype(x.dtype))
    dispatched = constrain(dispatched, "slots2d")
    xd = constrain(dispatched.reshape(e, capacity, d), "experts")

    # ---- dense expert GEMMs (EP shards the leading E axis) ----
    gate = jnp.einsum("ecd,edf->ecf", xd, p["w_gate"].astype(x.dtype))
    up = jnp.einsum("ecd,edf->ecf", xd, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    ye = constrain(ye, "experts")

    # ---- combine ----
    y_flat = constrain(ye.reshape(e * capacity, d), "slots2d")
    gathered = jnp.where(keep[:, None], y_flat[slot],
                         jnp.zeros((1, d), x.dtype))
    gathered = constrain(gathered, "tokens2d")
    y = jnp.zeros((t, d), x.dtype).at[flat_t].add(
        gathered * flat_w[:, None].astype(x.dtype))
    y = constrain(y, "tokens2d")

    # ---- shared experts (always-on dense path) ----
    if cfg.n_shared_experts:
        sp = p["shared"]
        g = jax.nn.silu((x @ sp["w_gate"].astype(x.dtype)).astype(jnp.float32))
        y = y + (g.astype(x.dtype) * (x @ sp["w_up"].astype(x.dtype))
                 ) @ sp["w_down"].astype(x.dtype)

    # ---- load-balance aux loss (Switch-style) ----
    frac_tokens = jnp.zeros((e,), jnp.float32).at[flat_e].add(
        keep.astype(jnp.float32)) / jnp.maximum(keep.sum(), 1.0)
    mean_prob = probs.mean(axis=0)
    aux = e * jnp.sum(frac_tokens * mean_prob)
    return y, aux


def moe_param_count(cfg: ModelConfig) -> int:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    n = e * (3 * d * f) + d * e
    if cfg.n_shared_experts:
        n += 3 * d * f * cfg.n_shared_experts
    return n
