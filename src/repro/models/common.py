"""Shared model machinery: configs, norms, RoPE, inits, activation dtypes.

One ``ModelConfig`` covers every assigned architecture family; fields unused
by a family default to inert values. Layer parameters are plain nested dicts
(pure JAX, no flax); stacked layers carry a leading L axis and run under
``jax.lax.scan`` with optional remat -- the production pattern that keeps
HLO size O(1) in depth for the 512-chip dry-runs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | dit | unet
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- attention pattern ---
    attn_pattern: Tuple[str, ...] = ("global",)   # cycled over layers
    global_layer_indices: Tuple[int, ...] = ()    # force-global layers (hymba)
    window: int = 1024               # sliding-window size for 'local' layers
    logit_softcap: float = 0.0       # gemma2-style final-logit softcap
    attn_softcap: float = 0.0        # gemma2-style attention-logit softcap
    norm: str = "rmsnorm"            # rmsnorm | layernorm | nonparam_ln
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_groups: int = 1
    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 0             # stub frame count (whisper: 1500)
    cross_attention: bool = False
    # --- VLM ---
    vis_tokens: int = 0              # stub patch-embedding count
    # --- DiT / UNet (diffusion) ---
    latent_size: int = 0             # spatial latent (e.g. 64 for 512px f8)
    latent_channels: int = 4
    patch_size: int = 2
    cond_dim: int = 0                # text-conditioning width (0 = class-cond)
    cond_tokens: int = 0             # text tokens for cross-attn (PixArt/SD)
    unet_channels: Tuple[int, ...] = ()
    num_classes: int = 0
    # --- execution ---
    dtype: Any = jnp.bfloat16        # activation/compute dtype
    param_dtype: Any = jnp.float32
    remat: bool = True
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer attention kind, cycling attn_pattern over depth."""
        p = self.attn_pattern
        kinds = [p[i % len(p)] for i in range(self.n_layers)]
        for i in self.global_layer_indices:
            kinds[i % self.n_layers] = "global"
        return tuple(kinds)

    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer window (0 = unbounded/global)."""
        return tuple(0 if k == "global" else self.window
                     for k in self.layer_kinds())

    @property
    def ssm_heads(self) -> int:
        return (self.d_model * self.ssm_expand) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.d_model * self.ssm_expand


# ----------------------------------------------------------------- inits
def trunc_normal(key, shape, std, dtype):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    return trunc_normal(key, (d_in, d_out), 1.0 / math.sqrt(d_in), dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return trunc_normal(key, (vocab, d), 1.0, dtype)


# ----------------------------------------------------------------- norms
def rmsnorm(x: jax.Array, scale: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(dt)


def layernorm(x: jax.Array, scale: Optional[jax.Array],
              bias: Optional[jax.Array], eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def apply_norm(cfg: ModelConfig, p: Optional[Params], x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, None if p is None else p.get("scale"))
    if cfg.norm == "layernorm":
        return layernorm(x, None if p is None else p.get("scale"),
                         None if p is None else p.get("bias"))
    if cfg.norm == "nonparam_ln":   # OLMo: non-parametric LayerNorm
        return layernorm(x, None, None)
    raise ValueError(cfg.norm)


def norm_params(cfg: ModelConfig, key) -> Params:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((cfg.d_model,), cfg.param_dtype)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype),
                "bias": jnp.zeros((cfg.d_model,), cfg.param_dtype)}
    return {}  # nonparam_ln


# ------------------------------------------------------------------ RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D), positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def activation(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "silu":
        return jax.nn.silu(x)
    if cfg.act == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(cfg.act)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# --------------------------------------------------------------- scanning
def stack_layer_params(init_one, n_layers: int, key) -> Params:
    """vmap a single-layer init over depth -> leading L axis on every leaf."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)


def scan_layers(body, x, stacked_params: Params, xs_extra=None,
                remat: bool = True, unroll: bool = False):
    """Run ``body(x, layer_params, extra) -> (x, ys)`` over stacked layers.

    ``xs_extra`` is an optional pytree with leading L axis (per-layer masks,
    KV-cache slices, drift-state slices...). Returns (x, stacked_ys).
    """
    if unroll:
        n = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
        ys_all = []
        for i in range(n):
            p_i = jax.tree.map(lambda a: a[i], stacked_params)
            e_i = (None if xs_extra is None
                   else jax.tree.map(lambda a: a[i], xs_extra))
            x, ys = body(x, p_i, e_i)
            ys_all.append(ys)
        stacked = (jax.tree.map(lambda *a: jnp.stack(a), *ys_all)
                   if ys_all and ys_all[0] is not None else None)
        return x, stacked

    def step(carry, per_layer):
        p_i, e_i = per_layer
        y, ys = body(carry, p_i, e_i)
        return y, ys

    fn = jax.checkpoint(step) if remat else step
    x, ys = jax.lax.scan(fn, x, (stacked_params, xs_extra))
    return x, ys


def count_params(params: Params) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))
