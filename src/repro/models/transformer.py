"""Unified decoder LM covering the dense / MoE / SSM / hybrid / VLM families.

One parameter schema + three execution paths (train forward, prefill,
decode) driven entirely by ``ModelConfig``:

  dense   -- gemma3-27b, gemma2-9b, olmo-1b, glm4-9b (GQA, local/global
             patterns, softcaps, non-parametric LN)
  moe     -- kimi-k2 (384e top-8), deepseek-moe (2 shared + 64e top-6)
  ssm     -- mamba2-370m (attention-free SSD blocks)
  hybrid  -- hymba-1.5b (parallel attention + SSM heads per layer)
  vlm     -- internvl2-76b (stub patch embeddings prepended to the stream)

Layers are stacked (leading L axis) and run under lax.scan with remat;
per-layer heterogeneity (window sizes, rope on/off) rides along as scan xs,
so the traced HLO stays O(1) in depth -- required for the 512-chip
multi-pod dry-run to lower/compile in reasonable time.

DRIFT integration: ``decode_step(..., drift=...)`` threads the rollback
checkpoint store (stacked per layer) through the scan and routes every
projection GEMM through an ExecContext; see core/exec_ctx.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dvfs
from repro.core.exec_ctx import DriftSystemConfig, ExecContext
from repro.distributed.constraints import constrain
from repro.models import attention, common, mamba2, moe
from repro.models.common import (ModelConfig, Params, apply_norm, dense_init,
                                 embed_init, norm_params)


# ============================================================ parameters
def _init_attn(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd
    return {
        "wq": dense_init(ks[0], d, h * hd, cfg.param_dtype),
        "wk": dense_init(ks[1], d, hkv * hd, cfg.param_dtype),
        "wv": dense_init(ks[2], d, hkv * hd, cfg.param_dtype),
        "wo": dense_init(ks[3], h * hd, d, cfg.param_dtype),
    }


def _init_mlp(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": dense_init(ks[0], d, f, cfg.param_dtype),
        "w_up": dense_init(ks[1], d, f, cfg.param_dtype),
        "w_down": dense_init(ks[2], f, d, cfg.param_dtype),
    }


def init_layer(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 5)
    p: Params = {"ln1": norm_params(cfg, ks[0])}
    if cfg.family == "ssm":
        p["ssm"] = mamba2.init_ssm_params(cfg, ks[1])
        return p
    p["attn"] = _init_attn(cfg, ks[1])
    p["ln2"] = norm_params(cfg, ks[2])
    if cfg.family == "moe":
        p["moe"] = moe.init_moe_params(cfg, ks[3])
    else:
        p["mlp"] = _init_mlp(cfg, ks[3])
    if cfg.family == "hybrid":
        p["ssm"] = mamba2.init_ssm_params(cfg, ks[4])
        p["mix_attn"] = jnp.ones((), jnp.float32)
        p["mix_ssm"] = jnp.ones((), jnp.float32)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    k_embed, k_layers, k_final, k_head = jax.random.split(key, 4)
    p: Params = {
        "embed": embed_init(k_embed, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "layers": common.stack_layer_params(
            lambda k: init_layer(cfg, k), cfg.n_layers, k_layers),
        "final_norm": norm_params(cfg, k_final),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab,
                                  cfg.param_dtype)
    return p


# ============================================================== caching
class Cache(NamedTuple):
    k: Optional[jax.Array]          # (L, B, S, Hkv, hd)
    v: Optional[jax.Array]
    ssm: Optional[mamba2.SsmState]  # leaves stacked (L, ...)
    pos: jax.Array                  # scalar int32: next write index


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Cache:
    k = v = None
    if cfg.family != "ssm":
        shape = (cfg.n_layers, batch, max_seq, cfg.kv_heads, cfg.hd)
        k, v = jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)
    ssm = None
    if cfg.family in ("ssm", "hybrid"):
        one = mamba2.init_ssm_state(cfg, batch, dtype)
        ssm = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)
    return Cache(k, v, ssm, jnp.int32(0))


# ====================================================== layer primitives
def _proj(ctx: Optional[ExecContext], x, w, name, rclass):
    if ctx is None:
        return x @ w.astype(x.dtype)
    return ctx.matmul(x, w.astype(x.dtype), name=name, rclass=rclass)


def _attn_block(cfg: ModelConfig, p: Params, x: jax.Array, *,
                window, positions, mode: str,
                cache_kv=None, cache_pos=None,
                ctx: Optional[ExecContext] = None, rclass=dvfs.CLASS_BODY):
    """Self-attention sub-block. mode: 'full' | 'prefill' | 'decode'."""
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    q = _proj(ctx, x, p["wq"], "attn.q", rclass).reshape(b, s, h, hd)
    k = _proj(ctx, x, p["wk"], "attn.k", rclass).reshape(b, s, hkv, hd)
    v = _proj(ctx, x, p["wv"], "attn.v", rclass).reshape(b, s, hkv, hd)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)

    new_kv = None
    if mode == "full":
        o = attention.attention_any(q, k, v, causal=True, window=window,
                                    attn_softcap=cfg.attn_softcap)
    elif mode == "prefill":
        ck, cv = cache_kv
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, 0, 0))
        new_kv = (ck, cv)
        o = attention.attention_any(q, k, v, causal=True, window=window,
                                    attn_softcap=cfg.attn_softcap)
    elif mode == "decode":
        ck, cv = cache_kv
        ck = jax.lax.dynamic_update_slice(
            ck, k.astype(ck.dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cv, v.astype(cv.dtype), (0, cache_pos, 0, 0))
        new_kv = (ck, cv)
        o = attention.decode_attention(q, ck, cv, pos=cache_pos,
                                       window=window,
                                       attn_softcap=cfg.attn_softcap)
    else:
        raise ValueError(mode)
    o = o.reshape(b, s, h * hd)
    return _proj(ctx, o, p["wo"], "attn.o", rclass), new_kv


def _mlp_block(cfg: ModelConfig, p: Params, x: jax.Array,
               ctx: Optional[ExecContext] = None, rclass=dvfs.CLASS_BODY):
    g = _proj(ctx, x, p["w_gate"], "mlp.gate", rclass)
    u = _proj(ctx, x, p["w_up"], "mlp.up", rclass)
    h = common.activation(cfg, g.astype(jnp.float32)).astype(x.dtype) * u
    return _proj(ctx, h, p["w_down"], "mlp.down", rclass)


def _layer(cfg: ModelConfig, p: Params, x: jax.Array, *,
           window, positions, mode: str,
           cache_kv=None, cache_pos=None, ssm_state=None,
           ctx: Optional[ExecContext] = None, rclass=dvfs.CLASS_BODY):
    """One transformer/SSM/hybrid layer. Returns (x, new_kv, new_ssm, aux)."""
    aux = jnp.float32(0.0)
    h_in = apply_norm(cfg, p["ln1"], x)
    new_kv, new_ssm = None, None

    if cfg.family == "ssm":
        if mode == "decode":
            y, new_ssm = mamba2.ssd_decode_step(cfg, p["ssm"], h_in, ssm_state)
        else:
            y, new_ssm = mamba2.ssd_forward(cfg, p["ssm"], h_in,
                                            return_state=(mode == "prefill"))
        return x + y, new_kv, new_ssm, aux

    attn_out, new_kv = _attn_block(cfg, p["attn"], h_in, window=window,
                                   positions=positions, mode=mode,
                                   cache_kv=cache_kv, cache_pos=cache_pos,
                                   ctx=ctx, rclass=rclass)
    if cfg.family == "hybrid":
        if mode == "decode":
            ssm_out, new_ssm = mamba2.ssd_decode_step(cfg, p["ssm"], h_in,
                                                      ssm_state)
        else:
            ssm_out, new_ssm = mamba2.ssd_forward(
                cfg, p["ssm"], h_in, return_state=(mode == "prefill"))
        # hymba: mean of per-branch-normalized outputs, learnable scales
        attn_n = common.rmsnorm(attn_out, None) * p["mix_attn"].astype(x.dtype)
        ssm_n = common.rmsnorm(ssm_out, None) * p["mix_ssm"].astype(x.dtype)
        x = x + 0.5 * (attn_n + ssm_n)
    else:
        x = x + attn_out

    h2 = apply_norm(cfg, p["ln2"], x)
    if cfg.family == "moe":
        t = h2.shape[0] * h2.shape[1]
        y2, aux = moe.moe_ffn(cfg, p["moe"], h2.reshape(t, -1))
        y2 = y2.reshape(h2.shape)
    else:
        y2 = _mlp_block(cfg, p["mlp"], h2, ctx=ctx, rclass=rclass)
    return x + y2, new_kv, new_ssm, aux


# ========================================================== full forward
def _window_xs(cfg: ModelConfig) -> jax.Array:
    return jnp.asarray(cfg.layer_windows(), jnp.int32)


def _embed(cfg: ModelConfig, params: Params, tokens: jax.Array,
           vis_embeds: Optional[jax.Array]) -> jax.Array:
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    if vis_embeds is not None:
        x = jnp.concatenate([vis_embeds.astype(cfg.dtype), x], axis=1)
    return constrain(x, "act")


def _unembed(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(x.dtype).T
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    logits = constrain(logits, "logits")
    return common.softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            vis_embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Training/teacher-forcing pass. Returns (logits_f32, aux_loss)."""
    x = _embed(cfg, params, tokens, vis_embeds)
    b, s, _ = x.shape
    positions = jnp.arange(s)

    def body(xc, p_i, win):
        y, _, _, aux = _layer(cfg, p_i, xc, window=win, positions=positions,
                              mode="full")
        return constrain(y, "act"), aux

    x, auxs = common.scan_layers(body, x, params["layers"],
                                 xs_extra=_window_xs(cfg),
                                 remat=cfg.remat,
                                 unroll=not cfg.scan_layers)
    x = apply_norm(cfg, params["final_norm"], x)
    aux = jnp.mean(auxs) if auxs is not None else jnp.float32(0.0)
    return _unembed(cfg, params, x), aux


# ================================================================ serving
def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            max_seq: int, vis_embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Cache]:
    """Process a prompt; returns (logits (B, S, V) f32, primed cache)."""
    x = _embed(cfg, params, tokens, vis_embeds)
    b, s, _ = x.shape
    cache = init_cache(cfg, b, max_seq, cfg.dtype)
    positions = jnp.arange(s)

    def body(xc, p_i, extra):
        win, kv_i, ssm_i = extra
        y, new_kv, new_ssm, _ = _layer(cfg, p_i, xc, window=win,
                                       positions=positions, mode="prefill",
                                       cache_kv=kv_i, ssm_state=ssm_i)
        return constrain(y, "act"), (new_kv, new_ssm)

    xs = (_window_xs(cfg),
          (cache.k, cache.v) if cache.k is not None else None,
          cache.ssm)
    x, ys = common.scan_layers(body, x, params["layers"], xs_extra=xs,
                               remat=cfg.remat, unroll=not cfg.scan_layers)
    new_kv, new_ssm = ys
    k, v = (new_kv if new_kv is not None else (None, None))
    x = apply_norm(cfg, params["final_norm"], x)
    return _unembed(cfg, params, x), Cache(k, v, new_ssm, jnp.int32(s))


@dataclasses.dataclass(frozen=True)
class DriftDecode:
    """Static config + per-step dynamic inputs for DRIFT-protected decode."""
    cfg: DriftSystemConfig
    key: jax.Array
    ber_by_class: jax.Array        # (N_CLASSES,)
    store: Dict[str, jax.Array]    # stacked (L, ...) checkpoint store
    step: jax.Array                # decode step (drives interval/rollback)


def decode_step(cfg: ModelConfig, params: Params, cache: Cache,
                tokens: jax.Array,
                drift: Optional[DriftDecode] = None
                ) -> Tuple[jax.Array, Cache, Optional[Dict[str, jax.Array]]]:
    """One decode step. tokens: (B, 1). Returns (logits, cache, drift_store)."""
    x = _embed(cfg, params, tokens, None)
    positions = jnp.full((1,), cache.pos, jnp.int32)

    def body(carry, p_i, extra):
        xc, layer_idx = carry
        win, kv_i, ssm_i, store_i = extra
        ctx = None
        if drift is not None:
            rclass = jnp.where(layer_idx < 1, dvfs.CLASS_FIRST_BLOCK,
                               dvfs.CLASS_BODY)
            ctx = ExecContext(drift.cfg,
                              key=jax.random.fold_in(drift.key, layer_idx),
                              step=drift.step,
                              ber_by_class=drift.ber_by_class,
                              state_in=store_i,
                              have_ckpt=drift.step > 0)
        else:
            rclass = dvfs.CLASS_BODY
        y, new_kv, new_ssm, _ = _layer(cfg, p_i, xc, window=win,
                                       positions=positions, mode="decode",
                                       cache_kv=kv_i, cache_pos=cache.pos,
                                       ssm_state=ssm_i, ctx=ctx,
                                       rclass=rclass)
        out_store = ctx.state_out if ctx is not None else None
        return (constrain(y, "act"), layer_idx + 1), (new_kv, new_ssm,
                                                      out_store)

    xs = (_window_xs(cfg),
          (cache.k, cache.v) if cache.k is not None else None,
          cache.ssm,
          drift.store if drift is not None else None)

    def body2(x_and_i, p_i, extra):
        return body(x_and_i, p_i, extra)

    (x, _), ys = common.scan_layers(body2, (x, jnp.int32(0)),
                                    params["layers"], xs_extra=xs,
                                    remat=False,
                                    unroll=not cfg.scan_layers)
    new_kv, new_ssm, new_store = ys
    k, v = (new_kv if new_kv is not None else (None, None))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    return logits, Cache(k, v, new_ssm, cache.pos + 1), new_store


def decode_step_stats(cfg: ModelConfig, params: Params, cache: Cache,
                      tokens: jax.Array, ctx_factory
                      ) -> Tuple[jax.Array, Cache, Dict[str, jax.Array]]:
    """One decode step routed through caller-supplied execution contexts.

    ``ctx_factory(layer_idx)`` returns a duck-typed ExecContext (anything
    with ``.matmul(x, w, name=, rclass=)`` and a ``.stats`` dict of traced
    scalars) built fresh per layer; the serving AR path uses this to run
    statistical-ABFT detection (serving/ar.StatAbftContext) without the
    checkpoint-store plumbing ``decode_step(..., drift=...)`` carries.
    Returns ``(logits, cache, stats)`` with stats tree-summed over layers
    -- unlike ``decode_step``, which discards per-layer ctx.stats.

    SSM layers route no GEMMs through the ctx (mamba2 scans are unprotected
    -- documented in docs/servable.md), and MoE FFNs only protect the
    attention projections; both still contribute well-formed zero stats.
    """
    x = _embed(cfg, params, tokens, None)
    positions = jnp.full((1,), cache.pos, jnp.int32)

    def body(carry, p_i, extra):
        xc, layer_idx = carry
        win, kv_i, ssm_i = extra
        rclass = jnp.where(layer_idx < 1, dvfs.CLASS_FIRST_BLOCK,
                           dvfs.CLASS_BODY)
        ctx = ctx_factory(layer_idx)
        y, new_kv, new_ssm, _ = _layer(cfg, p_i, xc, window=win,
                                       positions=positions, mode="decode",
                                       cache_kv=kv_i, cache_pos=cache.pos,
                                       ssm_state=ssm_i, ctx=ctx,
                                       rclass=rclass)
        return (constrain(y, "act"), layer_idx + 1), (new_kv, new_ssm,
                                                      dict(ctx.stats))

    xs = (_window_xs(cfg),
          (cache.k, cache.v) if cache.k is not None else None,
          cache.ssm)
    (x, _), ys = common.scan_layers(body, (x, jnp.int32(0)),
                                    params["layers"], xs_extra=xs,
                                    remat=False,
                                    unroll=not cfg.scan_layers)
    new_kv, new_ssm, stats_layers = ys
    stats = jax.tree.map(lambda a: jnp.sum(a, axis=0), stats_layers)
    k, v = (new_kv if new_kv is not None else (None, None))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    return logits, Cache(k, v, new_ssm, cache.pos + 1), stats


# ================================================== windowed decode (opt)
#
# Perf-optimized decode for local/global interleaved architectures
# (gemma3 5:1, gemma2 1:1): local layers keep a WINDOW-SIZED ring-buffer
# cache and attend O(window) instead of masked-O(S). Layers are scanned in
# pattern cycles (params reshaped (n_cycles, cycle, ...)) with the cycle
# unrolled in the body, so each layer's window is STATIC and the HLO stays
# O(cycle) in size. Leftover layers (62 = 10x6 + 2 for gemma3) run
# unrolled. See EXPERIMENTS.md Sec Perf, hillclimb #1.

class MixedCache(NamedTuple):
    k_local: jax.Array    # (n_local, B, W, Hkv, hd) ring buffers
    v_local: jax.Array
    k_global: jax.Array   # (n_global, B, S, Hkv, hd)
    v_global: jax.Array
    pos: jax.Array


def mixed_layout(cfg: ModelConfig):
    """(cycle_kinds, n_cycles, tail_kinds, local_idx, global_idx)."""
    kinds = cfg.layer_kinds()
    cycle = len(cfg.attn_pattern)
    n_cycles = cfg.n_layers // cycle
    tail = kinds[n_cycles * cycle:]
    local_idx = [i for i, k in enumerate(kinds) if k == "local"]
    global_idx = [i for i, k in enumerate(kinds) if k == "global"]
    return (cfg.attn_pattern, n_cycles, tail, local_idx, global_idx)


def supports_mixed_decode(cfg: ModelConfig) -> bool:
    kinds = cfg.layer_kinds()
    return (cfg.family == "dense" and "local" in kinds and cfg.window > 0
            and not cfg.global_layer_indices)


def init_mixed_cache(cfg: ModelConfig, batch: int, max_seq: int,
                     dtype=jnp.bfloat16) -> MixedCache:
    _, _, _, local_idx, global_idx = mixed_layout(cfg)
    w = cfg.window
    shape_l = (len(local_idx), batch, w, cfg.kv_heads, cfg.hd)
    shape_g = (len(global_idx), batch, max_seq, cfg.kv_heads, cfg.hd)
    return MixedCache(jnp.zeros(shape_l, dtype), jnp.zeros(shape_l, dtype),
                      jnp.zeros(shape_g, dtype), jnp.zeros(shape_g, dtype),
                      jnp.int32(0))


def mixed_from_full(cfg: ModelConfig, cache: Cache) -> MixedCache:
    """Convert a full prefill cache into the windowed layout (ring-aligned:
    position p lands in slot p % W)."""
    _, _, _, local_idx, global_idx = mixed_layout(cfg)
    w = cfg.window
    pos = cache.pos
    s = cache.k.shape[2]

    def ring(full):  # (B, S, Hkv, hd) -> (B, W, Hkv, hd)
        start = jnp.clip(pos - w, 0, s - w)
        sl_k = jax.lax.dynamic_slice_in_dim(full, start, w, axis=1)
        # entry i holds position start+i -> slot (start+i) % W
        shift = start % w
        return jnp.roll(sl_k, shift, axis=1)

    kl = jnp.stack([ring(cache.k[i]) for i in local_idx]) if local_idx \
        else jnp.zeros((0,))
    vl = jnp.stack([ring(cache.v[i]) for i in local_idx]) if local_idx \
        else jnp.zeros((0,))
    kg = jnp.stack([cache.k[i] for i in global_idx])
    vg = jnp.stack([cache.v[i] for i in global_idx])
    return MixedCache(kl, vl, kg, vg, pos)


def _mixed_layer(cfg: ModelConfig, p, x, *, kind: str, positions, pos,
                 kv_ring=None, kv_full=None):
    """One decode layer with a static local/global kind."""
    h_in = apply_norm(cfg, p["ln1"], x)
    b, s, d = x.shape
    hh, hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    ap = p["attn"]
    q = (h_in @ ap["wq"].astype(x.dtype)).reshape(b, s, hh, hd)
    k = (h_in @ ap["wk"].astype(x.dtype)).reshape(b, s, hkv, hd)
    v = (h_in @ ap["wv"].astype(x.dtype)).reshape(b, s, hkv, hd)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    if kind == "local":
        ck, cv = kv_ring
        slot = pos % cfg.window
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, slot, 0, 0))
        o = attention.decode_attention_ring(q, ck, cv, pos=pos,
                                            attn_softcap=cfg.attn_softcap)
        new_kv = (ck, cv)
    else:
        ck, cv = kv_full
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, pos, 0, 0))
        o = attention.decode_attention(q, ck, cv, pos=pos, window=None,
                                       attn_softcap=cfg.attn_softcap)
        new_kv = (ck, cv)
    x = x + (o.reshape(b, s, hh * hd) @ ap["wo"].astype(x.dtype))
    h2 = apply_norm(cfg, p["ln2"], x)
    x = x + _mlp_block(cfg, p["mlp"], h2)
    return constrain(x, "act"), new_kv


def decode_step_mixed(cfg: ModelConfig, params: Params, cache: MixedCache,
                      tokens: jax.Array) -> Tuple[jax.Array, MixedCache]:
    """Windowed decode: pattern-cycle scan, ring buffers for local layers."""
    pattern, n_cycles, tail, local_idx, global_idx = mixed_layout(cfg)
    cycle = len(pattern)
    n_loc_c = sum(1 for k in pattern if k == "local")
    n_glo_c = cycle - n_loc_c
    pos = cache.pos
    x = _embed(cfg, params, tokens, None)
    positions = jnp.full((1,), pos, jnp.int32)

    def split_cycles(a):
        return jax.tree.map(
            lambda t: t[: n_cycles * cycle].reshape((n_cycles, cycle)
                                                    + t.shape[1:]), a)

    p_cycles = split_cycles(params["layers"])
    p_tail = jax.tree.map(lambda t: t[n_cycles * cycle:], params["layers"])
    kl = cache.k_local[: n_cycles * n_loc_c].reshape(
        (n_cycles, n_loc_c) + cache.k_local.shape[1:])
    vl = cache.v_local[: n_cycles * n_loc_c].reshape(
        (n_cycles, n_loc_c) + cache.v_local.shape[1:])
    kg = cache.k_global[: n_cycles * n_glo_c].reshape(
        (n_cycles, n_glo_c) + cache.k_global.shape[1:])
    vg = cache.v_global[: n_cycles * n_glo_c].reshape(
        (n_cycles, n_glo_c) + cache.v_global.shape[1:])

    def body(xc, p_c, extra):
        kl_c, vl_c, kg_c, vg_c = extra
        li = gi = 0
        new_l, new_g = [], []
        for j, kind in enumerate(pattern):
            p_j = jax.tree.map(lambda t: t[j], p_c)
            if kind == "local":
                xc, (nk, nv) = _mixed_layer(
                    cfg, p_j, xc, kind="local", positions=positions,
                    pos=pos, kv_ring=(kl_c[li], vl_c[li]))
                new_l.append((nk, nv))
                li += 1
            else:
                xc, (nk, nv) = _mixed_layer(
                    cfg, p_j, xc, kind="global", positions=positions,
                    pos=pos, kv_full=(kg_c[gi], vg_c[gi]))
                new_g.append((nk, nv))
                gi += 1
        ys = (jnp.stack([t[0] for t in new_l]) if new_l else kl_c,
              jnp.stack([t[1] for t in new_l]) if new_l else vl_c,
              jnp.stack([t[0] for t in new_g]) if new_g else kg_c,
              jnp.stack([t[1] for t in new_g]) if new_g else vg_c)
        return xc, ys

    x, ys = common.scan_layers(body, x, p_cycles,
                               xs_extra=(kl, vl, kg, vg), remat=False)
    nkl, nvl, nkg, nvg = ys
    nkl = nkl.reshape((n_cycles * n_loc_c,) + cache.k_local.shape[1:])
    nvl = nvl.reshape((n_cycles * n_loc_c,) + cache.v_local.shape[1:])
    nkg = nkg.reshape((n_cycles * n_glo_c,) + cache.k_global.shape[1:])
    nvg = nvg.reshape((n_cycles * n_glo_c,) + cache.v_global.shape[1:])

    # tail layers (pattern remainder), unrolled
    li = n_cycles * n_loc_c
    gi = n_cycles * n_glo_c
    tail_l, tail_g = [], []
    for j, kind in enumerate(tail):
        p_j = jax.tree.map(lambda t: t[j], p_tail)
        if kind == "local":
            x, (nk, nv) = _mixed_layer(cfg, p_j, x, kind="local",
                                       positions=positions, pos=pos,
                                       kv_ring=(cache.k_local[li],
                                                cache.v_local[li]))
            tail_l.append((nk, nv))
            li += 1
        else:
            x, (nk, nv) = _mixed_layer(cfg, p_j, x, kind="global",
                                       positions=positions, pos=pos,
                                       kv_full=(cache.k_global[gi],
                                                cache.v_global[gi]))
            tail_g.append((nk, nv))
            gi += 1
    if tail_l:
        nkl = jnp.concatenate([nkl, jnp.stack([t[0] for t in tail_l])])
        nvl = jnp.concatenate([nvl, jnp.stack([t[1] for t in tail_l])])
    if tail_g:
        nkg = jnp.concatenate([nkg, jnp.stack([t[0] for t in tail_g])])
        nvg = jnp.concatenate([nvg, jnp.stack([t[1] for t in tail_g])])

    x = apply_norm(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    return logits, MixedCache(nkl, nvl, nkg, nvg, pos + 1)


def drift_store_spec(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    """Zero-init stacked checkpoint store for DRIFT-protected decode."""
    d, h, hkv, hd, f = (cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd,
                        cfg.d_ff)
    m = batch  # one token per decode step
    def z(nout):
        return jnp.zeros((cfg.n_layers, m, nout), jnp.float32)
    store = {
        "attn.q": z(h * hd), "attn.k": z(hkv * hd), "attn.v": z(hkv * hd),
        "attn.o": z(d),
    }
    if cfg.family != "moe":
        store.update({"mlp.gate": z(f), "mlp.up": z(f), "mlp.down": z(d)})
    return store


def param_count(cfg: ModelConfig) -> int:
    """Analytical parameter count (drives MODEL_FLOPS in the roofline)."""
    d, h, hkv, hd, f, v = (cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd,
                           cfg.d_ff, cfg.vocab)
    per_layer = 0
    if cfg.family != "ssm":
        per_layer += d * h * hd + 2 * d * hkv * hd + h * hd * d
    if cfg.family == "moe":
        per_layer += moe.moe_param_count(cfg)
    elif cfg.family != "ssm":
        per_layer += 3 * d * f
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.d_inner
        per_layer += d * (2 * di + 2 * cfg.ssm_groups * cfg.ssm_state
                          + cfg.ssm_heads) + di * d
    n = cfg.n_layers * per_layer + v * d
    if not cfg.tie_embeddings:
        n += v * d
    return n
