"""Conditional latent UNet (Stable-Diffusion v1.5 family backbone).

ResBlocks (GroupNorm + SiLU + 3x3 conv) with timestep injection, self- +
cross-attention at the lower resolutions, down/up path with skip
connections. Channel widths/config come from ModelConfig.unet_channels.
Convolutions stay un-protected under DRIFT (the paper's accelerator maps
GEMMs; SD's conv layers are lowered to implicit GEMM on the systolic array
-- we charge them in the perfmodel but route only the attention/projection
GEMMs through ExecContext, the dominant FLOPs at latent resolution).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dvfs
from repro.core.exec_ctx import ExecContext
from repro.distributed.constraints import constrain
from repro.models import attention, common
from repro.models.common import ModelConfig, Params, dense_init


def _conv_init(key, kh, kw, cin, cout, dtype):
    fan_in = kh * kw * cin
    return common.trunc_normal(key, (kh, kw, cin, cout), fan_in ** -0.5,
                               dtype)


def _conv(x, w, b=None, stride=1):
    y = jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def group_norm(x: jax.Array, scale, bias, groups: int = 32) -> jax.Array:
    b, h, w, c = x.shape
    g = min(groups, c)
    xf = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mu = xf.mean(axis=(1, 2, 4), keepdims=True)
    var = xf.var(axis=(1, 2, 4), keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    xf = xf.reshape(b, h, w, c)
    return (xf * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def _init_res(cfg, key, cin, cout):
    ks = jax.random.split(key, 4)
    return {
        "gn1_s": jnp.ones((cin,), cfg.param_dtype),
        "gn1_b": jnp.zeros((cin,), cfg.param_dtype),
        "conv1": _conv_init(ks[0], 3, 3, cin, cout, cfg.param_dtype),
        "temb_w": dense_init(ks[1], cfg.d_model, cout, cfg.param_dtype),
        "gn2_s": jnp.ones((cout,), cfg.param_dtype),
        "gn2_b": jnp.zeros((cout,), cfg.param_dtype),
        "conv2": _conv_init(ks[2], 3, 3, cout, cout, cfg.param_dtype),
        "skip": (_conv_init(ks[3], 1, 1, cin, cout, cfg.param_dtype)
                 if cin != cout else None),
    }


def _res_block(cfg, p, x, temb):
    h = jax.nn.silu(group_norm(x, p["gn1_s"], p["gn1_b"]).astype(jnp.float32)
                    ).astype(x.dtype)
    h = _conv(h, p["conv1"])
    h = h + (jax.nn.silu(temb.astype(jnp.float32)).astype(x.dtype)
             @ p["temb_w"].astype(x.dtype))[:, None, None, :]
    h = jax.nn.silu(group_norm(h, p["gn2_s"], p["gn2_b"]).astype(jnp.float32)
                    ).astype(x.dtype)
    h = _conv(h, p["conv2"])
    skip = x if p["skip"] is None else _conv(x, p["skip"])
    return skip + h


def _init_attnblock(cfg, key, ch):
    ks = jax.random.split(key, 9)
    return {
        "gn_s": jnp.ones((ch,), cfg.param_dtype),
        "gn_b": jnp.zeros((ch,), cfg.param_dtype),
        "self": {"wq": dense_init(ks[0], ch, ch, cfg.param_dtype),
                 "wk": dense_init(ks[1], ch, ch, cfg.param_dtype),
                 "wv": dense_init(ks[2], ch, ch, cfg.param_dtype),
                 "wo": dense_init(ks[3], ch, ch, cfg.param_dtype)},
        "cross": {"wq": dense_init(ks[4], ch, ch, cfg.param_dtype),
                  "wk": dense_init(ks[5], cfg.cond_dim, ch, cfg.param_dtype),
                  "wv": dense_init(ks[6], cfg.cond_dim, ch, cfg.param_dtype),
                  "wo": dense_init(ks[7], ch, ch, cfg.param_dtype)},
    }


def _proj(ctx, x, w, name, rclass):
    if ctx is None:
        return x @ w.astype(x.dtype)
    lead = x.shape[:-1]
    y = ctx.matmul(x.reshape(-1, x.shape[-1]), w.astype(x.dtype),
                   name=name, rclass=rclass)
    return y.reshape(*lead, -1)


def _attn_block(cfg, p, x, text, ctx=None, name="", rclass=dvfs.CLASS_BODY):
    b, hh, ww, c = x.shape
    heads = max(c // 64, 1)
    hd = c // heads
    xn = group_norm(x, p["gn_s"], p["gn_b"]).reshape(b, hh * ww, c)

    def mha(pp, q_src, kv_src, tag):
        q = _proj(ctx, q_src, pp["wq"], f"{name}.{tag}.q", rclass
                  ).reshape(b, -1, heads, hd)
        k = _proj(ctx, kv_src, pp["wk"], f"{name}.{tag}.k", rclass
                  ).reshape(b, -1, heads, hd)
        v = _proj(ctx, kv_src, pp["wv"], f"{name}.{tag}.v", rclass
                  ).reshape(b, -1, heads, hd)
        o = attention.full_attention(q, k, v, causal=False)
        return _proj(ctx, o.reshape(b, -1, heads * hd), pp["wo"],
                     f"{name}.{tag}.o", rclass)

    y = xn + mha(p["self"], xn, xn, "self")
    if text is not None:
        y = y + mha(p["cross"], y, text.astype(x.dtype), "cross")
    return x + y.reshape(b, hh, ww, c)


def init_params(cfg: ModelConfig, key) -> Params:
    chans = cfg.unet_channels            # e.g. (320, 640, 1280)
    ks = iter(jax.random.split(key, 64))
    d = cfg.d_model                      # timestep-embedding width
    p: Params = {
        "t_w1": dense_init(next(ks), 256, d, cfg.param_dtype),
        "t_w2": dense_init(next(ks), d, d, cfg.param_dtype),
        "conv_in": _conv_init(next(ks), 3, 3, cfg.latent_channels, chans[0],
                              cfg.param_dtype),
        "down": [], "mid": {}, "up": [],
        "gn_out_s": jnp.ones((chans[0],), cfg.param_dtype),
        "gn_out_b": jnp.zeros((chans[0],), cfg.param_dtype),
        "conv_out": jnp.zeros((3, 3, chans[0], cfg.latent_channels),
                              cfg.param_dtype),
    }
    cin = chans[0]
    for li, ch in enumerate(chans):
        level = {"res1": _init_res(cfg, next(ks), cin, ch),
                 "res2": _init_res(cfg, next(ks), ch, ch),
                 "attn": (_init_attnblock(cfg, next(ks), ch)
                          if li >= 1 else None),
                 "down": (_conv_init(next(ks), 3, 3, ch, ch, cfg.param_dtype)
                          if li < len(chans) - 1 else None)}
        p["down"].append(level)
        cin = ch
    p["mid"] = {"res1": _init_res(cfg, next(ks), cin, cin),
                "attn": _init_attnblock(cfg, next(ks), cin),
                "res2": _init_res(cfg, next(ks), cin, cin)}
    for li, ch in enumerate(reversed(chans)):
        level = {"res1": _init_res(cfg, next(ks), cin + ch, ch),
                 "res2": _init_res(cfg, next(ks), ch, ch),
                 "attn": (_init_attnblock(cfg, next(ks), ch)
                          if li < len(chans) - 1 else None),
                 "up": (_conv_init(next(ks), 3, 3, ch, ch, cfg.param_dtype)
                        if li < len(chans) - 1 else None)}
        p["up"].append(level)
        cin = ch
    return p


def forward(cfg: ModelConfig, params: Params, latents: jax.Array,
            t: jax.Array, text: Optional[jax.Array],
            ctx: Optional[ExecContext] = None) -> jax.Array:
    """Predict noise. latents (B,H,W,C); t (B,); text (B, Tt, cond_dim)."""
    from repro.models.dit import timestep_embedding
    x = latents.astype(cfg.dtype)
    temb = timestep_embedding(t).astype(cfg.dtype)
    temb = jax.nn.silu((temb @ params["t_w1"].astype(temb.dtype)
                        ).astype(jnp.float32)).astype(cfg.dtype)
    temb = temb @ params["t_w2"].astype(temb.dtype)

    x = constrain(_conv(x, params["conv_in"]), "act")
    skips: List[jax.Array] = []
    for li, lvl in enumerate(params["down"]):
        x = _res_block(cfg, lvl["res1"], x, temb)
        x = _res_block(cfg, lvl["res2"], x, temb)
        if lvl["attn"] is not None:
            x = _attn_block(cfg, lvl["attn"], x, text, ctx, f"down{li}")
        x = constrain(x, "act")
        skips.append(x)
        if lvl["down"] is not None:
            x = _conv(x, lvl["down"], stride=2)
    x = _res_block(cfg, params["mid"]["res1"], x, temb)
    x = _attn_block(cfg, params["mid"]["attn"], x, text, ctx, "mid")
    x = _res_block(cfg, params["mid"]["res2"], x, temb)
    for li, lvl in enumerate(params["up"]):
        x = jnp.concatenate([x, skips[-(li + 1)]], axis=-1)
        x = _res_block(cfg, lvl["res1"], x, temb)
        x = _res_block(cfg, lvl["res2"], x, temb)
        if lvl["attn"] is not None:
            x = _attn_block(cfg, lvl["attn"], x, text, ctx, f"up{li}")
        x = constrain(x, "act")
        if lvl["up"] is not None:
            b, hh, ww, c = x.shape
            x = jax.image.resize(x, (b, hh * 2, ww * 2, c), "nearest")
            x = _conv(x, lvl["up"])
    x = jax.nn.silu(group_norm(x, params["gn_out_s"], params["gn_out_b"]
                               ).astype(jnp.float32)).astype(cfg.dtype)
    return _conv(x, params["conv_out"]).astype(jnp.float32)
