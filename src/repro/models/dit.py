"""Diffusion Transformer (DiT) -- the paper's primary model family.

Faithful DiT (Peebles & Xie) with adaLN-Zero conditioning; PixArt-alpha
variant adds cross-attention to (stub-encoded) text tokens. This is the
model DRIFT protects end-to-end: every projection GEMM routes through an
optional ExecContext, with resilience classes
    patch/timestep/class/text embeddings -> CLASS_EMBED   (Sec 4.3: global
        influence through conditioning at every step -> protected)
    block 0                              -> CLASS_FIRST_BLOCK
    remaining blocks                     -> CLASS_BODY
The rollback checkpoint store is stacked (L, ...) for the block GEMMs plus
a flat dict for the embedding GEMMs, carried by the sampler's scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dvfs
from repro.core.exec_ctx import DriftSystemConfig, ExecContext
from repro.distributed.constraints import constrain
from repro.models import attention, common
from repro.models.common import ModelConfig, Params, dense_init, layernorm


# ---------------------------------------------------------------- params
def _init_attn(cfg: ModelConfig, key, kv_dim: Optional[int] = None) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    kv = kv_dim or d
    h, hd = cfg.n_heads, cfg.hd
    return {"wq": dense_init(ks[0], d, h * hd, cfg.param_dtype),
            "wk": dense_init(ks[1], kv, h * hd, cfg.param_dtype),
            "wv": dense_init(ks[2], kv, h * hd, cfg.param_dtype),
            "wo": dense_init(ks[3], h * hd, d, cfg.param_dtype)}


def _init_block(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 5)
    d, f = cfg.d_model, cfg.d_ff
    p = {
        "adaln_w": jnp.zeros((d, 6 * d), cfg.param_dtype),   # adaLN-Zero
        "adaln_b": jnp.zeros((6 * d,), cfg.param_dtype),
        "attn": _init_attn(cfg, ks[0]),
        "mlp_w1": dense_init(ks[1], d, f, cfg.param_dtype),
        "mlp_w2": dense_init(ks[2], f, d, cfg.param_dtype),
    }
    if cfg.cond_tokens:   # PixArt: cross-attention to text tokens
        p["xattn"] = _init_attn(cfg, ks[3])
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    t = (cfg.latent_size // cfg.patch_size) ** 2
    pdim = cfg.patch_size ** 2 * cfg.latent_channels
    p: Params = {
        "patch_w": dense_init(ks[0], pdim, d, cfg.param_dtype),
        "patch_b": jnp.zeros((d,), cfg.param_dtype),
        "pos_embed": common.trunc_normal(ks[1], (t, d), 0.02, cfg.param_dtype),
        "t_w1": dense_init(ks[2], 256, d, cfg.param_dtype),
        "t_b1": jnp.zeros((d,), cfg.param_dtype),
        "t_w2": dense_init(ks[3], d, d, cfg.param_dtype),
        "t_b2": jnp.zeros((d,), cfg.param_dtype),
        "blocks": common.stack_layer_params(
            lambda k: _init_block(cfg, k), cfg.n_layers, ks[4]),
        "final_adaln_w": jnp.zeros((d, 2 * d), cfg.param_dtype),
        "final_adaln_b": jnp.zeros((2 * d,), cfg.param_dtype),
        "final_w": jnp.zeros((d, pdim), cfg.param_dtype),     # zero-init out
        "final_b": jnp.zeros((pdim,), cfg.param_dtype),
    }
    if cfg.cond_tokens:
        p["text_proj"] = dense_init(ks[5], cfg.cond_dim, d, cfg.param_dtype)
    else:
        p["class_embed"] = common.trunc_normal(
            ks[6], (cfg.num_classes + 1, d), 0.02, cfg.param_dtype)
    return p


# --------------------------------------------------------------- helpers
def timestep_embedding(t: jax.Array, dim: int = 256) -> jax.Array:
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def patchify(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """(B, H, W, C) -> (B, T, p*p*C)."""
    b, hh, ww, c = x.shape
    p = cfg.patch_size
    x = x.reshape(b, hh // p, p, ww // p, p, c)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(b, (hh // p) * (ww // p),
                                                 p * p * c)


def unpatchify(cfg: ModelConfig, x: jax.Array, hh: int, ww: int) -> jax.Array:
    b, t, _ = x.shape
    p = cfg.patch_size
    gh, gw = hh // p, ww // p
    x = x.reshape(b, gh, gw, p, p, cfg.latent_channels)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(b, hh, ww,
                                                 cfg.latent_channels)


def _modulate(x: jax.Array, shift: jax.Array, scale: jax.Array) -> jax.Array:
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


def _proj(ctx, x, w, name, rclass):
    if ctx is None:
        return x @ w.astype(x.dtype)
    lead = x.shape[:-1]
    y = ctx.matmul(x.reshape(-1, x.shape[-1]), w.astype(x.dtype),
                   name=name, rclass=rclass)
    return y.reshape(*lead, -1)


# ---------------------------------------------------------------- blocks
def dit_block(cfg: ModelConfig, p: Params, x: jax.Array, c: jax.Array,
              text: Optional[jax.Array] = None,
              ctx: Optional[ExecContext] = None,
              rclass=dvfs.CLASS_BODY) -> jax.Array:
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    mod = (jax.nn.silu(c.astype(jnp.float32)).astype(x.dtype)
           @ p["adaln_w"].astype(x.dtype) + p["adaln_b"].astype(x.dtype))
    s1, sc1, g1, s2, sc2, g2 = jnp.split(mod, 6, axis=-1)

    xn = _modulate(layernorm(x, None, None), s1, sc1)
    q = _proj(ctx, xn, p["attn"]["wq"], "attn.q", rclass).reshape(b, t, h, hd)
    k = _proj(ctx, xn, p["attn"]["wk"], "attn.k", rclass).reshape(b, t, h, hd)
    v = _proj(ctx, xn, p["attn"]["wv"], "attn.v", rclass).reshape(b, t, h, hd)
    o = attention.attention_any(q, k, v, causal=False)
    o = _proj(ctx, o.reshape(b, t, h * hd), p["attn"]["wo"], "attn.o", rclass)
    x = x + g1[:, None, :] * o

    if text is not None and "xattn" in p:
        xn = layernorm(x, None, None)
        q = _proj(ctx, xn, p["xattn"]["wq"], "xattn.q", rclass
                  ).reshape(b, t, h, hd)
        k = _proj(ctx, text, p["xattn"]["wk"], "xattn.k", rclass
                  ).reshape(b, -1, h, hd)
        v = _proj(ctx, text, p["xattn"]["wv"], "xattn.v", rclass
                  ).reshape(b, -1, h, hd)
        o = attention.full_attention(q, k, v, causal=False)
        x = x + _proj(ctx, o.reshape(b, t, h * hd), p["xattn"]["wo"],
                      "xattn.o", rclass)

    xn = _modulate(layernorm(x, None, None), s2, sc2)
    hdn = _proj(ctx, xn, p["mlp_w1"], "mlp.w1", rclass)
    hdn = jax.nn.gelu(hdn.astype(jnp.float32)).astype(x.dtype)
    x = x + g2[:, None, :] * _proj(ctx, hdn, p["mlp_w2"], "mlp.w2", rclass)
    return x


@dataclasses.dataclass
class DriftState:
    """Checkpoint store + per-step drift inputs threaded by the sampler."""
    cfg: DriftSystemConfig
    key: jax.Array
    step: jax.Array
    ber_by_class: jax.Array
    embed_store: Dict[str, jax.Array]
    block_store: Dict[str, jax.Array]   # leaves stacked (L, ...)
    have_ckpt: Any = False
    # Per-site gates for the block-level resilience study (Fig 6): BER is
    # multiplied by layer_gate[layer] / embed_gate. None = all-on.
    layer_gate: Any = None              # (L,) f32 or None
    embed_gate: Any = None              # scalar f32 or None


def forward(cfg: ModelConfig, params: Params, latents: jax.Array,
            t: jax.Array, cond: jax.Array,
            text: Optional[jax.Array] = None,
            drift: Optional[DriftState] = None
            ) -> Tuple[jax.Array, Optional[DriftState], Dict[str, jax.Array]]:
    """Predict noise. latents: (B,H,W,C); t: (B,); cond: class ids (B,) or
    pooled text if cfg.cond_tokens (then ``text`` is (B, Tt, cond_dim)).

    Returns (eps_pred, new_drift_state_or_None, stats).
    """
    b, hh, ww, _ = latents.shape
    stats: Dict[str, jax.Array] = {}

    ectx = None
    if drift is not None:
        e_ber = drift.ber_by_class
        if drift.embed_gate is not None:
            e_ber = e_ber * drift.embed_gate
        ectx = ExecContext(drift.cfg, key=jax.random.fold_in(drift.key, 1000),
                           step=drift.step, ber_by_class=e_ber,
                           state_in=drift.embed_store,
                           have_ckpt=drift.have_ckpt)

    x = patchify(cfg, latents.astype(cfg.dtype))
    x = _proj(ectx, x, params["patch_w"], "patch", dvfs.CLASS_EMBED)
    x = x + params["patch_b"].astype(x.dtype) + params["pos_embed"].astype(x.dtype)
    x = constrain(x, "act")

    temb = timestep_embedding(t).astype(cfg.dtype)
    temb = _proj(ectx, temb, params["t_w1"], "t.w1", dvfs.CLASS_EMBED)
    temb = jax.nn.silu(temb + params["t_b1"].astype(temb.dtype))
    temb = _proj(ectx, temb, params["t_w2"], "t.w2", dvfs.CLASS_EMBED)
    temb = temb + params["t_b2"].astype(temb.dtype)

    text_proj = None
    if cfg.cond_tokens:
        text_proj = _proj(ectx, text.astype(cfg.dtype), params["text_proj"],
                          "text", dvfs.CLASS_EMBED)
        c = temb + text_proj.mean(axis=1)
    else:
        c = temb + params["class_embed"].astype(cfg.dtype)[cond]

    def body(xc, p_i, extra):
        layer_idx, store_i = extra
        bctx = None
        if drift is not None:
            rcl = jnp.where(layer_idx < 1, dvfs.CLASS_FIRST_BLOCK,
                            dvfs.CLASS_BODY)
            b_ber = drift.ber_by_class
            if drift.layer_gate is not None:
                b_ber = b_ber * jnp.asarray(drift.layer_gate)[layer_idx]
            bctx = ExecContext(drift.cfg,
                               key=jax.random.fold_in(drift.key, layer_idx),
                               step=drift.step,
                               ber_by_class=b_ber,
                               state_in=store_i, have_ckpt=drift.have_ckpt)
            y = dit_block(cfg, p_i, xc, c, text_proj, ctx=bctx, rclass=rcl)
            return constrain(y, "act"), (bctx.state_out,
                                         bctx.stats["corrected_elems"],
                                         bctx.stats["detected_row_errors"])
        y = dit_block(cfg, p_i, xc, c, text_proj)
        return constrain(y, "act"), (None, jnp.int32(0), jnp.int32(0))

    n_layers = cfg.n_layers
    xs = (jnp.arange(n_layers, dtype=jnp.int32),
          drift.block_store if drift is not None else None)
    x, ys = common.scan_layers(body, x, params["blocks"], xs_extra=xs,
                               remat=cfg.remat and drift is None,
                               unroll=not cfg.scan_layers)
    new_block_store, corrected, detected = ys

    mod = (jax.nn.silu(c.astype(jnp.float32)).astype(x.dtype)
           @ params["final_adaln_w"].astype(x.dtype)
           + params["final_adaln_b"].astype(x.dtype))
    shift, scale = jnp.split(mod, 2, axis=-1)
    x = _modulate(layernorm(x, None, None), shift, scale)
    x = _proj(ectx, x, params["final_w"], "final", dvfs.CLASS_EMBED)
    x = x + params["final_b"].astype(x.dtype)
    eps = unpatchify(cfg, x, hh, ww).astype(jnp.float32)

    new_drift = None
    if drift is not None:
        stats["corrected_elems"] = (jnp.sum(corrected)
                                    + ectx.stats["corrected_elems"])
        stats["detected_row_errors"] = (jnp.sum(detected)
                                        + ectx.stats["detected_row_errors"])
        # Per-site detection vector for the resilience heatmap (paper
        # Figs 5-6): row 0 = embedding/conditioning GEMMs, rows 1..L =
        # transformer blocks. Integer counts, so the scalar above stays
        # exactly sum(detected_per_block).
        stats["detected_per_block"] = jnp.concatenate(
            [ectx.stats["detected_row_errors"][None],
             jnp.asarray(detected, jnp.int32)])
        new_drift = dataclasses.replace(
            drift, embed_store=ectx.state_out, block_store=new_block_store)
    return eps, new_drift, stats


def drift_store_spec(cfg: ModelConfig, batch: int
                     ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """(embed_store, block_store) zero-init checkpoint stores.

    Block-store leaves are stacked (L, ...) to ride the layer scan.
    """
    d, f, h, hd = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.hd
    t = (cfg.latent_size // cfg.patch_size) ** 2
    pdim = cfg.patch_size ** 2 * cfg.latent_channels
    bt = batch * t

    embed = {
        "patch": jnp.zeros((bt, d), jnp.float32),
        "t.w1": jnp.zeros((batch, d), jnp.float32),
        "t.w2": jnp.zeros((batch, d), jnp.float32),
        "final": jnp.zeros((bt, pdim), jnp.float32),
    }
    if cfg.cond_tokens:
        embed["text"] = jnp.zeros((batch * cfg.cond_tokens, d), jnp.float32)

    def zb(nout, rows=bt):
        return jnp.zeros((cfg.n_layers, rows, nout), jnp.float32)
    block = {
        "attn.q": zb(h * hd), "attn.k": zb(h * hd), "attn.v": zb(h * hd),
        "attn.o": zb(d), "mlp.w1": zb(f), "mlp.w2": zb(d),
    }
    if cfg.cond_tokens:
        block.update({
            "xattn.q": zb(h * hd),
            "xattn.k": zb(h * hd, batch * cfg.cond_tokens),
            "xattn.v": zb(h * hd, batch * cfg.cond_tokens),
            "xattn.o": zb(d),
        })
    return embed, block


def param_count(cfg: ModelConfig) -> int:
    d, f = cfg.d_model, cfg.d_ff
    per_block = 6 * d * d + 4 * d * d + 2 * d * f
    if cfg.cond_tokens:
        per_block += 4 * d * d
    t = (cfg.latent_size // cfg.patch_size) ** 2
    pdim = cfg.patch_size ** 2 * cfg.latent_channels
    base = (pdim * d + t * d + 256 * d + d * d + 2 * d * d + d * pdim)
    return cfg.n_layers * per_block + base
