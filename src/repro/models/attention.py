"""Attention: GQA, sliding windows, softcap, chunked (online-softmax) path.

Three execution paths, all mask-equivalent:
  * ``full_attention``    -- plain einsum, for short sequences.
  * ``chunked_attention`` -- lax.scan over query/KV chunks with an online
    softmax (flash-attention recurrence in pure XLA). Memory is
    O(q_chunk x kv_chunk) per (batch, head) instead of O(S^2); this is what
    makes the 32k-prefill dry-run cells lowerable at batch 32.
  * ``decode_attention``  -- single-token query against a KV cache.

GQA never materializes repeated KV heads: queries are reshaped to
(B, S, Hkv, G, D) and contracted group-wise.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import softcap

NEG_INF = -2.0e38


def _mask(pos_q: jax.Array, pos_k: jax.Array, causal: bool,
          window) -> jax.Array:
    """(Sq, Sk) boolean validity mask. window<=0 or None -> unbounded."""
    m = jnp.ones((pos_q.shape[0], pos_k.shape[0]), bool)
    if causal:
        m &= pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        m &= jnp.where(w > 0,
                       pos_q[:, None] - pos_k[None, :] < w,
                       True)
    return m


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True,
                   window=None,
                   attn_softcap: float = 0.0,
                   q_offset: jax.Array | int = 0,
                   kv_valid_len=None) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D) -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    scale = d ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = softcap(scores, attn_softcap)
    pos_q = jnp.asarray(q_offset) + jnp.arange(sq)
    pos_k = jnp.arange(sk)
    m = _mask(pos_q, pos_k, causal, window)
    if kv_valid_len is not None:
        m &= (pos_k < kv_valid_len)[None, :]
    scores = jnp.where(m[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True,
                      window=None,
                      attn_softcap: float = 0.0,
                      q_chunk: int = 512,
                      kv_chunk: int = 1024) -> jax.Array:
    """Online-softmax blockwise attention (pure XLA flash recurrence)."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    assert sq % q_chunk == 0 and sk % kv_chunk == 0, (sq, sk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = d ** -0.5

    qg = q.reshape(b, nq, q_chunk, hkv, g, d)
    kc = k.reshape(b, nk, kv_chunk, hkv, d)
    vc = v.reshape(b, nk, kv_chunk, hkv, d)

    def q_block(qi_and_q):
        qi, qb = qi_and_q                       # qb: (b, q_chunk, hkv, g, d)
        pos_q = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kj_and_kv):
            m_prev, l_prev, acc = carry
            kj, (kb, vb) = kj_and_kv
            pos_k = kj * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            s = softcap(s, attn_softcap)
            msk = _mask(pos_q, pos_k, causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_prev - m_cur)
            p = jnp.exp(s - m_cur[..., None])
            l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32))
            acc = acc * alpha[..., None] + pv
            return (m_cur, l_cur, acc), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0))))
        out = acc / jnp.maximum(l_f, 1e-37)[..., None]
        return jnp.einsum("bkgqd->bqkgd", out)    # (b, q_chunk, hkv, g, d)

    outs = jax.lax.map(q_block, (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     pos: jax.Array,
                     window=None,
                     attn_softcap: float = 0.0) -> jax.Array:
    """One-token query vs cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S, Hkv, D); pos: scalar int32 --
    the index the current token occupies (entries > pos are invalid).
    """
    b, _, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    scale = d ** -0.5
    # contract in the cache's native dtype with f32 accumulation -- casting
    # the cache to f32 first materializes a full-cache copy (2x reads + 2x
    # HBM at 500k context; see EXPERIMENTS.md Perf hillclimb #1 iter 2)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(k_cache.dtype), k_cache,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, attn_softcap)
    pos_k = jnp.arange(s)
    valid = pos_k <= pos
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        valid &= jnp.where(w > 0, pos - pos_k < w, True)
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def decode_attention_ring(q: jax.Array, k_ring: jax.Array, v_ring: jax.Array,
                          *, pos: jax.Array,
                          attn_softcap: float = 0.0) -> jax.Array:
    """One-token query vs a WINDOW-SIZED ring-buffer cache.

    k_ring/v_ring: (B, W, Hkv, D) where slot s holds the KV of the most
    recent position p with p % W == s. All resident entries are inside the
    window by construction, so the only masking needed is ring fill level
    (slots > pos are empty until the first wrap).

    This is the production memory layout for local-attention layers
    (gemma-style sliding window): O(W) reads per step instead of O(S).
    """
    b, _, h, d = q.shape
    w, hkv = k_ring.shape[1], k_ring.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    scale = d ** -0.5
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(k_ring.dtype), k_ring,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, attn_softcap)
    valid = jnp.where(pos >= w, True, jnp.arange(w) <= pos)
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_ring.dtype), v_ring,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def attention_any(q, k, v, *, causal=True, window=None, attn_softcap=0.0,
                  chunk_threshold: int = 4096,
                  q_chunk: int = 512, kv_chunk: int = 1024) -> jax.Array:
    """Dispatch: plain einsum for short S, chunked flash path for long S."""
    sq, sk = q.shape[1], k.shape[1]
    if max(sq, sk) <= chunk_threshold or sq % q_chunk or sk % kv_chunk:
        return full_attention(q, k, v, causal=causal, window=window,
                              attn_softcap=attn_softcap)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             attn_softcap=attn_softcap,
                             q_chunk=q_chunk, kv_chunk=kv_chunk)
