"""Error-feedback INT8 gradient compression for cross-pod all-reduce.

At 512+ chips the cross-pod data-parallel all-reduce is the longest-haul
collective (DCI links between pods are ~10x slower than in-pod ICI). We
compress pod-crossing gradients to int8 with per-tensor scales and keep the
quantization residual in an error-feedback buffer (Seide et al. / 1-bit Adam
lineage) so compression noise is unbiased over steps and convergence is
preserved.

Used by train steps as: compress -> psum('pod') on int-ish payload ->
decompress. In-pod reductions stay full precision.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def init_error_buffer(grads: Params) -> Params:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compress(grads: Params, err: Params) -> Tuple[Params, Params, Params]:
    """Returns (q_int8, scales, new_error_buffer)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(gf))
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(jnp.float32) * scale
        return q, scale, new_e

    out = jax.tree.map(one, grads, err)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    e = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return q, s, e


def decompress(q: Params, scales: Params) -> Params:
    return jax.tree.map(lambda qq, ss: qq.astype(jnp.float32) * ss, q, scales)


def allreduce_compressed(grads: Params, err: Params, axis_name: str
                         ) -> Tuple[Params, Params]:
    """Mean-all-reduce over ``axis_name`` with int8 payload + error feedback.

    The int8 payloads are summed in int32 (exact for <=2^23 contributors),
    scales are all-gathered implicitly by psum of scale-weighted floats --
    here we sum dequantized int32 against a psum'd max-scale, which keeps
    the wire payload at 1 byte/grad + 1 scalar/tensor.
    """
    q, s, new_err = compress(grads, err)
    n = jax.lax.psum(1, axis_name)

    def reduce_one(qq, ss):
        acc = jax.lax.psum(qq.astype(jnp.int32), axis_name)
        smax = jax.lax.pmax(ss, axis_name)
        return acc.astype(jnp.float32) * smax / n

    red = jax.tree.map(reduce_one, q, s)
    return red, new_err
