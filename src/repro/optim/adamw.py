"""Optimizers in pure JAX: AdamW (f32 moments) and Adafactor (factored
second moment -- the memory-frugal choice for the 1T-param kimi-k2 cell).

State layout mirrors the param pytree so pjit shards optimizer state with
the same PartitionSpecs as the weights (FSDP-style "zero-3" by default).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    kind: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: Optional[Params]       # adamw first moment
    nu: Optional[Params]       # adamw second moment
    vr: Optional[Params]       # adafactor row stats
    vc: Optional[Params]       # adafactor col stats


def lr_at(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32) + 1.0   # first step gets lr > 0
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _factored_dims(shape) -> Optional[Tuple[int, int]]:
    if len(shape) < 2:
        return None
    # factor the two largest dims (standard Adafactor rule)
    idx = sorted(range(len(shape)), key=lambda i: shape[i])[-2:]
    return min(idx), max(idx)


def init(cfg: OptimConfig, params: Params) -> OptState:
    if cfg.kind == "adamw":
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return OptState(jnp.int32(0), jax.tree.map(zeros, params),
                        jax.tree.map(zeros, params), None, None)
    if cfg.kind == "adafactor":
        def row(p):
            f = _factored_dims(p.shape)
            if f is None:
                return jnp.zeros(p.shape, jnp.float32)
            shape = list(p.shape); del shape[f[1]]
            return jnp.zeros(tuple(shape), jnp.float32)

        def col(p):
            f = _factored_dims(p.shape)
            if f is None:
                return jnp.zeros((1,), jnp.float32)
            shape = list(p.shape); del shape[f[0]]
            return jnp.zeros(tuple(shape), jnp.float32)

        return OptState(jnp.int32(0), None, None,
                        jax.tree.map(row, params), jax.tree.map(col, params))
    raise ValueError(cfg.kind)


def apply(cfg: OptimConfig, state: OptState, params: Params, grads: Params
          ) -> Tuple[Params, OptState, dict]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    lr = lr_at(cfg, state.step)
    step = state.step + 1

    if cfg.kind == "adamw":
        b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step, new_m, new_v, None, None), {
            "grad_norm": gnorm, "lr": lr}

    # ---------------- adafactor (factored 2nd moment, no 1st moment)
    decay = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd_af(p, g, vr, vc):
        f = _factored_dims(p.shape)
        g2 = g * g + 1e-30
        if f is None:
            vr_n = decay * vr + (1 - decay) * g2
            precond = g * jax.lax.rsqrt(vr_n + 1e-30)
            vc_n = vc
        else:
            r, c = f
            vr_n = decay * vr + (1 - decay) * jnp.mean(g2, axis=c)
            vc_n = decay * vc + (1 - decay) * jnp.mean(g2, axis=r)
            denom = jnp.mean(vr_n, axis=None) + 1e-30
            rfac = jnp.expand_dims(vr_n / denom, c)
            cfac = jnp.expand_dims(vc_n, r)
            precond = g * jax.lax.rsqrt(rfac * cfac + 1e-30)
        # update clipping (Adafactor rms-1 rule)
        rms = jnp.sqrt(jnp.mean(precond ** 2) + 1e-30)
        precond = precond / jnp.maximum(1.0, rms)
        newp = (p.astype(jnp.float32) - lr * precond
                - lr * cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), vr_n, vc_n

    out = jax.tree.map(upd_af, params, grads, state.vr, state.vc)
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_vr = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_vc = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_p, OptState(step, None, None, new_vr, new_vc), {
        "grad_norm": gnorm, "lr": lr}
