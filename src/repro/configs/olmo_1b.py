"""olmo-1b [dense] -- 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304,
non-parametric LayerNorm. [arXiv:2402.00838; hf]"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=50304, attn_pattern=("global",),
    norm="nonparam_ln", act="silu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="olmo-1b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    attn_pattern=("global",), norm="nonparam_ln", act="silu",
    dtype=jnp.float32,
)
