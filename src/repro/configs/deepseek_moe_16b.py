"""deepseek-moe-16b [moe] -- 28L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=102400, 2 shared + 64 routed top-6, fine-grained experts.
[arXiv:2401.06066; hf]"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400,
    n_experts=64, n_shared_experts=2, top_k=6, capacity_factor=1.25,
    attn_pattern=("global",), norm="rmsnorm", act="silu",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32, vocab=512,
    n_experts=8, n_shared_experts=2, top_k=3, capacity_factor=8.0,
    attn_pattern=("global",), norm="rmsnorm", act="silu",
    tie_embeddings=False, dtype=jnp.float32,
)
