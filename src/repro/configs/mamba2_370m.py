"""mamba2-370m [ssm] -- 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    norm="rmsnorm", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke", family="ssm",
    n_layers=3, d_model=64, vocab=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=8,
    norm="rmsnorm", dtype=jnp.float32,
)
