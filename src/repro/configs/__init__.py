"""Architecture registry: ``get_config(arch, smoke=False)`` by public id."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

_MODULES: Dict[str, str] = {
    "gemma3-27b": "gemma3_27b",
    "gemma2-9b": "gemma2_9b",
    "olmo-1b": "olmo_1b",
    "glm4-9b": "glm4_9b",
    "whisper-base": "whisper_base",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mamba2-370m": "mamba2_370m",
    "hymba-1.5b": "hymba_1p5b",
    "internvl2-76b": "internvl2_76b",
    "dit-xl-512": "dit_xl_512",
    "pixart-alpha": "pixart_alpha",
    "sd15-unet": "sd15_unet",
}

ASSIGNED_ARCHS = tuple(list(_MODULES)[:10])
PAPER_ARCHS = tuple(list(_MODULES)[10:])
ALL_ARCHS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    name = arch.replace("_", "-")
    if name not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.FULL


def list_archs() -> List[str]:
    return list(_MODULES)
