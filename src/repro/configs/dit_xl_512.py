"""dit-xl-512 (paper arch #1) -- DiT-XL/2 at 512x512: 28L d=1152 16H
d_ff=4608, latent 64x64x4, patch 2 (1024 tokens), 1000 ImageNet classes.
[arXiv:2212.09748 (Peebles & Xie)]"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="dit-xl-512", family="dit",
    n_layers=28, d_model=1152, n_heads=16, n_kv_heads=16, d_ff=4608,
    latent_size=64, latent_channels=4, patch_size=2, num_classes=1000,
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="dit-smoke", family="dit",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
    latent_size=8, latent_channels=4, patch_size=2, num_classes=10,
    norm="layernorm", dtype=jnp.float32, scan_layers=False,
)

# ~100M-parameter trainable variant for the end-to-end training example
TRAIN_100M = ModelConfig(
    name="dit-s-train", family="dit",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    latent_size=16, latent_channels=4, patch_size=2, num_classes=10,
    norm="layernorm", dtype=jnp.float32, scan_layers=True,
)
