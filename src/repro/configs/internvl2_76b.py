"""internvl2-76b [vlm] -- 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256: InternViT frontend STUB (input_specs provides 256 precomputed
patch embeddings) + InternLM2/Llama3-70B-class backbone.
[arXiv:2404.16821; unverified]"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, vis_tokens=256,
    attn_pattern=("global",), norm="rmsnorm", act="silu",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    vis_tokens=8, attn_pattern=("global",), norm="rmsnorm", act="silu",
    tie_embeddings=False, dtype=jnp.float32,
)
