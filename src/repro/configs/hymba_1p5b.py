"""hymba-1.5b [hybrid] -- 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16: parallel attention + mamba heads per layer,
SWA everywhere except 3 global layers (first/middle/last).
[arXiv:2411.13676; hf]"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab=32001, head_dim=64,
    attn_pattern=("local",), global_layer_indices=(0, 15, 31), window=1024,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    norm="rmsnorm", act="silu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="hymba-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16, attn_pattern=("local",), global_layer_indices=(0, 2),
    window=8, ssm_state=8, ssm_expand=2, ssm_head_dim=16, ssm_chunk=8,
    norm="rmsnorm", act="silu", dtype=jnp.float32,
)
