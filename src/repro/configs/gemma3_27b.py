"""gemma3-27b [dense] -- 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global interleave, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504,
    vocab=262144, head_dim=168,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024, norm="rmsnorm", act="gelu", tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="gemma3-27b-smoke", family="dense",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window=8, norm="rmsnorm", act="gelu", tie_embeddings=True,
    dtype=jnp.float32,
)
