"""sd15-unet (paper arch #3) -- Stable Diffusion v1.5 conditional UNet
backbone: channels (320, 640, 1280), latent 64x64x4, CLIP text cond
(77 x 768 stub embeddings). [arXiv:2112.10752]"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="sd15-unet", family="unet",
    n_layers=0, d_model=1280, unet_channels=(320, 640, 1280),
    latent_size=64, latent_channels=4,
    cond_dim=768, cond_tokens=77,
)

SMOKE = ModelConfig(
    name="sd15-smoke", family="unet",
    n_layers=0, d_model=128, unet_channels=(32, 64, 96),
    latent_size=16, latent_channels=4,
    cond_dim=32, cond_tokens=8, dtype=jnp.float32,
)
