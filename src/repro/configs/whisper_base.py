"""whisper-base [audio] -- 6L d_model=512 8H d_ff=2048 vocab=51865,
enc-dec with conv frontend STUB (input_specs provides precomputed 1500-frame
embeddings). [arXiv:2212.04356; unverified]"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51865, n_encoder_layers=6, encoder_seq=1500, cross_attention=True,
    norm="layernorm", act="gelu",
)

SMOKE = ModelConfig(
    name="whisper-base-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    n_encoder_layers=2, encoder_seq=20, cross_attention=True,
    norm="layernorm", act="gelu", dtype=jnp.float32,
)
