"""gemma2-9b [dense] -- 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000, local+global alternating, logit softcap. [arXiv:2408.00118; hf]"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_ff=14336,
    vocab=256000, head_dim=256,
    attn_pattern=("local", "global"), window=4096,
    logit_softcap=30.0, attn_softcap=50.0,
    norm="rmsnorm", act="gelu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma2-9b-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16, attn_pattern=("local", "global"), window=8,
    logit_softcap=30.0, attn_softcap=50.0,
    norm="rmsnorm", act="gelu", dtype=jnp.float32,
)
