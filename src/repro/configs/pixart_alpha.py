"""pixart-alpha (paper arch #2) -- PixArt-alpha-512: DiT backbone 28L d=1152
16H d_ff=4608 + cross-attention to T5-XXL text tokens (stub: input_specs
provides precomputed (B, 120, 4096) embeddings). [arXiv:2310.00426]"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="pixart-alpha", family="dit",
    n_layers=28, d_model=1152, n_heads=16, n_kv_heads=16, d_ff=4608,
    latent_size=64, latent_channels=4, patch_size=2,
    cond_dim=4096, cond_tokens=120,
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="pixart-smoke", family="dit",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
    latent_size=8, latent_channels=4, patch_size=2,
    cond_dim=32, cond_tokens=8,
    norm="layernorm", dtype=jnp.float32, scan_layers=False,
)
