"""Assigned input-shape sets and per-arch cell applicability.

LM shapes (seq_len x global_batch):
  train_4k     4,096 x 256   -- training step
  prefill_32k  32,768 x 32   -- inference prefill
  decode_32k   32,768 x 128  -- one new token, 32k KV cache (serve_step)
  long_500k    524,288 x 1   -- long-context decode (sub-quadratic archs)

Diffusion (paper) shapes:
  denoise_train  latents 64x64x4, batch 256  -- DiT/UNet training step
  sample_512     latents 64x64x4, batch 64   -- one denoising serve step

Skips (recorded here AND in DESIGN.md Sec 4):
  long_500k  : skipped for pure full-attention archs (olmo, glm4, kimi-k2,
               deepseek-moe, internvl2) -- every layer would carry the full
               524288-entry KV cache; run for SSM/hybrid (mamba2, hymba) and
               the local-attention gemma family (gemma3 5:1, gemma2 1:1
               local:global).
  long_500k  : skipped for whisper (decoder context is 448 by design).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                 # train | prefill | decode | denoise_train | sample
    seq_len: int = 0
    global_batch: int = 0


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

DIFFUSION_SHAPES = {
    "denoise_train": ShapeSpec("denoise_train", "denoise_train", 0, 256),
    "sample_512": ShapeSpec("sample_512", "sample", 0, 64),
}

# archs allowed to run the 500k-decode cell (sub-quadratic / local-attention)
LONG_CONTEXT_OK = {"mamba2-370m", "hymba-1.5b", "gemma3-27b", "gemma2-9b"}

LM_ARCHS = ("gemma3-27b", "gemma2-9b", "olmo-1b", "glm4-9b", "whisper-base",
            "kimi-k2-1t-a32b", "deepseek-moe-16b", "mamba2-370m",
            "hymba-1.5b", "internvl2-76b")
DIFFUSION_ARCHS = ("dit-xl-512", "pixart-alpha", "sd15-unet")


def cells_for(arch: str) -> Tuple[str, ...]:
    """Shape cells applicable to an arch (the dry-run/roofline matrix)."""
    if arch in DIFFUSION_ARCHS:
        return tuple(DIFFUSION_SHAPES)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_OK:
        cells.append("long_500k")
    return tuple(cells)


def skipped_cells(arch: str) -> Dict[str, str]:
    if arch in DIFFUSION_ARCHS:
        return {}
    out = {}
    if arch not in LONG_CONTEXT_OK:
        reason = ("decoder max context 448; backbone decode_32k still run"
                  if arch == "whisper-base"
                  else "pure full attention: 500k KV on every layer")
        out["long_500k"] = reason
    return out


def get_shape(name: str) -> ShapeSpec:
    return {**LM_SHAPES, **DIFFUSION_SHAPES}[name]
