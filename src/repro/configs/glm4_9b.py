"""glm4-9b [dense] -- 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552, RoPE, GQA. [hf:THUDM/glm-4-9b]"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab=151552, attn_pattern=("global",),
    norm="rmsnorm", act="silu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="glm4-9b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128, vocab=512,
    attn_pattern=("global",), norm="rmsnorm", act="silu",
    tie_embeddings=False, dtype=jnp.float32,
)
