"""kimi-k2-1t-a32b [moe] -- 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, MoE 384 routed experts top-8 (+1 shared, per the K2 report).
Trillion-parameter MoE (paper-table entry). [arXiv:2501.kimi2; unverified]

Scale notes: ~1.04e12 total params (bf16 weights = ~2.1 TB) -> requires
full (pod, data, model) FSDP+EP sharding at 512 chips (~4 GB/chip) and the
Adafactor optimizer for the training cell (see configs/optim policy).
"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840, head_dim=112,
    n_experts=384, n_shared_experts=1, top_k=8, capacity_factor=1.25,
    attn_pattern=("global",), norm="rmsnorm", act="silu",
    tie_embeddings=False,
    # 1T params: bf16 weights + Adafactor (factored stats) is the only
    # combination that fits 16 GB/chip at 512 ways (see DESIGN.md Sec 5).
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=32, vocab=512,
    head_dim=8, n_experts=8, n_shared_experts=1, top_k=2,
    capacity_factor=8.0, attn_pattern=("global",), norm="rmsnorm",
    act="silu", tie_embeddings=False, dtype=jnp.float32,
)
