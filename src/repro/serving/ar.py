"""Autoregressive decode loop with statistical ABFT + KV-window rollback.

This is the second inference paradigm behind the ``ServableModel``
protocol (docs/servable.md): token-by-token greedy decoding over the
unified LM (``models/transformer.py``, reusing its KV ``Cache``), run
under the same DVFS ladder as the diffusion path, with ReaLM-style
**statistical ABFT** (``kernels/stat_abft.py``) on every projection GEMM
and a KV-cache snapshot/rollback story mirroring the diffusion
checkpoint store:

  * every decode step routes ``attn.{q,k,v,o}`` / ``mlp.{gate,up,down}``
    through a detection-only ``StatAbftContext``: bit flips are injected
    on the float GEMM outputs at the operating point's BER, and per-row
    checksum residuals are compared against the calibrated rounding
    envelope. Detections are summed inside the jitted step -- under a
    sharded mesh that sum lowers to a psum across the ``data`` axis,
    exactly like the diffusion BER monitor's detection tap;
  * decoding proceeds in **windows** of ``rollback_interval`` tokens.
    Before each window the host snapshots ``(cache, last_token)`` --
    O(1), JAX arrays are immutable so a snapshot is a reference. If the
    window reports any detection, the snapshot is restored and the window
    replays with injection scaled to zero (same compiled fn; ``ber_scale``
    is a traced operand, so the replay costs no retrace). Corrupted
    windows therefore revert-and-replay instead of recompute-from-scratch
    -- the KV analogue of the diffusion tile rollback;
  * the shared engine BER monitor (``dvfs.ber_monitor_update``) is fed
    once per primary decode step from the detection count, driving the
    same ``op="auto"`` ladder feedback as diffusion serving.

Unlike the diffusion path there is no inline correction: the existing
``exec_ctx`` "stat_abft" mode corrects against a clean duplicate GEMM,
which would defeat the point -- here detection is cheap (one rank-1
checksum lane) and **correction is the window rollback**.

Compiled-function accounting: ``make_decoder`` returns exactly two jitted
fns (prefill + decode step) per ``SamplerKey``; both fire ``on_trace``
while JAX stages them, so the serving cache's trace counter stays ground
truth. The decode step takes the step index, monitor state, and
``ber_scale`` as traced operands -- one trace serves every step of every
window, primary or replay.

Protection coverage: SSM (mamba2) scans and MoE expert FFNs do not route
through the context (no projection GEMMs on the protected path); for the
``ssm`` family the GEMM word count is zero and detection is a no-op --
the registry still serves it (fault injection off), docs/servable.md
documents the gap.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import dvfs, fault
from repro.kernels import stat_abft
from repro.models import transformer
from repro.models.common import ModelConfig

#: fixed prompt length: prompts are synthetic (seed-derived), a static
#: length keeps the prefill trace unique per SamplerKey.
PROMPT_LEN = 8


def _site_id(name: str) -> int:
    return zlib.crc32(name.encode()) & 0x7FFFFFFF


def prompt_tokens(cfg: ModelConfig, seeds) -> jax.Array:
    """Deterministic per-seed synthetic prompts, (B, PROMPT_LEN) int32."""
    base = jax.random.PRNGKey(0x41525052)  # "ARPR"
    rows = [
        jax.random.randint(jax.random.fold_in(base, int(s)),
                           (PROMPT_LEN,), 0, cfg.vocab, dtype=jnp.int32)
        for s in seeds
    ]
    return jnp.stack(rows)


def protected_words_per_step(cfg: ModelConfig, batch: int) -> int:
    """Static count of GEMM output words routed through the ABFT context
    per decode step (drives the BER-monitor normalization)."""
    d, h, hkv, hd, f = (cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd,
                        cfg.d_ff)
    per_layer = 0
    if cfg.family != "ssm":
        per_layer += h * hd + 2 * hkv * hd + d          # attn.{q,k,v,o}
        if cfg.family != "moe":
            per_layer += 2 * f + d                      # mlp.{gate,up,down}
    return cfg.n_layers * per_layer * batch


class StatAbftContext:
    """Detection-only execution context for one decode layer.

    Duck-typed against ``core.exec_ctx.ExecContext`` where the model
    touches it (``.matmul(x, w, name=, rclass=)`` + ``.stats``): computes
    the clean product in the model dtype, injects DVFS bit flips on the
    float32 view at ``ber_by_class[rclass] * ber_scale``, and (in
    ``stat_abft`` mode) flags rows whose checksum residual exceeds the
    statistical threshold. No correction, no checkpoint store.
    """

    def __init__(self, key: jax.Array, step: jax.Array,
                 ber_by_class: jax.Array, detect: bool):
        self.key = key
        self.step = step
        self.ber_by_class = ber_by_class
        self.detect = detect
        self.stats: Dict[str, jax.Array] = {
            "detected_rows": jnp.float32(0.0),
            "gemm_words": jnp.float32(0.0),
        }

    def matmul(self, x: jax.Array, w: jax.Array, *, name: str,
               rclass) -> jax.Array:
        y = x @ w                                    # clean product
        ber = self.ber_by_class[rclass]
        fkey = fault.site_key(self.key, self.step, _site_id(name), 0)
        y_faulty = fault.inject_f32(y.astype(jnp.float32), fkey, ber)
        if self.detect:
            flagged = stat_abft.detect(x, w, y_faulty)
            self.stats["detected_rows"] += jnp.sum(
                flagged.astype(jnp.float32))
        self.stats["gemm_words"] += jnp.float32(y.size)
        return y_faulty.astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class DecodeConfig:
    """Static decode-loop shape baked into the compiled fns."""
    steps: int                   # tokens to generate (incl. prefill's)
    window: int                  # rollback window, in decode steps
    mode: str                    # "clean" | "faulty" | "stat_abft"
    monitor_target_ber: float


@dataclasses.dataclass(frozen=True)
class DecoderFns:
    """What ``make_decoder`` hands the serving cache: two jitted fns plus
    the static config ``decode_batch`` drives the host loop with."""
    dcfg: DecodeConfig
    prefill: Callable
    step: Callable
    words_per_step: int


class DecodeOut(NamedTuple):
    tokens: jax.Array            # (B, steps) int32 generated tokens
    monitor: dvfs.BerMonitorState
    detections: float            # flagged checksum rows, summed
    rollbacks: int               # windows reverted + replayed
    n_model_evals: int           # prefill + decode steps incl. replays
    n_words: float               # GEMM words checked (0 for clean/ssm)
    # Per-decode-step detection counts, shape (steps, 1) -- the AR twin of
    # SampleOutput.heatmap (one "all" site; decode has no per-block split
    # on the host loop). None for stub decoders that predate it.
    heatmap: Optional[jax.Array] = None


def make_decoder(cfg: ModelConfig, dcfg: DecodeConfig, *,
                 schedule: Optional[dvfs.DvfsSchedule] = None,
                 on_trace: Optional[Callable[[], None]] = None,
                 mesh=None) -> DecoderFns:
    """Build the two compiled fns for one AR serving configuration.

    ``schedule`` is the per-step DVFS BER table (None => fault-free);
    ``mesh`` is accepted for signature parity with the diffusion sampler
    factory -- sharding comes from the engine's ambient mesh/policy at
    trace time, nothing mesh-specific is baked here.
    """
    del mesh
    max_seq = PROMPT_LEN + dcfg.steps
    n_rows = max(dcfg.steps, 1)
    if schedule is not None:
        ber_table = jnp.asarray(schedule.ber_table, jnp.float32)
        n_rows = ber_table.shape[0]
    else:
        ber_table = jnp.zeros((n_rows, dvfs.N_CLASSES), jnp.float32)

    def _prefill(params, tokens):
        if on_trace is not None:
            on_trace()
        logits, cache = transformer.prefill(cfg, params, tokens, max_seq)
        first = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return first, cache

    def _step(params, cache, tok, step, monitor, key, ber_scale):
        if on_trace is not None:
            on_trace()
        if dcfg.mode == "clean":
            logits, cache, _ = transformer.decode_step(
                cfg, params, cache, tok[:, None], None)
            det = jnp.float32(0.0)
            words = jnp.float32(0.0)
        else:
            row = ber_table[jnp.clip(step, 0, n_rows - 1)] * ber_scale
            base = jax.random.fold_in(key, step)

            def ctx_factory(layer_idx):
                return StatAbftContext(
                    key=jax.random.fold_in(base, layer_idx), step=step,
                    ber_by_class=row, detect=(dcfg.mode == "stat_abft"))

            logits, cache, stats = transformer.decode_step_stats(
                cfg, params, cache, tok[:, None], ctx_factory)
            det = stats["detected_rows"]
            words = stats["gemm_words"]
            monitor = dvfs.ber_monitor_update(
                monitor, det,
                max(protected_words_per_step(cfg, tok.shape[0]), 1),
                0, dcfg.monitor_target_ber)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt, cache, monitor, det, words

    return DecoderFns(dcfg=dcfg, prefill=jax.jit(_prefill),
                      step=jax.jit(_step),
                      words_per_step=protected_words_per_step(cfg, 1))


def decode_batch(fns: DecoderFns, params, tokens: jax.Array,
                 monitor0: dvfs.BerMonitorState,
                 run_key: jax.Array,
                 on_window: Optional[Callable[[int], None]] = None,
                 on_replay: Optional[Callable[[int, int], None]] = None
                 ) -> DecodeOut:
    """Host decode loop: prefill, then windows of decode steps with
    snapshot / detect / rollback-replay. See module docstring.

    ``on_window(done_steps)`` / ``on_replay(window_start, window_len)``
    are host-side flight-recorder taps fired after each decoded window /
    each rollback replay; like the diffusion sampler's ``on_window`` they
    run strictly between compiled calls and cannot perturb the tokens.
    """
    dcfg = fns.dcfg
    assert tokens.shape[1] == PROMPT_LEN, tokens.shape
    last_tok, cache = fns.prefill(params, tokens)
    generated = [last_tok]
    monitor = monitor0
    detections = 0.0
    n_words = 0.0
    rollbacks = 0
    n_model_evals = 1                    # the prefill pass
    window = max(dcfg.window, 1)
    det_steps = [0.0]                    # prefill runs clean: no detections

    i = 1
    while i < dcfg.steps:
        n = min(window, dcfg.steps - i)
        snap_cache, snap_tok = cache, last_tok      # O(1): arrays immutable
        window_toks = []
        det_w = 0.0
        for j in range(n):
            step = jnp.int32(i + j)
            last_tok, cache, monitor, det, words = fns.step(
                params, cache, last_tok, step, monitor, run_key,
                jnp.float32(1.0))
            window_toks.append(last_tok)
            det_steps.append(float(det))
            det_w += float(det)
            n_words += float(words)
        detections += det_w
        n_model_evals += n
        if dcfg.mode == "stat_abft" and det_w > 0:
            # Revert the corrupted window and replay it fault-free: same
            # compiled fn, ber_scale=0 (monitor output of the replay is
            # discarded -- the ladder saw the faulty pass, which is the
            # signal it exists for).
            cache, last_tok = snap_cache, snap_tok
            window_toks = []
            for j in range(n):
                step = jnp.int32(i + j)
                last_tok, cache, _m, _d, _w = fns.step(
                    params, cache, last_tok, step, monitor, run_key,
                    jnp.float32(0.0))
                window_toks.append(last_tok)
            rollbacks += 1
            n_model_evals += n
            if on_replay is not None:
                on_replay(i, n)
        generated.extend(window_toks)
        i += n
        if on_window is not None:
            on_window(i)

    toks = jnp.stack(generated, axis=1)             # (B, steps)
    heatmap = jnp.asarray(det_steps, jnp.int32)[:, None]   # (steps, 1)
    return DecodeOut(tokens=toks, monitor=monitor,
                     detections=detections, rollbacks=rollbacks,
                     n_model_evals=n_model_evals, n_words=n_words,
                     heatmap=heatmap)
