"""Sharded DRIFT serving: one micro-batch spread across a device mesh.

``ShardedDriftServeEngine`` is ``DriftServeEngine`` with placement: the
``MicroBatcher``'s fixed-size buckets land on a ``(data, model)``
``jax.sharding.Mesh`` (built by ``launch.mesh.make_serving_mesh``) instead
of one device. The serving loop, request/bucket semantics, caches, and the
Sec 5.1 BER-monitor feedback are byte-identical to the single-device
engine -- only where arrays live changes:

  ======================  =========================  =====================
  array                   axes                       rule
  ======================  =========================  =====================
  latents / batch inputs  batch on ``data``          ``sharding.batch_spec``
  model params            TP on ``model``, FSDP on   ``sharding.param_specs``
                          ``data`` (DiT rules)
  BER-monitor state       replicated                 ``sharding.replicated``
  detected-error counts   psum over ``data``         GSPMD (sum over the
                                                     sharded batch dim)
  checkpoint stores       follow their activations   GSPMD propagation
  ======================  =========================  =====================

Because the batch dimension never mixes examples inside the sampler, a
data-parallel mesh computes bit-identical latents to the single-device
engine for the same seeds (the sharded CI job asserts this); a ``model``
axis > 1 re-associates GEMM reductions and is only numerically close.

The BER-monitor ladder stays well-ordered exactly as before: batches run
sequentially, each batch's ABFT detection counts are reduced across the
mesh into a replicated scalar before the monitor update, and the engine
carries the replicated monitor state into the next batch -- so per-request
``op="auto"`` reads one shared ladder no matter how many devices served
the bucket.

Single-device degradation: ``make_engine`` returns the plain
``DriftServeEngine`` when there is nothing to shard over
(``jax.device_count() == 1`` or a size-1 mesh), so callers can use it
unconditionally::

    from repro.serving.sharded import make_engine

    engine = make_engine(bucket=8, model_parallel=1)   # sharded if >1 dev
    engine.submit(steps=10, mode="drift", op="auto", seed=0)
    results = engine.run()

Streaming (``run_stream``) and the deadline scheduler compose unchanged:
the windowed sampler pins the same placements at window boundaries, so a
streamed data-parallel run stays bit-identical to the single-device
one-shot path (asserted in tests/test_serving_sharded.py), and
``DeadlineScheduler`` only swaps the batcher -- nothing mesh-related.

Testable on CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(set before the first jax import); see tests/test_serving_sharded.py and
docs/serving.md.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.diffusion import sampler as sampler_lib
from repro.distributed import constraints
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_lib
from repro.serving.cache import SamplerKey
from repro.serving.engine import DriftServeEngine


class ShardedDriftServeEngine(DriftServeEngine):
    """DriftServeEngine whose micro-batches run SPMD across a device mesh."""

    def __init__(self, mesh: Optional[Mesh] = None, model_parallel: int = 1,
                 **kw):
        self.mesh = mesh if mesh is not None else \
            mesh_lib.make_serving_mesh(model_parallel)
        if "pod" in self.mesh.axis_names:
            raise ValueError("serving meshes are (data, model); multi-pod "
                             "training meshes do not apply here")
        self._mesh_shape = tuple(
            (a, int(self.mesh.shape[a])) for a in self.mesh.axis_names)
        kw.setdefault("sampler_factory", self._sharded_sampler_factory)
        super().__init__(**kw)
        bucket = self.batcher.bucket
        dsize = shd.axis_size(self.mesh, "data")
        if bucket % dsize:
            # batch_spec degrades to a replicated batch; correct but wasteful
            print(f"[sharded] bucket={bucket} not divisible by data axis "
                  f"{dsize}: batch will be replicated, not sharded")

    # ------------------------------------------------------------ placement
    def _sampler_key_extra(self, bucket: int) -> Dict[str, object]:
        bucket_spec = shd.batch_spec((bucket, 1, 1, 1), self.mesh)
        return {"mesh_shape": self._mesh_shape,
                "batch_spec": shd.spec_str(bucket_spec)}

    def _sharded_sampler_factory(self, key: SamplerKey, model_cfg, scfg,
                                 on_trace):
        # on_carry: the checkpoint-offload tap works unchanged on the mesh
        # -- snapshots read the shard-resident store leaves (device->host
        # per addressable shard, shardings recorded for restore), and the
        # commit decision consumes only replicated inputs: the trace-
        # static step count and the monitor state, whose detection sums
        # were already psum-reduced across the mesh. Every shard therefore
        # agrees on every commit/skip with no extra collective.
        return sampler_lib.make_sampler(model_cfg, scfg, on_trace=on_trace,
                                        mesh=self.mesh,
                                        stream_window=key.stream,
                                        on_window=self._on_stream_window,
                                        on_carry=self._offload_on_carry)

    def _params_for(self, arch: str, smoke: bool):
        k = (arch, smoke)
        if k not in self._params:
            params = super()._params_for(arch, smoke)
            self._params[k] = jax.device_put(
                params, shd.shardings_for(params, self.mesh))
        return self._params[k]

    def place_inputs(self, tree):
        # Batch-shaped staged inputs (whatever the paradigm's ServableModel
        # built) get sharded along ``data``; jax.tree.map skips None leaves.
        put = lambda x: jax.device_put(
            x, NamedSharding(self.mesh, shd.batch_spec(x.shape, self.mesh)))
        return jax.tree.map(put, tree)

    # ------------------------------------------------------------ one batch
    def _run_batch(self, mb):
        # the MeshPolicy anchors activation shardings inside the model (see
        # distributed/constraints.py) and the ambient mesh lets bare
        # PartitionSpecs inside the jitted sampler resolve; restore both so
        # a sharded engine can coexist with single-device ones in-process.
        prev = constraints.get_policy()
        constraints.set_policy(constraints.MeshPolicy(self.mesh))
        try:
            with self.mesh:
                return super()._run_batch(mb)
        finally:
            constraints.set_policy(prev)

    def _run_batch_stream(self, mb, preview_interval):
        # Same mesh/policy bracketing as _run_batch, but held open across
        # the whole generator: every window (and the consumer code between
        # yields) runs inside it. The engine is single-threaded, so don't
        # interleave another engine's batches while a stream is mid-batch.
        prev = constraints.get_policy()
        constraints.set_policy(constraints.MeshPolicy(self.mesh))
        try:
            with self.mesh:
                yield from super()._run_batch_stream(mb, preview_interval)
        finally:
            constraints.set_policy(prev)


def make_engine(mesh: Optional[Mesh] = None, model_parallel: int = 1,
                **kw) -> DriftServeEngine:
    """Build the widest engine the process supports.

    Returns ``ShardedDriftServeEngine`` on a multi-device mesh, or the
    plain single-device ``DriftServeEngine`` when ``jax.device_count() == 1``
    (or the caller hands in a size-1 mesh) -- the graceful-degradation
    entry point launchers should use.
    """
    if mesh is not None and model_parallel != 1:
        raise ValueError("pass either an explicit mesh or model_parallel, "
                         "not both")
    if mesh is None and jax.device_count() == 1:
        return DriftServeEngine(**kw)
    if mesh is not None and mesh.size == 1:
        return DriftServeEngine(**kw)
    return ShardedDriftServeEngine(mesh=mesh, model_parallel=model_parallel,
                                   **kw)
