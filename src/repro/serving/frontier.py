"""Compute-optimal request policy: the joint (steps, precision,
TaylorSeer, DVFS) Pareto frontier the scheduler picks from.

DRIFT's Sec 6 design-space exploration treats steps, precision, and the
DVFS operating point as ONE co-design space, but the PR 3 scheduler only
trades steps vs overclock. Following DiffPro (joint timestep + precision
optimization) and the compute-optimal-deployment argument of "Fewer
Denoising Steps or Cheaper Per-Step Inference", this module precomputes,
per (arch, bucket, requested-steps) configuration, the Pareto frontier of

    knob point  =  (DVFS op, step count, precision plan, TaylorSeer)
    cost vector =  (quality proxy MAX, energy_j MIN, latency_s MIN)

and the ``DeadlineScheduler`` consults it whenever a request states a
frontier objective (``energy_budget_j`` / ``quality_floor``): minimum
energy meeting the deadline, or minimum latency meeting the quality
floor, or maximum quality inside the budget.

Pricing is the SAME perfmodel the engine bills results with
(``perfmodel.energy.run_cost`` -- V^2 energy scaling, frequency latency
scaling, TaylorSeer skip schedule, the ``body_bits`` precision branches),
so a frontier projection equals the engine's virtual-clock charge for
that configuration. Energy is quoted per request slot assuming a full
bucket; latency is the shared full-bucket batch latency. The residual
checkpoint-offload stall is deliberately NOT in the point (it depends on
the engine's offload store); the scheduler adds
``engine.offload_stall_s`` on top when filtering against a deadline.

The quality proxy is derived from the resilience metrics the repo
already ranks configurations by -- it is an *ordering* device for the
frontier, not a calibrated LPIPS predictor:

* ``(steps / requested) ** 0.35`` -- diminishing returns of DDIM steps
  (DiffPro's observation: quality collapses only under a handful);
* ``1 - 2.0 * excess_noise * body_frac`` -- precision term: the narrowed
  plan's quantization step in excess of the INT8 baseline
  (``core.quant.quant_noise``), weighted by the resilient-body MAC share
  (the sensitive sites never narrow, mirroring ``core.policies``'
  CLASS_EMBED/CLASS_FIRST_BLOCK protection). Exactly 1.0 for "int8".
* ``1 - 0.15 * skipped_frac`` -- TaylorSeer term: forecast steps reuse
  stale features; the skipped fraction uses the exact compute schedule
  ``run_cost`` prices (interval 3, first ``nominal_steps`` protected);
* ``1 - 0.05 * min(1, ber_of(op) / 3e-3)`` -- DVFS term: residual error
  exposure at the point's BER relative to the monitor target; ~1.0 at
  nominal, 0.95 at the deep-undervolt/overclock corners.

All four factors live in (0, 1] and are monotone the way the invariant
tests demand: fewer steps or fewer bits never raise quality at a fixed
op. The product form keeps the proxy free of cross terms, so dominance
pruning (``pareto_front``, the ``serving/offload/planner.py`` helper
pattern lifted to three objectives) is exact.

Frontiers are memoized per (arch, bucket, requested steps, mode,
rollback interval) exactly like ``engine.auto_rollback_interval`` -- the
sweep is pure arithmetic (~2k points) and never touches a trace.

Worked example + the scheduler's selection rules: docs/frontier.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import dvfs as dvfs_lib
from repro.core import quant as quant_lib
from repro.core.rollback import DEFAULT_INTERVAL
from repro.perfmodel import energy as energy_lib

# Modes that pay ABFT + checkpoint overheads (mirrors engine's table;
# duplicated here to keep this module importable without the engine).
_PROTECTED_MODES = ("drift", "thundervolt", "approx_abft", "dmr",
                    "stat_abft")

#: The DVFS knob: every monitor-ladder point plus the speed-mode
#: overclock corner (the escalation target the PR 3 ladder already uses).
FRONTIER_OPS: Tuple[dvfs_lib.OperatingPoint, ...] = \
    dvfs_lib.OP_LADDER + (dvfs_lib.OVERCLOCK,)

#: TaylorSeer compute interval the pricing (and the servable's RunConfig)
#: assumes -- keep in sync with DiffusionServable.finalize.
TAYLORSEER_INTERVAL = 3

# Quality-proxy coefficients (see module docstring for the derivation).
_STEP_EXPONENT = 0.35
_PREC_WEIGHT = 2.0
_TS_WEIGHT = 0.15
_OP_WEIGHT = 0.05
_OP_BER_SCALE = 3e-3          # the monitor target the ladder regulates to
#: Resilient-body share of per-step MACs (1 - embedding share); constant
#: so the precision term cannot break monotonicity in the step count.
_BODY_FRAC = 1.0 - 0.02


@dataclasses.dataclass(frozen=True)
class FrontierPoint:
    """One knob assignment with its priced cost vector."""
    op: str                    # operating-point name (FRONTIER_OPS)
    steps: int                 # DDIM step count
    precision: str             # core.quant.PRECISION_PLANS name
    taylorseer: bool
    quality: float             # proxy in (0, 1], maximize
    energy_j: float            # per request slot at a full bucket, minimize
    latency_s: float           # full-bucket batch latency, minimize

    def knobs(self) -> Tuple[str, int, str, bool]:
        return (self.op, self.steps, self.precision, self.taylorseer)


def sort_key(p: FrontierPoint) -> Tuple:
    """Deterministic total order: best quality first, then cheapest, then
    the knob tuple -- the scheduler's final tie-break, so equal-cost picks
    never depend on enumeration order."""
    return (-p.quality, p.energy_j, p.latency_s, p.op, -p.steps,
            p.precision, p.taylorseer)


def dominates(a: FrontierPoint, b: FrontierPoint) -> bool:
    """True iff ``a`` is at least as good as ``b`` on every objective and
    strictly better on at least one (quality max; energy/latency min)."""
    ge = (a.quality >= b.quality and a.energy_j <= b.energy_j
          and a.latency_s <= b.latency_s)
    gt = (a.quality > b.quality or a.energy_j < b.energy_j
          or a.latency_s < b.latency_s)
    return ge and gt


def pareto_front(points: Sequence[FrontierPoint]) -> List[FrontierPoint]:
    """Non-dominated subset over (quality, energy_j, latency_s), ties
    kept -- ``serving/offload/planner.pareto_frontier`` lifted to three
    objectives. Returned in :func:`sort_key` order (deterministic)."""
    out = [p for p in points
           if not any(dominates(q, p) for q in points)]
    return sorted(out, key=sort_key)


def taylorseer_computed_steps(steps: int, nominal_steps: int) -> int:
    """Computed (non-forecast) steps under TaylorSeer -- the exact
    schedule ``energy.run_cost`` prices: every ``TAYLORSEER_INTERVAL``-th
    step plus the protected first ``nominal_steps``."""
    return sum(1 for s in range(steps)
               if s % TAYLORSEER_INTERVAL == 0 or s < nominal_steps)


def quality_proxy(steps: int, requested_steps: int,
                  plan: quant_lib.PrecisionPlan, taylorseer: bool,
                  op: dvfs_lib.OperatingPoint,
                  nominal_steps: int = 2) -> float:
    """Resilience-derived quality ordering for one knob point (see module
    docstring). 1.0 only for (requested steps, baseline precision,
    TaylorSeer off) at a BER-free operating point; monotone
    non-increasing as steps shrink or ``plan`` narrows at a fixed op."""
    assert 1 <= steps <= requested_steps, (steps, requested_steps)
    q_steps = (steps / requested_steps) ** _STEP_EXPONENT
    excess = quant_lib.quant_noise(plan.body_bits) \
        - quant_lib.quant_noise(quant_lib.BASE_BITS)
    q_prec = 1.0 - _PREC_WEIGHT * excess * _BODY_FRAC
    if taylorseer:
        skipped = 1.0 - taylorseer_computed_steps(steps, nominal_steps) \
            / steps
        q_ts = 1.0 - _TS_WEIGHT * skipped
    else:
        q_ts = 1.0
    q_op = 1.0 - _OP_WEIGHT * min(1.0, dvfs_lib.ber_of(op) / _OP_BER_SCALE)
    return q_steps * q_prec * q_ts * q_op


class FrontierBuilder:
    """Per-(arch config, bucket, requested steps) frontier enumerator.

    Mirrors ``OffloadPlanner``: constructed once with the engine's energy
    model and protection constants, consulted per configuration, memoized
    (``auto_rollback_interval`` style) because the sweep re-prices ~2k
    pure-arithmetic points.
    """

    def __init__(self, em: Optional[energy_lib.EnergyModel] = None,
                 nominal_steps: int = 2, min_steps: int = 4,
                 ops: Tuple[dvfs_lib.OperatingPoint, ...] = FRONTIER_OPS,
                 plans: Optional[Iterable[quant_lib.PrecisionPlan]] = None
                 ) -> None:
        self.em = em if em is not None else energy_lib.calibrate()
        self.nominal_steps = nominal_steps
        self.min_steps = min_steps
        self.ops = ops
        self.plans = tuple(plans) if plans is not None \
            else tuple(quant_lib.PRECISION_PLANS.values())
        self._memo: Dict[tuple, List[FrontierPoint]] = {}

    # ------------------------------------------------------------ pricing
    def price(self, cfg, op: dvfs_lib.OperatingPoint, steps: int,
              requested_steps: int, plan: quant_lib.PrecisionPlan,
              taylorseer: bool, bucket: int, mode: str = "drift",
              rollback_interval: int = DEFAULT_INTERVAL) -> FrontierPoint:
        """One knob point's cost vector, priced exactly as the engine
        bills it (same RunConfig shape ``DiffusionServable.finalize``
        builds, minus the realized rollback-recovery traffic, which is
        unknowable at admission time)."""
        protected = mode in _PROTECTED_MODES
        rc = energy_lib.RunConfig(
            num_steps=steps, nominal_steps=self.nominal_steps,
            aggressive=op,
            ckpt_interval=rollback_interval if protected else 10 ** 9,
            abft_enabled=protected,
            taylorseer_interval=TAYLORSEER_INTERVAL if taylorseer else 0,
            body_bits=plan.body_bits)
        cost = energy_lib.run_cost(cfg, rc, batch=bucket, em=self.em)
        return FrontierPoint(
            op=op.name, steps=steps, precision=plan.name,
            taylorseer=taylorseer,
            quality=quality_proxy(steps, requested_steps, plan, taylorseer,
                                  op, self.nominal_steps),
            energy_j=cost["energy_j"] / bucket,
            latency_s=cost["latency_s"])

    def enumerate(self, cfg, requested_steps: int, bucket: int,
                  mode: str = "drift",
                  rollback_interval: int = DEFAULT_INTERVAL
                  ) -> List[FrontierPoint]:
        """The FULL knob space, unpruned -- the brute-force ground truth
        the frontier tests compare the pruned set (and the scheduler's
        pick) against. Steps sweep from ``requested_steps`` down to
        ``min_steps`` (never above the request: the frontier degrades,
        it does not spend more than asked)."""
        floor = min(requested_steps, self.min_steps)
        points = []
        for op in self.ops:
            for steps in range(requested_steps, floor - 1, -1):
                for plan in self.plans:
                    for ts in (False, True):
                        points.append(self.price(
                            cfg, op, steps, requested_steps, plan, ts,
                            bucket, mode, rollback_interval))
        return points

    def frontier(self, cfg, requested_steps: int, bucket: int,
                 mode: str = "drift",
                 rollback_interval: int = DEFAULT_INTERVAL
                 ) -> List[FrontierPoint]:
        """Memoized Pareto set for one configuration, in :func:`sort_key`
        order. The memo key carries everything the pricing depends on."""
        key = (cfg.name, requested_steps, bucket, mode,
               int(rollback_interval))
        cached = self._memo.get(key)
        if cached is None:
            cached = self._memo[key] = pareto_front(self.enumerate(
                cfg, requested_steps, bucket, mode, rollback_interval))
        return cached


def _main() -> None:
    """Print one arch's frontier (the docs/frontier.md worked example)."""
    import argparse
    import json

    from repro import configs

    ap = argparse.ArgumentParser(
        description="Enumerate the compute-optimal serving frontier for "
                    "one (arch, bucket, steps) configuration.")
    ap.add_argument("--arch", default="dit-xl-512")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--bucket", type=int, default=2)
    ap.add_argument("--mode", default="drift")
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable JSON instead of a table")
    args = ap.parse_args()

    builder = FrontierBuilder()
    cfg = configs.get_config(args.arch)
    full = builder.enumerate(cfg, args.steps, args.bucket, args.mode)
    front = builder.frontier(cfg, args.steps, args.bucket, args.mode)
    if args.json:
        print(json.dumps({
            "arch": args.arch, "enumerated": len(full),
            "frontier": [dataclasses.asdict(p) for p in front]}))
        return
    print(f"# {args.arch} steps={args.steps} bucket={args.bucket} "
          f"mode={args.mode}: {len(front)} frontier points "
          f"of {len(full)} enumerated")
    print(f"{'op':>14} {'steps':>5} {'precision':>10} {'ts':>3} "
          f"{'quality':>8} {'energy_j':>9} {'latency_s':>9}")
    for p in front:
        print(f"{p.op:>14} {p.steps:>5} {p.precision:>10} "
              f"{'on' if p.taylorseer else 'off':>3} {p.quality:>8.4f} "
              f"{p.energy_j:>9.4f} {p.latency_s:>9.5f}")


if __name__ == "__main__":
    _main()
