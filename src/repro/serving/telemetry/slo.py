"""SLO engine: burn-rate objectives evaluated on the engine's virtual clock.

The serving stack's deadline misses, detection rates, billed energy, and
queue waits were observable one metric at a time but never judged against
*objectives*. This module closes that gap with SRE-style multiwindow
burn-rate alerting, with one deliberate twist: every window is measured
on the engine's **virtual clock** (``engine.clock_s``, modeled-accelerator
seconds), not wall time -- the host runs smoke models on CPU, so wall
windows would make SLO state a function of the machine the test ran on.
On the virtual clock the whole evaluation is deterministic: the same
request stream produces the same burn rates, breaches included
(tests/test_energy_slo.py pins exact values).

Objectives (``OBJECTIVES``), each with a target from :class:`SLOConfig`:

``deadline_miss_rate``
    fraction of requests completed past their deadline in the window;
    requests without a deadline don't count against the budget.
``ber_detection_rate``
    window mean of the BER monitor's post-batch estimate over monitored
    batches, normalized by the engine's target BER.
``energy_per_request_j``
    window mean of per-request billed energy (the ledger total).
``queue_wait_p99_s``
    nearest-rank p99 of per-request virtual-clock queue waits.

Burn rate = observed / target, per window. Two windows run per objective
-- ``fast`` (recent spike detector) and ``slow`` (sustained burn) -- and
an objective is **breached** only when BOTH exceed
``SLOConfig.breach_threshold``, the standard multiwindow guard against
paging on a single bad batch. Breach state is edge-counted into
``drift_slo_breaches_total`` and the energy objective's breach feeds the
GuardbandController (``set_energy_slo_breach``): while the energy SLO
burns, ``op="auto"`` is pinned to the guardband floor -- the cheapest
operating point reliability currently allows.

Surfaces: ``GET /slo`` (wire format in docs/slo.md) and the
``drift_slo_burn_rate{objective,window}`` gauges.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

from repro.serving.telemetry.metrics import nearest_rank

OBJECTIVES = ("deadline_miss_rate", "ber_detection_rate",
              "energy_per_request_j", "queue_wait_p99_s")
WINDOWS = ("fast", "slow")

# Bound on retained events; windows are virtual-time bounded anyway, this
# is the memory backstop for degenerate clocks (e.g. zero-latency stubs).
_MAX_EVENTS = 4096


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Objective targets + burn-rate window geometry (virtual seconds)."""
    # Fraction of deadline-carrying requests allowed to miss.
    deadline_miss_rate: float = 0.01
    # BER target as a multiple of the engine's monitor target (1.0 = the
    # monitor target itself is the objective).
    ber_target_ratio: float = 1.0
    # Mean billed joules per request the fleet budgets for. The default
    # comfortably covers a full 50-step DiT-XL-512 baseline sample
    # (~6 J, Table 1); deployments size it to their power envelope.
    energy_per_request_j: float = 8.0
    # p99 virtual-clock queue wait budget.
    queue_wait_p99_s: float = 1.0
    # Burn-rate windows on the virtual clock. Fast catches spikes, slow
    # confirms they are sustained; both must burn to breach.
    fast_window_s: float = 5.0
    slow_window_s: float = 60.0
    # Burn rate both windows must exceed for a breach.
    breach_threshold: float = 1.0


@dataclasses.dataclass(frozen=True)
class _RequestEvent:
    clock_s: float
    has_deadline: bool
    missed: bool
    energy_j: float
    queue_wait_s: float


class SLOTracker:
    """Rolling virtual-clock SLO evaluation for one engine.

    The engine's telemetry calls :meth:`observe_batch` once per served
    batch (deterministic order -- the engine is single-threaded); every
    read (:meth:`burn_rates`, :meth:`snapshot`, :attr:`breached`) is pure
    over the retained events, so HTTP reads racing a drain see a
    consistent last-batch state.
    """

    def __init__(self, target_ber: float,
                 config: Optional[SLOConfig] = None) -> None:
        assert target_ber > 0, target_ber
        self.cfg = config or SLOConfig()
        self.target_ber = float(target_ber)
        self.now_s = 0.0
        self.batches = 0
        self._requests: Deque[_RequestEvent] = collections.deque(
            maxlen=_MAX_EVENTS)
        self._ber: Deque[Tuple[float, float]] = collections.deque(
            maxlen=_MAX_EVENTS)           # (clock_s, ema_ber), monitored
        self.breached: Dict[str, bool] = {obj: False for obj in OBJECTIVES}

    # ------------------------------------------------------------- targets
    def target(self, objective: str) -> float:
        cfg = self.cfg
        if objective == "deadline_miss_rate":
            return cfg.deadline_miss_rate
        if objective == "ber_detection_rate":
            return cfg.ber_target_ratio * self.target_ber
        if objective == "energy_per_request_j":
            return cfg.energy_per_request_j
        if objective == "queue_wait_p99_s":
            return cfg.queue_wait_p99_s
        raise KeyError(f"unknown SLO objective {objective!r}; "
                       f"one of {OBJECTIVES}")

    # ------------------------------------------------------------- observe
    def observe_batch(self, clock_s: float, ema_ber: float,
                      monitored: bool, results) -> None:
        """Fold one served batch in and re-evaluate breach state."""
        self.now_s = float(clock_s)
        self.batches += 1
        if monitored:
            self._ber.append((self.now_s, float(ema_ber)))
        for res in results:
            self._requests.append(_RequestEvent(
                clock_s=self.now_s,
                has_deadline=res.deadline_s is not None,
                missed=bool(res.deadline_missed),
                energy_j=float(res.energy_j),
                queue_wait_s=float(res.queue_wait_s)))
        self._evict()
        thr = self.cfg.breach_threshold
        burns = self.burn_rates()
        self.breached = {
            obj: (burns[(obj, "fast")] > thr and burns[(obj, "slow")] > thr)
            for obj in OBJECTIVES}

    def _evict(self) -> None:
        horizon = self.now_s - max(self.cfg.fast_window_s,
                                   self.cfg.slow_window_s)
        while self._requests and self._requests[0].clock_s < horizon:
            self._requests.popleft()
        while self._ber and self._ber[0][0] < horizon:
            self._ber.popleft()

    # -------------------------------------------------------------- values
    def _window_requests(self, window_s: float) -> List[_RequestEvent]:
        cut = self.now_s - window_s
        return [e for e in self._requests if e.clock_s >= cut]

    def value(self, objective: str, window_s: float) -> float:
        """Observed value of one objective over one trailing window."""
        if objective == "ber_detection_rate":
            cut = self.now_s - window_s
            bers = [b for t, b in self._ber if t >= cut]
            return sum(bers) / len(bers) if bers else 0.0
        events = self._window_requests(window_s)
        if objective == "deadline_miss_rate":
            carrying = [e for e in events if e.has_deadline]
            if not carrying:
                return 0.0
            return sum(e.missed for e in carrying) / len(carrying)
        if objective == "energy_per_request_j":
            if not events:
                return 0.0
            return sum(e.energy_j for e in events) / len(events)
        if objective == "queue_wait_p99_s":
            if not events:
                return 0.0
            return nearest_rank(sorted(e.queue_wait_s for e in events), 99)
        raise KeyError(f"unknown SLO objective {objective!r}")

    def burn_rates(self) -> Dict[Tuple[str, str], float]:
        """``{(objective, window): observed / target}`` for every pair."""
        out: Dict[Tuple[str, str], float] = {}
        for obj in OBJECTIVES:
            target = self.target(obj)
            for win, span in (("fast", self.cfg.fast_window_s),
                              ("slow", self.cfg.slow_window_s)):
                v = self.value(obj, span)
                out[(obj, win)] = v / target if target > 0 else 0.0
        return out

    # ------------------------------------------------------------ breaches
    @property
    def energy_breached(self) -> bool:
        return self.breached["energy_per_request_j"]

    @property
    def any_breached(self) -> bool:
        return any(self.breached.values())

    def breached_objectives(self) -> Tuple[str, ...]:
        return tuple(obj for obj in OBJECTIVES if self.breached[obj])

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> Dict[str, object]:
        """The ``GET /slo`` body: per-objective targets, windowed values,
        burn rates, and breach state, plus the window geometry -- all
        deterministic functions of the virtual clock."""
        burns = self.burn_rates()
        objectives = {}
        for obj in OBJECTIVES:
            objectives[obj] = {
                "target": self.target(obj),
                "value_fast": self.value(obj, self.cfg.fast_window_s),
                "value_slow": self.value(obj, self.cfg.slow_window_s),
                "burn_fast": burns[(obj, "fast")],
                "burn_slow": burns[(obj, "slow")],
                "breached": self.breached[obj],
            }
        return {
            "clock_s": self.now_s,
            "batches": self.batches,
            "windows": {"fast_s": self.cfg.fast_window_s,
                        "slow_s": self.cfg.slow_window_s},
            "breach_threshold": self.cfg.breach_threshold,
            "objectives": objectives,
        }
