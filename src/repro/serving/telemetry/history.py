"""Served-batch history store + learned latency estimator.

The DeadlineScheduler's admission projections are only as good as its
latency estimates. The perfmodel clock is *worst-case-calibrated and
open-loop*: it prices a configuration once and never looks at what the
engine actually measured. This module closes that gap (the Energy
Scaling Laws argument -- deployment decisions should be driven by
measured, not modeled, cost):

* ``BatchObservation`` -- one served batch's measured latency, stamped
  with the full pricing key ``(arch, op, steps, bucket)`` plus the
  engine clock and batch index;
* ``LatencyEstimator`` -- per-key online model: an EWMA point estimate
  plus a bounded window of raw observations for percentile queries
  (p50/p99 feed the benchmark trajectory and the backlog projection's
  tail view). The key carries mode/taylorseer/rollback_interval
  discriminators beyond the scheduler's pricing signature so
  differently-billed batches never pool.

Contract with the scheduler (``serving/scheduler.py``):

* ``estimate_s`` returns ``None`` until ``min_observations`` batches of
  that key have been served -- the scheduler then falls back to the
  perfmodel clock, making the empty-history path **bit-identical** to
  the pre-telemetry scheduler (asserted in tests/test_telemetry.py and
  the 8-device twin in tests/test_serving_sharded.py);
* once history exists, the estimate is
  ``max(EWMA, percentile(conservative_percentile))`` -- the EWMA tracks
  drift quickly, the percentile guard keeps one lucky fast batch from
  under-promising completion times (admission must stay conservative).

The estimator is plain host-side Python fed once per batch -- nothing
here is traced, so it adds no recompiles and works identically on the
sharded engine (the observation is the replicated batch latency).
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

from repro.core.rollback import DEFAULT_INTERVAL
from repro.serving.telemetry.metrics import nearest_rank

# (arch, resolved operating-point name, steps, bucket, mode, taylorseer,
# rollback_interval, precision): everything that changes a batch's billed
# latency. The first four mirror the scheduler's perfmodel pricing
# signature; the rest keep differently-billed batches (a clean-mode batch
# pays no ABFT/checkpoint overhead, TaylorSeer skips model evals, the
# rollback interval scales checkpoint DRAM traffic, a narrowed precision
# plan streams the body faster) from contaminating each other's learned
# estimates.
LatencyKey = Tuple[str, str, int, int, str, bool, int, str]


@dataclasses.dataclass(frozen=True)
class BatchObservation:
    """One served micro-batch's measured (virtual-clock) latency."""
    arch: str
    op: str                # resolved operating-point name ("" for clean)
    steps: int
    bucket: int
    latency_s: float
    clock_s: float         # engine virtual clock after the batch
    batch_index: int
    mode: str = "drift"
    taylorseer: bool = False
    rollback_interval: int = DEFAULT_INTERVAL
    precision: str = "int8"

    @property
    def key(self) -> LatencyKey:
        return (self.arch, self.op, self.steps, self.bucket, self.mode,
                self.taylorseer, self.rollback_interval, self.precision)


class _KeyModel:
    # window keeps insertion order (for eviction); sorted_window is the
    # same values kept sorted incrementally, so percentile queries on the
    # admission hot path are O(1) lookups, not O(n log n) sorts.
    __slots__ = ("ewma", "n", "window", "sorted_window")

    def __init__(self) -> None:
        self.ewma: Optional[float] = None
        self.n = 0
        self.window: List[float] = []
        self.sorted_window: List[float] = []


class LatencyEstimator:
    """Online per-configuration latency model over served-batch history."""

    def __init__(self, decay: float = 0.7, window: int = 128,
                 min_observations: int = 1,
                 conservative_percentile: float = 90.0) -> None:
        assert 0.0 < decay <= 1.0, decay
        self.decay = decay
        self.window = window
        self.min_observations = min_observations
        self.conservative_percentile = conservative_percentile
        self._models: Dict[LatencyKey, _KeyModel] = {}
        self._lock = threading.Lock()
        self.total_observations = 0

    # ------------------------------------------------------------ feeding
    def observe(self, obs: BatchObservation) -> None:
        """Fold one served batch into the model for its key."""
        with self._lock:
            m = self._models.setdefault(obs.key, _KeyModel())
            if m.ewma is None:
                m.ewma = obs.latency_s
            else:
                m.ewma = self.decay * m.ewma + (1 - self.decay) \
                    * obs.latency_s
            m.n += 1
            m.window.append(obs.latency_s)
            bisect.insort(m.sorted_window, obs.latency_s)
            while len(m.window) > self.window:
                evicted = m.window.pop(0)
                del m.sorted_window[bisect.bisect_left(m.sorted_window,
                                                       evicted)]
            self.total_observations += 1

    # ----------------------------------------------------------- querying
    @staticmethod
    def key_for(arch: str, op: str, steps: int, bucket: int,
                mode: str = "drift", taylorseer: bool = False,
                rollback_interval: int = DEFAULT_INTERVAL,
                precision: str = "int8") -> LatencyKey:
        """The full latency key; the trailing discriminators default to
        ``GenerationRequest``'s defaults so plain (arch, op, steps,
        bucket) queries mean the standard drift configuration."""
        return (arch, op, steps, bucket, mode, taylorseer,
                rollback_interval, precision)

    def n_observations(self, arch: str, op: str, steps: int, bucket: int,
                       **disc) -> int:
        m = self._models.get(self.key_for(arch, op, steps, bucket, **disc))
        return m.n if m else 0

    def estimate_s(self, arch: str, op: str, steps: int, bucket: int,
                   **disc) -> Optional[float]:
        """Learned batch latency, or None when history is too thin (the
        scheduler's perfmodel fallback trigger). O(1) on the admission
        hot path: the window is kept sorted as it is fed."""
        with self._lock:
            m = self._models.get(self.key_for(arch, op, steps, bucket,
                                              **disc))
            if m is None or m.n < self.min_observations or m.ewma is None:
                return None
            return max(m.ewma,
                       nearest_rank(m.sorted_window,
                                    self.conservative_percentile))

    def percentile_s(self, arch: str, op: str, steps: int, bucket: int,
                     q: float, **disc) -> Optional[float]:
        """Exact percentile over the bounded observation window."""
        with self._lock:
            m = self._models.get(self.key_for(arch, op, steps, bucket,
                                              **disc))
            if m is None or not m.sorted_window:
                return None
            return nearest_rank(m.sorted_window, q)

    def keys(self) -> List[LatencyKey]:
        with self._lock:
            return sorted(self._models)

    def __len__(self) -> int:
        return len(self._models)
