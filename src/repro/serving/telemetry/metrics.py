"""Metrics registry: counters, gauges, histograms, Prometheus exposition.

A deliberately small, stdlib-only metrics core for the serving stack.
Three metric kinds, all label-aware:

* ``Counter`` -- monotonically increasing (``inc``);
* ``Gauge``   -- set to the latest value (``set``), optionally ``inc``;
* ``Histogram`` -- ``observe`` values into fixed cumulative buckets, with
  ``_sum``/``_count`` series and a bounded reservoir of recent raw
  observations so percentile queries (``percentile``) don't need a
  sidecar store.

One ``MetricsRegistry`` holds every metric an engine emits;
``registry.expose()`` renders the whole set in the Prometheus text
exposition format (``text/plain; version=0.0.4``), which is what the
HTTP front-end serves at ``/metrics`` (``telemetry/http.py``).

Thread-safety: the engine mutates metrics from its serving thread while
the HTTP server reads from per-connection threads, so every mutation and
the exposition walk take the registry's lock. The engine's hot path does
a handful of dict updates per *batch* (not per step), so the lock is
uncontended in practice.

Metric catalog for the serving engine: docs/telemetry.md.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# Default histogram buckets in (virtual) seconds: spans the smoke models'
# millisecond batches through multi-second full-arch buckets.
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
                   5.0, 10.0)


def nearest_rank(sorted_data: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) over already-sorted data --
    the one shared definition for histogram reservoirs and the latency
    estimator's observation windows.

    Raises ``ValueError`` on empty data or an out-of-range ``q`` (real
    errors, not asserts: they must survive ``python -O``, and the empty
    case is reachable from any caller that forgets the
    ``percentile() -> None`` contract on a fresh reservoir)."""
    if not sorted_data:
        raise ValueError("percentile of empty data")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q!r} outside [0, 100]")
    rank = round(q / 100.0 * (len(sorted_data) - 1))
    return sorted_data[max(0, min(len(sorted_data) - 1, int(rank)))]


def _fmt(v: float) -> str:
    """Prometheus float rendering: integers without a trailing .0 noise."""
    if v == float("inf"):
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _label_str(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    body = ",".join(
        '%s="%s"' % (k, v.replace("\\", r"\\").replace('"', r"\"")
                     .replace("\n", r"\n"))
        for k, v in labels)
    return "{%s}" % body


class _Metric:
    """Shared label handling + exposition plumbing for one metric family."""

    kind = "untyped"

    def __init__(self, name: str, help_text: str, registry: "MetricsRegistry",
                 label_names: Sequence[str] = ()):
        self.name = name
        self.help_text = help_text
        self.label_names = tuple(label_names)
        self._registry = registry
        self._lock = registry._lock
        self._children: Dict[Tuple[Tuple[str, str], ...], object] = {}

    def _labelkey(self, kv: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"{self.name}: labels {sorted(kv)} != declared "
                f"{sorted(self.label_names)}")
        return tuple((k, str(kv[k])) for k in self.label_names)

    def _child(self, key: Tuple[Tuple[str, str], ...]):
        raise NotImplementedError

    def labels(self, **kv):
        key = self._labelkey(kv)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._child(key)
        return child

    def _default(self):
        """The label-less child (only legal when no labels are declared)."""
        assert not self.label_names, \
            f"{self.name} declares labels {self.label_names}; use .labels()"
        return self.labels()

    def expose_lines(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help_text}",
                 f"# TYPE {self.name} {self.kind}"]
        for key, child in sorted(self._children.items()):
            lines.extend(child.expose(self.name, key))  # type: ignore
        return lines


class _CounterChild:
    def __init__(self, lock):
        self._lock = lock
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        assert amount >= 0, f"counter decrement: {amount}"
        with self._lock:
            self.value += amount

    def expose(self, name, key):
        return [f"{name}{_label_str(key)} {_fmt(self.value)}"]


class Counter(_Metric):
    kind = "counter"

    def _child(self, key):
        return _CounterChild(self._lock)

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    @property
    def value(self) -> float:
        return self._default().value


class _GaugeChild(_CounterChild):
    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount


class Gauge(_Metric):
    kind = "gauge"

    def _child(self, key):
        return _GaugeChild(self._lock)

    def set(self, value: float) -> None:
        self._default().set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    @property
    def value(self) -> float:
        return self._default().value


class _HistogramChild:
    def __init__(self, lock, buckets: Tuple[float, ...], reservoir: int):
        self._lock = lock
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)   # +1 for +Inf
        self.total = 0.0
        self.n = 0
        self._recent: List[float] = []
        self._reservoir = reservoir

    def observe(self, value: float) -> None:
        with self._lock:
            for i, ub in enumerate(self.buckets):
                if value <= ub:
                    self.counts[i] += 1
                    break
            else:
                self.counts[-1] += 1
            self.total += value
            self.n += 1
            self._recent.append(float(value))
            if len(self._recent) > self._reservoir:
                del self._recent[:len(self._recent) - self._reservoir]

    def percentile(self, q: float) -> Optional[float]:
        """q in [0, 100] over the bounded reservoir of raw observations
        (exact over the last ``reservoir`` points, not bucket-interpolated)."""
        with self._lock:
            if not self._recent:
                return None
            data = sorted(self._recent)
        return nearest_rank(data, q)

    def expose(self, name, key):
        lines = []
        cum = 0
        for ub, c in zip(tuple(self.buckets) + (float("inf"),), self.counts):
            cum += c
            lk = key + (("le", _fmt(ub)),)
            lines.append(f"{name}_bucket{_label_str(lk)} {cum}")
        lines.append(f"{name}_sum{_label_str(key)} {_fmt(self.total)}")
        lines.append(f"{name}_count{_label_str(key)} {self.n}")
        return lines


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help_text, registry, label_names=(),
                 buckets: Iterable[float] = DEFAULT_BUCKETS,
                 reservoir: int = 512):
        super().__init__(name, help_text, registry, label_names)
        self._buckets = tuple(sorted(buckets))
        assert self._buckets, "histogram needs at least one bucket"
        self._reservoir = reservoir

    def _child(self, key):
        return _HistogramChild(self._lock, self._buckets, self._reservoir)

    def observe(self, value: float) -> None:
        self._default().observe(value)

    def percentile(self, q: float) -> Optional[float]:
        return self._default().percentile(q)


class MetricsRegistry:
    """Namespace of metrics with one exposition endpoint.

    Metric creation is idempotent per name -- asking for an existing name
    returns the existing metric (and asserts the kind matches), so engine
    and scheduler can both say ``registry.counter("x", ...)`` safely.
    """

    CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._metrics: Dict[str, _Metric] = {}

    def _get_or_make(self, cls, name, help_text, label_names, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                assert isinstance(m, cls), \
                    f"{name} already registered as {m.kind}"
                return m
            m = cls(name, help_text, self, label_names, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help_text: str = "",
                label_names: Sequence[str] = ()) -> Counter:
        return self._get_or_make(Counter, name, help_text, label_names)

    def gauge(self, name: str, help_text: str = "",
              label_names: Sequence[str] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help_text, label_names)

    def histogram(self, name: str, help_text: str = "",
                  label_names: Sequence[str] = (),
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_make(Histogram, name, help_text, label_names,
                                 buckets=buckets)

    def expose(self) -> str:
        """The whole registry in Prometheus text format (sorted by name,
        trailing newline included -- some scrapers insist)."""
        with self._lock:
            metrics = [self._metrics[k] for k in sorted(self._metrics)]
            lines: List[str] = []
            for m in metrics:
                lines.extend(m.expose_lines())
        return "\n".join(lines) + "\n"


def _inject_label(sample_line: str, key: str, value: str) -> str:
    """Prepend ``key="value"`` to one exposition sample line."""
    body, sep, val = sample_line.rpartition(" ")
    assert sep, f"malformed sample line: {sample_line!r}"
    escaped = value.replace("\\", r"\\").replace('"', r"\"")
    if "{" in body:
        name, rest = body.split("{", 1)
        return f'{name}{{{key}="{escaped}",{rest} {val}'
    return f'{body}{{{key}="{escaped}"}} {val}'


def merge_labeled_expositions(named: Dict[str, str]) -> str:
    """Merge several registries' expositions into one scrape payload,
    tagging every sample with an ``engine="<name>"`` label.

    This is the multi-engine aggregation path: one ``/metrics`` endpoint
    fronting several engines (``TelemetryHTTPServer(engines={...})``)
    renders each engine's ``registry.expose()`` text and merges here.
    The format requires every sample of a metric family to sit in one
    contiguous block under its ``# HELP``/``# TYPE`` headers, so the
    merge regroups by family (headers taken from the first engine that
    exposes it) with every series' labels gaining a leading
    ``engine="<name>"`` -- identical series from different engines never
    collide.
    """
    headers: Dict[str, List[str]] = {}
    samples: Dict[str, List[str]] = {}
    for name in sorted(named):
        family = None
        for line in named[name].splitlines():
            if not line.strip():
                continue
            if line.startswith("# HELP ") or line.startswith("# TYPE "):
                family = line.split()[2]
                fam_headers = headers.setdefault(family, [])
                samples.setdefault(family, [])
                if line not in fam_headers:
                    fam_headers.append(line)
                continue
            assert family is not None, f"sample before headers: {line!r}"
            samples[family].append(_inject_label(line, "engine", name))
    out: List[str] = []
    for family in sorted(headers):
        out.extend(headers[family])
        out.extend(samples[family])
    return "\n".join(out) + "\n"
