"""Adaptive BER guardband controller: the DRIFT loop, closed online.

The engine already runs the paper's Sec 5.1 feedback *inside* the trace:
ABFT detection counts (psum-reduced across the mesh on the sharded
engine) drive ``core.dvfs.ber_monitor_update``, which walks the
``OP_LADDER`` index carried across batches. That loop reacts per *step*
but has no memory beyond one EMA and no notion of "this operating point
keeps running hot" -- exactly the statistical error-monitoring signal
ReaLM argues a reliability controller should consume.

``GuardbandController`` is the host-side outer loop layered on top:

* it **observes** every monitored batch -- the monitor's post-batch BER
  estimate, the batch's rollback-corrected element count, and which
  operating point actually ran -- and maintains a per-operating-point
  EWMA of *realized* BER plus a global rollback-rate estimate;
* it maintains a **guardband**: a floor on the ladder index that
  ``op="auto"`` resolution is clamped to
  (``engine.auto_op_index`` applies ``controller.clamp``). Index 0 is
  the most aggressive undervolt; a wider guardband means a higher floor,
  i.e. "auto" requests run closer to nominal;
* the **state machine** (one transition per adaptation window of
  ``window_batches`` monitored batches, full table in
  docs/telemetry.md):

  - window BER  > ``spike_ratio * target``  -> WIDEN: floor += 1, quiet
    streak reset;
  - window BER  < ``quiet_ratio * target``  -> QUIET: streak += 1, and
    after ``quiet_windows`` consecutive quiet windows the floor steps
    back down (re-tighten) and the streak restarts;
  - otherwise (in-band)                     -> HOLD: streak reset, floor
    unchanged.

Hysteresis is the point: widening is immediate (one window), tightening
needs ``quiet_windows`` consecutive quiet windows, so the floor cannot
flap batch-to-batch. Since the floor only selects among the fixed
``OP_LADDER`` names, the compiled-sampler cache stays bounded by the
ladder length no matter how long the controller runs (asserted in
tests/test_telemetry.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import dvfs as dvfs_lib

# Adaptation-window outcomes (also the controller's observable state).
WIDEN, TIGHTEN, QUIET, HOLD = "widen", "tighten", "quiet", "hold"


@dataclasses.dataclass(frozen=True)
class GuardbandConfig:
    """Knobs for the guardband state machine."""
    # Monitored batches folded into one adaptation window.
    window_batches: int = 1
    # Window-mean BER above spike_ratio * target widens the guardband.
    spike_ratio: float = 2.0
    # Window-mean BER below quiet_ratio * target counts as a quiet window.
    quiet_ratio: float = 0.5
    # Consecutive quiet windows required before the guardband re-tightens.
    quiet_windows: int = 3
    # Highest floor the controller may set (None = ladder top, i.e. it may
    # pin "auto" all the way to nominal under a sustained detection storm).
    max_guard: Optional[int] = None
    # EWMA decay for the per-op realized-BER and rollback-rate estimates.
    decay: float = 0.8

    @property
    def guard_cap(self) -> int:
        top = len(dvfs_lib.OP_LADDER) - 1
        return top if self.max_guard is None else min(self.max_guard, top)


@dataclasses.dataclass
class GuardbandStats:
    windows: int = 0
    widenings: int = 0
    tightenings: int = 0
    quiet_streak: int = 0          # current consecutive quiet windows
    last_action: str = HOLD


class GuardbandController:
    """Online guardband adaptation over BER-monitor observations."""

    def __init__(self, target_ber: float,
                 config: Optional[GuardbandConfig] = None) -> None:
        assert target_ber > 0, target_ber
        self.target_ber = target_ber
        self.cfg = config or GuardbandConfig()
        self.guard_index = 0           # ladder floor; 0 = no guardband
        self.stats = GuardbandStats()
        # realized BER per operating-point name (EWMA of the monitor's
        # post-batch estimate attributed to the op that actually ran)
        self.realized_ber: Dict[str, float] = {}
        # rollback intensity: EWMA of corrected elements per latent word
        self.rollback_rate = 0.0
        self._window_sum = 0.0
        self._window_n = 0
        # Energy-SLO breach input (telemetry's SLOTracker sets it each
        # batch): while the fleet burns its energy budget, "auto" is
        # pinned to the guardband floor itself -- the cheapest operating
        # point the reliability state machine currently allows.
        self.energy_slo_breached = False

    # ----------------------------------------------------------- observe
    def observe_batch(self, ema_ber: float, op_name: str,
                      corrected_elems: int = 0, n_words: int = 1) -> str:
        """Fold one monitored batch in; returns the window action taken
        (``hold`` while a window is still filling)."""
        d = self.cfg.decay
        prev = self.realized_ber.get(op_name)
        self.realized_ber[op_name] = ema_ber if prev is None \
            else d * prev + (1 - d) * ema_ber
        rate = corrected_elems / max(n_words, 1)
        self.rollback_rate = d * self.rollback_rate + (1 - d) * rate
        self._window_sum += ema_ber
        self._window_n += 1
        if self._window_n < self.cfg.window_batches:
            return HOLD
        window_ber = self._window_sum / self._window_n
        self._window_sum, self._window_n = 0.0, 0
        return self._step_window(window_ber)

    def _step_window(self, window_ber: float) -> str:
        """One state-machine transition at an adaptation-window boundary."""
        st = self.stats
        st.windows += 1
        if window_ber > self.cfg.spike_ratio * self.target_ber:
            st.quiet_streak = 0
            if self.guard_index < self.cfg.guard_cap:
                self.guard_index += 1
                st.widenings += 1
                st.last_action = WIDEN
            else:
                st.last_action = HOLD
            return st.last_action
        if window_ber < self.cfg.quiet_ratio * self.target_ber:
            st.quiet_streak += 1
            if st.quiet_streak >= self.cfg.quiet_windows:
                st.quiet_streak = 0
                if self.guard_index > 0:
                    self.guard_index -= 1
                    st.tightenings += 1
                    st.last_action = TIGHTEN
                    return st.last_action
            st.last_action = QUIET
            return st.last_action
        st.quiet_streak = 0            # in-band: hysteresis restarts
        st.last_action = HOLD
        return st.last_action

    def set_energy_slo_breach(self, breached: bool) -> None:
        """Energy-SLO floor input (docs/slo.md): telemetry calls this
        after every batch with the tracker's energy-objective breach
        state; it only affects ``op="auto"`` resolution via clamp()."""
        self.energy_slo_breached = bool(breached)

    # ------------------------------------------------------------- apply
    def clamp(self, op_index: int) -> int:
        """Apply the guardband floor to a monitor ladder index. Under an
        energy-SLO breach the floor becomes the *ceiling* too: "auto"
        resolves to exactly the guardband index -- as aggressive (cheap)
        as the reliability guardband permits, no higher."""
        if self.energy_slo_breached:
            return self.guard_index
        return max(int(op_index), self.guard_index)

    def guard_op_name(self) -> str:
        """Ladder name of the current floor (for gauges / logs)."""
        return dvfs_lib.ladder_op(self.guard_index).name
