"""Telemetry & online adaptation for the DRIFT serving stack.

The serving engine runs the DRIFT loop -- detect errors cheaply, adapt
the operating point, correct only what matters -- but before this
subsystem it ran *open loop* at the serving layer: worst-case perfmodel
latencies for admission, a DVFS ladder that never learned from the
detection counts it collects, previews that died at a Python generator.
This package is the observe -> learn -> adapt layer:

===================  =====================================================
module               role
===================  =====================================================
``metrics``          counters / gauges / histograms + Prometheus text
                     exposition (the ``/metrics`` payload)
``history``          served-batch history + learned per-(arch, op, steps,
                     bucket) latency estimator the scheduler consults,
                     with perfmodel fallback on empty history
``controller``       adaptive BER guardband: widens/tightens the floor
                     under the auto-op ladder from the monitor's
                     psum-reduced detection statistics, with hysteresis
``http``             stdlib HTTP front-end: ``/metrics``, ``/healthz``,
                     and an SSE ``/events`` endpoint relaying
                     ``PreviewEvent`` streams
===================  =====================================================

``EngineTelemetry`` (below) bundles the three host-side parts into the
single object the engine owns (``engine.telemetry``); every tap is a
plain Python call on the batch boundary -- nothing is traced, so
telemetry never changes what a given configuration *computes*. It can
change which configuration runs, on purpose: the guardband floors
``op="auto"`` resolution, and learned estimates steer admission once
history exists. With ``enabled=False`` (or for workloads that name
explicit operating points, before any history/guardband effect) serving
is bit-identical to the telemetry-free engine.

Metric catalog, controller state machine, and the SSE wire format:
docs/telemetry.md.
"""
from __future__ import annotations

import time
from typing import Optional

from repro.serving.telemetry.controller import (GuardbandConfig,
                                                GuardbandController,
                                                GuardbandStats)
from repro.serving.telemetry.energy import (ENERGY_COMPONENTS, EnergyLedger,
                                            verify_cost)
from repro.serving.telemetry.history import (BatchObservation,
                                             LatencyEstimator, LatencyKey)
from repro.serving.telemetry.metrics import (Counter, Gauge, Histogram,
                                             MetricsRegistry,
                                             merge_labeled_expositions)
from repro.serving.telemetry.slo import OBJECTIVES, SLOConfig, SLOTracker
from repro.version import __version__ as _build_version

__all__ = [
    "EngineTelemetry",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "merge_labeled_expositions",
    "LatencyEstimator", "BatchObservation", "LatencyKey",
    "GuardbandController", "GuardbandConfig", "GuardbandStats",
    "EnergyLedger", "ENERGY_COMPONENTS", "verify_cost",
    "SLOTracker", "SLOConfig", "OBJECTIVES",
    "TelemetryHTTPServer", "serve_telemetry", "aggregate_metrics",
]

# Buckets for the per-request energy histogram: smoke archs bill
# millijoules, full DiT-XL-512 samples land around 4-6 J, and fleets
# budget tens of joules -- log-spaced to cover all three regimes.
REQUEST_ENERGY_BUCKETS_J = (0.001, 0.003, 0.01, 0.03, 0.1, 0.3,
                            1.0, 3.0, 10.0, 30.0, 100.0)


class EngineTelemetry:
    """The engine's telemetry bundle: registry + estimator + controller.

    Construction is cheap and side-effect-free; the engine calls
    :meth:`bind` once with its monitor target BER, which instantiates the
    guardband controller (unless ``guardband=False``) and registers the
    metric families. ``enabled=False`` turns every hook into a no-op and
    keeps the estimator/controller absent, so the scheduler's perfmodel
    fallback and the engine's ladder resolution behave exactly as without
    telemetry (``--no-telemetry`` on the CLIs builds this).
    """

    def __init__(self, enabled: bool = True,
                 registry: Optional[MetricsRegistry] = None,
                 estimator: Optional[LatencyEstimator] = None,
                 controller: Optional[GuardbandController] = None,
                 guardband: bool = True,
                 guardband_config: Optional[GuardbandConfig] = None,
                 slo_config: Optional[SLOConfig] = None) -> None:
        self.enabled = enabled
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        self.estimator = (estimator if estimator is not None else
                          LatencyEstimator()) if enabled else None
        self.controller = controller
        self._want_guardband = guardband and enabled
        self._guardband_config = guardband_config
        self._slo_config = slo_config
        # Energy ledger + SLO tracker, built at bind() (the tracker needs
        # the engine's target BER). None while disabled/unbound.
        self.ledger: Optional[EnergyLedger] = None
        self.slo: Optional[SLOTracker] = None
        self._bound = False

    @classmethod
    def disabled(cls) -> "EngineTelemetry":
        return cls(enabled=False)

    # ------------------------------------------------------------ binding
    def bind(self, target_ber: float) -> "EngineTelemetry":
        """Engine attach point: build the controller against the engine's
        monitor target and register the metric families. Idempotent."""
        if self._bound or not self.enabled:
            self._bound = True
            return self
        self._bound = True
        if self._want_guardband and self.controller is None:
            self.controller = GuardbandController(
                target_ber, self._guardband_config)
        r = self.registry
        self._m_submitted = r.counter(
            "drift_requests_submitted_total",
            "Requests accepted into the engine queue")
        self._m_served = r.counter(
            "drift_requests_served_total",
            "Requests completed with a RequestResult")
        self._m_batches = r.counter(
            "drift_batches_total", "Micro-batches served",
            label_names=("mode", "op"))
        self._m_padded = r.counter(
            "drift_padded_slots_total", "Bucket slots filled with padding")
        self._m_previews = r.counter(
            "drift_preview_events_total", "Streamed latent previews yielded")
        self._m_windows = r.counter(
            "drift_stream_windows_total",
            "Jitted streaming windows executed by the sampler")
        self._m_misses = r.counter(
            "drift_deadline_misses_total",
            "Requests completed past their virtual-clock deadline")
        self._m_corrected = r.counter(
            "drift_rollback_corrected_elems_total",
            "Rollback-corrected tensor elements (whole batches)")
        self._m_batch_lat = r.histogram(
            "drift_batch_latency_seconds",
            "Modeled (virtual-clock) latency per served micro-batch",
            label_names=("op",))
        self._m_queue_wait = r.histogram(
            "drift_queue_wait_seconds",
            "Virtual-clock wait between submission and batch start")
        self._m_clock = r.gauge(
            "drift_clock_seconds", "Engine virtual clock")
        self._m_depth = r.gauge(
            "drift_queue_depth", "Pending requests after the last batch")
        self._m_ema = r.gauge(
            "drift_monitor_ema_ber", "BER monitor EMA after the last batch")
        self._m_ladder = r.gauge(
            "drift_monitor_ladder_index",
            "BER monitor ladder index after the last batch")
        self._m_guard = r.gauge(
            "drift_guardband_index", "Guardband controller ladder floor")
        self._m_widen = r.counter(
            "drift_guardband_widenings_total", "Guardband widen transitions")
        self._m_tighten = r.counter(
            "drift_guardband_tightenings_total",
            "Guardband re-tighten transitions")
        self._m_realized = r.gauge(
            "drift_realized_ber",
            "EWMA of the monitor's BER estimate per operating point",
            label_names=("op",))
        self._m_obs = r.counter(
            "drift_estimator_observations_total",
            "Served-batch latency observations folded into the estimator")
        self._m_est_keys = r.gauge(
            "drift_estimator_keys",
            "Distinct (arch, op, steps, bucket) latency models")
        self._m_admissions = r.counter(
            "drift_admissions_total", "Scheduler admission decisions",
            label_names=("action",))
        self._m_projection = r.counter(
            "drift_projection_source_total",
            "Latency source used for admission projections",
            label_names=("source",))
        self._m_frontier = r.counter(
            "drift_frontier_choices_total",
            "Frontier points selected by the scheduler's compute-optimal "
            "resolution", label_names=("objective",))
        self._m_frontier_size = r.gauge(
            "drift_frontier_size",
            "Pareto-frontier size of the last consulted (arch, bucket)")
        # checkpoint-offload subsystem (repro.serving.offload)
        self._m_off_commits = r.counter(
            "drift_offload_commits_total",
            "Checkpoint snapshots committed to the host offload store")
        self._m_off_skipped = r.counter(
            "drift_offload_skipped_total",
            "Refresh commits deferred by a BER detection spike")
        self._m_off_restores = r.counter(
            "drift_offload_restores_total",
            "Committed snapshots re-uploaded to device (rollback restore)")
        self._m_off_bytes = r.counter(
            "drift_offload_bytes_total",
            "Host bytes offloaded (tile-contiguous layout, padding incl.)")
        self._m_off_stall = r.counter(
            "drift_offload_stall_seconds_total",
            "Modeled residual refresh stall charged on the virtual clock")
        self._m_off_interval = r.gauge(
            "drift_offload_interval",
            "Rollback refresh interval of the last offloaded batch")
        # flight-recorder / forensics surfaces (repro.serving.trace)
        self._m_heatmap = r.counter(
            "drift_detect_heatmap_total",
            "ABFT detections bucketed by model site and timestep bin "
            "(the live analogue of DRIFT Figs 5-6)",
            label_names=("block", "step_bin"))
        self._m_rejections = r.counter(
            "drift_scheduler_rejections_total",
            "Requests the scheduler refused to enqueue",
            label_names=("reason",))
        self._m_build = r.gauge(
            "drift_build_info",
            "Constant 1; build metadata rides in the labels",
            label_names=("version", "paradigms"))
        self._m_build.labels(version=_build_version,
                             paradigms="diffusion,autoregressive").set(1.0)
        self._m_uptime = r.gauge(
            "drift_engine_uptime_seconds",
            "Wall seconds since this engine's telemetry was bound")
        self._t0_wall = time.monotonic()
        self._m_uptime.set(0.0)
        # energy ledger + SLO engine (docs/slo.md)
        self.ledger = EnergyLedger()
        self.slo = SLOTracker(target_ber, self._slo_config)
        self._slo_prev_breached = dict(self.slo.breached)
        self._m_energy = r.counter(
            "drift_energy_joules_total",
            "Billed joules by ledger component and operating point "
            "(component sums reconcile bitwise with billed energy_j)",
            label_names=("component", "op"))
        self._m_req_energy = r.histogram(
            "drift_request_energy_joules",
            "Billed energy per completed request (its share of the "
            "batch ledger)", buckets=REQUEST_ENERGY_BUCKETS_J)
        self._m_burn = r.gauge(
            "drift_slo_burn_rate",
            "Observed/target burn rate per SLO objective and window "
            "(virtual-clock windows; breach = both windows above the "
            "threshold)", label_names=("objective", "window"))
        self._m_slo_breaches = r.gauge(
            "drift_slo_breached",
            "1 while an SLO objective's fast AND slow windows both burn "
            "above threshold, else 0", label_names=("objective",))
        self._m_slo_breach_edges = r.counter(
            "drift_slo_breaches_total",
            "Breach onsets per SLO objective (ok->breached transitions)",
            label_names=("objective",))
        self._m_skew = r.gauge(
            "drift_clock_skew_ratio",
            "Virtual clock seconds per wall uptime second: how fast "
            "modeled-accelerator time runs relative to this host")
        return self

    # -------------------------------------------------------------- hooks
    # Every hook no-ops when disabled; the engine calls them
    # unconditionally so the serving loop stays branch-free.
    def on_submit(self) -> None:
        if self.enabled:
            self._m_submitted.inc()

    def on_batch(self, key, n_live: int, n_pad: int, latency_s: float,
                 ema_ber: float, op_index: int, corrected: int,
                 n_words: int, monitored: bool, clock_s: float,
                 queue_depth: int, results,
                 energy_breakdown=None) -> None:
        """One served micro-batch: metrics, history, energy ledger, SLO
        evaluation, and -- for monitored modes -- one guardband-controller
        observation. ``energy_breakdown`` is the BATCH-level component
        dict from ``perfmodel.energy.run_cost`` (each result additionally
        carries its own per-request share)."""
        if not self.enabled:
            return
        op_name = key.op or "nominal"
        self._m_batches.labels(mode=key.mode, op=op_name).inc()
        self._m_padded.inc(n_pad)
        self._m_served.inc(n_live)
        self._m_batch_lat.labels(op=op_name).observe(latency_s)
        self._m_clock.set(clock_s)
        self._m_depth.set(queue_depth)
        self._m_ema.set(ema_ber)
        self._m_ladder.set(op_index)
        self._m_corrected.inc(corrected)
        # One shared wall sample for uptime AND clock skew, so the two
        # gauges reconcile exactly: skew == clock_gauge / uptime_gauge
        # (tests/test_telemetry.py pins this on the fake-device engine).
        wall = time.monotonic() - self._t0_wall
        self._m_uptime.set(wall)
        self._m_skew.set(clock_s / wall if wall > 0 else 0.0)
        if energy_breakdown is not None:
            self.ledger.charge_batch(op_name, energy_breakdown)
            for comp in ENERGY_COMPONENTS:
                j = energy_breakdown[comp]
                if j:
                    self._m_energy.labels(component=comp, op=op_name).inc(j)
        for res in results:
            self._m_queue_wait.observe(res.queue_wait_s)
            if res.deadline_missed:
                self._m_misses.inc()
            self.ledger.charge_request(res.energy_j)
            self._m_req_energy.observe(res.energy_j)
        # SLO engine: fold the batch in on the virtual clock, publish burn
        # rates, edge-count breach onsets, and hand the energy objective's
        # breach state to the guardband (its "run cheaper" floor input).
        self.slo.observe_batch(clock_s, ema_ber, monitored, results)
        for (obj, win), rate in self.slo.burn_rates().items():
            self._m_burn.labels(objective=obj, window=win).set(rate)
        for obj, breached in self.slo.breached.items():
            self._m_slo_breaches.labels(objective=obj).set(float(breached))
            if breached and not self._slo_prev_breached[obj]:
                self._m_slo_breach_edges.labels(objective=obj).inc()
        self._slo_prev_breached = dict(self.slo.breached)
        if self.controller is not None:
            self.controller.set_energy_slo_breach(self.slo.energy_breached)
        self.estimator.observe(BatchObservation(
            arch=key.arch, op=op_name, steps=key.steps, bucket=key.bucket,
            latency_s=latency_s, clock_s=clock_s,
            batch_index=results[0].batch_index if results else -1,
            mode=key.mode, taylorseer=key.taylorseer,
            rollback_interval=key.rollback_interval,
            precision=key.precision))
        self._m_obs.inc()
        self._m_est_keys.set(len(self.estimator))
        if monitored and self.controller is not None:
            self.controller.observe_batch(ema_ber, op_name,
                                          corrected_elems=corrected,
                                          n_words=n_words)
            self._m_guard.set(self.controller.guard_index)
            st = self.controller.stats
            self._sync_counter(self._m_widen, st.widenings)
            self._sync_counter(self._m_tighten, st.tightenings)
            self._m_realized.labels(op=op_name).set(
                self.controller.realized_ber[op_name])

    @staticmethod
    def _sync_counter(counter: Counter, target: float) -> None:
        delta = target - counter.value
        if delta > 0:
            counter.inc(delta)

    def on_preview(self) -> None:
        if self.enabled:
            self._m_previews.inc()

    def on_offload(self, delta, interval: int, stall_s: float) -> None:
        """One offload-enabled batch's store accounting: ``delta`` is the
        batch's ``OffloadStats`` delta (commits/skips/restores/bytes),
        ``stall_s`` the modeled residual stall the clock was charged."""
        if not self.enabled:
            return
        self._m_off_commits.inc(delta.commits)
        self._m_off_skipped.inc(delta.skipped)
        self._m_off_restores.inc(delta.restores)
        self._m_off_bytes.inc(delta.bytes_offloaded)
        self._m_off_stall.inc(stall_s)
        self._m_off_interval.set(interval)

    def on_stream_window(self, done_steps: int) -> None:
        """Sampler tap: fires once per completed jitted streaming window
        (threaded through ``sampler.make_sampler(on_window=...)``)."""
        if self.enabled:
            self._m_windows.inc()

    def on_admission(self, action: str) -> None:
        if self.enabled:
            self._m_admissions.labels(action=action).inc()

    def on_rejection(self, reason: str) -> None:
        """One scheduler refusal. ``reason``: "projected-miss" (deadline
        unreachable on the ladder) | "budget-infeasible" (frontier
        objective with no qualifying point) | "validation" (malformed
        request fields)."""
        if self.enabled:
            self._m_rejections.labels(reason=reason).inc()

    def on_heatmap(self, heatmap, blocks) -> None:
        """One monitored batch's binned detection heatmap: ``heatmap`` is
        the nested int tuple (sites, step_bins) from
        ``trace.heatmap.summarize``, ``blocks`` the matching site labels.
        Accumulated into ``drift_detect_heatmap_total{block, step_bin}``;
        zero cells are skipped so the exposition stays sparse."""
        if not self.enabled or heatmap is None:
            return
        for site, row in zip(blocks, heatmap):
            for b, count in enumerate(row):
                if count:
                    self._m_heatmap.labels(
                        block=site, step_bin=str(b)).inc(count)

    def on_projection(self, source: str) -> None:
        """source: "learned" | "perfmodel" -- which clock priced a
        scheduler projection."""
        if self.enabled:
            self._m_projection.labels(source=source).inc()

    def on_frontier_choice(self, objective: str, frontier_size: int) -> None:
        """One compute-optimal frontier selection by the scheduler.
        ``objective``: "min-energy" (deadline-constrained) |
        "min-latency" (quality-floor) | "max-quality" (budget-only)."""
        if self.enabled:
            self._m_frontier.labels(objective=objective).inc()
            self._m_frontier_size.set(frontier_size)

    # ------------------------------------------------------------ queries
    def slo_snapshot(self) -> Optional[dict]:
        """The ``GET /slo`` body (docs/slo.md), or None while telemetry is
        disabled/unbound."""
        if not self.enabled or self.slo is None:
            return None
        return self.slo.snapshot()

    def clamp_ladder_index(self, op_index: int) -> int:
        """Apply the guardband floor (identity when disabled/absent)."""
        if self.enabled and self.controller is not None:
            return self.controller.clamp(op_index)
        return int(op_index)

    def learned_latency_s(self, arch: str, op: str, steps: int,
                          bucket: int, **disc) -> Optional[float]:
        """Learned batch latency, or None (disabled / empty history).
        ``disc`` are the extra ``LatencyKey`` discriminators (mode,
        taylorseer, rollback_interval), defaulting to the standard drift
        configuration."""
        if not self.enabled or self.estimator is None:
            return None
        return self.estimator.estimate_s(arch, op, steps, bucket, **disc)


# Re-exported late: http imports request types, keep the cheap modules above
# importable without dragging the server in first.
from repro.serving.telemetry.http import (TelemetryHTTPServer,  # noqa: E402
                                          aggregate_metrics,
                                          serve_telemetry)
