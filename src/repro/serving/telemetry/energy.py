"""Serving-layer energy ledger: per-(component, op) joule accounting.

``perfmodel.energy.run_cost`` prices every served batch and now returns a
per-component breakdown (``perfmodel.energy.ENERGY_COMPONENTS``) whose
fixed-order sum IS the billed total -- see ``ledger_total``. This module
is the serving-side accumulator over those breakdowns: the engine's
telemetry charges one batch-level breakdown per served batch (labelled by
the operating point that ran) plus one per-request energy observation per
result, and the ledger answers the aggregate questions the SLO engine,
the ``/metrics`` counters, and ``benchmarks/energy_slo.py`` ask --
where do the joules go, per DVFS operating point, and what does a request
cost on average.

The ledger never re-derives totals from its own accumulation order: the
exact-sum guarantee lives in ``perfmodel.energy`` (components are the
primary arithmetic there), and ``verify_cost`` re-checks it on any priced
cost dict, bitwise.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.perfmodel.energy import ENERGY_COMPONENTS, ledger_total

__all__ = ["ENERGY_COMPONENTS", "EnergyLedger", "ledger_total",
           "verify_cost"]


def verify_cost(cost: Dict[str, object]) -> float:
    """Exact-sum check on one priced cost dict (``run_cost`` or
    ``per_request_cost`` output): returns the absolute residual between
    the component sum and the billed ``energy_j`` -- 0.0, bitwise, by
    construction. Raises ``AssertionError`` on any residual; callers that
    want the number (the energy benchmark reports it) read the return."""
    residual = abs(ledger_total(cost["breakdown"]) - cost["energy_j"])
    assert residual == 0.0, (
        f"energy ledger does not reconcile: component sum differs from "
        f"energy_j by {residual!r}")
    return residual


class EnergyLedger:
    """Cumulative joules per (component, operating point) + request stats.

    Bounded by construction: the key space is |ENERGY_COMPONENTS| x the
    operating points that actually served batches, and the per-request
    side keeps two scalars. Mutated on the engine's serving thread only
    (the metrics registry's counters are the thread-safe read surface);
    reads from benchmarks/CLIs happen after a drain.
    """

    def __init__(self) -> None:
        self.joules: Dict[Tuple[str, str], float] = {}
        self.batches = 0
        self.requests = 0
        self.request_joules = 0.0

    # ------------------------------------------------------------ charging
    def charge_batch(self, op: str, breakdown: Dict[str, float]) -> None:
        """Fold one served batch's component breakdown in, attributed to
        the operating point that ran it."""
        self.batches += 1
        for comp in ENERGY_COMPONENTS:
            j = breakdown[comp]
            if j:
                key = (comp, op)
                self.joules[key] = self.joules.get(key, 0.0) + j

    def charge_request(self, energy_j: float) -> None:
        self.requests += 1
        self.request_joules += float(energy_j)

    # ------------------------------------------------------------- queries
    def component_totals(self, op: Optional[str] = None) -> Dict[str, float]:
        """Cumulative joules per component, optionally for one op."""
        out = {comp: 0.0 for comp in ENERGY_COMPONENTS}
        for (comp, o), j in self.joules.items():
            if op is None or o == op:
                out[comp] += j
        return out

    def shares(self, op: Optional[str] = None) -> Dict[str, float]:
        """Each component's fraction of the cumulative total (0.0 when
        nothing has been charged)."""
        totals = self.component_totals(op)
        denom = sum(totals.values())
        if denom <= 0.0:
            return {comp: 0.0 for comp in ENERGY_COMPONENTS}
        return {comp: j / denom for comp, j in totals.items()}

    def ops(self) -> Tuple[str, ...]:
        """Operating points that have been charged, sorted."""
        return tuple(sorted({op for _, op in self.joules}))

    def energy_per_request_j(self) -> float:
        """Mean billed energy per completed request (0.0 before any)."""
        return self.request_joules / self.requests if self.requests else 0.0
