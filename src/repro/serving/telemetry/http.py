"""HTTP front-end for one serving engine: /metrics, /healthz, SSE /events.

Stdlib only (``http.server``), one server thread per engine plus one
handler thread per connection (``ThreadingHTTPServer``); the engine
itself stays single-threaded -- a server-level lock serializes queue
*drains* (``/events`` handlers and the CLIs' own ``run()`` calls), so
batches and the BER-monitor carry remain well-ordered no matter how
many clients poll. ``/healthz`` and ``/metrics`` are lock-free reads of
scalar snapshots: individually atomic under the GIL, but a response
racing a drain may mix pre-/post-batch values across fields.

Endpoints:

``GET /healthz``
    Liveness + a one-glance engine snapshot, as JSON: virtual clock,
    queue depth, batches served, monitor ladder index, guardband floor.
    Always 200 when the process is up (load balancers key on this).

``GET /metrics``
    The engine's ``MetricsRegistry`` in Prometheus text exposition
    format (``text/plain; version=0.0.4``). With telemetry disabled the
    payload is a single comment line, still 200.

``GET /slo``
    The SLO engine's snapshot as JSON (docs/slo.md): per objective the
    target, fast/slow virtual-clock window values, burn rates, and
    breach state. Deterministic on the virtual clock; multi-engine
    servers add a per-engine map like ``/healthz``.

``GET /events?interval=K``
    Server-Sent Events: drains the engine's queue through
    ``run_stream(K)`` (default: the server's ``preview_interval``) and
    relays every ``PreviewEvent`` and ``RequestResult`` as SSE frames --
    the *same* event sequence the in-process generator yields, with
    latent tensors replaced by their SHA-256 so finals can be checked
    bit-identical to ``run()`` without shipping arrays
    (tests/test_telemetry.py asserts both). ``K`` is restricted to the
    server's ``allowed_intervals`` (each distinct window length compiles
    its own streaming sampler; an open endpoint must keep that set
    finite). If the client disconnects mid-stream the server finishes
    the drain engine-side, so no queued request is ever lost to a
    dropped connection. Frames:

    .. code-block:: text

        event: preview
        id: 0
        data: {"request_id": 0, "batch_index": 0, "step": 2,
               "total_steps": 6, "shape": [8, 8, 4], "dtype": "float32",
               "latents_sha256": "..."}

        event: result
        id: 1
        data: {"request_id": 0, "op": "undervolt", ... ,
               "latents_sha256": "..."}

        event: end
        data: {"served": 1, "previews": 2}

    A concurrent ``/events`` drain answers 503 rather than interleaving
    batches. The lock can only see drains that go through it: in-process
    callers that run the engine directly while the server is up must
    hold ``server.engine_lock`` around their own ``run()``/
    ``run_stream()`` (the serve CLIs do), which makes a simultaneous
    ``/events`` request 503 instead of corrupting the single-threaded
    engine.

``GET /trace/<request_id>``
    One request's span tree from the engine's flight recorder
    (``serving/trace``), as JSON: spans oldest-first with both clocks,
    plus the scheduler's decision record surfaced at the top level.
    404 when the id is unknown, has been evicted from the bounded ring
    buffer, or the recorder is disabled.

``GET /flight``
    The whole flight-recorder ring buffer as Chrome/Perfetto
    trace-event JSON -- save it and load at ``ui.perfetto.dev``. Empty
    ``traceEvents`` (plus metadata) when nothing is recorded.

Wire-format details and the metric catalog: docs/telemetry.md; span
taxonomy and recorder bounds: docs/tracing.md.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional, Tuple
from urllib.parse import parse_qs, urlparse

import numpy as np

from repro.serving.request import PreviewEvent, RequestResult
from repro.serving.telemetry.metrics import merge_labeled_expositions
from repro.serving.trace import request_tree, to_chrome_trace


def latents_sha256(latents) -> str:
    """Digest of the raw latent bytes -- the bit-identity currency of the
    SSE wire format (arrays never leave the process)."""
    arr = np.asarray(latents)
    return hashlib.sha256(arr.tobytes()).hexdigest()


def preview_wire(ev: PreviewEvent) -> Dict[str, object]:
    """JSON-able body of one SSE ``preview`` frame."""
    arr = np.asarray(ev.latents)
    return {"request_id": ev.request_id, "batch_index": ev.batch_index,
            "step": ev.step, "total_steps": ev.total_steps,
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "latents_sha256": latents_sha256(arr)}


def result_wire(res: RequestResult) -> Dict[str, object]:
    """JSON-able body of one SSE ``result`` frame: every scalar field of
    the RequestResult, latents replaced by shape/dtype/digest."""
    body = {}
    for f in dataclasses.fields(res):
        v = getattr(res, f.name)
        if f.name == "latents":
            continue
        body[f.name] = v
    if res.latents is not None:
        arr = np.asarray(res.latents)
        body["shape"] = list(arr.shape)
        body["dtype"] = str(arr.dtype)
        body["latents_sha256"] = latents_sha256(arr)
    return body


class TelemetryHTTPServer:
    """Threaded HTTP server bound to one engine (or DeadlineScheduler).

    ``port=0`` binds an ephemeral port (read it back from ``.port`` --
    what the tests and the smoke tool do); ``start()`` serves on a daemon
    thread, ``close()`` shuts down and joins. Usable as a context
    manager. Pass a ``DeadlineScheduler`` to expose its engine; the
    scheduler's own admission metrics land in the same registry.

    ``engine_lock`` serializes queue drains: ``/events`` handlers take
    it, and in-process code that drains the engine while the server is
    up should hold it too (``with server.engine_lock: engine.run()``).
    """

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 0,
                 preview_interval: int = 1,
                 allowed_intervals: Tuple[int, ...] = (1, 2, 4, 8),
                 engines: Optional[Dict[str, object]] = None) -> None:
        # accept a DeadlineScheduler transparently
        self.engine = getattr(engine, "engine", engine)
        # Multi-engine aggregation (ROADMAP telemetry follow-on): pass
        # ``engines={"name": engine, ...}`` and /metrics merges every
        # engine's registry into one scrape payload with an
        # engine="<name>" label per series (scrape-friendly family
        # grouping -- see metrics.merge_labeled_expositions). /healthz
        # reports a per-engine snapshot map; /events still drains only
        # the primary ``engine``.
        self.engines: Optional[Dict[str, object]] = (
            {n: getattr(e, "engine", e) for n, e in engines.items()}
            if engines else None)
        self.preview_interval = preview_interval
        # /events?interval=K values clients may request beyond the default:
        # each distinct K compiles its own streaming sampler, so the set
        # must be finite to keep the compiled-fn cache bounded.
        self.allowed_intervals = tuple(allowed_intervals)
        self.engine_lock = threading.Lock()
        server = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, *a):      # tests/CLIs stay quiet
                pass

            def do_GET(self):
                try:
                    server._route(self)
                except (BrokenPipeError, ConnectionResetError):
                    pass                    # client went away mid-stream

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ control
    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}"

    def start(self) -> "TelemetryHTTPServer":
        assert self._thread is None, "already started"
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="drift-telemetry-http",
                                        daemon=True)
        self._thread.start()
        return self

    def close(self) -> None:
        # shutdown() blocks on an event only serve_forever() sets; calling
        # it on a never-started server would deadlock, so skip straight to
        # releasing the socket in that case.
        if self._thread is not None:
            self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self) -> "TelemetryHTTPServer":
        return self if self._thread is not None else self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ routing
    def _route(self, h: BaseHTTPRequestHandler) -> None:
        parsed = urlparse(h.path)
        if parsed.path == "/healthz":
            return self._healthz(h)
        if parsed.path == "/metrics":
            return self._metrics(h)
        if parsed.path == "/slo":
            return self._slo(h)
        if parsed.path == "/events":
            return self._events(h, parse_qs(parsed.query))
        if parsed.path == "/flight":
            return self._flight(h)
        if parsed.path.startswith("/trace/"):
            return self._trace(h, parsed.path[len("/trace/"):])
        self._respond(h, 404, "application/json",
                      json.dumps({"error": f"no route {parsed.path}"}))

    @staticmethod
    def _respond(h, code: int, ctype: str, body: str) -> None:
        data = body.encode("utf-8")
        h.send_response(code)
        h.send_header("Content-Type", ctype)
        h.send_header("Content-Length", str(len(data)))
        h.end_headers()
        h.wfile.write(data)

    # ---------------------------------------------------------- endpoints
    @staticmethod
    def _engine_snapshot(eng) -> Dict[str, object]:
        tele = getattr(eng, "telemetry", None)
        ctrl = getattr(tele, "controller", None) if tele else None
        return {
            "status": "ok",
            "arch": eng.default_arch,
            "clock_s": eng.clock_s,
            "queue_depth": len(eng.queue),
            "batches": eng.stats.batches,
            "deadline_misses": eng.stats.deadline_misses,
            "monitor_ladder_index": int(eng.monitor.op_index),
            "monitor_ema_ber": float(eng.monitor.ema_ber),
            "guardband_index": ctrl.guard_index if ctrl else 0,
            "telemetry_enabled": bool(tele is not None and tele.enabled),
        }

    def _healthz(self, h) -> None:
        body = self._engine_snapshot(self.engine)
        if self.engines:
            body["engines"] = {name: self._engine_snapshot(e)
                               for name, e in self.engines.items()}
        self._respond(h, 200, "application/json", json.dumps(body))

    def _metrics(self, h) -> None:
        if self.engines:
            self._respond(h, 200, "text/plain; version=0.0.4; charset=utf-8",
                          aggregate_metrics(self.engines))
            return
        tele = getattr(self.engine, "telemetry", None)
        if tele is None or not tele.enabled:
            self._respond(h, 200, "text/plain; charset=utf-8",
                          "# telemetry disabled\n")
            return
        self._respond(h, 200, tele.registry.CONTENT_TYPE,
                      tele.registry.expose())

    @staticmethod
    def _slo_snapshot(eng):
        tele = getattr(eng, "telemetry", None)
        snap = tele.slo_snapshot() if tele is not None else None
        return snap if snap is not None else {"slo": "disabled"}

    def _slo(self, h) -> None:
        """``GET /slo``: the engine's SLO tracker snapshot (objectives,
        targets, fast/slow window values, burn rates, breach state) on
        the deterministic virtual clock -- wire format in docs/slo.md.
        Multi-engine servers report a per-engine map like /healthz."""
        body = self._slo_snapshot(self.engine)
        if self.engines:
            body["engines"] = {name: self._slo_snapshot(e)
                               for name, e in self.engines.items()}
        self._respond(h, 200, "application/json", json.dumps(body))

    def _flight(self, h) -> None:
        """The whole ring buffer as Chrome trace JSON (lock-free read:
        the recorder snapshots its deque under its own lock)."""
        tracer = getattr(self.engine, "tracer", None)
        spans = tracer.spans() if tracer is not None else []
        self._respond(h, 200, "application/json",
                      json.dumps(to_chrome_trace(spans)))

    def _trace(self, h, tail: str) -> None:
        """``GET /trace/<request_id>``: one request's span tree, or 404
        for a non-integer id, an unknown/evicted request, or a disabled
        (or absent) recorder -- an empty ring buffer can't distinguish
        "never existed" from "evicted", so both are 404."""
        try:
            rid = int(tail)
        except ValueError:
            self._respond(h, 404, "application/json",
                          json.dumps({"error": f"bad request id {tail!r}"}))
            return
        tracer = getattr(self.engine, "tracer", None)
        spans = tracer.spans(request_id=rid) if tracer is not None else []
        if not spans:
            self._respond(h, 404, "application/json",
                          json.dumps({"error": f"no trace for request "
                                               f"{rid} (unknown, evicted, "
                                               "or recorder disabled)"}))
            return
        self._respond(h, 200, "application/json",
                      json.dumps(request_tree(spans, rid)))

    def _events(self, h, query) -> None:
        try:
            interval = int(query.get("interval", [self.preview_interval])[0])
            assert interval >= 1
        except (ValueError, AssertionError):
            self._respond(h, 400, "application/json",
                          json.dumps({"error": "interval must be an int "
                                               ">= 1"}))
            return
        if interval != self.preview_interval \
                and interval not in self.allowed_intervals:
            # every distinct interval is a new SamplerKey.stream -> a fresh
            # multi-second trace and a permanent compiled-sampler cache
            # entry; an open endpoint must not let clients grow that
            # without bound
            self._respond(h, 400, "application/json",
                          json.dumps({"error": f"interval {interval} not "
                                      "allowed; one of "
                                      f"{sorted(self.allowed_intervals)}"}))
            return
        if not self.engine_lock.acquire(blocking=False):
            self._respond(h, 503, "application/json",
                          json.dumps({"error": "engine busy: another drain "
                                               "is in progress"}))
            return
        try:
            h.send_response(200)
            h.send_header("Content-Type", "text/event-stream")
            h.send_header("Cache-Control", "no-cache")
            # SSE is open-ended; no Content-Length, so close delimits it
            h.send_header("Connection", "close")
            h.end_headers()
            served = previews = n = 0
            client_gone = False
            if len(self.engine.queue):
                for ev in self.engine.run_stream(interval):
                    if isinstance(ev, PreviewEvent):
                        kind, body = "preview", preview_wire(ev)
                        previews += 1
                    else:
                        kind, body = "result", result_wire(ev)
                        served += 1
                    if client_gone:
                        continue    # keep draining; see below
                    try:
                        self._write_frame(h, kind, body, event_id=n)
                        n += 1
                    except (BrokenPipeError, ConnectionResetError):
                        # The client went away mid-batch. Abandoning the
                        # generator here would LOSE the in-flight bucket:
                        # its requests were already popped from the queue
                        # and the monitor/clock carry happens at batch
                        # end. Finish the drain engine-side (discarding
                        # frames) so every request completes and the
                        # engine stays consistent; results are only lost
                        # to this client.
                        client_gone = True
            if not client_gone:
                self._write_frame(h, "end",
                                  {"served": served, "previews": previews})
                h.wfile.flush()
        finally:
            self.engine_lock.release()

    @staticmethod
    def _write_frame(h, kind: str, body: Dict[str, object],
                     event_id: Optional[int] = None) -> None:
        frame = f"event: {kind}\n"
        if event_id is not None:
            frame += f"id: {event_id}\n"
        frame += f"data: {json.dumps(body)}\n\n"
        h.wfile.write(frame.encode("utf-8"))
        h.wfile.flush()


def aggregate_metrics(engines: Dict[str, object]) -> str:
    """One Prometheus payload for several engines, every series tagged
    ``engine="<name>"``. Engines with telemetry disabled contribute a
    comment only (their registry has nothing registered)."""
    named = {}
    for name, eng in engines.items():
        eng = getattr(eng, "engine", eng)       # DeadlineScheduler ok
        tele = getattr(eng, "telemetry", None)
        named[name] = (tele.registry.expose()
                       if tele is not None and tele.enabled else "")
    return merge_labeled_expositions(named)


def serve_telemetry(engine, host: str = "127.0.0.1", port: int = 0,
                    engines: Optional[Dict[str, object]] = None
                    ) -> TelemetryHTTPServer:
    """Build + start a telemetry server for ``engine``; returns it running
    (the CLIs print ``server.url`` and ``close()`` it after the drain).
    ``engines`` additionally aggregates several engines' registries under
    one /metrics endpoint with an ``engine`` label per series."""
    return TelemetryHTTPServer(engine, host=host, port=port,
                               engines=engines).start()
