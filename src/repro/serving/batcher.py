"""Micro-batcher: groups FIFO requests into fixed-size same-config buckets.

Requests only share a sampler invocation when they resolve to the same
``SamplerKey`` (same arch/steps/mode/op/...), so batches are formed by
taking the head request's key and sweeping the queue for up to ``bucket``
matches; later non-matching requests keep their queue position. A short
final group is padded up to the bucket size (duplicating the last live
request's latents downstream) so every compiled sampler sees exactly one
batch shape -- the whole point of fixed-size buckets.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.serving.cache import SamplerKey
from repro.serving.request import GenerationRequest, RequestQueue


@dataclasses.dataclass(frozen=True)
class MicroBatch:
    """One bucket of same-config requests ready to run."""
    key: SamplerKey
    requests: List[GenerationRequest]   # live requests, FIFO order

    @property
    def n_pad(self) -> int:
        return self.key.bucket - len(self.requests)


def request_key(req: GenerationRequest, bucket: int, resolved_op: str,
                extra: Optional[Dict[str, object]] = None) -> SamplerKey:
    """SamplerKey for a request whose operating point is already resolved.

    Clean mode runs with no DVFS schedule at all, so its op normalizes to
    "": clean requests with different nominal op names share one compiled
    sampler (the same key the engine's clean-reference path uses), and the
    energy accounting falls back to the nominal point actually run.

    ``extra`` overrides engine-level key fields a request cannot express --
    the sharded engine stamps its (mesh_shape, batch_spec) placement here
    so two engines on different meshes never alias a compiled fn.
    """
    key = SamplerKey(arch=req.arch, smoke=req.smoke, steps=req.steps,
                     mode=req.mode,
                     op="" if req.mode == "clean" else resolved_op,
                     bucket=bucket,
                     taylorseer=req.taylorseer,
                     rollback_interval=req.rollback_interval)
    return dataclasses.replace(key, **extra) if extra else key


class MicroBatcher:
    """Forms one bucket at a time so "auto" operating points can consult the
    engine's live BER-monitor state between batches."""

    def __init__(self, bucket: int,
                 key_extra: Optional[Dict[str, object]] = None) -> None:
        assert bucket >= 1, bucket
        self.bucket = bucket
        self.key_extra = dict(key_extra or {})

    def next_batch(self, queue: RequestQueue,
                   resolve_op: Callable[[GenerationRequest], str]
                   ) -> MicroBatch:
        """Pop the next bucket. ``resolve_op`` maps a request to a concrete
        operating-point name (handling "auto" via the monitor ladder); it is
        applied per-request while scanning, so two "auto" requests land in
        the same bucket only if they resolve identically."""
        head = queue.peek()
        assert head is not None, "next_batch on an empty queue"
        key_of = lambda r: request_key(r, self.bucket, resolve_op(r),
                                       self.key_extra)
        key = key_of(head)
        reqs = queue.take_matching(key, key_of, self.bucket)
        return MicroBatch(key=key, requests=reqs)
