"""Micro-batcher: groups pending requests into fixed-size same-config
buckets.

The bucketing contract, in full:

* Requests only share a sampler invocation when they resolve to the same
  ``SamplerKey`` (same arch/steps/mode/resolved op/bucket/stream/mesh
  placement -- everything that changes the traced computation, see
  ``cache.SamplerKey``). ``request_key`` is the single place that mapping
  lives.
* A batch is formed by taking a *seed* request's key and sweeping the
  queue (``RequestQueue.take_matching``) for up to ``bucket`` matches, in
  FIFO order; later non-matching requests keep their queue position. The
  base ``MicroBatcher`` seeds from the queue head (pure FIFO);
  ``serving.scheduler.PriorityMicroBatcher`` seeds from the most urgent
  pending request (priority, then earliest absolute deadline, then FIFO)
  and inherits everything else.
* A short final group is padded up to the bucket size (duplicating the
  last live request's latents downstream) so every compiled sampler sees
  exactly one batch shape -- the whole point of fixed-size buckets. The
  padding slots' energy is attributed to the live requests
  (``perfmodel.energy.per_request_cost``), never hidden.
* Exactly one bucket is formed per call, so ``op="auto"`` resolution can
  consult the engine's *live* BER-monitor state between batches.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.serving.cache import SamplerKey
from repro.serving.request import GenerationRequest, RequestQueue


@dataclasses.dataclass(frozen=True)
class MicroBatch:
    """One bucket of same-config requests ready to run."""
    key: SamplerKey
    requests: List[GenerationRequest]   # live requests, FIFO order

    @property
    def n_pad(self) -> int:
        return self.key.bucket - len(self.requests)


def request_key(req: GenerationRequest, bucket: int, resolved_op: str,
                extra: Optional[Dict[str, object]] = None,
                resolved_interval: Optional[int] = None) -> SamplerKey:
    """SamplerKey for a request whose operating point is already resolved.

    This is the whole bucketing predicate: two requests co-batch iff their
    ``request_key``s are equal. Scheduler fields (``priority``,
    ``deadline_s``, ``submitted_at_s``) deliberately do NOT appear in the
    key -- urgency decides *when* a bucket forms, not *what* it computes,
    so an interactive and a background request with the same resolved
    configuration still share a compiled sampler and a batch. The
    scheduler's per-request (op, step) assignment lands in the key via the
    rewritten ``op``/``steps`` fields, which is how a deadline-degraded
    request ends up in a different bucket than an as-requested one.

    Clean mode runs with no DVFS schedule at all, so its op normalizes to
    "": clean requests with different nominal op names share one compiled
    sampler (the same key the engine's clean-reference path uses), and the
    energy accounting falls back to the nominal point actually run.

    ``extra`` overrides engine-level key fields a request cannot express --
    the sharded engine stamps its (mesh_shape, batch_spec) placement here
    so two engines on different meshes never alias a compiled fn, and the
    streaming path stamps ``stream`` (the preview window) per run.

    ``resolved_interval`` is the concrete checkpoint-refresh interval for
    a ``rollback_interval="auto"`` request (the engine resolves it through
    the offload planner, exactly like ``op="auto"`` through the monitor
    ladder); a key must never carry the "auto" sentinel.
    """
    interval = (resolved_interval if resolved_interval is not None
                else req.rollback_interval)
    assert not isinstance(interval, str), \
        "resolve rollback_interval='auto' before building a SamplerKey"
    key = SamplerKey(arch=req.arch, smoke=req.smoke, steps=req.steps,
                     mode=req.mode,
                     op="" if req.mode == "clean" else resolved_op,
                     bucket=bucket,
                     taylorseer=req.taylorseer,
                     precision=req.precision,
                     rollback_interval=interval)
    return dataclasses.replace(key, **extra) if extra else key


class MicroBatcher:
    """Forms one bucket at a time so "auto" operating points can consult the
    engine's live BER-monitor state between batches."""

    def __init__(self, bucket: int,
                 key_extra: Optional[Dict[str, object]] = None) -> None:
        assert bucket >= 1, bucket
        self.bucket = bucket
        self.key_extra = dict(key_extra or {})

    def next_batch(self, queue: RequestQueue,
                   resolve_op: Callable[[GenerationRequest], str],
                   resolve_interval: Optional[
                       Callable[[GenerationRequest], int]] = None
                   ) -> MicroBatch:
        """Pop the next bucket. ``resolve_op`` maps a request to a concrete
        operating-point name (handling "auto" via the monitor ladder) and
        ``resolve_interval`` to a concrete rollback interval (handling
        "auto" via the offload planner; None = use the request's int);
        both are applied per-request while scanning, so two "auto"
        requests land in the same bucket only if they resolve
        identically."""
        head = queue.peek()
        assert head is not None, "next_batch on an empty queue"
        key_of = lambda r: request_key(
            r, self.bucket, resolve_op(r), self.key_extra,
            resolve_interval(r) if resolve_interval is not None else None)
        key = key_of(head)
        reqs = queue.take_matching(key, key_of, self.bucket)
        return MicroBatch(key=key, requests=reqs)
