"""Per-request tracing and fault forensics for the serving stack.

See :mod:`repro.serving.trace.recorder` for the span taxonomy and the
zero-perturbation contract, :mod:`repro.serving.trace.export` for the
Chrome/Perfetto writer, and ``docs/tracing.md`` for the operator view.
"""
from .recorder import SPAN_KINDS, FlightRecorder, Span
from .export import (request_tree, span_to_event, to_chrome_trace,
                     write_chrome_trace)
from .heatmap import N_STEP_BINS, bin_heatmap, site_labels, summarize

__all__ = ["SPAN_KINDS", "FlightRecorder", "Span", "request_tree",
           "span_to_event", "to_chrome_trace", "write_chrome_trace",
           "N_STEP_BINS", "bin_heatmap", "site_labels", "summarize"]
