"""Flight recorder: a bounded ring buffer of serving-stack spans.

Every interesting event in a request's life -- submit, admission decision,
queue wait, batch assembly, compile (cache miss), each scan window, each
offload commit/restore, rollback replays, detection summary, finalize --
is recorded as a :class:`Span` carrying BOTH clocks:

* ``virtual_s`` -- the engine's deterministic perfmodel clock
  (``engine.clock_s``, modeled accelerator seconds). The engine only
  advances it when a batch finishes, so every span inside a batch carries
  the batch's *starting* virtual time; ``finalize`` spans carry the
  advanced clock. Virtual durations beyond that resolution are attached
  as attrs (e.g. the batch's modeled ``latency_s``) rather than faked.
* ``wall_s`` -- host ``time.perf_counter`` relative to the recorder's
  epoch. Real durations: compile cost, window cadence, offload commit
  latency.

The recorder is **zero-perturbation by construction**: every hook runs
host-side between traced computations (the heatmap the detect spans
summarize is computed unconditionally inside the scan, tracing on or
off), so finals are bit-identical with the recorder enabled, disabled,
or absent -- ``tests/test_trace.py`` asserts it on both engines.

Thread-safety: offload commits fire from the store's background thread,
so all mutation happens under one lock. Bounded memory: the ring keeps
the newest ``capacity`` spans and counts what it dropped.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

# Span kinds, the full taxonomy (docs/tracing.md documents each):
SPAN_KINDS = (
    "submit",            # request accepted into the queue
    "admission",         # scheduler decision (audit record in attrs)
    "queue_wait",        # submit -> batch assembly, per request
    "batch_assembly",    # micro-batch formed from the queue
    "compile",           # sampler-cache miss: trace + compile
    "window",            # one scan window (diffusion steps / AR tokens)
    "offload_commit",    # checkpoint snapshot -> host double buffer
    "offload_restore",   # checkpoint re-upload
    "replay",            # rollback replay (AR window re-decode)
    "detect",            # per-batch detection summary (heatmap attrs)
    "finalize",          # quality/energy attribution, results built
)


@dataclasses.dataclass(frozen=True)
class Span:
    name: str
    kind: str                       # one of SPAN_KINDS
    request_ids: Tuple[int, ...]    # every request the span applies to
    batch_index: int                # -1 when not tied to a batch
    t0_virtual_s: float
    t1_virtual_s: float
    t0_wall_s: float
    t1_wall_s: float
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["request_ids"] = list(self.request_ids)
        return d


class FlightRecorder:
    """Bounded span ring buffer shared by one engine and its scheduler."""

    def __init__(self, capacity: int = 4096, enabled: bool = True):
        assert capacity >= 1, capacity
        self.capacity = capacity
        self.enabled = enabled
        self._lock = threading.Lock()
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self.recorded = 0
        self.dropped = 0
        self._epoch = time.perf_counter()
        # Current-batch context (the engine is single-threaded between
        # batches; only offload commits arrive from another thread, and
        # they only read these fields under the lock).
        self._batch_index = -1
        self._batch_request_ids: Tuple[int, ...] = ()
        self._batch_virtual_s = 0.0
        self._batch_wall_s = 0.0
        self._last_window_wall_s = 0.0
        self._last_window_steps = 0
        # Per-request submit wall times, for queue_wait spans.
        self._submit_wall: Dict[int, float] = {}
        self._submit_virtual: Dict[int, float] = {}

    # ------------------------------------------------------------ plumbing
    def now_wall(self) -> float:
        return time.perf_counter() - self._epoch

    def record(self, name: str, kind: str, request_ids=(),
               batch_index: int = -1,
               t0_virtual_s: float = 0.0,
               t1_virtual_s: Optional[float] = None,
               t0_wall_s: Optional[float] = None,
               t1_wall_s: Optional[float] = None,
               **attrs) -> Optional[Span]:
        if not self.enabled:
            return None
        wall = self.now_wall()
        span = Span(name=name, kind=kind,
                    request_ids=tuple(int(r) for r in request_ids),
                    batch_index=int(batch_index),
                    t0_virtual_s=float(t0_virtual_s),
                    t1_virtual_s=float(t1_virtual_s
                                       if t1_virtual_s is not None
                                       else t0_virtual_s),
                    t0_wall_s=float(t0_wall_s if t0_wall_s is not None
                                    else wall),
                    t1_wall_s=float(t1_wall_s if t1_wall_s is not None
                                    else wall),
                    attrs=attrs)
        with self._lock:
            if len(self._ring) == self.capacity:
                self.dropped += 1
            self._ring.append(span)
            self.recorded += 1
        return span

    # --------------------------------------------------------- engine taps
    def on_submit(self, request_id: int, virtual_s: float, **attrs) -> None:
        if not self.enabled:
            return
        wall = self.now_wall()
        with self._lock:
            self._submit_wall[int(request_id)] = wall
            self._submit_virtual[int(request_id)] = float(virtual_s)
        self.record("submit", "submit", request_ids=(request_id,),
                    t0_virtual_s=virtual_s, t0_wall_s=wall, t1_wall_s=wall,
                    **attrs)

    def begin_batch(self, batch_index: int, request_ids, virtual_s: float,
                    **attrs) -> None:
        """Open a batch context: queue_wait spans for each member, then a
        batch_assembly span. Window/offload/detect spans recorded until
        the next ``begin_batch`` attach to this batch."""
        if not self.enabled:
            return
        wall = self.now_wall()
        with self._lock:
            self._batch_index = int(batch_index)
            self._batch_request_ids = tuple(int(r) for r in request_ids)
            self._batch_virtual_s = float(virtual_s)
            self._batch_wall_s = wall
            self._last_window_wall_s = wall
            self._last_window_steps = 0
            submit_wall = dict(self._submit_wall)
            submit_virtual = dict(self._submit_virtual)
        for rid in self._batch_request_ids:
            t0w = submit_wall.get(rid, wall)
            t0v = submit_virtual.get(rid, virtual_s)
            self.record(f"queue_wait r{rid}", "queue_wait",
                        request_ids=(rid,), batch_index=batch_index,
                        t0_virtual_s=t0v, t1_virtual_s=virtual_s,
                        t0_wall_s=t0w, t1_wall_s=wall)
        self.record(f"batch {batch_index}", "batch_assembly",
                    request_ids=self._batch_request_ids,
                    batch_index=batch_index, t0_virtual_s=virtual_s,
                    t0_wall_s=wall, t1_wall_s=wall, **attrs)

    def on_compile(self, wall_elapsed_s: float, **attrs) -> None:
        if not self.enabled:
            return
        wall = self.now_wall()
        with self._lock:
            bi, rids, v = (self._batch_index, self._batch_request_ids,
                           self._batch_virtual_s)
        self.record("compile", "compile", request_ids=rids, batch_index=bi,
                    t0_virtual_s=v, t0_wall_s=wall - wall_elapsed_s,
                    t1_wall_s=wall, **attrs)

    def on_window(self, done_steps: int, **attrs) -> None:
        if not self.enabled:
            return
        wall = self.now_wall()
        with self._lock:
            bi, rids, v = (self._batch_index, self._batch_request_ids,
                           self._batch_virtual_s)
            t0w = self._last_window_wall_s
            from_step = self._last_window_steps
            self._last_window_wall_s = wall
            self._last_window_steps = int(done_steps)
        self.record(f"window ->{done_steps}", "window", request_ids=rids,
                    batch_index=bi, t0_virtual_s=v, t0_wall_s=t0w,
                    t1_wall_s=wall, from_step=from_step,
                    done_steps=int(done_steps), **attrs)

    def on_offload(self, event: str, step: int, wall_elapsed_s: float = 0.0,
                   **attrs) -> None:
        """``event`` is "commit" or "restore"; called from the offload
        store's background commit thread, hence the lock discipline."""
        if not self.enabled:
            return
        wall = self.now_wall()
        with self._lock:
            bi, rids, v = (self._batch_index, self._batch_request_ids,
                           self._batch_virtual_s)
        self.record(f"offload_{event} @{step}", f"offload_{event}",
                    request_ids=rids, batch_index=bi, t0_virtual_s=v,
                    t0_wall_s=wall - max(wall_elapsed_s, 0.0),
                    t1_wall_s=wall, step=int(step), **attrs)

    def on_replay(self, window_start: int, window_len: int, **attrs) -> None:
        if not self.enabled:
            return
        with self._lock:
            bi, rids, v = (self._batch_index, self._batch_request_ids,
                           self._batch_virtual_s)
        self.record(f"replay @{window_start}", "replay", request_ids=rids,
                    batch_index=bi, t0_virtual_s=v,
                    window_start=int(window_start),
                    window_len=int(window_len), **attrs)

    def finish_batch(self, virtual_t1_s: float, detect_attrs=None,
                     **finalize_attrs) -> None:
        """Close the batch: a detect-summary span (heatmap totals) when
        detection ran, then the finalize span spanning the batch's whole
        virtual interval."""
        if not self.enabled:
            return
        wall = self.now_wall()
        with self._lock:
            bi, rids, v0 = (self._batch_index, self._batch_request_ids,
                            self._batch_virtual_s)
            t0w = self._batch_wall_s
            for rid in rids:
                self._submit_wall.pop(rid, None)
                self._submit_virtual.pop(rid, None)
        if detect_attrs is not None:
            self.record(f"detect batch {bi}", "detect", request_ids=rids,
                        batch_index=bi, t0_virtual_s=v0,
                        t1_virtual_s=virtual_t1_s, t0_wall_s=t0w,
                        t1_wall_s=wall, **detect_attrs)
        self.record(f"finalize batch {bi}", "finalize", request_ids=rids,
                    batch_index=bi, t0_virtual_s=v0,
                    t1_virtual_s=virtual_t1_s, t0_wall_s=t0w,
                    t1_wall_s=wall, **finalize_attrs)

    # ------------------------------------------------------------- queries
    def spans(self, request_id: Optional[int] = None) -> List[Span]:
        """Newest-last snapshot; filtered to one request when given."""
        with self._lock:
            snap = list(self._ring)
        if request_id is None:
            return snap
        rid = int(request_id)
        return [s for s in snap if rid in s.request_ids]

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)
