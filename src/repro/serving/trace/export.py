"""Exporters for the flight recorder: Chrome/Perfetto trace-event JSON
and the per-request span tree served by ``GET /trace/<id>``.

The Chrome format (loadable at ``ui.perfetto.dev`` or
``chrome://tracing``) wants complete events::

    {"name", "cat", "ph": "X", "ts": <us>, "dur": <us>, "pid", "tid",
     "args": {...}}

Wall-clock timestamps drive ``ts``/``dur`` (that is what a trace viewer
lays out); the virtual-clock interval and every span attr ride along in
``args`` so the perfmodel story stays reconstructible from the file.
Spans are grouped one ``tid`` per batch (``tid 0`` for pre-batch spans
like submit/admission), all under a single ``pid``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List

from repro.version import __version__

from .recorder import Span

_PID = 1


def span_to_event(span: Span) -> Dict[str, Any]:
    dur_us = max((span.t1_wall_s - span.t0_wall_s) * 1e6, 1.0)
    return {
        "name": span.name,
        "cat": span.kind,
        "ph": "X",
        "ts": span.t0_wall_s * 1e6,
        "dur": dur_us,
        "pid": _PID,
        "tid": span.batch_index + 1,     # batch -1 (pre-batch) -> tid 0
        "args": {
            "request_ids": list(span.request_ids),
            "virtual_t0_s": span.t0_virtual_s,
            "virtual_t1_s": span.t1_virtual_s,
            **span.attrs,
        },
    }


def to_chrome_trace(spans: Iterable[Span]) -> Dict[str, Any]:
    spans = list(spans)
    events: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": _PID,
        "args": {"name": f"drift-serve {__version__}"},
    }]
    for bi in sorted({s.batch_index for s in spans}):
        events.append({
            "name": "thread_name", "ph": "M", "pid": _PID, "tid": bi + 1,
            "args": {"name": "scheduler/queue" if bi < 0
                     else f"batch {bi}"},
        })
    events.extend(span_to_event(s) for s in spans)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: Iterable[Span]) -> None:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(spans), f, indent=1)


def request_tree(spans: Iterable[Span], request_id: int) -> Dict[str, Any]:
    """The ``GET /trace/<id>`` payload: the request's spans oldest-first,
    both clocks explicit, with the scheduler decision record (if any)
    surfaced at the top level."""
    rid = int(request_id)
    mine = [s for s in spans if rid in s.request_ids]
    decision = None
    for s in mine:
        if s.kind == "admission":
            decision = s.attrs
    return {
        "request_id": rid,
        "n_spans": len(mine),
        "decision": decision,
        "spans": [s.to_dict() for s in mine],
    }
