"""Resilience-heatmap helpers: timestep binning and site labels.

The sampler emits a per-step detection vector (``SampleOutput.heatmap``,
shape (steps, sites)); serving summarizes it into (sites, timestep-bin)
buckets -- the live-serving analogue of the paper's Figs 5-6, where the
early (protected) timesteps and the embedding/first-block sites are
exactly the cells DRIFT keeps at nominal voltage.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

# Timestep bins in the exported heatmap. Four is enough to separate the
# protected head (nominal_steps live in bin 0 for typical step counts)
# from the resilient tail without exploding metric cardinality.
N_STEP_BINS = 4


def bin_heatmap(heat, n_bins: int = N_STEP_BINS) -> np.ndarray:
    """(steps, sites) detection counts -> (sites, bins) int64 buckets.

    Steps are partitioned into ``n_bins`` contiguous ranges (edges via
    linspace, so a non-divisible step count spreads the remainder); fewer
    steps than bins degrades to one bin per step.
    """
    heat = np.asarray(heat)
    assert heat.ndim == 2, heat.shape
    steps, sites = heat.shape
    n_bins = max(1, min(n_bins, steps))
    edges = np.linspace(0, steps, n_bins + 1).astype(int)
    out = np.zeros((sites, n_bins), dtype=np.int64)
    for b in range(n_bins):
        out[:, b] = heat[edges[b]:edges[b + 1]].sum(axis=0)
    return out


def site_labels(n_sites: int) -> Tuple[str, ...]:
    """Row labels matching the sampler's detection-row layout
    (``sampler.detection_rows``): DiT-family rows are the embedding GEMMs
    followed by one row per block; single-row families (UNet's flat
    ExecContext, AR decode windows) get "all"."""
    if n_sites == 1:
        return ("all",)
    return ("embed",) + tuple(f"block{i}" for i in range(n_sites - 1))


def summarize(heat, n_bins: int = N_STEP_BINS
              ) -> Tuple[Optional[tuple], Optional[tuple]]:
    """(steps, sites) array -> (nested int tuple (sites, bins), labels);
    (None, None) for a sampler that produced no heatmap."""
    if heat is None:
        return None, None
    binned = bin_heatmap(heat, n_bins)
    rows = tuple(tuple(int(v) for v in row) for row in binned)
    return rows, site_labels(binned.shape[0])
