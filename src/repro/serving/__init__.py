"""Batched DRIFT serving: request queue, micro-batcher, compiled-sampler
cache, and the single-process engine tying them together.

Public API (see ``engine.DriftServeEngine`` for the full contract)::

    from repro.serving import DriftServeEngine

    engine = DriftServeEngine(arch="dit-xl-512", smoke=True, bucket=2)
    engine.submit(steps=10, mode="drift", op="undervolt", seed=0)
    engine.submit(steps=10, mode="drift", op="auto", seed=1)
    results = engine.run()          # List[RequestResult], submission order

``ShardedDriftServeEngine`` (or the ``make_engine`` factory, which degrades
to the single-device engine when there is one device) runs the same loop
with each micro-batch sharded across a device mesh -- see
``repro.serving.sharded`` and docs/serving.md.

Each distinct (arch, steps, mode, operating point, bucket, mesh) configuration
compiles exactly once per process (``engine.cache.traces`` counts actual
JAX traces); the BER monitor persists across batches and feeds requests
that pick their DVFS operating point with ``op="auto"``.
"""
from repro.serving.batcher import MicroBatch, MicroBatcher, request_key
from repro.serving.cache import CompiledSamplerCache, SamplerKey
from repro.serving.engine import OP_BY_NAME, DriftServeEngine, EngineStats
from repro.serving.request import (REQUEST_OPS, GenerationRequest,
                                   RequestQueue, RequestResult)
from repro.serving.sharded import ShardedDriftServeEngine, make_engine

__all__ = [
    "DriftServeEngine", "ShardedDriftServeEngine", "make_engine",
    "EngineStats", "OP_BY_NAME",
    "GenerationRequest", "RequestQueue", "RequestResult", "REQUEST_OPS",
    "MicroBatch", "MicroBatcher", "request_key",
    "CompiledSamplerCache", "SamplerKey",
]
