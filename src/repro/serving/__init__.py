"""Batched DRIFT serving: request queue, micro-batcher, compiled-sampler
cache, and the single-process engine tying them together.

Public API (see ``engine.DriftServeEngine`` for the full contract)::

    from repro.serving import DriftServeEngine

    engine = DriftServeEngine(arch="dit-xl-512", smoke=True, bucket=2)
    engine.submit(steps=10, mode="drift", op="undervolt", seed=0)
    engine.submit(steps=10, mode="drift", op="auto", seed=1)
    results = engine.run()          # List[RequestResult], submission order

Each distinct (arch, steps, mode, operating point, bucket) configuration
compiles exactly once per process (``engine.cache.traces`` counts actual
JAX traces); the BER monitor persists across batches and feeds requests
that pick their DVFS operating point with ``op="auto"``.
"""
from repro.serving.batcher import MicroBatch, MicroBatcher, request_key
from repro.serving.cache import CompiledSamplerCache, SamplerKey
from repro.serving.engine import OP_BY_NAME, DriftServeEngine, EngineStats
from repro.serving.request import (REQUEST_OPS, GenerationRequest,
                                   RequestQueue, RequestResult)

__all__ = [
    "DriftServeEngine", "EngineStats", "OP_BY_NAME",
    "GenerationRequest", "RequestQueue", "RequestResult", "REQUEST_OPS",
    "MicroBatch", "MicroBatcher", "request_key",
    "CompiledSamplerCache", "SamplerKey",
]
