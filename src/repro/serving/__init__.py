"""Batched DRIFT serving: request queue, micro-batcher, compiled-sampler
cache, the single-process engine tying them together, and the deadline-
aware scheduling layer on top.

Public API (see ``engine.DriftServeEngine`` for the full contract)::

    from repro.serving import DriftServeEngine

    engine = DriftServeEngine(arch="dit-xl-512", smoke=True, bucket=2)
    engine.submit(steps=10, mode="drift", op="undervolt", seed=0)
    engine.submit(steps=10, mode="drift", op="auto", seed=1)
    results = engine.run()          # List[RequestResult], submission order

    for ev in engine.run_stream(preview_interval=2):
        ...                         # PreviewEvents, then RequestResults

``ShardedDriftServeEngine`` (or the ``make_engine`` factory, which degrades
to the single-device engine when there is one device) runs the same loop
with each micro-batch sharded across a device mesh -- see
``repro.serving.sharded`` and docs/serving.md.

``DeadlineScheduler`` wraps either engine with admission control, a joint
(DVFS operating point, step budget) policy, and priority-bucketed batch
formation -- see ``repro.serving.scheduler`` and docs/scheduler.md.
Requests stating an ``energy_budget_j``/``quality_floor`` objective
resolve against the precomputed compute-optimal (steps x precision x
TaylorSeer x DVFS) Pareto frontier instead (``repro.serving.frontier``,
docs/frontier.md)::

    from repro.serving import DeadlineScheduler

    sched = DeadlineScheduler(engine)
    adm = sched.submit(steps=10, mode="drift", op="auto",
                       priority="interactive", deadline_s=0.08)
    print(adm.action, adm.op, adm.steps)        # e.g. trimmed-steps
    results = sched.run()

Each distinct (arch, steps, mode, operating point, bucket, stream, mesh)
configuration compiles exactly once per process (``engine.cache.traces``
counts actual JAX traces); the BER monitor persists across batches and
feeds requests that pick their DVFS operating point with ``op="auto"``.

Telemetry & online adaptation (``repro.serving.telemetry``,
docs/telemetry.md) ride every engine by default: a Prometheus-style
metrics registry, a served-batch latency history the scheduler's
admission control learns from (perfmodel fallback on empty history), an
adaptive BER guardband floor under the "auto" ladder, and an HTTP/SSE
front-end::

    from repro.serving import serve_telemetry

    server = serve_telemetry(engine, port=0)     # /metrics /healthz /events
    print(server.url)
    ...
    server.close()

Async checkpoint offload (``repro.serving.offload``, docs/offload.md)
moves the rollback checkpoint store out of the sampling scan: with
``DriftServeEngine(offload=OffloadConfig())`` every monitored batch's
store snapshots commit to a double-buffered host buffer between
denoising windows on a background thread (tile-contiguous layout,
restore-on-rollback), the planner resolves
``rollback_interval="auto"`` per configuration, and finals stay
bit-identical to an offload-free engine.
"""
from repro.serving.batcher import MicroBatch, MicroBatcher, request_key
from repro.serving.servable import (PARADIGM_BY_FAMILY, UNSUPPORTED_FAMILIES,
                                    AutoregressiveServable,
                                    DiffusionServable, ServableModel,
                                    UnsupportedArchError, build_servable,
                                    paradigm_for)
from repro.serving.cache import CompiledSamplerCache, SamplerKey
from repro.serving.engine import OP_BY_NAME, DriftServeEngine, EngineStats
from repro.serving.request import (PRIORITY_RANK, REQUEST_OPS,
                                   REQUEST_PRIORITIES, GenerationRequest,
                                   PreviewEvent, RequestQueue, RequestResult)
from repro.serving.frontier import (FRONTIER_OPS, FrontierBuilder,
                                    FrontierPoint, dominates, pareto_front,
                                    quality_proxy)
from repro.serving.scheduler import (Admission, DeadlineScheduler,
                                     PriorityMicroBatcher, SchedulerConfig,
                                     SchedulerStats)
from repro.serving.offload import (IntervalPlan, OffloadConfig,
                                   OffloadPlanner, OffloadStats,
                                   OffloadStore)
from repro.serving.sharded import ShardedDriftServeEngine, make_engine
from repro.serving.telemetry import (EngineTelemetry, GuardbandConfig,
                                     GuardbandController, LatencyEstimator,
                                     MetricsRegistry, TelemetryHTTPServer,
                                     aggregate_metrics, serve_telemetry)

__all__ = [
    "DriftServeEngine", "ShardedDriftServeEngine", "make_engine",
    "ServableModel", "DiffusionServable", "AutoregressiveServable",
    "build_servable", "paradigm_for", "PARADIGM_BY_FAMILY",
    "UNSUPPORTED_FAMILIES", "UnsupportedArchError",
    "EngineStats", "OP_BY_NAME",
    "GenerationRequest", "RequestQueue", "RequestResult", "PreviewEvent",
    "REQUEST_OPS", "REQUEST_PRIORITIES", "PRIORITY_RANK",
    "MicroBatch", "MicroBatcher", "request_key",
    "CompiledSamplerCache", "SamplerKey",
    "DeadlineScheduler", "PriorityMicroBatcher", "SchedulerConfig",
    "SchedulerStats", "Admission",
    "FrontierBuilder", "FrontierPoint", "FRONTIER_OPS", "pareto_front",
    "dominates", "quality_proxy",
    "OffloadConfig", "OffloadStats", "OffloadStore", "OffloadPlanner",
    "IntervalPlan",
    "EngineTelemetry", "MetricsRegistry", "LatencyEstimator",
    "GuardbandController", "GuardbandConfig", "TelemetryHTTPServer",
    "serve_telemetry", "aggregate_metrics",
]
