"""ServableModel: the protocol between the serving engine and a paradigm.

The engine (``serving/engine.py``) owns everything paradigm-agnostic --
request queue, micro-batcher, compiled-fn cache, BER monitor, virtual
clock, telemetry, perfmodel attribution, offload plumbing. What it does
NOT own is how one micro-batch actually computes: how request seeds
become model inputs, what the compiled function looks like, how a batch
iterates (denoising steps vs decode windows), and how a finished batch
turns into per-request quality numbers and a perfmodel ``RunConfig``.
That surface is a ``ServableModel``:

  ================  =====================================================
  hook              contract
  ================  =====================================================
  validate_request  reject/coerce paradigm-irrelevant request fields at
                    submit time (clear errors, nothing silently ignored)
  batch_inputs      seeds -> stacked model inputs for one bucket (placed
                    on the engine's mesh via ``engine.place_inputs``)
  build_fn          ``CompiledSamplerCache`` builder: SamplerKey -> the
                    compiled callable(s) for one configuration
  execute           run one prepared micro-batch, return its output
  execute_stream    generator twin: previews, then ('final', output)
  finalize          output -> ``BatchOutcome`` (per-slot metrics + the
                    perfmodel RunConfig + telemetry word count)
  ================  =====================================================

Two implementations ship:

* ``DiffusionServable`` -- the DRIFT denoising path, code moved verbatim
  from the pre-refactor engine so finals stay bit-identical (the
  serving tests pin exact trace/compile counts and the CI legs compare
  latent digests single-device vs 8-fake-device).
* ``AutoregressiveServable`` -- token-by-token decode over
  ``models/transformer.py`` with ReaLM-style statistical ABFT on the
  projection GEMMs and KV-cache snapshot/rollback (``serving/ar.py``).

Families partition (``tests/test_servable.py`` asserts totality over
``configs.list_archs()``): dit/unet -> diffusion; dense/moe/ssm/hybrid ->
autoregressive; encdec/vlm -> explicitly unsupported (multi-modal input
staging the request schema has no fields for).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import dvfs as dvfs_lib
from repro.core import metrics
from repro.core import quant as quant_lib
from repro.core.exec_ctx import DriftSystemConfig
from repro.core.rollback import RollbackConfig
from repro.diffusion import sampler as sampler_lib
from repro.diffusion.taylorseer import TaylorSeerConfig
from repro.perfmodel import energy
from repro.serving.cache import SamplerKey

# ---------------------------------------------------------------- registry
# family -> serving paradigm. Every config family must appear in exactly
# one of these two tables; test_servable.py asserts the partition is total
# so a new config can't silently fall through to a confusing trace error.
PARADIGM_BY_FAMILY: Dict[str, str] = {
    "dit": "diffusion",
    "unet": "diffusion",
    "dense": "autoregressive",
    "moe": "autoregressive",
    "ssm": "autoregressive",
    "hybrid": "autoregressive",
}

# family -> reason it cannot be served (named explicitly, not inferred).
UNSUPPORTED_FAMILIES: Dict[str, str] = {
    "encdec": "encoder-decoder models need an audio/encoder input the "
              "request schema has no fields for (use launch/train.py)",
    "vlm": "vision-language models need image inputs the request schema "
           "has no fields for (use launch/train.py)",
}


class UnsupportedArchError(ValueError):
    """Raised at submit time for archs no ServableModel family covers."""


def paradigm_for(arch: str) -> str:
    """Serving paradigm for an arch name; raises UnsupportedArchError with
    the registry's reason when the family is explicitly unsupported."""
    family = configs.get_config(arch).family
    paradigm = PARADIGM_BY_FAMILY.get(family)
    if paradigm is None:
        reason = UNSUPPORTED_FAMILIES.get(
            family, f"family {family!r} is not in the ServableModel "
                    "registry (add it to servable.PARADIGM_BY_FAMILY or "
                    "servable.UNSUPPORTED_FAMILIES)")
        raise UnsupportedArchError(f"arch {arch!r}: {reason}")
    return paradigm


# ---------------------------------------------------------------- protocol
@dataclasses.dataclass
class BatchOutcome:
    """What ``finalize`` hands back to the engine's generic accounting."""
    corrected: int                 # rollback-corrected elems / replayed slots
    n_model_evals: int             # computed steps (incl. rollback replays)
    rc: energy.RunConfig           # perfmodel run shape for this batch
    n_words: int                   # telemetry BER denominator (GEMM words)
    per_slot: List[dict]           # extra RequestResult fields per live slot
    # Resilience heatmap summary (serving/trace/heatmap.py): nested tuple
    # (sites, timestep bins) of detection counts plus its row labels; None
    # when the batch produced none (stub samplers, unmonitored paths).
    heatmap: Optional[tuple] = None
    heatmap_blocks: Optional[tuple] = None


class ServableModel:
    """Base protocol; subclasses hold a back-reference to their engine."""

    paradigm: str = ""
    #: Whether ``run_stream`` previews exist for this paradigm.
    supports_streaming: bool = False

    def __init__(self, engine):
        self.eng = engine

    # -- intake --------------------------------------------------------
    def validate_request(self, fields: dict) -> dict:
        """Check paradigm-irrelevant knobs before enqueueing; return the
        (possibly coerced) fields or raise ValueError."""
        return fields

    # -- batch construction -------------------------------------------
    def batch_inputs(self, model_cfg, seeds: List[int]) -> Tuple:
        raise NotImplementedError

    def build_fn(self, key: SamplerKey) -> Callable:
        """CompiledSamplerCache builder for one configuration."""
        raise NotImplementedError

    # -- execution -----------------------------------------------------
    def execute(self, mb, ctx):
        """Run one prepared micro-batch; returns the batch output object
        (must expose ``.monitor`` for monitored modes)."""
        raise NotImplementedError

    def execute_stream(self, mb, ctx, preview_interval: int) -> Iterator:
        """Yield ``PreviewEvent``s, then ``('final', output)``."""
        raise NotImplementedError

    # -- accounting ----------------------------------------------------
    def finalize(self, mb, ctx, out) -> BatchOutcome:
        raise NotImplementedError


# ---------------------------------------------------------- diffusion path
class DiffusionServable(ServableModel):
    """The DRIFT denoising path, re-expressed through the protocol.

    Every method body is the pre-refactor engine code moved here intact
    (same fold-in constants, same clip points, same cache-key edits), so
    diffusion finals are bit-identical to PR 5 -- the refactor moved
    code, it did not touch math.
    """

    paradigm = "diffusion"
    supports_streaming = True

    # (validate_request: the base identity -- every GenerationRequest
    # field is diffusion-meaningful; modes are validated by
    # DriftSystemConfig at build time.)

    # -- batch construction -------------------------------------------
    def batch_inputs(self, model_cfg, seeds: List[int]) -> Tuple:
        """Per-request initial latents + conditioning, stacked to the
        bucket and placed via the engine (mesh batch-spec when sharded)."""
        shape = (model_cfg.latent_size, model_cfg.latent_size,
                 model_cfg.latent_channels)
        lat = jnp.stack([
            jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(s), 7),
                              shape) for s in seeds])
        if model_cfg.cond_tokens:
            text = jnp.stack([
                0.1 * jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(s), 8),
                    (model_cfg.cond_tokens, model_cfg.cond_dim))
                for s in seeds])
            return self.eng.place_inputs((lat, None, text))
        cond = jnp.asarray([s % max(model_cfg.num_classes, 1) for s in seeds],
                           dtype=jnp.int32)
        return self.eng.place_inputs((lat, cond, None))

    def build_fn(self, key: SamplerKey) -> Callable:
        eng = self.eng
        model_cfg = configs.get_config(key.arch, smoke=key.smoke)
        if key.mode == "clean" or not key.op:
            schedule = None
        else:
            from repro.serving.engine import OP_BY_NAME
            schedule = dvfs_lib.fine_grained_schedule(
                key.steps, OP_BY_NAME[key.op],
                nominal_steps=eng.nominal_steps)
        scfg = sampler_lib.SamplerConfig(
            num_sample_steps=key.steps,
            drift=DriftSystemConfig(
                mode=key.mode,
                rollback=RollbackConfig(interval=key.rollback_interval)),
            schedule=schedule,
            taylorseer=TaylorSeerConfig(enabled=key.taylorseer),
            # protect_steps rides the engine's nominal_steps so the
            # precision protection window matches the DVFS one
            precision=quant_lib.get_plan(key.precision).with_protect_steps(
                eng.nominal_steps),
            monitor_target_ber=eng.monitor_target_ber)
        return eng._sampler_factory(key, model_cfg, scfg,
                                    eng.cache.note_trace)

    def _clean_reference(self, key: SamplerKey, seeds: Tuple[int, ...],
                         params, latents, cond, text) -> jax.Array:
        """Error-free reference latents for this batch, cached by
        (configuration, latent seeds) in the engine's bounded LRU."""
        eng = self.eng
        # stream=0: previews never need a reference, and streamed finals
        # are bit-identical to one-shot, so both share one clean sample.
        # precision="int8": references are always full-width -- a narrowed
        # run is scored against the error-free full-precision sample.
        ckey = dataclasses.replace(key, mode="clean", op="", stream=0,
                                   precision="int8")
        sample_id = (ckey, seeds)
        cached = eng._clean_samples.get(sample_id)
        if cached is not None:
            eng._clean_samples.move_to_end(sample_id)
            eng.stats.clean_sample_hits += 1
            return cached
        fn = eng.cache.get(ckey, self.build_fn)
        out = fn(params, jax.random.PRNGKey(0), latents, cond, text,
                 dvfs_lib.ber_monitor_init())
        clean = jnp.clip(out.latents, -1, 1)
        eng._clean_samples[sample_id] = clean
        while len(eng._clean_samples) > eng._clean_cache_size:
            eng._clean_samples.popitem(last=False)
        eng.stats.clean_samples_computed += 1
        return clean

    # -- execution -----------------------------------------------------
    def execute(self, mb, ctx):
        eng = self.eng
        store = eng._offload_for(mb.key)
        if store is None:
            fn = eng.cache.get(mb.key, self.build_fn)
            latents, cond, text = ctx.inputs
            return fn(ctx.params, ctx.run_key, latents, cond, text,
                      eng.monitor)
        # Offload-enabled one-shot path: run the windowed sampler with the
        # refresh interval as the window so every committed snapshot
        # offloads between windows, overlapped with the next window's
        # dispatch. Streamed finals are bit-identical to the one-shot
        # scan (the PR 3 invariant), so enabling offload cannot change a
        # single latent bit -- tests/test_offload.py asserts exactly that.
        window = min(mb.key.rollback_interval, mb.key.steps)
        skey = dataclasses.replace(mb.key, stream=window)
        fn = eng.cache.get(skey, self.build_fn)
        out = None
        store.begin_batch(interval=mb.key.rollback_interval,
                          batch_index=ctx.batch_index)
        eng._active_offload = store
        try:
            latents, cond, text = ctx.inputs
            for ev in fn(ctx.params, ctx.run_key, latents, cond, text,
                         eng.monitor):
                if isinstance(ev, sampler_lib.SampleOutput):
                    out = ev           # previews are discarded: run() only
        finally:
            eng._active_offload = None
            # join the in-flight commit; the settled delta feeds the
            # telemetry tap in _finish_batch
            ctx.offload_delta = store.finish_batch()
        assert out is not None, "offload sampler ended without SampleOutput"
        return out

    def execute_stream(self, mb, ctx, preview_interval: int) -> Iterator:
        from repro.serving.request import PreviewEvent
        eng = self.eng
        skey = dataclasses.replace(mb.key, stream=preview_interval)
        fn = eng.cache.get(skey, self.build_fn)
        out = None
        store = eng._offload_for(mb.key)
        if store is not None:
            # commits ride the preview windows: the store itself decides
            # which window boundaries crossed a refresh step
            store.begin_batch(interval=mb.key.rollback_interval,
                              batch_index=ctx.batch_index)
            eng._active_offload = store
        try:
            latents, cond, text = ctx.inputs
            for ev in fn(ctx.params, ctx.run_key, latents, cond, text,
                         eng.monitor):
                if isinstance(ev, sampler_lib.SampleOutput):
                    out = ev
                    break           # terminating item; nothing follows
                preview = jnp.clip(ev.latents, -1, 1)
                for slot, req in enumerate(mb.requests):  # live slots only
                    eng.stats.preview_events += 1
                    eng.telemetry.on_preview()
                    yield PreviewEvent(request_id=req.request_id,
                                       batch_index=ctx.batch_index,
                                       step=int(ev.step),
                                       total_steps=mb.key.steps,
                                       latents=preview[slot])
        finally:
            if store is not None:
                eng._active_offload = None
                ctx.offload_delta = store.finish_batch()
        assert out is not None, "streaming sampler ended without SampleOutput"
        yield ("final", out)

    # -- accounting ----------------------------------------------------
    def finalize(self, mb, ctx, out) -> BatchOutcome:
        from repro.serving.engine import OP_BY_NAME, _MONITORED_MODES
        key = mb.key
        latents, cond, text = ctx.inputs
        img = jnp.clip(out.latents, -1, 1)
        if key.mode == "clean":
            clean = img       # the run IS the reference; don't jit a twin
        else:
            clean = self._clean_reference(key, ctx.padded_seeds, ctx.params,
                                          latents, cond, text)
        corrected = int(out.total_corrected)
        nevals = int(out.n_model_evals)
        op_point = OP_BY_NAME.get(key.op, dvfs_lib.NOMINAL)
        # only protected modes pay ABFT compute + checkpoint DRAM traffic;
        # clean/faulty/float_clean run neither mechanism
        protected = key.mode in _MONITORED_MODES
        rc = energy.RunConfig(
            num_steps=key.steps, nominal_steps=self.eng.nominal_steps,
            aggressive=op_point,
            ckpt_interval=key.rollback_interval if protected else 10 ** 9,
            abft_enabled=protected,
            taylorseer_interval=3 if key.taylorseer else 0,
            body_bits=quant_lib.get_plan(key.precision).body_bits,
            recovery_tiles_per_step=corrected / max(key.steps, 1)
            / (32 * 32))
        per_slot = []
        for slot, req in enumerate(mb.requests):
            a, b = img[slot:slot + 1], clean[slot:slot + 1]
            per_slot.append(dict(
                lpips_vs_clean=float(metrics.lpips_proxy(a, b)),
                psnr_vs_clean_db=float(metrics.psnr(a, b)),
                latents=a[0]))
        from repro.serving.trace import heatmap as heatmap_lib
        heat, blocks = heatmap_lib.summarize(getattr(out, "heatmap", None))
        return BatchOutcome(
            corrected=corrected, n_model_evals=nevals, rc=rc,
            n_words=int(latents.size) * max(key.steps, 1),
            per_slot=per_slot, heatmap=heat, heatmap_blocks=blocks)


# ----------------------------------------------------- autoregressive path
class AutoregressiveServable(ServableModel):
    """Token-by-token decode with statistical ABFT + KV-cache rollback.

    The heavy lifting -- compiled prefill/window functions, the
    detection-only statistical-ABFT execution context, the KV snapshot
    store, and the host decode loop -- lives in ``serving/ar.py``; this
    adapter maps it onto the protocol so the engine's queue, cache,
    monitor, scheduler, and telemetry drive it unchanged.
    """

    paradigm = "autoregressive"
    supports_streaming = False

    #: modes the AR path implements. "drift" (inline tile rollback) is a
    #: diffusion mechanism; the AR protection story is detection + window
    #: rollback, so everything else is rejected at submit time.
    ALLOWED_MODES = ("clean", "faulty", "stat_abft")

    # -- intake --------------------------------------------------------
    def validate_request(self, fields: dict) -> dict:
        arch = fields.get("arch", "?")
        if fields.get("taylorseer"):
            raise ValueError(
                f"request for AR arch {arch!r} sets taylorseer=True: "
                "TaylorSeer caches diffusion denoiser features across "
                "timesteps and does not apply to token decoding. Drop the "
                "flag (or serve a dit/unet arch).")
        if fields.get("precision", "int8") != "int8":
            raise ValueError(
                f"request for AR arch {arch!r} sets precision="
                f"{fields['precision']!r}: precision plans narrow the "
                "diffusion denoiser body per timestep and do not apply to "
                "token decoding. Use the default 'int8' (or serve a "
                "dit/unet arch).")
        if fields.get("energy_budget_j") is not None \
                or fields.get("quality_floor") is not None:
            raise ValueError(
                f"request for AR arch {arch!r} sets a frontier objective "
                "(energy_budget_j/quality_floor): the compute-optimal "
                "frontier enumerates diffusion knobs (steps x precision x "
                "TaylorSeer x DVFS) and is not built for autoregressive "
                "serving. Use deadline_s/step_budget instead.")
        mode = fields.get("mode", "drift")
        if mode not in self.ALLOWED_MODES:
            raise ValueError(
                f"request for AR arch {arch!r} has mode={mode!r}: "
                "autoregressive serving supports modes "
                f"{'/'.join(self.ALLOWED_MODES)} (statistical ABFT with "
                "KV-cache window rollback). Diffusion-only modes like "
                "'drift' do inline tile rollback inside the denoiser and "
                "do not apply to decode.")
        return fields

    # -- batch construction -------------------------------------------
    def batch_inputs(self, model_cfg, seeds: List[int]) -> Tuple:
        from repro.serving import ar
        tokens = ar.prompt_tokens(model_cfg, seeds)
        return self.eng.place_inputs((tokens,))

    def build_fn(self, key: SamplerKey):
        from repro.serving import ar
        from repro.serving.engine import OP_BY_NAME
        eng = self.eng
        model_cfg = configs.get_config(key.arch, smoke=key.smoke)
        if key.mode == "clean" or not key.op:
            schedule = None
        else:
            schedule = dvfs_lib.fine_grained_schedule(
                key.steps, OP_BY_NAME[key.op],
                nominal_steps=eng.nominal_steps)
        return ar.make_decoder(
            model_cfg,
            ar.DecodeConfig(
                steps=key.steps,
                window=min(int(key.rollback_interval), key.steps),
                mode=key.mode,
                monitor_target_ber=eng.monitor_target_ber),
            schedule=schedule,
            on_trace=eng.cache.note_trace,
            mesh=getattr(eng, "mesh", None))

    # -- execution -----------------------------------------------------
    def execute(self, mb, ctx):
        from repro.serving import ar
        eng = self.eng
        fns = eng.cache.get(mb.key, self.build_fn)
        (tokens,) = ctx.inputs
        tracer = getattr(eng, "tracer", None)
        if tracer is None:
            return ar.decode_batch(fns, ctx.params, tokens, eng.monitor,
                                   ctx.run_key)

        # Window/replay spans carry joules (docs/slo.md): decoded tokens
        # use the engine's per-step estimate for the window just finished,
        # replays charge their re-decoded window length at the same rate.
        def on_window(done_steps: int) -> None:
            tracer.on_window(done_steps,
                             energy_j=eng._window_energy_delta_j(done_steps))

        def on_replay(window_start: int, window_len: int) -> None:
            tracer.on_replay(window_start, window_len,
                             energy_j=window_len * eng._window_step_j)

        return ar.decode_batch(
            fns, ctx.params, tokens, eng.monitor, ctx.run_key,
            on_window=on_window, on_replay=on_replay)

    def execute_stream(self, mb, ctx, preview_interval: int) -> Iterator:
        raise ValueError(
            "run_stream() previews are latent images -- a diffusion "
            "mechanism. Autoregressive requests return their tokens in "
            "RequestResult.tokens via run().")

    def _clean_tokens(self, mb, ctx):
        """Fault-free reference decode for this (configuration, prompts)
        batch, cached in the engine's clean-sample LRU exactly like the
        diffusion clean reference (stream forced to 0 for key hygiene)."""
        from repro.serving import ar
        eng = self.eng
        key = mb.key
        ckey = dataclasses.replace(key, mode="clean", op="", stream=0)
        sample_id = (ckey, ctx.padded_seeds)
        cached = eng._clean_samples.get(sample_id)
        if cached is not None:
            eng._clean_samples.move_to_end(sample_id)
            eng.stats.clean_sample_hits += 1
            return cached
        fns = eng.cache.get(ckey, self.build_fn)
        (tokens,) = ctx.inputs
        out = ar.decode_batch(fns, ctx.params, tokens,
                              dvfs_lib.ber_monitor_init(),
                              jax.random.PRNGKey(0))
        clean = out.tokens
        eng._clean_samples[sample_id] = clean
        while len(eng._clean_samples) > eng._clean_cache_size:
            eng._clean_samples.popitem(last=False)
        eng.stats.clean_samples_computed += 1
        return clean

    # -- accounting ----------------------------------------------------
    def finalize(self, mb, ctx, out) -> BatchOutcome:
        import numpy as np
        from repro.serving.engine import OP_BY_NAME, _MONITORED_MODES
        key = mb.key
        toks = np.asarray(out.tokens)                  # (B, steps)
        if key.mode == "clean":
            clean = toks
        else:
            clean = np.asarray(self._clean_tokens(mb, ctx))
        op_point = OP_BY_NAME.get(key.op, dvfs_lib.NOMINAL)
        protected = key.mode in _MONITORED_MODES
        # Rollback replays are real decode steps: charge them in the
        # perfmodel run shape (per-token cost x computed steps), and tell
        # the ledger which evals were replays -- evals = 1 prefill +
        # key.steps first-pass decodes + window re-decodes, so everything
        # past the first two terms bills as compute_replay.
        rc = energy.RunConfig(
            num_steps=int(out.n_model_evals),
            nominal_steps=self.eng.nominal_steps,
            aggressive=op_point,
            ckpt_interval=key.rollback_interval if protected else 10 ** 9,
            abft_enabled=protected,
            taylorseer_interval=0,
            recovery_tiles_per_step=0.0,
            replay_evals=max(int(out.n_model_evals) - 1 - key.steps, 0))
        per_slot = []
        for slot, req in enumerate(mb.requests):
            mismatch = float(np.mean(toks[slot] != clean[slot]))
            # token-space proxies for the image metrics the result schema
            # requires: lpips ~ mismatch fraction, psnr ~ -10log10 of it
            psnr = 99.0 if mismatch == 0.0 else float(
                -10.0 * np.log10(mismatch))
            per_slot.append(dict(
                lpips_vs_clean=mismatch,
                psnr_vs_clean_db=psnr,
                latents=None,
                tokens=tuple(int(t) for t in toks[slot]),
                token_match_vs_clean=1.0 - mismatch,
                ar_detections=int(out.detections),
                ar_rollbacks=int(out.rollbacks)))
        from repro.serving.trace import heatmap as heatmap_lib
        heat, blocks = heatmap_lib.summarize(getattr(out, "heatmap", None))
        return BatchOutcome(
            corrected=int(out.rollbacks),
            n_model_evals=int(out.n_model_evals),
            rc=rc,
            n_words=max(int(out.n_words), 1),
            per_slot=per_slot, heatmap=heat, heatmap_blocks=blocks)


_SERVABLE_CLASSES = {
    "diffusion": DiffusionServable,
    "autoregressive": AutoregressiveServable,
}


def build_servable(paradigm: str, engine) -> ServableModel:
    return _SERVABLE_CLASSES[paradigm](engine)
