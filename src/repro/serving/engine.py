"""The DRIFT batched serving engine.

Replaces the per-batch re-launch hack (old ``launch/serve.py`` +
``examples/drift_serve.py``, which re-parsed argv and re-jitted the full
sampler for every batch) with one process-resident engine:

  * a FIFO ``RequestQueue`` + ``MicroBatcher`` grouping pending requests
    into fixed-size same-configuration batch buckets (short tails padded),
  * a ``CompiledSamplerCache`` keyed by (arch, steps, mode, operating
    point, bucket, ...) so each configuration jits exactly once per
    process,
  * per-request DVFS operating-point selection: requests name a point or
    say ``"auto"``, which reads the engine's shared BER-monitor ladder
    index -- the Sec 5.1 feedback loop, with monitor state carried across
    batches via ``sampler.sample(monitor0=...)``,
  * a clean-reference cache: the error-free sample for a given
    (configuration, latent seeds) batch is computed once through the same
    compiled-sampler cache and reused for quality metrics,
  * per-request quality + energy accounting returned as structured
    ``RequestResult`` records (perfmodel bucket cost split across live
    requests),
  * a **virtual clock** (``clock_s``): each served batch advances it by the
    batch's perfmodel latency, giving deadline semantics a deterministic
    time base in modeled-accelerator seconds (host wall-clock of a CPU
    smoke run means nothing),
  * **streaming** (``run_stream``): the same queue drain, but each batch
    runs the windowed sampler (``SamplerKey.stream``) and yields
    ``PreviewEvent`` latent previews between windows before the final
    ``RequestResult`` records -- with final latents bit-identical to the
    one-shot ``run()`` path.

Typical use::

    engine = DriftServeEngine(bucket=2)
    for i, op in enumerate(["undervolt", "overclock", "auto"]):
        engine.submit(steps=10, mode="drift", op=op, seed=i)
    for res in engine.run():
        print(res.request_id, res.op, res.psnr_vs_clean_db, res.energy_j)

    engine.submit(steps=10, mode="drift", op="auto", seed=3)
    for ev in engine.run_stream(preview_interval=2):
        ...   # PreviewEvent previews, then the RequestResult

Deadline-aware admission control, (op, step-budget) degradation, and
priority batch formation live one layer up in
``serving/scheduler.DeadlineScheduler`` (see docs/scheduler.md); the bare
engine only records deadline misses.

Telemetry (``serving/telemetry``, docs/telemetry.md) is on by default and
entirely host-side: every served batch feeds a metrics registry (exposed
over HTTP as Prometheus text), a served-batch latency history the
scheduler consults for learned admission estimates, and -- for monitored
modes -- an adaptive guardband controller that floors the ``op="auto"``
ladder when detection counts spike (``auto_op_index`` is the single
resolution point). ``telemetry=EngineTelemetry(enabled=False)`` turns all
of it off; explicit-op workloads then serve bit-identically, while
``op="auto"`` may resolve to a more aggressive point (no guardband floor)
-- changing that resolution is exactly what the controller is for.

The engine is single-threaded by design: batches run sequentially so the
BER-monitor feedback is well-ordered. ``serving/sharded.py`` extends this
exact loop across a device mesh (one micro-batch spread over the ``data``
axis, params sharded per ``repro.distributed.sharding``) without changing
the ordering guarantee.

Async checkpoint offload (``offload=OffloadConfig()``, the CLIs'
``--offload``; docs/offload.md): monitored-mode batches run the windowed
sampler with the rollback refresh interval as the window, and a
double-buffered host store snapshots the scan carry's checkpoint stores
between windows on a background thread -- overlapped with the next
window's compute, which is the only concurrency in the engine and is
invisible to it (the store is joined before batch accounting). Finals are
bit-identical with offload on or off; the modeled residual refresh stall
is charged on the virtual clock and in the scheduler's projections, and
``rollback_interval="auto"`` requests resolve their refresh interval
through the offload planner (``auto_rollback_interval``, the
``auto_op_index`` analogue).

Architecture walk-through: ``docs/serving.md``.
"""
from __future__ import annotations

import collections
import dataclasses
import zlib
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import dvfs as dvfs_lib
from repro.core import quant as quant_lib
from repro.diffusion import sampler as sampler_lib
from repro.perfmodel import energy
from repro.serving.batcher import MicroBatch, MicroBatcher
from repro.serving.cache import CompiledSamplerCache, SamplerKey
from repro.serving import servable as servable_lib
from repro.serving.offload import OffloadConfig, OffloadPlanner, OffloadStore
from repro.serving.request import (GenerationRequest, RequestQueue,
                                   RequestResult)
from repro.serving.telemetry import EngineTelemetry
from repro.serving.trace import FlightRecorder
from repro.train import steps as steps_lib

# Named operating points a request (or the auto ladder) can resolve to.
OP_BY_NAME: Dict[str, dvfs_lib.OperatingPoint] = {
    p.name: p
    for p in (dvfs_lib.NOMINAL, dvfs_lib.UNDERVOLT, dvfs_lib.OVERCLOCK)
    + dvfs_lib.OP_LADDER
}

# Modes whose ABFT detections feed the BER monitor; other modes produce no
# detection signal, so folding their zero counts would drag the EMA down.
_MONITORED_MODES = ("drift", "thundervolt", "approx_abft", "dmr", "stat_abft")


@dataclasses.dataclass
class EngineStats:
    batches: int = 0
    padded_slots: int = 0
    clean_samples_computed: int = 0
    clean_sample_hits: int = 0
    preview_events: int = 0        # streamed previews yielded (live slots)
    deadline_misses: int = 0       # requests completed past their deadline


@dataclasses.dataclass
class _BatchCtx:
    """Everything _prepare_batch stages for one micro-batch run."""
    batch_index: int
    params: object
    padded_seeds: Tuple[int, ...]
    # Paradigm-shaped staged inputs: (latents, cond, text) for diffusion,
    # (prompt_tokens,) for autoregressive -- the batch's ServableModel
    # built them and is the only code that unpacks them.
    inputs: Tuple
    run_key: object
    # Filled by the offload-enabled drains after joining the store: this
    # batch's OffloadStats delta for the telemetry tap. None = no offload
    # ran for this batch.
    offload_delta: Optional[object] = None


class DriftServeEngine:
    """Continuous-batching serving engine for DRIFT diffusion sampling."""

    def __init__(self, arch: str = "dit-xl-512", smoke: bool = True,
                 bucket: int = 2, base_seed: int = 0,
                 nominal_steps: int = 2,
                 monitor_target_ber: float = 3e-3,
                 clean_cache_size: int = 8,
                 sampler_factory: Optional[Callable] = None,
                 energy_model: Optional[energy.EnergyModel] = None,
                 telemetry: Optional[EngineTelemetry] = None,
                 offload: Optional[OffloadConfig] = None,
                 tracer: Optional[FlightRecorder] = None):
        self.default_arch = arch
        self.default_smoke = smoke
        self.nominal_steps = nominal_steps
        self.monitor_target_ber = monitor_target_ber
        self.queue = RequestQueue()
        self.batcher = MicroBatcher(bucket,
                                    key_extra=self._sampler_key_extra(bucket))
        self.cache = CompiledSamplerCache()
        self.stats = EngineStats()
        # Telemetry bundle (metrics registry, latency-history estimator,
        # guardband controller): default ON -- every tap is a host-side
        # Python call per batch, nothing traced. Pass
        # EngineTelemetry(enabled=False) (the CLIs' --no-telemetry) for a
        # telemetry-free engine (bit-identical for explicit ops; "auto"
        # loses the guardband floor).
        self.telemetry = (telemetry if telemetry is not None
                          else EngineTelemetry()).bind(monitor_target_ber)
        # Flight recorder (repro.serving.trace, docs/tracing.md): span
        # ring buffer for per-request forensics. Default ON -- every tap
        # is host-side between traced computations, so finals are
        # bit-identical with it enabled, disabled, or replaced
        # (tests/test_trace.py asserts it on both engines). Pass
        # FlightRecorder(enabled=False) for a recorder-free engine.
        self.tracer = tracer if tracer is not None else FlightRecorder()
        self.cache.on_compile = self._on_compile
        self.monitor = dvfs_lib.ber_monitor_init()
        # Virtual clock in modeled-accelerator seconds: advanced by each
        # batch's perfmodel latency. Deadlines/aging are measured on it.
        self.clock_s = 0.0
        self._base_key = jax.random.PRNGKey(base_seed)
        self._batch_counter = 0
        self._params: Dict[Tuple[str, bool], object] = {}
        # LRU: exact seed batches rarely repeat in open-ended serving, so
        # the clean-sample store is bounded (the compiled clean *sampler*
        # stays cached in self.cache regardless).
        self._clean_samples: "collections.OrderedDict[Tuple[SamplerKey, Tuple[int, ...]], jax.Array]" = \
            collections.OrderedDict()
        self._clean_cache_size = clean_cache_size
        self._sampler_factory = sampler_factory or (
            lambda key, model_cfg, scfg, on_trace:
            sampler_lib.make_sampler(model_cfg, scfg, on_trace=on_trace,
                                     stream_window=key.stream,
                                     on_window=self._on_stream_window,
                                     on_carry=self._offload_on_carry))
        self._energy_model = energy_model
        self._full_cfgs: Dict[str, object] = {}
        # Async checkpoint offload (repro.serving.offload, docs/offload.md):
        # one double-buffered host store for the whole (single-threaded)
        # engine, rebound per batch; None = disabled, which is also the
        # bit-identical baseline the offload tests compare against. The
        # planner exists regardless so rollback_interval="auto" requests
        # resolve even on an offload-free engine.
        self.offload_cfg = offload if (offload is None or offload.enabled) \
            else None
        self._offload_store = (OffloadStore(self.offload_cfg)
                               if self.offload_cfg is not None else None)
        if self._offload_store is not None:
            self._offload_store.on_event = self.tracer.on_offload
        self._active_offload: Optional[OffloadStore] = None
        self._planner: Optional[OffloadPlanner] = None
        self._interval_memo: Dict[Tuple, int] = {}
        self._stall_memo: Dict[Tuple, float] = {}
        # Per-window energy attribution (docs/slo.md): a prospective
        # per-computed-step estimate memoized per configuration, so
        # window/replay trace spans can carry joules while the batch is
        # still in flight (the exact ledger lands at finalize).
        self._window_j_memo: Dict[Tuple, float] = {}
        self._window_step_j = 0.0
        self._window_prev_steps = 0
        # One ServableModel per paradigm (they're stateless adapters over
        # the engine; per-batch state rides _BatchCtx).
        self._servables: Dict[str, servable_lib.ServableModel] = {}

    # ---------------------------------------------------------- servables
    def servable_for(self, arch: str) -> servable_lib.ServableModel:
        """The ServableModel adapter serving this arch's paradigm; raises
        ``UnsupportedArchError`` for families outside the registry."""
        paradigm = servable_lib.paradigm_for(arch)
        sv = self._servables.get(paradigm)
        if sv is None:
            sv = self._servables[paradigm] = servable_lib.build_servable(
                paradigm, self)
        return sv

    def place_inputs(self, tree):
        """Device placement hook for servable-built batch inputs: identity
        here; the sharded engine device_puts each leaf with its mesh
        batch spec."""
        return tree

    # ------------------------------------------------------------- intake
    def submit(self, **fields) -> int:
        """Queue one generation request; returns its request id.

        Normalization the engine applies before enqueueing:

        * ``arch``/``smoke`` default to the engine's;
        * ``steps`` is clamped to ``step_budget`` when one is given (the
          DiffPro-style per-request quality/latency knob -- fewer denoising
          steps, cheaper request);
        * ``submitted_at_s`` is stamped with the engine's virtual clock
          (callers normally leave it unset), anchoring deadline-miss
          accounting and scheduler aging.
        """
        fields.setdefault("arch", self.default_arch)
        fields.setdefault("smoke", self.default_smoke)
        budget = fields.get("step_budget")
        if budget is not None:
            default_steps = GenerationRequest.__dataclass_fields__[
                "steps"].default
            fields["steps"] = min(fields.get("steps", default_steps),
                                  budget)
        fields.setdefault("submitted_at_s", self.clock_s)
        # Paradigm resolution + paradigm-irrelevant-knob validation: raises
        # UnsupportedArchError for families outside the ServableModel
        # registry, ValueError for e.g. an AR request with taylorseer=True.
        fields = self.servable_for(fields["arch"]).validate_request(fields)
        rid = self.queue.submit(**fields)
        self.telemetry.on_submit()
        self.tracer.on_submit(rid, self.clock_s,
                              arch=fields["arch"],
                              mode=fields.get("mode", "drift"),
                              op=fields.get("op", "undervolt"),
                              steps=fields.get("steps", 10),
                              priority=fields.get("priority", "standard"))
        return rid

    # ------------------------------------------------------------ serving
    def run(self) -> List[RequestResult]:
        """Drain the queue, one micro-batch at a time; results come back in
        submission order regardless of how batching regrouped them."""
        results: Dict[int, RequestResult] = {}
        while len(self.queue):
            mb = self.batcher.next_batch(self.queue, self._resolve_op,
                                         self._resolve_interval)
            for res in self._run_batch(mb):
                results[res.request_id] = res
        return [results[rid] for rid in sorted(results)]

    def run_stream(self, preview_interval: int = 1):
        """Drain the queue as a generator of streamed events.

        Per micro-batch: a ``PreviewEvent`` for every live request after
        each ``preview_interval`` denoising steps (the sampler's chunked
        scan window), then the batch's ``RequestResult`` records. Events
        arrive in batch-formation order (priority order under the
        scheduler), not globally sorted by request id -- streaming exists
        to surface results early, so no cross-batch reordering happens.
        Final latents are bit-identical to the ``run()`` path; a streamed
        configuration gets its own compiled-sampler cache slot
        (``SamplerKey.stream = preview_interval``).
        """
        assert preview_interval >= 1, preview_interval
        while len(self.queue):
            mb = self.batcher.next_batch(self.queue, self._resolve_op,
                                         self._resolve_interval)
            yield from self._run_batch_stream(mb, preview_interval)

    def _resolve_op(self, req: GenerationRequest) -> str:
        if req.op == "auto":
            return self.auto_op_name()
        return req.op

    def auto_op_index(self) -> int:
        """Ladder index an ``op="auto"`` request resolves to right now: the
        BER monitor's index, floored by the telemetry guardband controller
        (identity when telemetry is disabled). The single source of truth
        for "auto" -- batch formation and scheduler cost estimation both
        route here, so admission prices the point that will actually run."""
        return self.telemetry.clamp_ladder_index(int(self.monitor.op_index))

    def auto_op_name(self) -> str:
        return dvfs_lib.ladder_op(self.auto_op_index()).name

    # -------------------------------------------- rollback-interval auto
    def _resolve_interval(self, req: GenerationRequest) -> int:
        """Concrete checkpoint-refresh interval for one request: its own
        int, or -- for ``rollback_interval="auto"`` -- the offload
        planner's choice for (arch, resolved op, steps, bucket)."""
        if req.rollback_interval == "auto":
            return self.auto_rollback_interval(req.arch,
                                               self._resolve_op(req),
                                               req.steps)
        return int(req.rollback_interval)

    # public alias: the scheduler prices learned-estimator keys with it
    resolve_interval = _resolve_interval

    def auto_rollback_interval(self, arch: str, op_name: str,
                               steps: int) -> int:
        """The ``rollback_interval="auto"`` resolution point (the
        ``auto_op_index`` analogue): the offload planner's argmin interval
        for this configuration, with the detection rate taken from the
        telemetry history (guardband controller's realized BER for the
        operating point) and falling back to the monitor target.
        Memoized per (arch, op, steps, bucket, quantized detection rate)
        so the ladder's adaptation can move the choice without re-running
        the sweep every submit."""
        rate = self._detect_rate(op_name, arch)
        bucket = self.batcher.bucket
        key = (arch, op_name, steps, bucket, f"{rate:.1e}")
        cached = self._interval_memo.get(key)
        if cached is None:
            op = OP_BY_NAME.get(op_name, dvfs_lib.NOMINAL)
            plan = self._planner_for().plan(self._full_cfg(arch), op,
                                            steps, bucket,
                                            detect_rate=rate)
            cached = self._interval_memo[key] = plan.interval
        return cached

    def _detect_rate(self, op_name: str, arch: str) -> float:
        """Expected rollback-triggering detections per denoising step, in
        [0, 1]: realized BER (telemetry EWMA for this op when history
        exists, monitor target otherwise) times the per-step GEMM word
        count, saturated -- at realistic BERs every step sees a
        detection, so the planner's trade is refresh traffic vs
        staleness, exactly Sec 6.4's."""
        ber = None
        ctrl = self.telemetry.controller if self.telemetry.enabled else None
        if ctrl is not None:
            ber = ctrl.realized_ber.get(op_name)
        if ber is None:
            ber = self.monitor_target_ber
        words = energy.activation_bytes(self._full_cfg(arch), 1) / 4.0
        return min(1.0, float(ber) * words)

    def _planner_for(self) -> OffloadPlanner:
        if self._planner is None:
            cfg = self.offload_cfg or OffloadConfig()
            self._planner = OffloadPlanner(
                em=self._energy_model_for(),
                nominal_steps=self.nominal_steps,
                repacked=cfg.repacked, overlapped=cfg.async_commit,
                tile_m=cfg.tile_m, tile_n=cfg.tile_n)
        return self._planner

    def offload_stall_s(self, arch: str, op_name: str, steps: int,
                        interval, mode: str = "drift") -> float:
        """Modeled residual refresh stall one batch of this configuration
        pays with offload enabled (0.0 when offload is off or the mode
        never writes checkpoints). Charged on the virtual clock by
        ``_finish_batch`` and by the scheduler's perfmodel projection --
        the learned estimator sees it implicitly through observed batch
        latencies."""
        if self._offload_store is None or mode not in _MONITORED_MODES:
            return 0.0
        if interval == "auto":
            interval = self.auto_rollback_interval(arch, op_name, steps)
        key = (arch, op_name, steps, int(interval))
        cached = self._stall_memo.get(key)
        if cached is None:
            op = OP_BY_NAME.get(op_name, dvfs_lib.NOMINAL)
            cached = self._stall_memo[key] = \
                self._planner_for().residual_stall_s(
                    self._full_cfg(arch), op, steps, self.batcher.bucket,
                    int(interval))
        return cached

    @property
    def offload_store(self) -> Optional[OffloadStore]:
        """The engine's checkpoint-offload store, or None when offload is
        disabled -- the public handle for CLIs/benchmarks reading commit
        stats or driving a restore."""
        return self._offload_store

    def _offload_for(self, key: SamplerKey) -> Optional[OffloadStore]:
        """This batch's offload store, or None: only monitored modes
        write rollback checkpoints worth offloading (clean/faulty/
        float_clean batches run storeless semantics)."""
        if self._offload_store is None or key.mode not in _MONITORED_MODES:
            return None
        # Host refresh traffic is DRAM traffic in the paper's accounting:
        # arm the store with the calibrated per-byte cost so its commit/
        # restore trace events carry the joules they moved.
        self._offload_store.energy_per_byte_j = \
            self._energy_model_for().e_dram_pj_per_byte * 1e-12
        return self._offload_store

    def window_energy_per_step_j(self, key: SamplerKey) -> float:
        """Prospective per-computed-step energy for one batch of this
        configuration: the perfmodel batch cost (recovery traffic unknown
        mid-flight, charged zero) over its computed steps. Attached to
        window/replay spans so /flight shows joules as a batch progresses;
        the billed ledger (exact, including recovery) lands at finalize."""
        memo = (key.arch, key.op, key.steps, key.mode, key.precision,
                key.taylorseer, key.rollback_interval, key.bucket)
        cached = self._window_j_memo.get(memo)
        if cached is None:
            op = OP_BY_NAME.get(key.op, dvfs_lib.NOMINAL)
            protected = key.mode in _MONITORED_MODES
            rc = energy.RunConfig(
                num_steps=key.steps, nominal_steps=self.nominal_steps,
                aggressive=op,
                ckpt_interval=(key.rollback_interval if protected
                               else 10 ** 9),
                abft_enabled=protected,
                taylorseer_interval=3 if key.taylorseer else 0,
                body_bits=quant_lib.get_plan(key.precision).body_bits)
            cost = energy.run_cost(self._full_cfg(key.arch), rc,
                                   batch=key.bucket,
                                   em=self._energy_model_for())
            n = max(int(cost.get("n_computed_steps", key.steps)), 1)
            cached = self._window_j_memo[memo] = cost["energy_j"] / n
        return cached

    def _window_energy_delta_j(self, done_steps: int) -> float:
        """Joules attributed to the window that just completed: newly
        finished steps (since the last window tap) times the batch's
        per-step estimate. Single-threaded like the engine itself."""
        delta = max(int(done_steps) - self._window_prev_steps, 0)
        self._window_prev_steps = int(done_steps)
        return delta * self._window_step_j

    def _on_stream_window(self, done_steps: int) -> None:
        """Combined window-boundary tap handed to ``make_sampler``: the
        telemetry stream counter plus a flight-recorder window span. Both
        are host-side Python between windows -- zero trace impact."""
        self.telemetry.on_stream_window(done_steps)
        self.tracer.on_window(done_steps,
                              energy_j=self._window_energy_delta_j(
                                  done_steps))

    def _on_compile(self, key: SamplerKey, elapsed_s: float) -> None:
        """CompiledSamplerCache miss tap: a compile span with the factory's
        wall cost and enough key fields to identify the configuration."""
        self.tracer.on_compile(elapsed_s, arch=key.arch, mode=key.mode,
                               op=key.op, steps=key.steps,
                               stream=key.stream, bucket=key.bucket)

    def _offload_on_carry(self, done_steps: int, carry) -> None:
        """Sampler window-boundary tap (``make_sampler(on_carry=...)``):
        forwards the scan carry to the batch's bound offload store. A
        no-op unless ``_run_batch[_stream]`` armed a store -- so the hook
        is threaded unconditionally and costs one attribute read when
        offload is off."""
        store = self._active_offload
        if store is not None:
            store.on_window(done_steps, carry)

    def _sampler_key_extra(self, bucket: int) -> Dict[str, object]:
        """SamplerKey fields stamped by the engine rather than the request
        (the sharded subclass adds its mesh placement here)."""
        return {}

    # ------------------------------------------------------------ helpers
    def _params_for(self, arch: str, smoke: bool):
        k = (arch, smoke)
        if k not in self._params:
            cfg = configs.get_config(arch, smoke=smoke)
            # crc32, not hash(): Python randomizes str hashes per process,
            # and param init must be reproducible across runs.
            tag = zlib.crc32(f"{arch}:{smoke}".encode()) & 0x7FFFFFFF
            self._params[k] = steps_lib.init_model_params(
                cfg, jax.random.fold_in(self._base_key, tag))
        return self._params[k]

    def _energy_model_for(self):
        if self._energy_model is None:
            self._energy_model = energy.calibrate()
        return self._energy_model

    def _full_cfg(self, arch: str):
        if arch not in self._full_cfgs:
            self._full_cfgs[arch] = configs.get_config(arch)
        return self._full_cfgs[arch]

    # ---------------------------------------------------------- one batch
    def _prepare_batch(self, mb: MicroBatch) -> _BatchCtx:
        """Stage params + servable-built inputs for one micro-batch (shared
        by the one-shot and streaming execution paths)."""
        key = mb.key
        batch_index = self._batch_counter
        self._batch_counter += 1
        self.stats.batches += 1
        self.stats.padded_slots += mb.n_pad

        model_cfg = configs.get_config(key.arch, smoke=key.smoke)
        params = self._params_for(key.arch, key.smoke)
        live_seeds = [r.seed for r in mb.requests]
        padded_seeds = tuple(live_seeds + [live_seeds[-1]] * mb.n_pad)
        inputs = self.servable_for(key.arch).batch_inputs(
            model_cfg, list(padded_seeds))
        run_key = jax.random.fold_in(self._base_key, batch_index)
        # queue_wait spans per member + the batch_assembly span; window/
        # offload/detect spans until the next batch attach to this context
        self.tracer.begin_batch(batch_index,
                                [r.request_id for r in mb.requests],
                                self.clock_s, arch=key.arch, mode=key.mode,
                                op=key.op, steps=key.steps,
                                bucket=key.bucket, n_live=len(mb.requests),
                                n_pad=mb.n_pad)
        # arm per-window energy attribution for this batch's spans
        self._window_prev_steps = 0
        self._window_step_j = self.window_energy_per_step_j(key)
        return _BatchCtx(batch_index=batch_index, params=params,
                         padded_seeds=padded_seeds, inputs=inputs,
                         run_key=run_key)

    def _run_batch(self, mb: MicroBatch) -> List[RequestResult]:
        ctx = self._prepare_batch(mb)
        out = self.servable_for(mb.key.arch).execute(mb, ctx)
        return self._finish_batch(mb, ctx, out)

    def _run_batch_stream(self, mb: MicroBatch, preview_interval: int):
        """Streaming twin of ``_run_batch``: the servable yields per-request
        ``PreviewEvent``s between windows, then ``('final', out)``, and the
        batch finishes through the same accounting as the one-shot path --
        so a streamed request's result record is indistinguishable from an
        unstreamed one apart from having produced previews on the way.
        Paradigms without previews (autoregressive) raise a clear error."""
        ctx = self._prepare_batch(mb)
        out = None
        sv = self.servable_for(mb.key.arch)
        for ev in sv.execute_stream(mb, ctx, preview_interval):
            if isinstance(ev, tuple) and ev and ev[0] == "final":
                out = ev[1]
                break           # terminating item; nothing follows
            yield ev
        assert out is not None, "servable stream ended without a final"
        yield from self._finish_batch(mb, ctx, out)

    def _finish_batch(self, mb: MicroBatch, ctx: _BatchCtx,
                      out) -> List[RequestResult]:
        """Metrics, energy attribution, monitor/clock carry, and per-request
        result records for a completed batch -- paradigm specifics come
        back from the servable as a ``BatchOutcome``."""
        key = mb.key
        batch_index = ctx.batch_index
        protected = key.mode in _MONITORED_MODES
        if protected:
            self.monitor = out.monitor   # Sec 5.1 carry-over across batches

        outcome = self.servable_for(key.arch).finalize(mb, ctx, out)
        # report the engine's post-batch state: for unmonitored modes the
        # sampler's internal EMA decays toward zero on no-detection steps,
        # which would misrepresent the actual error estimate
        mon_ber = float(self.monitor.ema_ber)
        mon_idx = int(self.monitor.op_index)
        corrected = outcome.corrected
        nevals = outcome.n_model_evals

        # perfmodel attribution: full-arch energy model, bucket cost split
        # across the live requests (padding overhead lands on them). The
        # batch is priced once (run_cost) and the per-request view shares
        # that exact ledger, so batch and request breakdowns reconcile
        # bitwise (serving.telemetry.energy.verify_cost).
        em = self._energy_model_for()
        full = self._full_cfg(key.arch)
        rc = outcome.rc
        n_live = len(mb.requests)
        bcost = energy.run_cost(full, rc, batch=key.bucket, em=em)
        cost = energy.per_request_cost(full, rc, batch=key.bucket,
                                       n_live=n_live, em=em, cost=bcost)
        base = energy.per_request_cost(full, energy.baseline_rc(key.steps),
                                       batch=key.bucket, n_live=n_live,
                                       em=em)

        # advance the virtual clock by the batch's (shared) modeled latency
        # -- plus, with offload enabled, the planner's residual refresh
        # stall (the part of the host offload the next window's compute
        # could not hide); every request completes at the new timestamp
        stall_s = self.offload_stall_s(key.arch, key.op or "nominal",
                                       key.steps, key.rollback_interval,
                                       key.mode)
        batch_latency_s = cost["latency_s"] + stall_s
        self.clock_s += batch_latency_s
        completed_at = self.clock_s

        results = []
        for slot, req in enumerate(mb.requests):
            missed = (req.absolute_deadline_s is not None
                      and completed_at > req.absolute_deadline_s + 1e-9)
            self.stats.deadline_misses += int(missed)
            results.append(RequestResult(
                request_id=req.request_id,
                batch_index=batch_index,
                bucket_size=key.bucket,
                op=key.op or "nominal",
                mode=key.mode,
                steps=key.steps,
                taylorseer=key.taylorseer,
                precision=key.precision,
                batch_corrected_elems=corrected,
                n_model_evals=nevals,
                energy_j=cost["energy_j"],
                energy_breakdown=cost["breakdown"],
                latency_s=batch_latency_s,
                baseline_energy_j=base["energy_j"],
                baseline_latency_s=base["latency_s"],
                monitor_ber=mon_ber,
                monitor_op_index=mon_idx,
                priority=req.priority,
                deadline_s=req.deadline_s,
                completed_at_s=completed_at,
                queue_wait_s=max(
                    completed_at - req.submitted_at_s - batch_latency_s,
                    0.0),
                deadline_missed=missed,
                detect_heatmap=outcome.heatmap,
                detect_heatmap_blocks=outcome.heatmap_blocks,
                **outcome.per_slot[slot],
            ))
        # telemetry tap: metrics + latency history for the scheduler's
        # learned estimates, and (monitored modes) one guardband-controller
        # observation of the batch's realized BER / rollback intensity
        self.telemetry.on_batch(
            key=key, n_live=n_live, n_pad=mb.n_pad,
            latency_s=batch_latency_s, ema_ber=mon_ber, op_index=mon_idx,
            corrected=corrected,
            n_words=outcome.n_words,
            monitored=protected, clock_s=self.clock_s,
            queue_depth=len(self.queue), results=results,
            energy_breakdown=bcost["breakdown"])
        if ctx.offload_delta is not None:
            # settled by the drain's finish_batch() join before this ran
            self.telemetry.on_offload(ctx.offload_delta,
                                      interval=key.rollback_interval,
                                      stall_s=stall_s)
        # resilience-heatmap export (monitored batches with a real
        # sampler): labeled counters for /metrics, and a detect span in
        # the flight recorder summarizing where this batch's errors landed
        detect_attrs = None
        if outcome.heatmap is not None:
            self.telemetry.on_heatmap(outcome.heatmap,
                                      outcome.heatmap_blocks)
            detect_attrs = dict(heatmap=outcome.heatmap,
                                blocks=outcome.heatmap_blocks,
                                corrected=corrected)
        self.tracer.finish_batch(self.clock_s, detect_attrs=detect_attrs,
                                 latency_s=batch_latency_s,
                                 energy_j=cost["energy_j"],
                                 energy_breakdown=dict(bcost["breakdown"]),
                                 stall_s=stall_s, mode=key.mode,
                                 op=key.op or "nominal",
                                 n_model_evals=nevals)
        return results
