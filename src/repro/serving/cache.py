"""Compiled-sampler cache: one jitted sampler per serving configuration.

The cache key is everything that changes the traced computation -- arch,
step count, DRIFT mode, operating point (its name pins the DVFS schedule
baked into the trace), batch bucket, TaylorSeer, rollback interval,
streaming window size, and (for the sharded engine) the device-mesh
placement. Each key jits exactly once per process; the ``traces`` counter
(driven by ``sampler.make_sampler``'s ``on_trace`` hook, which only fires
while JAX stages the function) is the ground truth the serving tests
assert on. One caveat for streamed keys: a streaming sampler jits a
*window*, so a configuration whose step count is not a multiple of the
window traces twice (full window + remainder) -- still once per key, per
distinct window length.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

from repro.core.rollback import DEFAULT_INTERVAL


@dataclasses.dataclass(frozen=True)
class SamplerKey:
    """Hashable identity of one compiled sampler configuration."""
    arch: str
    smoke: bool
    steps: int
    mode: str
    op: str            # operating-point name; "" when no DVFS schedule
    bucket: int        # compiled batch size
    taylorseer: bool = False
    # Precision-plan name (core.quant.PRECISION_PLANS). "int8" is the
    # degenerate plan whose sampler trace is byte-identical to a pre-plan
    # build; narrowed plans add a fake-quant op, so they need their own
    # compiled fn. The clean-reference path normalizes this back to "int8"
    # (references are always scored at full width).
    precision: str = "int8"
    # Always a concrete int here: "auto" requests resolve through the
    # offload planner (engine.auto_rollback_interval) before keying.
    rollback_interval: int = DEFAULT_INTERVAL
    # Sharded-engine placement (empty on the single-device path): the mesh
    # axes/sizes the bucket is spread over and the latents batch
    # PartitionSpec, both rendered hashable. Different meshes bake
    # different collectives into the executable, so they must not share a
    # compiled fn even when every model-side field matches.
    mesh_shape: Tuple[Tuple[str, int], ...] = ()
    batch_spec: str = ""
    # Streaming preview window in denoising steps; 0 = the one-shot
    # full-scan sampler. A streamed run compiles a window function instead
    # of the whole chain, so the two must not alias one cache slot. The
    # clean-reference path always normalizes this back to 0 (previews never
    # need a reference, and bit-identity means streamed and one-shot runs
    # share the same clean sample).
    stream: int = 0


class CompiledSamplerCache:
    """Maps SamplerKey -> jitted sampler fn, with compile accounting."""

    def __init__(self) -> None:
        self._fns: Dict[SamplerKey, Callable] = {}
        self.compiles = 0   # cache misses (factory invocations)
        self.hits = 0       # cache hits (reused compiled fn)
        self.traces = 0     # actual JAX traces observed via on_trace
        # Flight-recorder tap: fired on every cache miss with
        # (key, wall seconds the factory took). The factory only *builds*
        # the jitted fn (tracing may be deferred to first call), so this
        # measures construction; trace-time compiles still show up through
        # note_trace and the window spans around the first call.
        self.on_compile: Optional[Callable[[SamplerKey, float], None]] = None

    def note_trace(self) -> None:
        self.traces += 1

    def get(self, key: SamplerKey,
            factory: Callable[[SamplerKey], Callable]) -> Callable:
        fn = self._fns.get(key)
        if fn is not None:
            self.hits += 1
            return fn
        t0 = time.perf_counter()
        fn = factory(key)
        self._fns[key] = fn
        self.compiles += 1
        if self.on_compile is not None:
            self.on_compile(key, time.perf_counter() - t0)
        return fn

    def __contains__(self, key: SamplerKey) -> bool:
        return key in self._fns

    def __len__(self) -> int:
        return len(self._fns)
