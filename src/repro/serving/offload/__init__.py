"""Asynchronous rollback-checkpoint offload for the serving stack.

Sec 5.4 of the paper optimizes the rollback-ABFT checkpoint store's
memory overhead two ways -- offloading intervals and tile-contiguous
data layouts -- and the ROADMAP's top serving follow-on was to overlap
the store refresh with the next denoising window instead of serializing
it inside the scan. This package is that subsystem:

===============  ======================================================
module           role
===============  ======================================================
``store``        double-buffered host-side checkpoint store: snapshots
                 the scan carry's stores at stream-window boundaries on
                 a background thread, overlapped with the next window's
                 compute; ``restore()`` re-uploads the last committed
                 snapshot (restore-on-rollback)
``layout``       routes snapshots through ``core.repack``
                 tile-contiguous layouts and charges partial-tile
                 recovery the ``perfmodel.dram`` repacked row count
``planner``      per-(arch, op, steps, bucket) refresh-interval
                 optimizer: minimizes modeled refresh energy + residual
                 stall + detection-rate-weighted staleness penalty;
                 resolves ``rollback_interval="auto"`` requests through
                 ``DriftServeEngine.auto_rollback_interval``
===============  ======================================================

Wiring: ``DriftServeEngine(offload=OffloadConfig())`` (the CLIs'
``--offload``) runs every monitored-mode batch through the windowed
sampler with the refresh interval as the window, committing between
windows via ``sampler.make_sampler(on_carry=...)``; the scheduler's
batch-latency projection and the engine's virtual clock both charge the
planner's residual stall, and telemetry gains offload counters. With
faults disabled, offload-enabled and offload-disabled runs are
bit-identical on both engines (asserted in tests/test_offload.py and
tests/test_serving_sharded.py). Lifecycle + timeline: docs/offload.md.
"""
from repro.serving.offload.layout import (PackedLeaf, layout_report,
                                          pack_leaf, pack_store,
                                          recovery_rows, store_nbytes,
                                          unpack_leaf, unpack_store)
from repro.serving.offload.planner import (IntervalPlan, OffloadPlanner,
                                           pareto_frontier)
from repro.serving.offload.store import (OffloadConfig, OffloadStats,
                                         OffloadStore)

__all__ = [
    "OffloadConfig", "OffloadStats", "OffloadStore",
    "OffloadPlanner", "IntervalPlan", "pareto_frontier",
    "PackedLeaf", "pack_leaf", "unpack_leaf", "pack_store", "unpack_store",
    "store_nbytes", "recovery_rows", "layout_report",
]
