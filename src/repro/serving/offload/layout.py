"""Tile-contiguous host layouts for offloaded checkpoint snapshots.

The offload store (``store.py``) does not ship checkpoint tensors to the
host row-major: it routes every leaf through the Sec 5.4 tile-contiguous
transform (``repro.core.repack``) first, so the host-side buffer has the
same layout a Pallas BlockSpec-tiled kernel consumes and -- the part that
matters for the paper's Fig 10(b)/13(b) claim -- a *partial* tile
restore is charged the repacked DRAM row count from
``repro.perfmodel.dram``, not one row activation per matrix row.

Leaves are arbitrary-rank (the DiT block store stacks leaves ``(L, ...)``
to ride the layer scan), so a leaf is first flattened to 2-D
``(prod(leading), last_dim)``, then tiled. The pack/unpack pair is exact
(pad -> reshape -> transpose -> crop), which is what keeps a restore
bit-identical to the live store -- asserted against ``core.rollback``
semantics in tests/test_offload.py, and property-tested across
non-aligned shapes/dtypes in tests/test_repack_property.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import repack as repack_lib
from repro.perfmodel import dram as dram_lib
from repro.perfmodel.hw import PAPER_ACCEL


@dataclasses.dataclass(frozen=True)
class PackedLeaf:
    """One checkpoint tensor in its host-side tile-contiguous form.

    ``data`` is host memory (numpy): ``(Mt, Nt, tm*tn)`` when packed, the
    raw array when the leaf was too small to tile (ndim < 2). ``sharding``
    remembers the device placement so a restore re-uploads shard-for-shard
    (``jax.device_put`` accepts the recorded ``NamedSharding`` unchanged).
    """
    data: np.ndarray
    shape: Tuple[int, ...]            # original (unflattened) leaf shape
    dtype: str
    tm: int
    tn: int
    packed: bool
    sharding: Optional[object] = None

    @property
    def nbytes(self) -> int:
        """Host bytes actually offloaded (tile padding included)."""
        return int(self.data.nbytes)


def _flat2d(shape: Tuple[int, ...]) -> Tuple[int, int]:
    lead = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
    return lead, int(shape[-1])


def pack_leaf(arr: jax.Array, tm: int, tn: int,
              repacked: bool = True) -> PackedLeaf:
    """Snapshot one device leaf to host in tile-contiguous layout.

    The repack itself runs on device (it is the free-at-kernel-boundary
    transform of ``core.repack``); the device->host copy then pulls the
    already-tile-contiguous buffer. On an accelerator deployment this is
    a ``jax.device_put`` to the host CPU device overlapping the next
    window's compute; on CPU CI the copy degenerates to a device_get of
    the same memory space -- the semantics (an immutable host snapshot
    decoupled from the live buffer) are identical.
    """
    sharding = getattr(arr, "sharding", None)
    if arr.ndim < 2 or not repacked:
        return PackedLeaf(data=np.asarray(arr), shape=tuple(arr.shape),
                          dtype=str(arr.dtype), tm=tm, tn=tn, packed=False,
                          sharding=sharding)
    m, n = _flat2d(arr.shape)
    tiled = repack_lib.repack(jnp.reshape(arr, (m, n)), tm, tn)
    return PackedLeaf(data=np.asarray(tiled), shape=tuple(arr.shape),
                      dtype=str(arr.dtype), tm=tm, tn=tn, packed=True,
                      sharding=sharding)


def unpack_leaf(leaf: PackedLeaf, device: bool = True):
    """Inverse of :func:`pack_leaf`: reassemble the original leaf.

    ``device=True`` re-uploads with the recorded sharding (the
    restore-on-rollback path); ``device=False`` returns host numpy (the
    accounting / test path).
    """
    if not leaf.packed:
        out = jnp.asarray(leaf.data)
    else:
        m, n = _flat2d(leaf.shape)
        flat = repack_lib.unpack(jnp.asarray(leaf.data), (m, n),
                                 leaf.tm, leaf.tn)
        out = jnp.reshape(flat, leaf.shape)
    out = out.astype(leaf.dtype)
    if not device:
        return np.asarray(out)
    if leaf.sharding is not None:
        return jax.device_put(out, leaf.sharding)
    return out


def pack_store(stores, tm: int, tn: int, repacked: bool = True):
    """Pack a whole checkpoint-store pytree (PackedLeaf per leaf)."""
    return jax.tree.map(lambda a: pack_leaf(a, tm, tn, repacked), stores)


def unpack_store(packed):
    """Restore a packed pytree back onto device (original shardings)."""
    return jax.tree.map(lambda l: unpack_leaf(l),
                        packed, is_leaf=lambda x: isinstance(x, PackedLeaf))


def store_nbytes(packed) -> int:
    """Total host bytes of one packed snapshot (the offload volume)."""
    return int(sum(l.nbytes for l in jax.tree.leaves(
        packed, is_leaf=lambda x: isinstance(x, PackedLeaf))))


def recovery_rows(leaf_shape: Tuple[int, ...], tm: int, tn: int,
                  n_tiles: int = 1, repacked: bool = True,
                  elem_bytes: int = 4,
                  row_bytes: int = PAPER_ACCEL.dram_row_bytes) -> int:
    """DRAM row activations charged for restoring ``n_tiles`` tiles of a
    leaf -- the accounting bridge to ``perfmodel.dram``: a repacked layout
    pays ``rows_per_tile_repacked``, a row-major one ``rows_per_tile_rowmajor``
    with the leaf's flattened column count."""
    _, n_cols = _flat2d(leaf_shape)
    if repacked:
        per_tile = dram_lib.rows_per_tile_repacked(tm, tn, elem_bytes,
                                                   row_bytes)
    else:
        per_tile = dram_lib.rows_per_tile_rowmajor(tm, tn, n_cols,
                                                   elem_bytes, row_bytes)
    return n_tiles * per_tile


def layout_report(stores, tm: int, tn: int) -> Dict[str, float]:
    """Whole-store layout accounting: total tiles, row activations for a
    full restore under both layouts, and the Fig 13(b)-style reduction."""
    tiles = rows_rp = rows_rm = 0
    for arr in jax.tree.leaves(stores):
        shape = tuple(arr.shape)
        if len(shape) < 2:
            continue
        m, n = _flat2d(shape)
        n_tiles = math.ceil(m / tm) * math.ceil(n / tn)
        tiles += n_tiles
        rows_rp += recovery_rows(shape, tm, tn, n_tiles, repacked=True)
        rows_rm += recovery_rows(shape, tm, tn, n_tiles, repacked=False)
    return {"tiles": float(tiles),
            "rows_repacked": float(rows_rp),
            "rows_rowmajor": float(rows_rm),
            "reduction": rows_rm / max(rows_rp, 1.0)}
