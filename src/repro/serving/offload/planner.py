"""Offload-interval optimizer: pick ``RollbackConfig.interval`` per
operating point instead of hard-coding the paper's default.

The refresh interval trades three modeled costs against each other
(Sec 5.4 / Fig 10b; the DiffPro argument that protection budgets should
be chosen per operating point from measured sensitivity):

* **refresh energy** -- every refresh writes the whole checkpoint store
  to DRAM: ``ceil(steps / interval) * activation_bytes * e_dram`` (plus
  the row-activation surcharge of the layout in use). Shrinks as the
  interval grows.
* **refresh stall** -- an offload that outlasts the window it overlaps
  leaves residual stall ``max(0, t_refresh - t_window)`` per refresh
  (``t_window = interval`` denoising steps of compute at the operating
  point's frequency). The serialized baseline pays ``t_refresh`` in
  full -- that gap is exactly what benchmarks/offload_overlap.py
  measures. Stall is priced into Joules at the die's static (leakage)
  power so the objective is a single scalar.
* **staleness penalty** -- a rollback correction reads the last
  committed snapshot, on average ``(interval - 1) / 2`` steps old; the
  cross-step similarity that makes rollback work (Fig 2b) decays with
  that distance, so each expected detection is charged a
  staleness-proportional fraction of a recompute-equivalent step. Grows
  with the interval, scaled by the *measured* detection rate: the
  telemetry guardband controller's realized-BER EWMA for the operating
  point when history exists, the monitor target otherwise.

``plan()`` minimizes the sum; since the total is a positively-weighted
sum of (energy, stall), its argmin is always on the (energy, stall)
Pareto frontier -- the benchmark asserts that explicitly against an
independently-computed frontier. The engine memoizes resolutions per
(arch, op, steps, bucket, quantized detection rate), so
``rollback_interval="auto"`` requests resolve through one point
(``DriftServeEngine.auto_rollback_interval``), the same single-resolution
shape as ``engine.auto_op_index()``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core import dvfs as dvfs_lib
from repro.perfmodel import dram as dram_lib
from repro.perfmodel import energy as energy_lib

# DRAM row-cycle time used for refresh/restore timing (matches
# perfmodel.dram.recovery_report's tRC), and the bank-level parallelism a
# streaming refresh write pipelines row activations across (HBM2
# pseudo-channels x banks; sequential writes hit banks round-robin, so
# only 1/DRAM_BANKS of the row cycles land on the critical path --
# without this the model contradicts Sec 6.4's "fully overlapped" shape).
T_RC_NS = 45.0
DRAM_BANKS = 16

# Most intervals ever considered; steps beyond this share the last point.
MAX_CANDIDATES = 64


@dataclasses.dataclass(frozen=True)
class IntervalPlan:
    """Modeled per-run cost of one candidate refresh interval."""
    interval: int
    n_refreshes: int
    refresh_s: float                 # one refresh's host-offload time
    stall_serialized_s: float        # per-run stall, refresh blocks scan
    stall_s: float                   # per-run residual stall, overlapped
    refresh_energy_j: float
    rollback_penalty_j: float
    total_j: float                   # energy + penalty + stall @ P_static

    @property
    def energy_j(self) -> float:
        """The energy axis of the (energy, stall) Pareto trade."""
        return self.refresh_energy_j + self.rollback_penalty_j


def pareto_frontier(plans: Sequence[IntervalPlan]) -> List[IntervalPlan]:
    """Non-dominated subset over (energy_j, stall_s), ties kept."""
    out = []
    for p in plans:
        dominated = any(
            (q.energy_j <= p.energy_j and q.stall_s <= p.stall_s)
            and (q.energy_j < p.energy_j or q.stall_s < p.stall_s)
            for q in plans)
        if not dominated:
            out.append(p)
    return out


class OffloadPlanner:
    """Per-(arch config, op, steps, bucket) refresh-interval optimizer."""

    def __init__(self, em: Optional[energy_lib.EnergyModel] = None,
                 nominal_steps: int = 2, repacked: bool = True,
                 overlapped: bool = True,
                 tile_m: int = 32, tile_n: int = 32) -> None:
        self.em = em if em is not None else energy_lib.calibrate()
        self.nominal_steps = nominal_steps
        self.repacked = repacked
        self.overlapped = overlapped
        self.tile_m, self.tile_n = tile_m, tile_n
        self._sweep_cache: Dict[tuple, List[IntervalPlan]] = {}

    # ------------------------------------------------------------- pieces
    def refresh_bytes(self, cfg, bucket: int) -> float:
        """One refresh's offload volume: the checkpointable GEMM-output
        store (same quantity the perfmodel charges as ckpt traffic)."""
        return energy_lib.activation_bytes(cfg, bucket)

    def refresh_time_s(self, cfg, bucket: int) -> float:
        """Streaming write of one snapshot: bytes / HBM BW + row cycles.

        A refresh streams the whole store sequentially, so its row count
        is layout-independent (``ceil(bytes / row_bytes)``); the layout
        only bites on partial-tile *recovery* reads (see
        :meth:`recovery_read_j`).
        """
        nbytes = self.refresh_bytes(cfg, bucket)
        hw = self.em.hw
        rows = math.ceil(nbytes / hw.dram_row_bytes)
        return (nbytes / (hw.hbm_gbps * 1e9)
                + rows * T_RC_NS * 1e-9 / DRAM_BANKS)

    def recovery_read_j(self, cfg) -> float:
        """DRAM energy of one tile recovery read from the offloaded
        store: tile bytes + the row-activation overhead of the layout in
        use (``perfmodel.dram`` row counts -- repacked tiles touch
        ``ceil(tile_bytes / row_bytes)`` rows, row-major ones a row per
        matrix row; same 64-byte-per-row surcharge convention as
        ``energy.run_cost``)."""
        hw = self.em.hw
        n_cols = getattr(cfg, "d_model", 1024)
        if self.repacked:
            rows = dram_lib.rows_per_tile_repacked(
                self.tile_m, self.tile_n, 4, hw.dram_row_bytes)
        else:
            rows = dram_lib.rows_per_tile_rowmajor(
                self.tile_m, self.tile_n, n_cols, 4, hw.dram_row_bytes)
        nbytes = self.tile_m * self.tile_n * 4 + rows * 64
        return nbytes * self.em.e_dram_pj_per_byte * 1e-12

    def step_latency_s(self, cfg, op: dvfs_lib.OperatingPoint,
                       bucket: int) -> float:
        """One aggressive-phase denoising step at this operating point
        (the compute a refresh overlaps with)."""
        rc = energy_lib.RunConfig(num_steps=1, nominal_steps=0,
                                  aggressive=op)
        return energy_lib.run_cost(cfg, rc, batch=bucket,
                                   em=self.em)["latency_s"]

    def step_energy_j(self, cfg, op: dvfs_lib.OperatingPoint,
                      bucket: int) -> float:
        rc = energy_lib.RunConfig(num_steps=1, nominal_steps=0,
                                  aggressive=op)
        return energy_lib.run_cost(cfg, rc, batch=bucket,
                                   em=self.em)["e_die"]

    # --------------------------------------------------------------- plan
    def _per_run_terms(self, cfg, op: dvfs_lib.OperatingPoint,
                       bucket: int) -> tuple:
        """The interval-INDEPENDENT cost pieces, computed once per sweep:
        (refresh time, refresh bytes, step latency, step die energy,
        recovery read energy)."""
        return (self.refresh_time_s(cfg, bucket),
                self.refresh_bytes(cfg, bucket),
                self.step_latency_s(cfg, op, bucket),
                self.step_energy_j(cfg, op, bucket),
                self.recovery_read_j(cfg))

    def _evaluate_terms(self, terms, steps: int, interval: int,
                        detect_rate: float) -> IntervalPlan:
        assert interval >= 1, interval
        t_refresh, nbytes, t_step, e_step, e_recovery = terms
        n_refreshes = math.ceil(steps / interval)
        t_window = t_step * interval
        serialized = n_refreshes * t_refresh
        overlapped = n_refreshes * max(0.0, t_refresh - t_window)
        stall = overlapped if self.overlapped else serialized
        refresh_j = n_refreshes * nbytes * self.em.e_dram_pj_per_byte * 1e-12
        staleness = (interval - 1) / 2.0
        detections = min(1.0, detect_rate) * steps
        penalty_j = detections * ((staleness / max(steps, 1)) * e_step
                                  + e_recovery)
        total = refresh_j + penalty_j + stall * self.em.static_w
        return IntervalPlan(interval=interval, n_refreshes=n_refreshes,
                            refresh_s=t_refresh,
                            stall_serialized_s=serialized,
                            stall_s=overlapped,
                            refresh_energy_j=refresh_j,
                            rollback_penalty_j=penalty_j,
                            total_j=total)

    def evaluate(self, cfg, op: dvfs_lib.OperatingPoint, steps: int,
                 bucket: int, interval: int,
                 detect_rate: float) -> IntervalPlan:
        """Modeled cost of one (interval) choice for one run."""
        return self._evaluate_terms(self._per_run_terms(cfg, op, bucket),
                                    steps, interval, detect_rate)

    def sweep(self, cfg, op: dvfs_lib.OperatingPoint, steps: int,
              bucket: int, detect_rate: float,
              candidates: Optional[Sequence[int]] = None
              ) -> List[IntervalPlan]:
        """Cost of every candidate interval. The interval-independent
        perfmodel terms are computed once per sweep (not per candidate),
        and the whole sweep is memoized per query key -- ModelConfig is a
        frozen (hashable) dataclass, so the key carries the config by
        value, never by object identity."""
        if candidates is None:
            candidates = range(1, min(max(steps, 1), MAX_CANDIDATES) + 1)
        key = (cfg, op.name, steps, bucket, f"{detect_rate:.2e}",
               tuple(candidates))
        cached = self._sweep_cache.get(key)
        if cached is None:
            terms = self._per_run_terms(cfg, op, bucket)
            cached = [self._evaluate_terms(terms, steps, n, detect_rate)
                      for n in candidates]
            self._sweep_cache[key] = cached
        return cached

    def plan(self, cfg, op: dvfs_lib.OperatingPoint, steps: int,
             bucket: int, detect_rate: float,
             candidates: Optional[Sequence[int]] = None) -> IntervalPlan:
        """The chosen interval: argmin of the summed objective (ties ->
        the larger interval, i.e. less refresh traffic)."""
        plans = self.sweep(cfg, op, steps, bucket, detect_rate, candidates)
        return min(plans, key=lambda p: (p.total_j, -p.interval))

    def residual_stall_s(self, cfg, op: dvfs_lib.OperatingPoint,
                         steps: int, bucket: int, interval: int) -> float:
        """Per-run stall the scheduler's projection (and the engine's
        virtual clock) charge for an offload-enabled batch."""
        plan = self.evaluate(cfg, op, steps, bucket, interval,
                             detect_rate=0.0)
        return plan.stall_s if self.overlapped else plan.stall_serialized_s
