"""Double-buffered asynchronous rollback-checkpoint offload store.

The sampler's scan refreshes the live rollback store every ``interval``
denoising steps (``core.rollback.should_checkpoint``), and before this
subsystem the *only* copy of that store rode the scan carry on device.
This module adds the Sec 5.4 memory-side half of the design: at stream-
window boundaries the engine snapshots the carry's checkpoint stores and
offloads them to a host-side buffer on a background thread, **overlapped
with the next window's denoising steps** -- the scan keeps carrying only
the live buffer, while the committed snapshot lives host-side in
tile-contiguous layout (``layout.py``). ``restore()`` re-uploads the last
committed snapshot (restore-on-rollback / preemption recovery).

Double buffering::

    window k   scan ───────────────►│ window k+1 scan ──────────────►│
                     on_window(carry)│                on_window(carry)│
    back   ◄── snapshot+pack (thread; overlapped with window k+1)
    front  ◄───────────────── swap when the copy completes
    restore() reads front: always the last *committed* snapshot, never
    a half-written one.

Everything here is host-side Python running *between* jitted windows, so
it cannot perturb the traced computation: offload-enabled and
offload-disabled runs produce bit-identical latents (the suite asserts
this on both engines), because the live store the scan corrects from is
untouched -- the host copy is redundancy, exactly like a DRAM-offloaded
checkpoint on the paper's accelerator.

Commit decision & sharding: whether a window commits is decided from the
completed-step count (did a ``step % interval == 0`` refresh land in the
window?) and, optionally, the carry's BER-monitor state
(``skip_spike_ratio``: a detection spike defers the commit so the last
*good* snapshot is kept instead of being overwritten with
possibly-corrupted activations -- the ReaLM argument). Both inputs are
replicated on a sharded engine -- the step count is trace-static and the
monitor's detection sums are psum-reduced across the mesh before they
reach the carry -- so every shard takes the same decision and the
per-shard device->host copies (``jax.device_put``-style snapshots of the
shard-resident leaves) stay consistent without any extra collective.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

from repro.core.rollback import DEFAULT_INTERVAL
from repro.serving.offload import layout as layout_lib


@dataclasses.dataclass(frozen=True)
class OffloadConfig:
    """Knobs for the checkpoint-offload subsystem (engine-level)."""
    enabled: bool = True
    # Systolic-tile shape the host layout is packed in (Sec 5.4; matches
    # the paper accelerator's 32x32 arrays and the ABFT tile granularity).
    tile_m: int = 32
    tile_n: int = 32
    # Tile-contiguous host layout (core.repack). False = row-major host
    # copies -- the Fig 10(b) ablation, charged more DRAM rows on restore.
    repacked: bool = True
    # Offload on a background thread, overlapped with the next window's
    # compute. False = commit synchronously inside the window boundary --
    # the serialized baseline benchmarks/offload_overlap.py measures.
    async_commit: bool = True
    # Defer (skip) a commit when the carry monitor's psum-reduced EMA BER
    # exceeds skip_spike_ratio * target_ber: under a detection storm the
    # activations being snapshotted are the likely-corrupted ones, so the
    # store keeps the last good snapshot instead. None = always commit.
    skip_spike_ratio: Optional[float] = None
    target_ber: float = 3e-3


@dataclasses.dataclass
class OffloadStats:
    """Cumulative store counters (telemetry reads per-batch deltas)."""
    commits: int = 0
    skipped: int = 0            # refresh windows deferred by a BER spike
    restores: int = 0
    bytes_offloaded: int = 0
    waits: int = 0              # joins that actually blocked on a commit

    def snapshot(self) -> "OffloadStats":
        return dataclasses.replace(self)

    def delta(self, since: "OffloadStats") -> "OffloadStats":
        return OffloadStats(
            commits=self.commits - since.commits,
            skipped=self.skipped - since.skipped,
            restores=self.restores - since.restores,
            bytes_offloaded=self.bytes_offloaded - since.bytes_offloaded,
            waits=self.waits - since.waits)


class OffloadStore:
    """Host-side double buffer for one engine's rollback checkpoints.

    One store serves the whole (single-threaded) engine: ``begin_batch``
    rebinds it to the next micro-batch's refresh interval,
    ``on_window(done, carry)`` is the sampler-boundary tap
    (``make_sampler(on_carry=...)``), and ``finish_batch`` joins any
    in-flight copy so the batch's accounting is settled before results
    are stamped. At most one copy is in flight; a new commit first joins
    the previous one (the double buffer is two deep, not a queue).
    """

    def __init__(self, cfg: Optional[OffloadConfig] = None) -> None:
        self.cfg = cfg or OffloadConfig()
        self.stats = OffloadStats()
        self._lock = threading.Lock()
        self._front = None              # last committed packed snapshot
        self._front_step = -1
        self._thread: Optional[threading.Thread] = None
        self._thread_exc: Optional[BaseException] = None
        self._interval = DEFAULT_INTERVAL
        self._prev_done = 0
        self._batch_index = -1
        self._batch_mark = self.stats.snapshot()
        # Flight-recorder tap, fired as on_event(event, step,
        # wall_elapsed_s, **attrs) after each commit swap (from the
        # background thread -- the recorder is lock-protected) and each
        # restore. None = no tracing.
        self.on_event: Optional[Callable] = None
        # Modeled joules per offloaded byte (the perfmodel's DRAM access
        # energy): the engine arms it when it binds the store, so commit/
        # restore events carry the energy their refresh traffic costs.
        self.energy_per_byte_j = 0.0

    # ------------------------------------------------------------ binding
    def begin_batch(self, interval: int, batch_index: int) -> None:
        """Rebind to one micro-batch run (engine calls this per batch)."""
        assert interval >= 1, interval
        self.wait()                     # settle the previous batch's copy
        self._interval = int(interval)
        self._prev_done = 0
        self._batch_index = batch_index
        self._batch_mark = self.stats.snapshot()

    def finish_batch(self) -> OffloadStats:
        """Join the in-flight copy; returns this batch's stat delta."""
        self.wait()
        return self.stats.delta(self._batch_mark)

    # ----------------------------------------------------------- the tap
    def on_window(self, done_steps: int, carry) -> None:
        """Sampler window-boundary hook: commit when a refresh landed.

        ``carry`` is the sampling scan's carry tuple ``(latents, stores,
        taylor, monitor, corrected, nevals)`` -- the live checkpoint
        stores are ``carry[1]``, the psum-reduced monitor ``carry[3]``.
        """
        start, self._prev_done = self._prev_done, done_steps
        refreshed = (done_steps > start
                     and start <= self._last_refresh_step(done_steps))
        if not refreshed:
            return
        if self._spiking(carry[3]):
            with self._lock:
                self.stats.skipped += 1
            return
        self.commit(self._last_refresh_step(done_steps), carry[1])

    def _last_refresh_step(self, done_steps: int) -> int:
        """Most recent step < done_steps with step % interval == 0."""
        return ((done_steps - 1) // self._interval) * self._interval

    def _spiking(self, monitor) -> bool:
        ratio = self.cfg.skip_spike_ratio
        if ratio is None:
            return False
        # float() of a replicated array: every shard holds the same
        # psum-reduced EMA, so the skip decision is mesh-consistent.
        return float(monitor.ema_ber) > ratio * self.cfg.target_ber

    # ------------------------------------------------------------ commits
    def commit(self, step: int, stores) -> None:
        """Offload one snapshot of ``stores``; async when configured.

        The device->host copy (repack on device, then the pull) runs on a
        background thread so the engine's main thread is free to dispatch
        the next window immediately -- that dispatch is what the copy
        overlaps with.
        """
        self.wait()                     # double buffer: at most 1 in flight

        def _do_commit() -> None:
            # Failures on the worker thread (host OOM mid-copy, a leaf
            # shape repack can't handle) must not be lost to the default
            # thread excepthook while the engine keeps serving as if the
            # offload were healthy: stash and re-raise from wait(), so
            # the next join point (begin/finish_batch, restore) surfaces
            # the broken recovery guarantee to the engine.
            t0 = time.perf_counter()
            try:
                packed = layout_lib.pack_store(stores, self.cfg.tile_m,
                                               self.cfg.tile_n,
                                               self.cfg.repacked)
                nbytes = layout_lib.store_nbytes(packed)
            except BaseException as exc:     # noqa: BLE001 -- re-raised
                self._thread_exc = exc
                return
            with self._lock:            # atomic swap: back -> front
                self._front = packed
                self._front_step = step
                self.stats.commits += 1
                self.stats.bytes_offloaded += nbytes
            if self.on_event is not None:
                self.on_event("commit", step,
                              time.perf_counter() - t0, nbytes=nbytes,
                              energy_j=nbytes * self.energy_per_byte_j,
                              asynchronous=self.cfg.async_commit)

        if not self.cfg.async_commit:
            _do_commit()
            self.wait()                 # surface a sync-commit failure now
            return
        self._thread = threading.Thread(target=_do_commit,
                                        name="drift-offload-commit",
                                        daemon=True)
        self._thread.start()

    def wait(self) -> None:
        """Join the in-flight commit, if any; re-raises a commit failure
        (the background thread's exception) at this join point."""
        t, self._thread = self._thread, None
        if t is not None and t.is_alive():
            with self._lock:
                self.stats.waits += 1
            t.join()
        elif t is not None:
            t.join()
        exc, self._thread_exc = self._thread_exc, None
        if exc is not None:
            raise RuntimeError("checkpoint offload commit failed") from exc

    # ------------------------------------------------------------ queries
    @property
    def committed_step(self) -> int:
        """Denoising step of the last committed snapshot (-1 = none)."""
        with self._lock:
            return self._front_step

    @property
    def committed_nbytes(self) -> int:
        with self._lock:
            return layout_lib.store_nbytes(self._front) \
                if self._front is not None else 0

    def restore(self):
        """Re-upload the last committed snapshot to device.

        The restore-on-rollback path: leaves come back bit-identical to
        the live store they were snapshotted from (pack/unpack is exact),
        with their recorded shardings, so ``core.rollback.correct`` run
        against a restored checkpoint equals the inline-store path --
        the regression tests/test_offload.py asserts.
        """
        self.wait()
        with self._lock:
            front = self._front
            front_step = self._front_step
        if front is None:
            raise RuntimeError("restore() before any committed snapshot")
        with self._lock:
            self.stats.restores += 1
        t0 = time.perf_counter()
        nbytes = layout_lib.store_nbytes(front)
        out = layout_lib.unpack_store(front)
        if self.on_event is not None:
            self.on_event("restore", front_step,
                          time.perf_counter() - t0, nbytes=nbytes,
                          energy_j=nbytes * self.energy_per_byte_j)
        return out
