"""Serving request/result types and the FIFO request queue.

A ``GenerationRequest`` is one user-facing generation job: which diffusion
arch to run, how many DDIM steps, which DRIFT protection mode, and which
DVFS operating point -- ``"auto"`` delegates the choice to the engine's
shared BER-monitor ladder (Sec 5.1). Results come back as structured
``RequestResult`` records (quality vs the clean reference, energy/latency
attribution, monitor state) instead of prints.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional

from repro.core.exec_ctx import MODES

# Operating points a request may name; "auto" resolves against the engine's
# BER-monitor ladder at batch-formation time.
REQUEST_OPS = ("nominal", "undervolt", "overclock", "auto")


@dataclasses.dataclass(frozen=True)
class GenerationRequest:
    """One queued generation job. Frozen: the queue hands out copies only."""
    request_id: int
    arch: str = "dit-xl-512"
    smoke: bool = True
    steps: int = 10
    mode: str = "drift"            # exec_ctx.MODES member
    op: str = "undervolt"          # REQUEST_OPS member
    seed: int = 0                  # drives this request's initial latents
    taylorseer: bool = False
    rollback_interval: int = 10

    def __post_init__(self):
        if self.op not in REQUEST_OPS:
            raise ValueError(
                f"unknown operating point {self.op!r}; one of {REQUEST_OPS}")
        if self.mode not in MODES:
            raise ValueError(
                f"unknown DRIFT mode {self.mode!r}; one of {MODES}")


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """Structured per-request outcome of one engine run."""
    request_id: int
    batch_index: int               # which micro-batch served this request
    bucket_size: int
    op: str                        # resolved operating-point name
    mode: str
    steps: int
    # quality vs the cached clean reference (same latents, BER 0)
    lpips_vs_clean: float
    psnr_vs_clean_db: float
    # rollback-corrected elements summed over the WHOLE batch tensor
    # (including padded slots) -- the sampler reports one scalar per scan,
    # so this cannot be split per request; don't sum it across results.
    batch_corrected_elems: int
    # computed denoising steps for this request's sample (identical for
    # every request in the batch; < steps when TaylorSeer forecasts)
    n_model_evals: int
    # perfmodel attribution (full-arch energy model, bucket cost split
    # across live requests; latency is the shared batch latency)
    energy_j: float
    latency_s: float
    baseline_energy_j: float
    baseline_latency_s: float
    # BER-monitor state after this request's batch
    monitor_ber: float
    monitor_op_index: int
    # this request's generated sample: its slot of the batch output latents,
    # clipped to [-1, 1], shape (H, W, C). Optional so metric-only fakes in
    # tests stay cheap; the real engine always fills it.
    latents: Optional[object] = None


class RequestQueue:
    """FIFO queue assigning monotonically increasing request ids."""

    def __init__(self) -> None:
        self._pending: Deque[GenerationRequest] = collections.deque()
        self._next_id = 0

    def submit(self, **fields) -> int:
        req = GenerationRequest(request_id=self._next_id, **fields)
        self._next_id += 1
        self._pending.append(req)
        return req.request_id

    def __len__(self) -> int:
        return len(self._pending)

    def peek(self) -> Optional[GenerationRequest]:
        return self._pending[0] if self._pending else None

    def take_matching(self, head_key, key_of, limit: int
                      ) -> List[GenerationRequest]:
        """Pop up to ``limit`` pending requests whose ``key_of(req)`` equals
        ``head_key``, scanning in FIFO order (later non-matching requests
        keep their place)."""
        taken: List[GenerationRequest] = []
        kept: Deque[GenerationRequest] = collections.deque()
        while self._pending and len(taken) < limit:
            req = self._pending.popleft()
            if key_of(req) == head_key:
                taken.append(req)
            else:
                kept.append(req)
        kept.extend(self._pending)
        self._pending = kept
        return taken
