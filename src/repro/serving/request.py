"""Serving request/result types and the FIFO request queue.

A ``GenerationRequest`` is one user-facing generation job: which arch to
run (the config family picks the serving paradigm -- diffusion sampling
or autoregressive decoding, see ``serving/servable.py``), how many DDIM
steps or generated tokens, which protection mode, and which DVFS
operating point -- ``"auto"`` delegates the choice to the engine's
shared BER-monitor ladder (Sec 5.1). Since PR 3 a request also carries its
*scheduling* contract -- ``priority``, ``deadline_s``, ``step_budget`` --
which the deadline-aware scheduler (``serving/scheduler.py``) turns into a
concrete (operating point, step count) assignment at admission time.
Results come back as structured ``RequestResult`` records (quality vs the
clean reference, energy/latency attribution, monitor state, deadline
bookkeeping) instead of prints; streaming runs additionally yield
``PreviewEvent`` records between denoising windows.

Time base: deadlines and completion stamps are measured on the engine's
**virtual clock** (``DriftServeEngine.clock_s``), which advances by the
perfmodel latency of each served batch -- i.e. seconds on the *modeled
accelerator*, not host wall-clock. That keeps deadline semantics
meaningful (the host runs smoke models on CPU) and deterministic in tests.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional, Union

from repro.core.dvfs import OP_LADDER
from repro.core.exec_ctx import MODES
from repro.core.quant import PRECISION_PLANS
from repro.core.rollback import DEFAULT_INTERVAL

# Operating points a request may name; "auto" resolves against the engine's
# BER-monitor ladder at batch-formation time. The intermediate ladder
# points (uv-mild/uv-safe/near-nominal) are requestable too -- the
# scheduler's frontier resolution assigns them, and anything the engine
# can be assigned a user may also ask for directly.
REQUEST_OPS = ("nominal", "undervolt", "overclock", "auto") + tuple(
    p.name for p in OP_LADDER
    if p.name not in ("nominal", "undervolt", "overclock"))

# Scheduling classes, most to least urgent. The priority batcher serves
# "interactive" buckets before "standard" before "background"; within a
# class, earlier deadlines first, then FIFO. Background requests are the
# ones the scheduler may leave on the energy-saving DVFS ladder.
REQUEST_PRIORITIES = ("interactive", "standard", "background")
PRIORITY_RANK = {name: i for i, name in enumerate(REQUEST_PRIORITIES)}


@dataclasses.dataclass(frozen=True)
class GenerationRequest:
    """One queued generation job. Frozen: the queue hands out copies only."""
    request_id: int
    arch: str = "dit-xl-512"
    smoke: bool = True
    steps: int = 10
    mode: str = "drift"            # exec_ctx.MODES member
    op: str = "undervolt"          # REQUEST_OPS member
    seed: int = 0                  # drives this request's initial latents
    taylorseer: bool = False
    # Precision-plan name (core.quant.PRECISION_PLANS). "int8" is the
    # baseline (today's path, bit for bit); narrowed plans drop the
    # resilient body blocks to fewer bits on resilient timesteps. Usually
    # chosen by the scheduler's frontier resolution, but requestable
    # directly like ``op``.
    precision: str = "int8"
    # Checkpoint-refresh cadence for rollback-ABFT. An int pins it;
    # "auto" defers to the engine's offload planner, which picks the
    # interval per (arch, op, steps, bucket) from the perfmodel and the
    # telemetry detection history (repro.serving.offload.planner) --
    # resolved to a concrete int at batch formation, like op="auto".
    rollback_interval: Union[int, str] = DEFAULT_INTERVAL
    # --- scheduling contract (see serving/scheduler.py, docs/scheduler.md)
    priority: str = "standard"     # REQUEST_PRIORITIES member
    # Relative deadline in engine virtual seconds (perfmodel time) counted
    # from submission; None = no deadline. The plain engine only *accounts*
    # misses; admission control / degradation needs the DeadlineScheduler.
    deadline_s: Optional[float] = None
    # User-requested cap on denoising steps (DiffPro-style quality knob).
    # The engine clamps ``steps`` to it at submit(); the scheduler may trim
    # further (never below its ``min_steps``) to meet a deadline.
    step_budget: Optional[int] = None
    # Energy budget in Joules for this request's share of its batch; None =
    # unconstrained. With a deadline, the scheduler's frontier resolution
    # picks the minimum-energy frontier point meeting the deadline (the
    # budget filters candidates); alone, it caps the frontier search.
    energy_budget_j: Optional[float] = None
    # Minimum acceptable quality proxy in (0, 1] (serving.frontier's scale,
    # 1.0 = as-requested full fidelity); None = no floor. Triggers frontier
    # resolution: minimum-latency point at or above the floor.
    quality_floor: Optional[float] = None
    # Engine virtual-clock stamp at submission; set by the engine, used for
    # deadline-miss accounting and scheduler aging. Not a user field.
    submitted_at_s: float = 0.0

    def __post_init__(self):
        if self.op not in REQUEST_OPS:
            raise ValueError(
                f"unknown operating point {self.op!r}; one of {REQUEST_OPS}")
        if self.mode not in MODES:
            raise ValueError(
                f"unknown DRIFT mode {self.mode!r}; one of {MODES}")
        if self.priority not in REQUEST_PRIORITIES:
            raise ValueError(
                f"unknown priority {self.priority!r}; one of "
                f"{REQUEST_PRIORITIES}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")
        if self.step_budget is not None and self.step_budget < 1:
            raise ValueError(
                f"step_budget must be >= 1, got {self.step_budget}")
        if self.precision not in PRECISION_PLANS:
            raise ValueError(
                f"unknown precision plan {self.precision!r}; one of "
                f"{tuple(PRECISION_PLANS)}")
        if self.energy_budget_j is not None and self.energy_budget_j <= 0:
            raise ValueError(
                f"energy_budget_j must be > 0, got {self.energy_budget_j}")
        if self.quality_floor is not None and not (
                0.0 < self.quality_floor <= 1.0):
            raise ValueError(
                f"quality_floor must be in (0, 1], got {self.quality_floor}")
        if isinstance(self.rollback_interval, str):
            if self.rollback_interval != "auto":
                raise ValueError(
                    f"rollback_interval must be an int >= 1 or 'auto', "
                    f"got {self.rollback_interval!r}")
        elif self.rollback_interval < 1:
            raise ValueError(
                f"rollback_interval must be >= 1, got "
                f"{self.rollback_interval}")

    @property
    def absolute_deadline_s(self) -> Optional[float]:
        """Deadline on the engine's virtual clock, or None."""
        if self.deadline_s is None:
            return None
        return self.submitted_at_s + self.deadline_s


@dataclasses.dataclass(frozen=True)
class PreviewEvent:
    """One streamed intermediate result: a request's slot of the batch
    latents after ``step`` of ``total_steps`` denoising steps. Yielded by
    ``DriftServeEngine.run_stream`` between windows; the matching
    ``RequestResult`` follows once the batch finishes."""
    request_id: int
    batch_index: int
    step: int                      # completed denoising steps (1-based)
    total_steps: int
    latents: object                # (H, W, C), clipped to [-1, 1]


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """Structured per-request outcome of one engine run."""
    request_id: int
    batch_index: int               # which micro-batch served this request
    bucket_size: int
    op: str                        # resolved operating-point name
    mode: str
    steps: int
    # quality vs the cached clean reference (same latents, BER 0)
    lpips_vs_clean: float
    psnr_vs_clean_db: float
    # rollback-corrected elements summed over the WHOLE batch tensor
    # (including padded slots) -- the sampler reports one scalar per scan,
    # so this cannot be split per request; don't sum it across results.
    batch_corrected_elems: int
    # computed denoising steps for this request's sample (identical for
    # every request in the batch; < steps when TaylorSeer forecasts)
    n_model_evals: int
    # perfmodel attribution (full-arch energy model, bucket cost split
    # across live requests; latency is the shared batch latency)
    energy_j: float
    latency_s: float
    baseline_energy_j: float
    baseline_latency_s: float
    # BER-monitor state after this request's batch
    monitor_ber: float
    monitor_op_index: int
    # knobs the batch actually ran under (frontier resolution may have
    # chosen them; as-requested runs echo the request's fields)
    taylorseer: bool = False
    precision: str = "int8"
    # this request's generated sample: its slot of the batch output latents,
    # clipped to [-1, 1], shape (H, W, C). Optional so metric-only fakes in
    # tests stay cheap; the real engine always fills it.
    latents: Optional[object] = None
    # --- autoregressive results (None/0 on diffusion requests; see
    # docs/servable.md). For AR requests ``lpips_vs_clean`` holds the
    # token-mismatch fraction and ``psnr_vs_clean_db`` its -10*log10, so
    # quality dashboards keep one schema across paradigms.
    tokens: Optional[tuple] = None         # generated token ids
    token_match_vs_clean: Optional[float] = None
    ar_detections: int = 0                 # statistical-ABFT flagged rows
    ar_rollbacks: int = 0                  # KV windows reverted + replayed
    # --- deadline bookkeeping (engine virtual clock, see module docstring)
    priority: str = "standard"
    deadline_s: Optional[float] = None     # the request's relative deadline
    completed_at_s: float = 0.0            # engine clock after this batch
    queue_wait_s: float = 0.0              # completed_at - submitted - batch
    deadline_missed: bool = False
    # --- resilience heatmap (batch-level, like batch_corrected_elems: the
    # detection counts are batch-tensor sums and cannot be split per
    # request). Nested tuple of ints, rows = detection sites (labeled by
    # ``detect_heatmap_blocks``; row 0 is the embedding/conditioning GEMMs
    # for DiT archs, AR decodes report one "all" row), cols = timestep
    # bins (docs/tracing.md). None when the batch produced no heatmap
    # (unmonitored modes, stub samplers in tests).
    detect_heatmap: Optional[tuple] = None
    detect_heatmap_blocks: Optional[tuple] = None
    # --- energy ledger (docs/slo.md): this request's share of the batch
    # cost, split over perfmodel.energy.ENERGY_COMPONENTS. The fixed-order
    # component sum equals ``energy_j`` bitwise (ledger_total); None only
    # from metric-only fakes in tests -- the engine always fills it.
    energy_breakdown: Optional[dict] = None


class RequestQueue:
    """FIFO queue assigning monotonically increasing request ids.

    The queue itself stays strictly FIFO; *scheduling order* is imposed from
    outside via ``take_matching``, which can extract any same-configuration
    subset while preserving the relative order of everything left behind.
    The priority batcher (``serving.scheduler.PriorityMicroBatcher``) picks
    its bucket seed from ``pending()`` and leaves FIFO as the tie-break.
    """

    def __init__(self) -> None:
        self._pending: Deque[GenerationRequest] = collections.deque()
        self._next_id = 0

    def submit(self, **fields) -> int:
        """Append one request, assigning the next id. ``fields`` are
        ``GenerationRequest`` fields (validated by its ``__post_init__``)."""
        req = GenerationRequest(request_id=self._next_id, **fields)
        self._next_id += 1
        self._pending.append(req)
        return req.request_id

    def __len__(self) -> int:
        return len(self._pending)

    def peek(self) -> Optional[GenerationRequest]:
        """Head of the FIFO without removing it; None when empty."""
        return self._pending[0] if self._pending else None

    def pending(self) -> tuple:
        """Immutable snapshot of pending requests in FIFO order. Used by
        priority batch formation and admission-control backlog projection;
        mutating the queue invalidates nothing (the snapshot is a copy)."""
        return tuple(self._pending)

    def take_matching(self, head_key, key_of, limit: int, rank=None
                      ) -> List[GenerationRequest]:
        """Pop up to ``limit`` pending requests whose ``key_of(req)`` equals
        ``head_key``.

        This is the bucketing primitive: ``key_of`` is the batcher's
        resolved ``SamplerKey`` function, so "matching" means *may share a
        compiled sampler invocation* (same arch/steps/mode/resolved op/
        bucket/mesh placement -- see ``batcher.request_key``). Guarantees:

        * without ``rank``, matches are chosen AND returned in FIFO order
          (submission order within the configuration);
        * with ``rank`` (the priority batcher's urgency key), the ``limit``
          *most urgent* matches are chosen -- an interactive request and a
          background request share a key, and an urgent seed must not pull
          older background work into its bucket ahead of its peers. Ties
          break FIFO (the sort is stable), and the returned bucket is
          re-ordered FIFO so slot assignment stays deterministic;
        * non-matching (and unchosen matching) requests keep their
          relative queue positions -- a later bucket for their
          configuration sees them in the original order;
        * ``head_key`` need not belong to the queue head: the priority
          batcher seeds it from the most urgent pending request, and the
          scan still sweeps the whole queue for co-batchable matches;
        * at most ``limit`` (the bucket size) requests are taken, even if
          more match; the remainder stay queued for the next bucket.
        """
        if rank is not None:
            matches = [r for r in self._pending if key_of(r) == head_key]
            chosen = sorted(matches, key=rank)[:limit]
            chosen_ids = {r.request_id for r in chosen}
            self._pending = collections.deque(
                r for r in self._pending if r.request_id not in chosen_ids)
            return sorted(chosen, key=lambda r: r.request_id)
        taken: List[GenerationRequest] = []
        kept: Deque[GenerationRequest] = collections.deque()
        while self._pending and len(taken) < limit:
            req = self._pending.popleft()
            if key_of(req) == head_key:
                taken.append(req)
            else:
                kept.append(req)
        kept.extend(self._pending)
        self._pending = kept
        return taken
