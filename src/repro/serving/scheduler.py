"""Deadline- and priority-aware scheduling on top of the DRIFT engine.

The engine gives every request two orthogonal quality/cost levers:

* the **DVFS operating point** (DRIFT Sec 5.1/5.2): undervolt saves ~36%
  energy at equal speed, overclock runs ~1.75x faster at nominal-ish
  energy -- reliability is bought back by ABFT + rollback either way;
* the **denoising step budget** (DiffPro-style): fewer DDIM steps cost
  proportionally less latency and energy at some quality loss.

``DeadlineScheduler`` navigates both jointly, per request, against an
admission-control projection of the queue:

1.  **Projection.** A request's completion time is estimated on the
    engine's virtual clock as ``clock + backlog + own batch latency``.
    Batch latencies come from the engine telemetry's **learned
    estimator** (EWMA + conservative percentile over served-batch
    history, per (arch, op, steps, bucket) plus mode/taylorseer/
    rollback-interval discriminators -- ``serving/telemetry``)
    when that configuration has history, and otherwise fall back to the
    same perfmodel the engine bills with
    (``perfmodel.energy.run_cost``) -- with no history the two paths
    are bit-identical, so a fresh scheduler behaves exactly like the
    pre-telemetry one. The backlog counts only pending requests that
    will be served *before* the newcomer under priority order.
2.  **Policy.** Given the time left after the backlog, pick (op, steps):
    keep the request as submitted if it fits; otherwise escalate the
    operating point to ``overclock`` (speed mode); otherwise trim steps at
    overclock down to ``SchedulerConfig.min_steps``; otherwise the request
    is hopeless -- reject it (default) or admit it flagged as a projected
    miss. Requests without a deadline are never touched: background work
    keeps its energy-saving ladder (``op="auto"`` stays auto).
3.  **Formation.** ``PriorityMicroBatcher`` seeds each bucket from the
    most urgent pending request -- (priority rank, absolute deadline,
    FIFO) -- instead of the queue head, with an aging escape hatch: any
    request that has waited longer than ``age_s`` virtual seconds is
    promoted to top rank, so a steady interactive stream cannot starve
    background work forever.

The scheduler *rewrites* the admitted request's ``op``/``steps`` fields,
so its assignment flows into ``SamplerKey`` bucketing and the perfmodel
accounting with no engine changes; ``priority``/``deadline_s`` ride along
for formation order and miss bookkeeping. Everything is deterministic:
time is the engine's virtual clock (modeled-accelerator seconds), never
host wall-clock.

Worked example and the full policy table: ``docs/scheduler.md``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

from repro.core import dvfs as dvfs_lib
from repro.core import rollback as rollback_lib
from repro.perfmodel import energy
from repro.serving import frontier as frontier_lib
from repro.serving import servable as servable_lib
from repro.serving.batcher import MicroBatch, MicroBatcher, request_key
from repro.serving.engine import OP_BY_NAME, DriftServeEngine
from repro.serving.request import (PRIORITY_RANK, GenerationRequest,
                                   RequestQueue)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Knobs for admission control and batch formation."""
    # Floor for deadline-driven step trimming: below this the sample is
    # assumed too degraded to be worth serving (DiffPro's observation that
    # quality collapses under a handful of steps).
    min_steps: int = 4
    # Reject requests whose deadline cannot be met even at (overclock,
    # min_steps); False admits them flagged as projected misses instead.
    reject_hopeless: bool = True
    # A pending request older than this (virtual seconds) is treated as
    # top priority by the batcher regardless of its class -- the
    # starvation guard. None disables aging.
    age_s: Optional[float] = 1.0
    # Consult the engine telemetry's learned latency estimator before the
    # perfmodel (False pins admission to the perfmodel clock even with
    # telemetry on; with empty history the two are bit-identical anyway).
    use_learned_latency: bool = True


@dataclasses.dataclass(frozen=True)
class Admission:
    """Outcome of one admission decision."""
    admitted: bool
    # Concrete assignment for admitted requests (echoes the request for
    # rejected ones, for the record).
    op: str
    steps: int
    # "as-requested" | "escalated-op" | "trimmed-steps" | "frontier"
    # | "projected-miss" | "rejected"
    action: str
    # Projected wait behind the existing queue and projected completion
    # latency (wait + own batch), both in engine virtual seconds. None
    # when the request has no deadline (no projection is computed).
    projected_wait_s: Optional[float] = None
    projected_total_s: Optional[float] = None
    request_id: int = -1           # -1 = rejected, never enqueued
    reason: str = ""
    # Frontier-chosen knobs beyond (op, steps); ladder decisions echo the
    # request's own fields so the submit rewrite is uniform.
    precision: str = "int8"
    taylorseer: bool = False
    # Frontier projections (None for ladder decisions): the picked
    # point's per-request energy share and quality proxy.
    projected_energy_j: Optional[float] = None
    quality: Optional[float] = None


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    escalated_op: int = 0          # op bumped to overclock for a deadline
    trimmed_steps: int = 0         # step budget cut for a deadline
    frontier_selected: int = 0     # compute-optimal frontier picks
    projected_misses: int = 0      # admitted although projected to miss


class PriorityMicroBatcher(MicroBatcher):
    """MicroBatcher that seeds each bucket from the most urgent pending
    request instead of the FIFO head.

    Urgency is whatever ``urgency(req)`` sorts first -- the scheduler
    supplies (priority rank with aging, absolute deadline, request id).
    The seed's resolved ``SamplerKey`` is swept through the whole queue
    (``take_matching``) and the bucket is filled with the most urgent
    matches (scheduler fields are not part of the key, so an interactive
    and a background request share a configuration -- the urgency ranking,
    not FIFO, decides who rides the urgent bucket). Non-matching and
    unchosen requests keep their FIFO positions.
    """

    def __init__(self, bucket: int,
                 key_extra: Optional[Dict[str, object]] = None,
                 urgency: Optional[Callable[[GenerationRequest], Tuple]]
                 = None) -> None:
        super().__init__(bucket, key_extra=key_extra)
        self._urgency = urgency or (lambda r: r.request_id)

    def next_batch(self, queue: RequestQueue,
                   resolve_op: Callable[[GenerationRequest], str],
                   resolve_interval: Optional[
                       Callable[[GenerationRequest], int]] = None
                   ) -> MicroBatch:
        pending = queue.pending()
        assert pending, "next_batch on an empty queue"
        seed = min(pending, key=self._urgency)
        key_of = lambda r: request_key(
            r, self.bucket, resolve_op(r), self.key_extra,
            resolve_interval(r) if resolve_interval is not None else None)
        key = key_of(seed)
        reqs = queue.take_matching(key, key_of, self.bucket,
                                   rank=self._urgency)
        return MicroBatch(key=key, requests=reqs)


class DeadlineScheduler:
    """Admission control + priority batch formation around one engine.

    Wraps an existing ``DriftServeEngine`` (or the sharded subclass):
    replaces its batcher with a ``PriorityMicroBatcher`` and funnels
    submissions through :meth:`submit`, which returns an :class:`Admission`
    record instead of a bare id. ``run()``/``run_stream()`` delegate to the
    engine unchanged -- results and previews come back exactly as without
    the scheduler, plus the deadline bookkeeping the engine already stamps.

    With no deadlines and uniform priorities the scheduler is behaviorally
    identical to the bare engine (the urgency sort degenerates to FIFO),
    so launchers can wrap unconditionally.
    """

    def __init__(self, engine: DriftServeEngine,
                 config: Optional[SchedulerConfig] = None) -> None:
        self.engine = engine
        self.cfg = config or SchedulerConfig()
        self.stats = SchedulerStats()
        engine.batcher = PriorityMicroBatcher(
            engine.batcher.bucket, key_extra=engine.batcher.key_extra,
            urgency=self._urgency)
        # Modeled-latency memo (run_cost is pure arithmetic but admission
        # sits on the submit path). Keyed on the *operating-point
        # parameters* -- (arch, voltage, frequency, steps, bucket,
        # nominal_steps) -- never on a request-facing name: "auto"
        # resolves through the monitor ladder and the guardband floor, so
        # a name-keyed memo would keep serving the latency of whatever
        # point "auto" meant at first call after the ladder adapts.
        # Learned estimates are never memoized here (history moves every
        # batch; the estimator lookup is O(1) anyway).
        self._latency_cache: Dict[
            Tuple[str, float, float, int, int, int], float] = {}
        # Compute-optimal frontier builder (serving/frontier.py), built
        # lazily against the engine's energy model so deadline-only
        # workloads never pay the calibration.
        self._frontier_builder: Optional[frontier_lib.FrontierBuilder] = \
            None
        # Decision-audit scratch: _plan_frontier stashes the candidate
        # set it considered here so submit() can attach it to the
        # request's "admission" span (docs/tracing.md). Reset per plan().
        self._frontier_audit: Optional[Dict[str, object]] = None

    # ------------------------------------------------------------- intake
    def submit(self, **fields) -> Admission:
        """Plan and (maybe) enqueue one request; returns the decision.

        ``fields`` are ``GenerationRequest`` fields as for
        ``engine.submit``. Admitted requests are enqueued with the planned
        ``(op, steps)`` rewritten in; rejected ones never touch the queue.
        """
        self.stats.submitted += 1
        eng = self.engine
        fields.setdefault("arch", eng.default_arch)
        fields.setdefault("smoke", eng.default_smoke)
        fields.setdefault("submitted_at_s", eng.clock_s)
        # Probe request: normalizes defaults + runs field validation once.
        try:
            probe = GenerationRequest(request_id=-1, **fields)
        except (TypeError, ValueError) as exc:
            eng.telemetry.on_rejection("validation")
            eng.tracer.record("admission", "admission",
                              t0_virtual_s=eng.clock_s, admitted=False,
                              action="rejected",
                              reason=f"validation: {exc}")
            raise
        adm = self.plan(probe)
        eng.telemetry.on_admission(adm.action)
        if not adm.admitted:
            self.stats.rejected += 1
            wants_frontier = (probe.energy_budget_j is not None
                              or probe.quality_floor is not None)
            eng.telemetry.on_rejection(
                "budget-infeasible" if wants_frontier else "projected-miss")
            self._record_decision(adm, request_id=-1)
            return adm
        self.stats.admitted += 1
        if adm.action == "escalated-op":
            self.stats.escalated_op += 1
        elif adm.action == "trimmed-steps":
            self.stats.trimmed_steps += 1
        elif adm.action == "frontier":
            self.stats.frontier_selected += 1
        elif adm.action == "projected-miss":
            self.stats.projected_misses += 1
        rewrite = {**fields, "op": adm.op, "steps": adm.steps}
        if adm.action == "frontier":
            # the frontier owns ALL four knobs; ladder decisions leave the
            # request's own precision/taylorseer untouched
            rewrite["precision"] = adm.precision
            rewrite["taylorseer"] = adm.taylorseer
        rid = eng.submit(**rewrite)
        adm = dataclasses.replace(adm, request_id=rid)
        self._record_decision(adm, request_id=rid)
        return adm

    def _record_decision(self, adm: Admission, request_id: int) -> None:
        """Decision audit (docs/tracing.md): one ``admission`` span per
        planned request in the engine's flight recorder, carrying the
        full :class:`Admission` record -- and, when a frontier objective
        was consulted, the candidate set ``_plan_frontier`` weighed --
        so every ``action="frontier"`` rewrite (and every fallback) is
        reconstructible from the trace alone."""
        eng = self.engine
        attrs: Dict[str, object] = dict(
            admitted=adm.admitted, action=adm.action, op=adm.op,
            steps=adm.steps, precision=adm.precision,
            taylorseer=adm.taylorseer)
        if adm.reason:
            attrs["reason"] = adm.reason
        if adm.projected_wait_s is not None:
            attrs["projected_wait_s"] = adm.projected_wait_s
            attrs["projected_total_s"] = adm.projected_total_s
        if adm.projected_energy_j is not None:
            attrs["projected_energy_j"] = adm.projected_energy_j
            attrs["quality"] = adm.quality
        # SLO context at decision time (docs/slo.md): which objectives
        # were burning when this admission was taken, so a post-hoc audit
        # can tell "admitted into a healthy fleet" from "admitted while
        # the energy budget was already breached".
        slo = getattr(eng.telemetry, "slo", None)
        if slo is not None and slo.any_breached:
            attrs["slo_breached"] = list(slo.breached_objectives())
        if self._frontier_audit is not None:
            if adm.action == "frontier":
                attrs.update(self._frontier_audit)
            else:
                # unsatisfiable objective that fell back to the ladder
                # (or to rejection): keep the evidence of what was
                # considered next to the fallback decision
                attrs["frontier_fallback"] = dict(self._frontier_audit)
        ids = () if request_id < 0 else (request_id,)
        eng.tracer.record("admission", "admission", request_ids=ids,
                          t0_virtual_s=eng.clock_s, **attrs)

    # ------------------------------------------------------------- policy
    def plan(self, req: GenerationRequest) -> Admission:
        """Joint (operating point, step count) assignment for one request.

        Requests stating a frontier objective (``energy_budget_j`` /
        ``quality_floor``) resolve against the compute-optimal
        (steps x precision x TaylorSeer x DVFS) Pareto frontier first --
        minimum energy meeting the deadline, minimum latency meeting the
        quality floor, or maximum quality inside the budget -- and fall
        back to the PR 3 ladder when no frontier point qualifies.

        The ladder, cheapest first (see docs/scheduler.md for the
        table): as-requested -> overclock at full steps -> overclock with
        trimmed steps -> reject / projected-miss.
        """
        cap = req.steps if req.step_budget is None \
            else min(req.steps, req.step_budget)
        self._frontier_audit = None
        wants_frontier = (req.energy_budget_j is not None
                          or req.quality_floor is not None)
        if req.deadline_s is None:
            if wants_frontier:
                adm = self._plan_frontier(req, cap, wait=None, budget=None)
                if adm is not None:
                    return adm
            # No deadline: never touch the energy-saving assignment.
            # (Unsatisfiable floor/budget falls through here too --
            # best-effort as-requested, documented in docs/frontier.md.)
            return Admission(admitted=True, op=req.op, steps=cap,
                             action="as-requested")
        wait = self.projected_wait_s(req)
        budget = req.deadline_s - wait     # time left for the own batch
        if wants_frontier:
            adm = self._plan_frontier(req, cap, wait=wait, budget=budget)
            if adm is not None:
                return adm
            # no qualifying frontier point: the existing escalation
            # ladder decides (including reject / projected-miss)
        disc = self._discriminators(req)
        candidates = [(req.op, cap, "as-requested")]
        if self._concrete_op(req.op) != "overclock":
            candidates.append(("overclock", cap, "escalated-op"))
        for op_name, steps, action in candidates:
            lat = self.batch_latency_s(req.arch, op_name, steps, **disc)
            if lat <= budget:
                return Admission(admitted=True, op=op_name, steps=steps,
                                 action=action, projected_wait_s=wait,
                                 projected_total_s=wait + lat)
        floor = min(cap, self.cfg.min_steps)
        for steps in range(cap - 1, floor - 1, -1):
            lat = self.batch_latency_s(req.arch, "overclock", steps, **disc)
            if lat <= budget:
                return Admission(admitted=True, op="overclock", steps=steps,
                                 action="trimmed-steps",
                                 projected_wait_s=wait,
                                 projected_total_s=wait + lat)
        lat = self.batch_latency_s(req.arch, "overclock", floor, **disc)
        if self.cfg.reject_hopeless:
            return Admission(
                admitted=False, op=req.op, steps=cap, action="rejected",
                projected_wait_s=wait, projected_total_s=wait + lat,
                reason=(f"projected {wait + lat:.3f}s > deadline "
                        f"{req.deadline_s:.3f}s even at (overclock, "
                        f"{floor} steps)"))
        return Admission(admitted=True, op="overclock", steps=floor,
                         action="projected-miss", projected_wait_s=wait,
                         projected_total_s=wait + lat,
                         reason="admitted past its deadline "
                                "(reject_hopeless=False)")

    # ----------------------------------------------------------- frontier
    def frontier_builder(self) -> frontier_lib.FrontierBuilder:
        """The scheduler's (lazily built) frontier enumerator -- public so
        tests and benchmarks sweep the same memoized frontiers admission
        consults."""
        if self._frontier_builder is None:
            eng = self.engine
            self._frontier_builder = frontier_lib.FrontierBuilder(
                em=eng._energy_model_for(),
                nominal_steps=eng.nominal_steps,
                min_steps=self.cfg.min_steps)
        return self._frontier_builder

    def frontier_latency_s(self, req: GenerationRequest,
                           point: frontier_lib.FrontierPoint) -> float:
        """A frontier point's completion latency as the engine will bill
        it: the point's full-bucket perfmodel latency plus the residual
        offload stall for this configuration (0.0 offload-free)."""
        return point.latency_s + self.engine.offload_stall_s(
            req.arch, point.op, point.steps,
            self.engine.resolve_interval(req), req.mode)

    def _plan_frontier(self, req: GenerationRequest, cap: int,
                       wait: Optional[float],
                       budget: Optional[float]) -> Optional[Admission]:
        """Frontier resolution step: pick the compute-optimal knob point
        for a request with an ``energy_budget_j``/``quality_floor``
        objective, or None when no point qualifies (the caller falls back
        to the escalation ladder).

        Selection is provably optimal over the FULL knob space even
        though only the pruned Pareto set is searched: every constraint
        here is monotone in the objectives (deadline/budget cap two
        minimized axes, the floor bounds the maximized one), so any
        feasible dominated point has a dominating frontier point that is
        also feasible and at least as good under every objective below
        -- the brute-force equivalence test in tests/test_frontier.py
        checks exactly this.
        """
        if servable_lib.paradigm_for(req.arch) != "diffusion":
            # AR requests reject these knobs at engine.submit with a
            # reasoned error; never consult a diffusion frontier for them.
            return None
        eng = self.engine
        points = self.frontier_builder().frontier(
            eng._full_cfg(req.arch), cap, eng.batcher.bucket, req.mode,
            eng.resolve_interval(req))
        lat = {p: self.frontier_latency_s(req, p) for p in points}
        ok = [p for p in points
              if (req.quality_floor is None
                  or p.quality >= req.quality_floor - 1e-12)
              and (req.energy_budget_j is None
                   or p.energy_j <= req.energy_budget_j + 1e-12)
              and (budget is None or lat[p] <= budget)]
        # Audit record for the admission span: every Pareto point that
        # was on the table, rendered compactly (the frontier is the
        # pruned set, typically a handful of points).
        self._frontier_audit = dict(
            frontier_points=len(points), frontier_ok=len(ok),
            frontier_considered=tuple(
                f"{p.op}/{p.steps}st/{p.precision}"
                + ("/ts" if p.taylorseer else "")
                + f" q={p.quality:.4f} e={p.energy_j:.4g}J"
                  f" l={lat[p]:.4g}s"
                for p in points))
        if not ok:
            return None
        if budget is not None:
            # deadline-constrained: cheapest energy that makes it in time
            objective = "min-energy"
            pick = min(ok, key=lambda p: (p.energy_j, -p.quality, lat[p],
                                          frontier_lib.sort_key(p)))
        elif req.quality_floor is not None:
            # quality floor, no deadline: fastest point at/above the floor
            objective = "min-latency"
            pick = min(ok, key=lambda p: (lat[p], -p.quality, p.energy_j,
                                          frontier_lib.sort_key(p)))
        else:
            # budget only: best quality the budget buys
            objective = "max-quality"
            pick = min(ok, key=lambda p: (-p.quality, p.energy_j, lat[p],
                                          frontier_lib.sort_key(p)))
        eng.telemetry.on_frontier_choice(objective, len(points))
        self._frontier_audit.update(
            objective=objective,
            chosen=(f"{pick.op}/{pick.steps}st/{pick.precision}"
                    + ("/ts" if pick.taylorseer else "")))
        return Admission(
            admitted=True, op=pick.op, steps=pick.steps, action="frontier",
            projected_wait_s=wait,
            projected_total_s=None if wait is None else wait + lat[pick],
            precision=pick.precision, taylorseer=pick.taylorseer,
            projected_energy_j=pick.energy_j, quality=pick.quality)

    # --------------------------------------------------------- projection
    def projected_wait_s(self, req: GenerationRequest) -> float:
        """Modeled time until ``req``'s bucket could start: the batch
        latencies of every pending request that outranks it, grouped into
        same-configuration buckets of the engine's bucket size.

        Approximations, on purpose (documented in docs/scheduler.md): the
        newcomer is assumed to open its own bucket (no co-batching credit),
        ``auto`` ops are priced at the monitor's current ladder point, and
        aging promotions between now and formation are ignored. All errors
        are conservative or second-order for admission purposes.
        """
        mine = self._urgency(req, _tiebreak=math.inf)
        ahead: Dict[Tuple, int] = {}
        for r in self.engine.queue.pending():
            if self._urgency(r) < mine:
                k = (r.arch, self._concrete_op(r.op), r.steps,
                     tuple(sorted(self._discriminators(r).items())))
                ahead[k] = ahead.get(k, 0) + 1
        bucket = self.engine.batcher.bucket
        wait = 0.0
        for (arch, op_name, steps, disc), n in ahead.items():
            n_batches = -(-n // bucket)            # ceil
            wait += n_batches * self.batch_latency_s(arch, op_name, steps,
                                                     **dict(disc))
        return wait

    def _discriminators(self, req: GenerationRequest) -> Dict[str, object]:
        """Learned-estimator key discriminators beyond (arch, op, steps,
        bucket): fields that change a batch's billed latency without
        changing its perfmodel admission price (the fallback deliberately
        ignores them to stay bit-identical to the pre-telemetry path).
        ``rollback_interval="auto"`` resolves through the engine's offload
        planner here, so projections price the interval that will actually
        run -- the same single-resolution contract as ``op="auto"``."""
        return {"mode": req.mode, "taylorseer": req.taylorseer,
                "rollback_interval": self.engine.resolve_interval(req),
                "precision": req.precision}

    def batch_latency_s(self, arch: str, op_name: str, steps: int,
                        **disc) -> float:
        """Estimated latency of one full bucket of this configuration.

        Learned first: if the engine telemetry's estimator has
        served-batch history for (arch, resolved op, steps, bucket) --
        plus the ``disc`` discriminators (mode, taylorseer,
        rollback_interval; defaulting to the standard drift
        configuration) -- its estimate wins: measured, not modeled,
        cost. Otherwise the perfmodel fallback is the same
        ``energy.run_cost`` call (full-size arch, batch = bucket) the
        engine bills results with and advances its clock by, memoized on
        operating-point *parameters* so ladder/guardband adaptation of
        "auto" can never be served a stale projection. With checkpoint
        offload enabled the perfmodel path additionally charges the
        planner's residual refresh stall (``engine.offload_stall_s``) --
        the same term the engine adds to its virtual clock -- while the
        learned path already sees it inside observed batch latencies."""
        eng = self.engine
        concrete = self._concrete_op(op_name)
        bucket = eng.batcher.bucket
        tele = getattr(eng, "telemetry", None)
        if self.cfg.use_learned_latency and tele is not None:
            learned = tele.learned_latency_s(arch, concrete, steps, bucket,
                                             **disc)
            if learned is not None:
                tele.on_projection("learned")
                return learned
            tele.on_projection("perfmodel")
        op = OP_BY_NAME.get(concrete, dvfs_lib.NOMINAL)
        key = (arch, op.voltage, op.freq_ghz, steps, bucket,
               eng.nominal_steps)
        cached = self._latency_cache.get(key)
        if cached is None:
            rc = energy.RunConfig(num_steps=steps,
                                  nominal_steps=eng.nominal_steps,
                                  aggressive=op)
            cost = energy.run_cost(eng._full_cfg(arch), rc, batch=bucket,
                                   em=eng._energy_model_for())
            cached = self._latency_cache[key] = cost["latency_s"]
        # refresh stall is interval-dependent, so it stays outside the
        # operating-point memo (the engine memoizes it per configuration);
        # identically 0.0 on an offload-free engine -- the bit-identical
        # pre-offload projection
        return cached + eng.offload_stall_s(
            arch, concrete, steps,
            disc.get("rollback_interval", rollback_lib.DEFAULT_INTERVAL),
            disc.get("mode", "drift"))

    # ---------------------------------------------------------- formation
    def _concrete_op(self, op_name: str) -> str:
        """Resolve "auto" to the point it would run at right now --
        ``engine.auto_op_name()``, i.e. the monitor's ladder index floored
        by the telemetry guardband -- for cost estimation (the batcher
        re-resolves through the same method at formation time; the ladder
        rarely moves between admission and formation, and all ladder
        points share nominal frequency, so the latency estimate is exact
        anyway)."""
        if op_name == "auto":
            return self.engine.auto_op_name()
        return op_name

    def _urgency(self, req: GenerationRequest,
                 _tiebreak: Optional[float] = None) -> Tuple:
        """Sort key for batch formation: (priority rank, absolute deadline,
        FIFO). Aged-out requests jump to rank -1 -- ahead of everything --
        which is the starvation guard. ``_tiebreak`` overrides the id for
        not-yet-enqueued probes so equal-urgency incumbents sort ahead."""
        rank = PRIORITY_RANK[req.priority]
        if (self.cfg.age_s is not None
                and self.engine.clock_s - req.submitted_at_s
                >= self.cfg.age_s):
            rank = -1
        dl = req.absolute_deadline_s
        return (rank, math.inf if dl is None else dl,
                req.request_id if _tiebreak is None else _tiebreak)

    # ------------------------------------------------------------ serving
    def run(self):
        """Drain the queue through the engine (priority formation order,
        results in submission order -- see ``DriftServeEngine.run``)."""
        return self.engine.run()

    def run_stream(self, preview_interval: int = 1):
        """Streaming drain: ``PreviewEvent``s + ``RequestResult``s in
        priority formation order (see ``DriftServeEngine.run_stream``)."""
        return self.engine.run_stream(preview_interval)
