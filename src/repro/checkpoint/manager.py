"""Fault-tolerant training checkpoints: atomic, verified, elastic.

Framework-level fault tolerance (distinct from the paper's in-inference
rollback-ABFT): a run on thousands of nodes must survive preemption, node
loss, and restarts onto a *different* mesh. Design:

  * **Atomic**: leaves are written to ``step_XXXX.tmp/`` then the directory
    is os.rename'd -- a crash mid-write never corrupts the latest
    checkpoint. A MANIFEST.json records tree structure, shapes, dtypes and
    per-leaf SHA256.
  * **Verified restore**: hashes are checked on load; a corrupt checkpoint
    is skipped and the previous valid one used (restore_latest walks
    backwards).
  * **Elastic / reshard-on-restore**: leaves are stored unsharded
    (gathered); ``restore`` returns host numpy arrays which the caller
    device_puts with the *new* mesh's NamedShardings -- so restoring
    512-chip state onto 256 chips (or onto a different DP/TP split) is the
    default path, not a special case.
  * **Pipeline state**: the data pipeline is a deterministic function of
    (seed, step) (see data/synthetic.py), so checkpointing ``step`` fully
    captures it.

On a real multi-host deployment, writes go per-process for the local shards
(Orbax-style); this single-host implementation keeps the same protocol.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep_last: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # ----------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        leaves, treedef = _flatten(tree)
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "extra": extra or {},
            "leaves": [],
        }
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), leaf)
            manifest["leaves"].append({
                "shape": list(leaf.shape),
                "dtype": str(leaf.dtype),
                "sha256": _sha(leaf),
            })
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic publish
        self._gc()
        return final

    # -------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _load(self, step: int, template: Any) -> Tuple[Any, Dict]:
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        _, treedef = jax.tree_util.tree_flatten(template)
        leaves = []
        for i, meta in enumerate(manifest["leaves"]):
            a = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
            if _sha(a) != meta["sha256"]:
                raise IOError(f"hash mismatch in {path} leaf {i}")
            leaves.append(a)
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]

    def restore_latest(self, template: Any
                       ) -> Optional[Tuple[int, Any, Dict]]:
        """Walk back from the newest step until a checkpoint verifies."""
        for step in reversed(self.steps()):
            try:
                tree, extra = self._load(step, template)
                return step, tree, extra
            except (IOError, OSError, json.JSONDecodeError) as e:
                print(f"[ckpt] step {step} invalid ({e}); trying previous")
        return None

    def restore_resharded(self, template: Any, shardings: Any
                          ) -> Optional[Tuple[int, Any, Dict]]:
        """Restore + device_put onto (possibly different) mesh shardings."""
        got = self.restore_latest(template)
        if got is None:
            return None
        step, tree, extra = got
        tree = jax.tree.map(jax.device_put, tree, shardings)
        return step, tree, extra

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
