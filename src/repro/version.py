"""Single source of the reproduction's version string.

``src/repro`` is a namespace package (no ``__init__.py``), so the usual
``repro.__version__`` has nowhere to live; telemetry's ``drift_build_info``
gauge and the trace exporters import it from here instead.
"""
__version__ = "0.9.0"
