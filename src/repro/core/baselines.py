"""Prior-work error-mitigation baselines the paper compares against (Fig 12).

Each strategy consumes the same ABFT detection report (or its own detection
semantics) and produces (corrected_output, cost_info). Costs feed
``repro.perfmodel`` so Fig 12(b)(d)'s recovery-efficiency comparison is
reproducible.

  ThUnderVolt [13]  -- timing-error detection in the MAC pipeline; faulty
                       results are dropped (treated as zero). We model it as
                       zeroing every element of a flagged row/col cross.
  ApproxABFT  [19]  -- ABFT detection, anomalies zeroed out. Distinguished
                       from ThUnderVolt by zeroing only above-threshold
                       checksum rows/cols (same detector as DRIFT).
  DMR         [10]  -- dual modular redundancy: everything computed twice,
                       mismatches recomputed. Output always correct; cost 2x
                       compute + recompute on any detected flip.
  StatABFT    [21]  -- REALM-style: ABFT with a statistical threshold;
                       flagged *tiles* are recomputed (correct output),
                       cost = recompute of flagged tiles.
  DRIFT (ours)      -- rollback to checkpoint; cost = sparse DRAM reads only.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import abft as abft_lib


class RecoveryCost(NamedTuple):
    """Per-GEMM recovery accounting (relative units consumed by perfmodel)."""

    extra_compute_flops: jax.Array   # recomputation / redundancy FLOPs
    extra_dram_bytes: jax.Array      # checkpoint reads (rollback) etc.
    corrected_elems: jax.Array       # how many outputs were touched


def _zero_cost(corrected: jax.Array) -> RecoveryCost:
    return RecoveryCost(jnp.float32(0.0), jnp.float32(0.0), corrected)


def thundervolt(y: jax.Array, report: abft_lib.AbftReport) -> Tuple[jax.Array, RecoveryCost]:
    """Zero every flagged-row x flagged-col element (dropped MAC results)."""
    mask = abft_lib.correction_mask(report)
    out = jnp.where(mask, jnp.zeros_like(y), y)
    return out, _zero_cost(jnp.sum(mask.astype(jnp.int32)))


def approx_abft(y: jax.Array, report: abft_lib.AbftReport) -> Tuple[jax.Array, RecoveryCost]:
    """Zero detected anomalies (whole flagged rows and columns)."""
    row = report.row_flag[:, None]
    col = report.col_flag[None, :]
    mask = row | col
    out = jnp.where(mask, jnp.zeros_like(y), y)
    return out, _zero_cost(jnp.sum(mask.astype(jnp.int32)))


def dmr(y_clean: jax.Array, n_detected: jax.Array, gemm_flops: float
        ) -> Tuple[jax.Array, RecoveryCost]:
    """DMR recomputes on mismatch; output is the clean result by definition.

    Cost: the duplicate pass always runs (+1x FLOPs); every detected
    mismatch triggers a third (arbitration) pass over the full GEMM.
    """
    recompute = (n_detected > 0).astype(jnp.float32)
    cost = RecoveryCost(jnp.float32(gemm_flops) * (1.0 + recompute),
                        jnp.float32(0.0),
                        jnp.int32(0))
    return y_clean, cost


def stat_abft(y_clean: jax.Array, y_faulty: jax.Array, tile_flag: jax.Array,
              tile_elems: int, k_dim: int) -> Tuple[jax.Array, RecoveryCost]:
    """Recompute flagged tiles (REALM): correct values, tile-recompute cost."""
    # Expand tile flags to element granularity to splice clean values in.
    mt, nt = tile_flag.shape
    m, n = y_faulty.shape
    tm, tn = -(-m // mt), -(-n // nt)
    elem_flag = jnp.repeat(jnp.repeat(tile_flag, tm, axis=0), tn, axis=1)[:m, :n]
    out = jnp.where(elem_flag, y_clean, y_faulty)
    n_tiles = jnp.sum(tile_flag.astype(jnp.float32))
    cost = RecoveryCost(n_tiles * tile_elems * 2.0 * k_dim,
                        jnp.float32(0.0),
                        jnp.sum(elem_flag.astype(jnp.int32)))
    return out, cost


def drift_rollback(y: jax.Array, report: abft_lib.AbftReport,
                   checkpoint: Optional[jax.Array], have_ckpt: jax.Array,
                   bytes_per_elem: int = 4) -> Tuple[jax.Array, RecoveryCost]:
    """DRIFT: masked overwrite from checkpoint; cost = sparse DRAM reads."""
    from repro.core import rollback as rb
    mask = abft_lib.correction_mask(report)
    out = rb.correct(y, checkpoint, mask, have_ckpt)
    n = jnp.sum(mask.astype(jnp.int32))
    return out, RecoveryCost(jnp.float32(0.0),
                             n.astype(jnp.float32) * bytes_per_elem,
                             n)
