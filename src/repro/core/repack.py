"""Tile-contiguous data-layout repacking (Sec 5.4, Fig 10b).

Conventional row-major layouts scatter a (tm x tn) tile across tm different
DRAM rows; tile-wise recovery then pays tm row activations per corrected
tile. Repacking stores each tile as a contiguous 1-D run so a tile recovery
touches ceil(tile_bytes / dram_row_bytes) rows instead.

The transform itself is functional (and is exactly the layout a Pallas
BlockSpec-tiled kernel consumes, so on TPU the repack is free at kernel
boundaries); the row-activation *accounting* lives in perfmodel/dram.py.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def pad_to_tiles(x: jax.Array, tm: int, tn: int) -> jax.Array:
    m, n = x.shape
    return jnp.pad(x, ((0, (-m) % tm), (0, (-n) % tn)))


def repack(x: jax.Array, tm: int, tn: int) -> jax.Array:
    """(M, N) row-major -> (Mt, Nt, tm*tn) tile-contiguous."""
    xp = pad_to_tiles(x, tm, tn)
    mp, np_ = xp.shape
    mt, nt = mp // tm, np_ // tn
    return xp.reshape(mt, tm, nt, tn).transpose(0, 2, 1, 3).reshape(mt, nt, tm * tn)


def unpack(xt: jax.Array, shape: Tuple[int, int], tm: int, tn: int) -> jax.Array:
    """Inverse of ``repack`` (crops padding)."""
    mt, nt, _ = xt.shape
    x = xt.reshape(mt, nt, tm, tn).transpose(0, 2, 1, 3).reshape(mt * tm, nt * tn)
    return x[: shape[0], : shape[1]]


def gather_tiles(xt: jax.Array, tile_flag: jax.Array) -> jax.Array:
    """Select flagged tiles from a repacked tensor (recovery read set).

    Returns (n_tiles_padded, tm*tn) with unflagged rows zeroed -- the
    fixed-shape analogue of the recovery scheduler's coalesced read list.
    """
    flags = tile_flag.reshape(-1)
    flat = xt.reshape(flags.shape[0], -1)
    return jnp.where(flags[:, None], flat, jnp.zeros_like(flat))
