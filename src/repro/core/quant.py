"""Symmetric INT8 quantization with INT32 accumulation, plus the
resilience-aware precision *plans* the serving frontier trades against
steps/DVFS (DiffPro-style joint timestep + precision optimization).

The paper (Sec 3.2) quantizes weights and input activations to INT8 and
injects faults into the INT32 output accumulators, following SmoothQuant-style
symmetric quantization practice [49]. This module provides the functional
quantized-GEMM path every DRIFT-protected matmul runs through.

Bit convention: bit 0 is the LSB of the INT32 accumulator; "the 10th bit"
threshold of the paper corresponds to ``threshold = 2**10`` on the
de-scaled-integer domain.

Precision plans (:class:`PrecisionPlan`, ``PRECISION_PLANS``) extend the
Sec 5.2 resilience story to bit width: the error-*sensitive* sites the
existing metrics rank (embedding/first-block GEMMs -- ``CLASS_EMBED`` /
``CLASS_FIRST_BLOCK`` in ``core.dvfs`` -- and the first ``nominal_steps``
timesteps) always stay at the baseline INT8, while the resilient body
blocks on resilient timesteps may narrow to fewer bits. The default plan
(``"int8"``) IS today's path -- no extra narrowing anywhere -- so code
threading a plan through is bit-identical to pre-plan code unless a
narrowed plan is explicitly chosen. Execution simulates narrowing at the
model-output (eps) level via :func:`fake_quant` (the output-level
simplification of layer-wise mixed precision, same level TaylorSeer
caches at); the energy/latency accounting uses the layer-wise bit widths
(``perfmodel.flops.mac_bit_energy_scale`` / ``mac_bit_time_scale``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0

#: Baseline GEMM operand width: the paper's INT8 path.
BASE_BITS = 8


@dataclasses.dataclass(frozen=True)
class QTensor:
    """An int8 tensor plus its (broadcastable) float32 scale."""

    q: jax.Array  # int8
    scale: jax.Array  # f32, broadcastable against q

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale


def quantize(x: jax.Array, axis: Optional[int] = None) -> QTensor:
    """Symmetric int8 quantization.

    axis=None  -> per-tensor scale.
    axis=k     -> per-channel scales along ``k`` (scale shape keeps dim k).
    """
    if axis is None:
        amax = jnp.max(jnp.abs(x))
        scale = jnp.maximum(amax, 1e-8) / INT8_MAX
        scale = scale[None] if x.ndim == 0 else scale
    else:
        reduce_axes = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
        amax = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)
        scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return QTensor(q=q, scale=jnp.asarray(scale, jnp.float32))


def int32_matmul(aq: jax.Array, bq: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 GEMM (the systolic-array accumulate).

    Contracts the last dim of ``aq`` with the first dim of ``bq``.
    """
    return jax.lax.dot_general(
        aq,
        bq,
        dimension_numbers=(((aq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def dequantize_matmul(acc: jax.Array, a_scale: jax.Array, b_scale: jax.Array) -> jax.Array:
    """De-scale an int32 accumulator back to float32.

    a_scale broadcasts over rows (per-tensor or per-row), b_scale over the
    output columns (per-tensor or per-column).
    """
    return acc.astype(jnp.float32) * a_scale * b_scale


def quantized_matmul(x: jax.Array, w: jax.Array) -> Tuple[jax.Array, QTensor, QTensor, jax.Array]:
    """Full quantized GEMM: returns (y_f32, x_q, w_q, acc_int32).

    x: (..., K)  w: (K, N). Per-tensor activation scale, per-column weight
    scale (the usual weight-stationary systolic setup).
    """
    xq = quantize(x, axis=None)
    wq = quantize(w, axis=1)
    acc = int32_matmul(xq.q, wq.q)
    y = dequantize_matmul(acc, xq.scale, wq.scale.reshape(1, -1) if wq.scale.ndim == 2 else wq.scale)
    return y, xq, wq, acc


def quant_error_bound(k_dim: int) -> float:
    """Worst-case |accumulator| for int8 operands with K-length contraction.

    Used to verify the int32 accumulator cannot saturate for our configs
    (127^2 * K < 2^31 for all assigned d_ff/d_model).
    """
    return INT8_MAX * INT8_MAX * k_dim


# ---------------------------------------------------------------------------
# Resilience-aware precision plans (the serving frontier's precision knob)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """Per-block-class / per-timestep bit-width assignment.

    ``body_bits`` applies to the resilient body blocks (``CLASS_BODY``) on
    resilient timesteps (``step >= protect_steps``); ``sensitive_bits``
    covers everything the resilience policy protects -- embeddings, the
    first block, and the first ``protect_steps`` timesteps -- and is pinned
    to the INT8 baseline (narrowing the sensitive sites is exactly what
    Sec 4's characterization says not to do).
    """
    name: str
    body_bits: int = BASE_BITS
    sensitive_bits: int = BASE_BITS
    # Leading timesteps that never narrow; mirrors the DVFS schedule's
    # ``nominal_steps`` protection window. Rebind per engine via
    # :meth:`with_protect_steps` so both protections share one constant.
    protect_steps: int = 2

    def __post_init__(self):
        if not 2 <= self.body_bits <= BASE_BITS:
            raise ValueError(
                f"body_bits must be in [2, {BASE_BITS}], got {self.body_bits}")
        if self.sensitive_bits != BASE_BITS:
            raise ValueError(
                "sensitive sites stay at the INT8 baseline "
                f"(sensitive_bits={self.sensitive_bits})")

    @property
    def narrowed(self) -> bool:
        """True when this plan actually narrows anything (the default
        ``"int8"`` plan is a no-op: today's path, bit for bit)."""
        return self.body_bits < BASE_BITS

    def with_protect_steps(self, n: int) -> "PrecisionPlan":
        return dataclasses.replace(self, protect_steps=int(n))


#: The plan ladder the serving frontier enumerates, widest first. "int8"
#: is the degenerate plan (today's path); the narrowed plans keep the
#: sensitive sites at INT8 and drop only the resilient body.
PRECISION_PLANS: Dict[str, PrecisionPlan] = {
    "int8": PrecisionPlan("int8", body_bits=8),
    "int8-body6": PrecisionPlan("int8-body6", body_bits=6),
    "int8-body4": PrecisionPlan("int8-body4", body_bits=4),
}

DEFAULT_PLAN = PRECISION_PLANS["int8"]


def get_plan(name: str) -> PrecisionPlan:
    """Plan registry lookup with a reasoned error for unknown names."""
    plan = PRECISION_PLANS.get(name)
    if plan is None:
        raise ValueError(f"unknown precision plan {name!r}; one of "
                         f"{tuple(PRECISION_PLANS)}")
    return plan


def fake_quant(x: jax.Array, bits: int) -> jax.Array:
    """Symmetric fake quantization to ``bits`` (quantize-dequantize).

    The execution-level proxy for running the resilient body at a narrower
    operand width: round-trip the tensor through a ``2**(bits-1) - 1``-level
    symmetric grid (per-tensor scale, same convention as :func:`quantize`).
    Deterministic and monotone: fewer bits -> coarser grid -> more noise.
    """
    levels = float(2 ** (int(bits) - 1) - 1)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-8) / levels
    return jnp.clip(jnp.round(x / scale), -levels, levels) * scale


def quant_noise(bits: int) -> float:
    """Relative quantization step size of a ``bits``-wide symmetric grid:
    ``2**-(bits-1)``. The frontier's quality proxy charges the *excess*
    over the INT8 baseline (``quant_noise(b) - quant_noise(8)``), which is
    exactly 0 for the default plan."""
    return 2.0 ** (-(int(bits) - 1))
