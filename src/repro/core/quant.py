"""Symmetric INT8 quantization with INT32 accumulation.

The paper (Sec 3.2) quantizes weights and input activations to INT8 and
injects faults into the INT32 output accumulators, following SmoothQuant-style
symmetric quantization practice [49]. This module provides the functional
quantized-GEMM path every DRIFT-protected matmul runs through.

Bit convention: bit 0 is the LSB of the INT32 accumulator; "the 10th bit"
threshold of the paper corresponds to ``threshold = 2**10`` on the
de-scaled-integer domain.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


@dataclasses.dataclass(frozen=True)
class QTensor:
    """An int8 tensor plus its (broadcastable) float32 scale."""

    q: jax.Array  # int8
    scale: jax.Array  # f32, broadcastable against q

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale


def quantize(x: jax.Array, axis: Optional[int] = None) -> QTensor:
    """Symmetric int8 quantization.

    axis=None  -> per-tensor scale.
    axis=k     -> per-channel scales along ``k`` (scale shape keeps dim k).
    """
    if axis is None:
        amax = jnp.max(jnp.abs(x))
        scale = jnp.maximum(amax, 1e-8) / INT8_MAX
        scale = scale[None] if x.ndim == 0 else scale
    else:
        reduce_axes = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
        amax = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)
        scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return QTensor(q=q, scale=jnp.asarray(scale, jnp.float32))


def int32_matmul(aq: jax.Array, bq: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 GEMM (the systolic-array accumulate).

    Contracts the last dim of ``aq`` with the first dim of ``bq``.
    """
    return jax.lax.dot_general(
        aq,
        bq,
        dimension_numbers=(((aq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def dequantize_matmul(acc: jax.Array, a_scale: jax.Array, b_scale: jax.Array) -> jax.Array:
    """De-scale an int32 accumulator back to float32.

    a_scale broadcasts over rows (per-tensor or per-row), b_scale over the
    output columns (per-tensor or per-column).
    """
    return acc.astype(jnp.float32) * a_scale * b_scale


def quantized_matmul(x: jax.Array, w: jax.Array) -> Tuple[jax.Array, QTensor, QTensor, jax.Array]:
    """Full quantized GEMM: returns (y_f32, x_q, w_q, acc_int32).

    x: (..., K)  w: (K, N). Per-tensor activation scale, per-column weight
    scale (the usual weight-stationary systolic setup).
    """
    xq = quantize(x, axis=None)
    wq = quantize(w, axis=1)
    acc = int32_matmul(xq.q, wq.q)
    y = dequantize_matmul(acc, xq.scale, wq.scale.reshape(1, -1) if wq.scale.ndim == 2 else wq.scale)
    return y, xq, wq, acc


def quant_error_bound(k_dim: int) -> float:
    """Worst-case |accumulator| for int8 operands with K-length contraction.

    Used to verify the int32 accumulator cannot saturate for our configs
    (127^2 * K < 2^31 for all assigned d_ff/d_model).
    """
    return INT8_MAX * INT8_MAX * k_dim
