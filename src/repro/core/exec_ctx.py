"""ExecContext: the composable execution layer for DRIFT.

Every model in ``repro.models`` routes its projections through
``ctx.matmul(x, w, name=..., rclass=...)``. The context decides, per call:

  * whether to run the float path or the quantized INT8->INT32 path,
  * the BER for this GEMM (from the fine-grained DVFS schedule: resilience
    class x current timestep),
  * fault injection (functional bit flips keyed by (step, site)),
  * detection + correction strategy (DRIFT rollback-ABFT or a baseline),
  * checkpoint-store reads/writes (rollback source, refreshed every n steps).

The context is created fresh inside each traced step; its mutable Python
dicts are trace-time containers (a la Flax mutable collections): the caller
extracts ``ctx.state_out`` / ``ctx.stats`` and threads them through the
sampling scan carry.

Modes
-----
  float_clean  pure f32 matmuls (training / reference)
  clean        quantized path, no faults (the quality baseline "w/o DRIFT")
  faulty       quantized + fault injection, no protection (characterization)
  drift        quantized + faults + ABFT + rollback  (the paper's system)
  thundervolt / approx_abft / dmr / stat_abft        (Fig 12 baselines)
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import abft as abft_lib
from repro.core import baselines, fault, quant, rollback
from repro.core.dvfs import CLASS_BODY

MODES = ("float_clean", "clean", "faulty", "drift",
         "thundervolt", "approx_abft", "dmr", "stat_abft")


@dataclasses.dataclass(frozen=True)
class DriftSystemConfig:
    mode: str = "float_clean"
    abft: abft_lib.AbftConfig = dataclasses.field(default_factory=abft_lib.AbftConfig)
    rollback: rollback.RollbackConfig = dataclasses.field(default_factory=rollback.RollbackConfig)
    protect_attention_gemms: bool = False   # also wrap QK^T / AV batched GEMMs
    double_flip: bool = False
    force_bit: int = -1                     # pin flipped bit (Sec 4.1 sweeps)
    backend: str = "jnp"                    # "jnp" | "pallas" (interpret on CPU)

    def __post_init__(self):
        assert self.mode in MODES, self.mode


def _site_id(name: str) -> int:
    return zlib.crc32(name.encode()) & 0x7FFFFFFF


class ExecContext:
    """Per-step execution context. Not a pytree; create inside the trace."""

    def __init__(self,
                 cfg: DriftSystemConfig,
                 key: Optional[jax.Array] = None,
                 step: jax.Array | int = 0,
                 ber_by_class: Optional[jax.Array] = None,
                 state_in: Optional[rollback.CkptStore] = None,
                 have_ckpt: jax.Array | bool = False):
        self.cfg = cfg
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.step = jnp.asarray(step, jnp.int32)
        # (N_CLASSES,) BERs for this step; zeros = error-free nominal point.
        self.ber_by_class = (ber_by_class if ber_by_class is not None
                             else jnp.zeros((3,), jnp.float32))
        self.state_in: rollback.CkptStore = state_in if state_in is not None else {}
        self.have_ckpt = jnp.asarray(have_ckpt, bool)
        self.state_out: rollback.CkptStore = {}
        self.stats: Dict[str, jax.Array] = {
            "detected_row_errors": jnp.int32(0),
            "corrected_elems": jnp.int32(0),
            "extra_compute_flops": jnp.float32(0.0),
            "extra_dram_bytes": jnp.float32(0.0),
            "gemm_words": jnp.int32(0),
        }
        self._names = set()

    # ------------------------------------------------------------------
    def matmul(self, x: jax.Array, w: jax.Array, *, name: str,
               rclass: int | jax.Array = CLASS_BODY) -> jax.Array:
        """Protected projection: x (..., K) @ w (K, N) -> (..., N)."""
        if self.cfg.mode == "float_clean":
            return x @ w

        lead = x.shape[:-1]
        k = x.shape[-1]
        n = w.shape[-1]
        x2 = x.reshape(-1, k)

        xq = quant.quantize(x2, axis=None)
        wq = quant.quantize(w, axis=1)
        # Under pjit, gather FSDP-sharded int8 weights over the data axis
        # before the GEMM: int8 gathers cost half the clean path's bf16
        # gathers, and the INT32 accumulator stays shard-local (otherwise
        # GSPMD all-reduces (M, N) int32 partial sums per GEMM -- measured
        # 3.4x collective blowup on the 512-chip drift dry-run).
        from repro.distributed.constraints import constrain
        wq_q = constrain(wq.q, "w2d_model")
        acc = quant.int32_matmul(xq.q, wq_q)
        w_scale = wq.scale.reshape(1, -1)

        if self.cfg.mode == "clean":
            y = quant.dequantize_matmul(acc, xq.scale, w_scale)
            return y.reshape(*lead, n).astype(x.dtype)

        ber = self.ber_by_class[jnp.asarray(rclass, jnp.int32)]
        site = _site_id(name)
        fkey = fault.site_key(self.key, self.step, site, 0)
        acc_faulty = fault.inject_int32(acc, fkey, ber,
                                        double_flip=self.cfg.double_flip,
                                        force_bit=self.cfg.force_bit)

        if self.cfg.mode == "faulty":
            y = quant.dequantize_matmul(acc_faulty, xq.scale, w_scale)
            return y.reshape(*lead, n).astype(x.dtype)

        report = abft_lib.detect_int(acc_faulty, xq.q, wq_q, self.cfg.abft)
        y_faulty = quant.dequantize_matmul(acc_faulty, xq.scale, w_scale)
        self._bump("detected_row_errors", report.n_row_err)
        self._bump("gemm_words", jnp.int32(acc.size))

        if self.cfg.mode == "drift":
            # Tile-granular recovery (Sec 5.4): the recovery scheduler works
            # tile-by-tile, so the row x col cross-combine happens *within*
            # each systolic tile -- far sparser masks than a full-matrix
            # cross at high BER, and exactly what the Pallas kernel emits.
            rd, cd = abft_lib.tile_checksum_diff(acc_faulty, xq.q, wq_q,
                                                 self.cfg.abft)
            mask, tile_flag = abft_lib.tile_error_mask(rd, cd, self.cfg.abft,
                                                       acc.shape)
            ckpt = self.state_in.get(name)
            y = rollback.correct(y_faulty, ckpt, mask, self.have_ckpt)
            n_corr = jnp.sum(mask.astype(jnp.int32))
            # DRAM cost: one repacked-tile read per flagged tile.
            tile_bytes = self.cfg.abft.tile_m * self.cfg.abft.tile_n * 4
            cost = baselines.RecoveryCost(
                jnp.float32(0.0),
                jnp.sum(tile_flag.astype(jnp.float32)) * tile_bytes,
                n_corr)
            self._write_ckpt(name, y)
        elif self.cfg.mode == "thundervolt":
            y, cost = baselines.thundervolt(y_faulty, report)
        elif self.cfg.mode == "approx_abft":
            y, cost = baselines.approx_abft(y_faulty, report)
        elif self.cfg.mode == "dmr":
            y_clean = quant.dequantize_matmul(acc, xq.scale, w_scale)
            y, cost = baselines.dmr(y_clean, report.n_row_err,
                                    gemm_flops=2.0 * x2.shape[0] * k * n)
        elif self.cfg.mode == "stat_abft":
            y_clean = quant.dequantize_matmul(acc, xq.scale, w_scale)
            rd, cd = abft_lib.tile_checksum_diff(acc_faulty, xq.q, wq_q,
                                                 self.cfg.abft)
            _, tile_flag = abft_lib.tile_error_mask(rd, cd, self.cfg.abft,
                                                    acc.shape)
            y, cost = baselines.stat_abft(
                y_clean, y_faulty, tile_flag,
                tile_elems=self.cfg.abft.tile_m * self.cfg.abft.tile_n,
                k_dim=k)
        else:  # pragma: no cover
            raise ValueError(self.cfg.mode)

        self._bump("corrected_elems", cost.corrected_elems)
        self._bump("extra_compute_flops", cost.extra_compute_flops)
        self._bump("extra_dram_bytes", cost.extra_dram_bytes)
        return y.reshape(*lead, n).astype(x.dtype)

    # ------------------------------------------------------------------
    def bmm(self, a: jax.Array, b: jax.Array, *, name: str,
            rclass: int | jax.Array = CLASS_BODY) -> jax.Array:
        """Batched GEMM (attention scores / mixing). Protected only when
        ``protect_attention_gemms`` -- these are activation x activation
        GEMMs, so rollback uses the same named checkpoint slot."""
        if (self.cfg.mode == "float_clean"
                or not self.cfg.protect_attention_gemms):
            return a @ b
        lead = a.shape[:-2]
        a2 = a.reshape((-1,) + a.shape[-2:])
        b2 = b.reshape((-1,) + b.shape[-2:])
        # vmap would duplicate trace-time state writes; loop over a small
        # static batch instead (heads x batch is static under jit).
        outs = [self.matmul(a2[i], b2[i], name=f"{name}.{i}", rclass=rclass)
                for i in range(a2.shape[0])]
        y = jnp.stack(outs, axis=0)
        return y.reshape(*lead, *y.shape[-2:])

    # ------------------------------------------------------------------
    def _write_ckpt(self, name: str, y: jax.Array) -> None:
        do = rollback.should_checkpoint(self.step, self.cfg.rollback.interval)
        prev = self.state_in.get(name, jnp.zeros_like(y))
        self.state_out[name] = jnp.where(do, y, prev)

    def _bump(self, stat: str, v: jax.Array) -> None:
        self.stats[stat] = self.stats[stat] + v

    # ------------------------------------------------------------------
    @property
    def protected(self) -> bool:
        return self.cfg.mode not in ("float_clean", "clean")


def clean_ctx() -> ExecContext:
    """Convenience: pure-f32 context for training / dry-runs."""
    return ExecContext(DriftSystemConfig(mode="float_clean"))
