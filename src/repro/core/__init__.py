"""DRIFT core: the paper's contribution as composable JAX modules."""
from repro.core.abft import AbftConfig, AbftReport, correction_mask, detect_int, detect_f32
from repro.core.dvfs import (NOMINAL, OVERCLOCK, UNDERVOLT, DvfsSchedule,
                             OperatingPoint, ber_of, fine_grained_schedule,
                             uniform_schedule)
from repro.core.exec_ctx import DriftSystemConfig, ExecContext, clean_ctx
from repro.core.rollback import RollbackConfig

__all__ = [
    "AbftConfig", "AbftReport", "correction_mask", "detect_int", "detect_f32",
    "NOMINAL", "OVERCLOCK", "UNDERVOLT", "DvfsSchedule", "OperatingPoint",
    "ber_of", "fine_grained_schedule", "uniform_schedule",
    "DriftSystemConfig", "ExecContext", "clean_ctx", "RollbackConfig",
]
