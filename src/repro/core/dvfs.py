"""DVFS operating points, the BER(V, f) surface, and fine-grained schedules.

The physical DVFS actuation (on-chip LDO + ADPLL, Sec 5.1) is below the ISA;
what the *algorithm* sees is: each (voltage, frequency) operating point has a
bit-error rate, an energy-per-op factor (~V^2), and a speed factor (~f). We
model that surface with an alpha-power-law critical-path delay and calibrate
log10(BER) against the paper's three anchor operating points:

    nominal    (0.90 V, 2.0 GHz)  -> effectively error-free (<=1e-12)
    undervolt  (0.68 V, 2.0 GHz)  -> BER ~ 3e-3   (energy mode)
    overclock  (0.88 V, 3.5 GHz)  -> BER ~ 3e-3   (speed mode)

so the efficiency/reliability arithmetic of Table 1 / Fig 11 is reproduced by
construction at the anchors and interpolated smoothly between them (Fig 1a).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

V_NOMINAL = 0.90
F_NOMINAL_GHZ = 2.0
V_TH = 0.30          # threshold voltage, alpha-power law
ALPHA = 1.30         # velocity-saturation exponent (14nm-class)
NOMINAL_SLACK = 0.10  # nominal point closes timing with 10% slack


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    voltage: float      # V
    freq_ghz: float     # GHz
    name: str = ""

    @property
    def energy_factor(self) -> float:
        """Dynamic energy per op relative to nominal (~ C V^2)."""
        return (self.voltage / V_NOMINAL) ** 2

    @property
    def speed_factor(self) -> float:
        """Throughput relative to nominal (~ f)."""
        return self.freq_ghz / F_NOMINAL_GHZ


NOMINAL = OperatingPoint(0.90, 2.0, "nominal")
UNDERVOLT = OperatingPoint(0.68, 2.0, "undervolt")   # energy mode
OVERCLOCK = OperatingPoint(0.88, 3.5, "overclock")   # speed mode

# The ladder the runtime BER monitor walks (Sec 5.1 feedback loop): index 0
# is the most aggressive undervolt point; when the monitored BER runs hot the
# index steps toward nominal, when it runs cold it steps back. Length matches
# ber_monitor_update's default ``n_ladder``.
OP_LADDER: Tuple[OperatingPoint, ...] = (
    UNDERVOLT,
    OperatingPoint(0.73, 2.0, "uv-mild"),
    OperatingPoint(0.78, 2.0, "uv-safe"),
    OperatingPoint(0.84, 2.0, "near-nominal"),
    NOMINAL,
)


def ladder_op(index) -> OperatingPoint:
    """Operating point for a (possibly traced, hence int()-able) ladder index."""
    return OP_LADDER[max(0, min(int(index), len(OP_LADDER) - 1))]


def _delay_ns(v: float) -> float:
    """Critical-path delay, alpha-power law, calibrated at the nominal point."""
    # d(V) = c * V / (V - Vth)^alpha ;  d(0.9V) == (1 - slack) * T(2GHz)
    t_nom = 1.0 / F_NOMINAL_GHZ
    c = (1.0 - NOMINAL_SLACK) * t_nom * (V_NOMINAL - V_TH) ** ALPHA / V_NOMINAL
    return c * v / (v - V_TH) ** ALPHA


def slack_ratio(op: OperatingPoint) -> float:
    """(clock period - critical delay) / clock period; negative = violating."""
    t = 1.0 / op.freq_ghz
    return (t - _delay_ns(op.voltage)) / t


def _fit_ber_coeffs() -> np.ndarray:
    """Exact quadratic fit of log10(BER) in slack ratio through the anchors."""
    anchors = [(NOMINAL, -12.0), (UNDERVOLT, np.log10(3e-3)), (OVERCLOCK, np.log10(3e-3))]
    s = np.array([slack_ratio(op) for op, _ in anchors])
    y = np.array([v for _, v in anchors])
    feats = np.stack([np.ones_like(s), s, s * s], axis=1)
    return np.linalg.solve(feats, y)


_BER_COEFFS = _fit_ber_coeffs()


def ber_of(op: OperatingPoint) -> float:
    """BER at an operating point (Fig 1a surface)."""
    s = slack_ratio(op)
    log10b = float(_BER_COEFFS[0] + _BER_COEFFS[1] * s + _BER_COEFFS[2] * s * s)
    return float(np.clip(10.0 ** log10b, 1e-15, 0.5))


def pareto_sweep(voltages: Sequence[float], freqs: Sequence[float]):
    """Enumerate (op, ber, energy_factor, speed_factor) for Fig 11(a)."""
    out = []
    for v in voltages:
        for f in freqs:
            op = OperatingPoint(v, f)
            out.append((op, ber_of(op), op.energy_factor, op.speed_factor))
    return out


# ----------------------------------------------------------------------------
# Fine-grained resilience-aware schedule (Sec 5.2)
# ----------------------------------------------------------------------------

# Block resilience classes (see core/policies.py for classification).
CLASS_EMBED = 0        # conditioning / timestep / token embeddings
CLASS_FIRST_BLOCK = 1  # first transformer block
CLASS_BODY = 2         # middle + deep blocks
N_CLASSES = 3


@dataclasses.dataclass(frozen=True)
class DvfsSchedule:
    """Per-(timestep, block-class) BER table for the sampling scan.

    ``ber_table``: (num_steps, N_CLASSES) float32 -- 0.0 rows encode the
    nominal (error-free) point. Built once per run; indexed inside the scan
    with the running step, so the whole schedule is trace-free.
    """

    ber_table: jax.Array           # (T, N_CLASSES)
    aggressive: OperatingPoint     # the point used for resilient work
    nominal_steps: int             # first k steps fully protected

    def ber_for(self, step: jax.Array, block_class: jax.Array) -> jax.Array:
        return self.ber_table[step, block_class]


def fine_grained_schedule(num_steps: int,
                          aggressive: OperatingPoint = UNDERVOLT,
                          nominal_steps: int = 2,
                          protect_embed: bool = True,
                          protect_first_block: bool = True) -> DvfsSchedule:
    """Paper default: nominal for (embeddings, first 2 steps), aggressive else."""
    agg_ber = ber_of(aggressive)
    table = np.full((num_steps, N_CLASSES), agg_ber, dtype=np.float32)
    table[:nominal_steps, :] = 0.0
    if protect_embed:
        table[:, CLASS_EMBED] = 0.0
    if protect_first_block:
        table[:, CLASS_FIRST_BLOCK] = 0.0
    return DvfsSchedule(jnp.asarray(table), aggressive, nominal_steps)


def uniform_schedule(num_steps: int, op: OperatingPoint) -> DvfsSchedule:
    """Coarse DVFS baseline: one operating point for everything."""
    table = np.full((num_steps, N_CLASSES), ber_of(op), dtype=np.float32)
    return DvfsSchedule(jnp.asarray(table), op, 0)


# ----------------------------------------------------------------------------
# Runtime BER monitor (Sec 5.1): ABFT-reported error counts -> BER estimate
# ----------------------------------------------------------------------------

class BerMonitorState(NamedTuple):
    ema_ber: jax.Array      # scalar f32, EMA of the estimated BER
    op_index: jax.Array     # scalar int32 index into the op-point ladder
    n_updates: jax.Array    # scalar int32


def ber_monitor_init(initial_ber: float = 0.0) -> BerMonitorState:
    return BerMonitorState(jnp.float32(initial_ber), jnp.int32(0), jnp.int32(0))


def ber_monitor_update(state: BerMonitorState,
                       detected_errors: jax.Array,
                       n_words: int,
                       threshold_bit: int,
                       target_ber: float,
                       n_ladder: int = 5,
                       decay: float = 0.9) -> BerMonitorState:
    """Update the runtime BER estimate from one GEMM's ABFT report.

    A large error is detected when any of the top (32 - threshold_bit) bits
    flips, so detected_count ~= n_words * (32 - threshold_bit) * BER and the
    unbiased estimate inverts that. The monitor walks an op-point ladder
    index: +1 (more conservative) when the estimate runs hot (>2x target),
    -1 when it runs cold (<target/2) -- hysteresis keeps it stable.
    """
    visible_bits = max(32 - threshold_bit, 1)
    est = detected_errors.astype(jnp.float32) / (n_words * visible_bits)
    ema = jnp.where(state.n_updates == 0, est,
                    decay * state.ema_ber + (1.0 - decay) * est)
    hot = ema > 2.0 * target_ber
    cold = ema < 0.5 * target_ber
    op_index = jnp.clip(state.op_index + hot.astype(jnp.int32)
                        - cold.astype(jnp.int32), 0, n_ladder - 1)
    return BerMonitorState(ema, op_index, state.n_updates + 1)
