"""Algorithm-based fault tolerance (ABFT) for GEMM, Huang & Abraham style.

For C = A @ B, maintain
    row checksum:  C @ 1  ==  A @ (B @ 1)
    col checksum:  1T @ C ==  (1T @ A) @ B
A mismatch in row i and column j localizes an error at (i, j) and the
mismatch magnitude equals the (summed) error value (Fig 3 of the paper).

Integer exactness
-----------------
For INT8xINT8->INT32 GEMMs, all checksum arithmetic is performed in int32
*with two's-complement wraparound*, which is a ring homomorphism mod 2^32:
the expected and actual checksums agree exactly in the error-free case even
when the mathematical sums exceed int32 range. A flip of bit b in one
element changes the checksum by exactly +/-2^b (mod 2^32), so interpreting
the wrapped difference as a signed int32 recovers the *exact* signed error
sum whenever |error| < 2^31. This removes any float rounding from detection:
thresholding is exact, with zero false positives -- strictly stronger than
the float-epsilon comparisons typical of GPU ABFT implementations and a good
match for TPU int8 MXU passes.

Float path: for bf16/f32 GEMMs, checksums are computed in f32 and compared
with a magnitude threshold scaled by a rounding-noise floor.

Tiled variant
-------------
The paper's recovery is tile-by-tile (Sec 5.4); ``tile_checksum_diff``
evaluates per-(tile-row, tile-col) checksums so the correction mask and the
DRAM-row accounting operate at tile granularity. The Pallas kernel in
``repro.kernels.abft_matmul`` fuses these per-tile sums into the GEMM
epilogue; this module is the pure-jnp oracle and the small-shape fallback.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def _exceeds(diff: jax.Array, thr) -> jax.Array:
    """|diff| >= thr robust to int32 overflow: abs(INT32_MIN) wraps negative,
    so a bit-31 flip (delta = -2^31) would escape an abs()-based check."""
    return (diff >= thr) | (diff <= -thr)


class AbftReport(NamedTuple):
    """Detection output for one GEMM."""

    row_diff: jax.Array   # (M,) signed error sum per row (int32 or f32)
    col_diff: jax.Array   # (N,) signed error sum per column
    row_flag: jax.Array   # (M,) bool, |row_diff| >= threshold
    col_flag: jax.Array   # (N,) bool
    n_row_err: jax.Array  # scalar int32
    n_col_err: jax.Array  # scalar int32


@dataclasses.dataclass(frozen=True)
class AbftConfig:
    threshold_bit: int = 10        # errors >= 2**threshold_bit are "large"
    tile_m: int = 32               # systolic-array tile (paper default 32)
    tile_n: int = 32
    enabled: bool = True
    # 'cross' = paper-faithful Fig 10(a): flagged-rows x flagged-cols.
    # 'union' = beyond-paper: whole flagged rows AND whole flagged cols of a
    #           tile. Same DRAM cost (recovery fetches whole repacked tiles),
    #           but also catches "paired large errors that cancel within the
    #           same row or column" -- the blind spot Sec 5.3 Step 2 accepts.
    mask_policy: str = "union"

    @property
    def threshold(self) -> int:
        return 1 << self.threshold_bit


# ----------------------------------------------------------------------------
# Full-matrix checksums
# ----------------------------------------------------------------------------

def expected_checksums_int(aq: jax.Array, bq: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(A @ B1, 1TA @ B) in wraparound int32. aq:(M,K) int8, bq:(K,N) int8."""
    a32 = aq.astype(jnp.int32)
    b32 = bq.astype(jnp.int32)
    b_rowsum = jnp.sum(b32, axis=1)                 # (K,) fits int32: K*127
    a_colsum = jnp.sum(a32, axis=0)                 # (K,)
    exp_row = a32 @ b_rowsum                        # (M,) wraps mod 2^32
    exp_col = a_colsum @ b32                        # (N,)
    return exp_row, exp_col


def checksum_diff_int(acc: jax.Array, exp_row: jax.Array, exp_col: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """Signed per-row / per-col error sums (exact mod-2^32 arithmetic)."""
    act_row = jnp.sum(acc, axis=1)
    act_col = jnp.sum(acc, axis=0)
    return act_row - exp_row, act_col - exp_col


def detect_int(acc: jax.Array, aq: jax.Array, bq: jax.Array,
               cfg: AbftConfig) -> AbftReport:
    """Detect large errors in an int32 accumulator C=(A@B)."""
    exp_row, exp_col = expected_checksums_int(aq, bq)
    row_diff, col_diff = checksum_diff_int(acc, exp_row, exp_col)
    thr = jnp.int32(cfg.threshold)
    row_flag = _exceeds(row_diff, thr)
    col_flag = _exceeds(col_diff, thr)
    return AbftReport(row_diff, col_diff, row_flag, col_flag,
                      jnp.sum(row_flag.astype(jnp.int32)),
                      jnp.sum(col_flag.astype(jnp.int32)))


def detect_f32(c: jax.Array, a: jax.Array, b: jax.Array,
               cfg: AbftConfig, rel_floor: float = 1e-3) -> AbftReport:
    """Float-path detection with a rounding-noise floor.

    threshold_eff = max(2**threshold_bit_scaled, rel_floor * mean|C|) where
    the bit threshold is interpreted on the same scale as C.
    """
    exp_row = a @ jnp.sum(b, axis=1)
    exp_col = jnp.sum(a, axis=0) @ b
    row_diff = jnp.sum(c, axis=1) - exp_row
    col_diff = jnp.sum(c, axis=0) - exp_col
    thr = jnp.maximum(jnp.float32(cfg.threshold),
                      rel_floor * jnp.mean(jnp.abs(c)) * c.shape[1])
    row_flag = jnp.abs(row_diff) >= thr
    col_flag = jnp.abs(col_diff) >= thr
    return AbftReport(row_diff, col_diff, row_flag, col_flag,
                      jnp.sum(row_flag.astype(jnp.int32)),
                      jnp.sum(col_flag.astype(jnp.int32)))


def correction_mask(report: AbftReport) -> jax.Array:
    """Cross-combine flagged rows x cols into the paper's correction mask.

    Fig 10(a): all (flagged row, flagged col) intersections are treated as
    potential error sites. Conservative (a superset of true sites), which is
    safe because replacement values come from a near-identical checkpoint.
    """
    return jnp.outer(report.row_flag, report.col_flag)


# ----------------------------------------------------------------------------
# Tile-level checksums (the granularity the recovery scheduler works at)
# ----------------------------------------------------------------------------

def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def tile_checksum_diff(acc: jax.Array, aq: jax.Array, bq: jax.Array,
                       cfg: AbftConfig) -> Tuple[jax.Array, jax.Array]:
    """Per-tile checksum differences.

    Returns (row_diff_t, col_diff_t):
      row_diff_t: (Mt, Nt, tile_m) -- per tile, per local row
      col_diff_t: (Mt, Nt, tile_n) -- per tile, per local col
    where Mt = ceil(M/tile_m), Nt = ceil(N/tile_n). Exact int32 arithmetic.
    """
    m, n = acc.shape
    k = aq.shape[1]
    tm, tn = cfg.tile_m, cfg.tile_n
    a32 = _pad_to(aq.astype(jnp.int32), tm, 0)
    b32 = _pad_to(bq.astype(jnp.int32), tn, 1)
    accp = _pad_to(_pad_to(acc, tm, 0), tn, 1)
    mt, nt = accp.shape[0] // tm, accp.shape[1] // tn
    acc_t = accp.reshape(mt, tm, nt, tn)

    # Expected per-tile row sums: A_tile @ (B col-block row-sum)
    b_blocksum = b32.reshape(k, nt, tn).sum(axis=2)          # (K, Nt)
    exp_row = jnp.einsum("mk,kn->mn", a32, b_blocksum,
                         preferred_element_type=jnp.int32)    # (Mp, Nt)
    exp_row_t = exp_row.reshape(mt, tm, nt).transpose(0, 2, 1)  # (Mt, Nt, tm)
    act_row_t = acc_t.sum(axis=3).transpose(0, 2, 1)            # (Mt, Nt, tm)

    a_blocksum = a32.reshape(mt, tm, k).sum(axis=1)          # (Mt, K)
    exp_col = jnp.einsum("mk,kn->mn", a_blocksum, b32,
                         preferred_element_type=jnp.int32)    # (Mt, Np)
    exp_col_t = exp_col.reshape(mt, nt, tn)                   # (Mt, Nt, tn)
    act_col_t = acc_t.sum(axis=1).reshape(mt, nt, tn)

    return act_row_t - exp_row_t, act_col_t - exp_col_t


def tile_error_mask(row_diff_t: jax.Array, col_diff_t: jax.Array,
                    cfg: AbftConfig, out_shape: Tuple[int, int]) -> Tuple[jax.Array, jax.Array]:
    """Element mask (M, N) + per-tile flag (Mt, Nt) from tile checksums."""
    thr = jnp.int32(cfg.threshold) if row_diff_t.dtype == jnp.int32 else jnp.float32(cfg.threshold)
    row_flag = _exceeds(row_diff_t, thr)                      # (Mt, Nt, tm)
    col_flag = _exceeds(col_diff_t, thr)                      # (Mt, Nt, tn)
    if cfg.mask_policy == "cross":
        mask_t = row_flag[:, :, :, None] & col_flag[:, :, None, :]
    else:  # union
        mask_t = row_flag[:, :, :, None] | col_flag[:, :, None, :]
    tile_flag = jnp.any(mask_t, axis=(2, 3))                  # (Mt, Nt)
    mt, nt, tm = row_flag.shape
    tn = col_flag.shape[2]
    mask = mask_t.transpose(0, 2, 1, 3).reshape(mt * tm, nt * tn)
    return mask[: out_shape[0], : out_shape[1]], tile_flag
