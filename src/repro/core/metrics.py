"""Generation-quality metrics (offline proxies for LPIPS / CLIP / FID).

The paper's characterization protocol (Sec 4) fixes the initial noise seed
and measures *perceptual deviation of the faulty output from the clean
output of the same run*. That protocol needs a perceptual distance, not the
pretrained LPIPS network specifically. We use:

  lpips_proxy  -- multi-scale random-feature perceptual distance: a fixed,
                  seed-pinned 3-level conv pyramid (random Gaussian filters,
                  which are well-documented to give usable perceptual
                  embeddings); unit-normalized feature diffs averaged over
                  scales, like LPIPS. Monotone in perceptual corruption.
  clip_proxy   -- cosine similarity in a fixed random-projection embedding
                  of (image features, conditioning vector); stands in for
                  semantic-fidelity trends only.
  psnr / ssim  -- standard reference metrics, exact implementations.
  fid_proxy    -- Frechet distance between Gaussian fits of random-feature
                  embeddings of two image batches.

Absolute values are NOT comparable to the paper's; orderings and
degradation thresholds are. See DESIGN.md "Changed assumptions".
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

_FEAT_SEED = 20260713


@functools.lru_cache(maxsize=None)
def _filters(in_ch: int, out_ch: int, level: int) -> np.ndarray:
    rng = np.random.RandomState(_FEAT_SEED + level)
    w = rng.randn(3, 3, in_ch, out_ch).astype(np.float32)
    return w / np.sqrt(9.0 * in_ch)


def _conv(x: jax.Array, w: np.ndarray, stride: int = 2) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x, jnp.asarray(w), window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _pyramid(img: jax.Array, channels=(16, 32, 64)) -> list[jax.Array]:
    """img: (B, H, W, C) in [-1, 1] -> list of feature maps."""
    feats = []
    x = img
    in_ch = img.shape[-1]
    for lvl, out_ch in enumerate(channels):
        x = jnp.tanh(_conv(x, _filters(in_ch, out_ch, lvl)))
        feats.append(x)
        in_ch = out_ch
    return feats


def lpips_proxy(a: jax.Array, b: jax.Array) -> jax.Array:
    """Perceptual distance between two (B,H,W,C) images in [-1,1]. Lower=closer."""
    fa, fb = _pyramid(a), _pyramid(b)
    total = 0.0
    for xa, xb in zip(fa, fb):
        na = xa / (jnp.linalg.norm(xa, axis=-1, keepdims=True) + 1e-6)
        nb = xb / (jnp.linalg.norm(xb, axis=-1, keepdims=True) + 1e-6)
        total = total + jnp.mean(jnp.sum((na - nb) ** 2, axis=-1))
    return total / len(fa)


def clip_proxy(img: jax.Array, cond: jax.Array) -> jax.Array:
    """Cosine(embedding(img), projection(cond)) -- semantic-trend proxy."""
    feats = _pyramid(img)[-1].mean(axis=(1, 2))          # (B, C)
    rng = np.random.RandomState(_FEAT_SEED + 99)
    proj = jnp.asarray(rng.randn(cond.shape[-1], feats.shape[-1])
                       .astype(np.float32) / np.sqrt(cond.shape[-1]))
    ce = cond @ proj
    num = jnp.sum(feats * ce, axis=-1)
    den = (jnp.linalg.norm(feats, axis=-1) * jnp.linalg.norm(ce, axis=-1) + 1e-6)
    return jnp.mean(num / den)


def psnr(a: jax.Array, b: jax.Array, data_range: float = 2.0) -> jax.Array:
    mse = jnp.mean((a - b) ** 2)
    return 10.0 * jnp.log10(data_range ** 2 / jnp.maximum(mse, 1e-12))


def ssim(a: jax.Array, b: jax.Array, data_range: float = 2.0) -> jax.Array:
    """Global-window SSIM (sufficient for relative comparisons)."""
    c1 = (0.01 * data_range) ** 2
    c2 = (0.03 * data_range) ** 2
    mu_a, mu_b = jnp.mean(a), jnp.mean(b)
    va, vb = jnp.var(a), jnp.var(b)
    cov = jnp.mean((a - mu_a) * (b - mu_b))
    return ((2 * mu_a * mu_b + c1) * (2 * cov + c2)
            / ((mu_a ** 2 + mu_b ** 2 + c1) * (va + vb + c2)))


def fid_proxy(batch_a: jax.Array, batch_b: jax.Array) -> jax.Array:
    """Frechet distance between random-feature Gaussians of two batches."""
    fa = _pyramid(batch_a)[-1].mean(axis=(1, 2))
    fb = _pyramid(batch_b)[-1].mean(axis=(1, 2))
    mu_a, mu_b = fa.mean(0), fb.mean(0)
    va, vb = fa.var(0), fb.var(0)
    # Diagonal-covariance Frechet (full sqrtm is ill-conditioned at B<64).
    return (jnp.sum((mu_a - mu_b) ** 2)
            + jnp.sum(va + vb - 2.0 * jnp.sqrt(jnp.maximum(va * vb, 0.0))))
