"""Rollback-ABFT: correct large errors with values from a previous timestep.

Sec 5.3-5.4 of the paper. Checkpoints of GEMM outputs are "offloaded" every
``interval`` denoising steps (functionally: carried alongside the sampler
state; the DRAM traffic is charged by ``repro.perfmodel``). When ABFT flags
large errors, the correction mask (flagged rows x flagged cols) is overwritten
with the checkpoint values -- exploiting the cross-step similarity of
diffusion activations (Fig 2b) instead of recomputing.

Sharding note: the checkpoint store is a pytree whose leaves mirror the live
activations, so under pjit it inherits their PartitionSpec; both checksum
verification and the masked select are shard-local (no collectives).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

CkptStore = Dict[str, jax.Array]

# The paper's default checkpoint-refresh cadence (Sec 6.4). THE single
# source of truth: RollbackConfig, GenerationRequest, SamplerKey, the
# perfmodel's RunConfig, and both serving CLIs' --rollback-interval help
# strings all derive from this constant (tools/check_help_sync.py asserts
# the rendered default matches). The serving offload planner
# (repro.serving.offload.planner) can replace it per operating point.
DEFAULT_INTERVAL = 10


@dataclasses.dataclass(frozen=True)
class RollbackConfig:
    interval: int = DEFAULT_INTERVAL   # refresh checkpoints every n steps
    enabled: bool = True


def should_checkpoint(step: jax.Array, interval: int) -> jax.Array:
    """Steps 0, n, 2n, ... refresh the checkpoint store."""
    return (step % interval) == 0


def update_store(store: CkptStore, name: str, value: jax.Array,
                 do_update: jax.Array) -> CkptStore:
    """Functionally refresh one entry when ``do_update`` (traced bool)."""
    prev = store.get(name)
    if prev is None:
        new = value
    else:
        new = jnp.where(do_update, value, prev)
    out = dict(store)
    out[name] = new
    return out


def correct(current: jax.Array, checkpoint: Optional[jax.Array],
            mask: jax.Array, have_ckpt: jax.Array) -> jax.Array:
    """Overwrite masked positions with checkpoint values (Step 4, Sec 5.3).

    When no checkpoint exists yet (very first steps -- which the fine-grained
    schedule runs at the nominal, error-free point anyway), fall back to
    zeroing the masked positions (ApproxABFT-style) so the value magnitude
    distortion is still removed.
    """
    if checkpoint is None:
        return jnp.where(mask, jnp.zeros_like(current), current)
    replacement = jnp.where(have_ckpt, checkpoint, jnp.zeros_like(current))
    return jnp.where(mask, replacement, current)


def init_store_like(example: Dict[str, jax.Array]) -> CkptStore:
    """Zero-initialized store matching an example activation pytree."""
    return {k: jnp.zeros_like(v) for k, v in example.items()}


def store_bytes(store: CkptStore) -> int:
    """Checkpoint footprint (the 'DRAM offload' volume per refresh)."""
    return int(sum(v.size * v.dtype.itemsize for v in store.values()))
