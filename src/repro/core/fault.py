"""DVFS timing-error model: uniform random bit flips on GEMM outputs.

Implements the paper's error model (Sec 3.1-3.2): transient computational
errors from aggressive DVFS are modeled as uniform random bit flips on the
INT32 output accumulators of quantized GEMMs, parameterized by BER
(bit error rate = probability that any given output *bit* flips).

Injection is functional: every fault site is keyed by
(timestep, block, tensor index, bit position) through a folded PRNG key, so
studies are exactly reproducible and individual sites can be pinned
(Sec 4's controlled experiments).

Approximation note: we draw at most one flipped bit per 32-bit word, with
word-flip probability 1-(1-ber)^32 and a uniform bit position. At the
paper's most aggressive operating point (BER=3e-3) the probability that a
*flipped word* carries >=2 flips is ~4.7%, and the second flip is
independently placed, so this underestimates multi-bit distortion slightly;
the characterization conclusions (high-bit flips dominate damage) are
insensitive to it. ``double_flip=True`` enables a second independent draw
for exactness-sensitive sweeps.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def word_flip_prob(ber: jax.Array, bits: int = 32) -> jax.Array:
    """P(at least one of `bits` bits flips) given per-bit BER."""
    ber = jnp.asarray(ber, jnp.float32)
    return -jnp.expm1(bits * jnp.log1p(-jnp.clip(ber, 0.0, 0.5)))


def _flip_words(bits_u32: jax.Array, key: jax.Array, ber: jax.Array,
                double_flip: bool = False, force_bit: int = -1) -> jax.Array:
    """XOR random single-bit masks into a uint32 tensor at the given BER.

    force_bit >= 0 pins the flipped position (bit-level resilience sweeps,
    Sec 4.1); ``ber`` is then interpreted as the per-word flip rate.
    """
    kf, kb, kf2, kb2 = jax.random.split(key, 4)
    if force_bit >= 0:
        p = jnp.asarray(ber, jnp.float32)
        flip = jax.random.uniform(kf, bits_u32.shape) < p
        pos = jnp.full(bits_u32.shape, force_bit, jnp.uint32)
        mask = jnp.where(flip, jnp.left_shift(jnp.uint32(1), pos),
                         jnp.uint32(0))
        return jax.lax.bitwise_xor(bits_u32, mask)
    p = word_flip_prob(ber)
    flip = jax.random.uniform(kf, bits_u32.shape) < p
    pos = jax.random.randint(kb, bits_u32.shape, 0, 32, dtype=jnp.uint32)
    mask = jnp.where(flip, jnp.left_shift(jnp.uint32(1), pos), jnp.uint32(0))
    out = jax.lax.bitwise_xor(bits_u32, mask)
    if double_flip:
        # Second-order term: P(>=2 flips | >=1 flip) ~ (bits-1)/2 * ber.
        p2 = jnp.clip(15.5 * ber, 0.0, 1.0)
        flip2 = flip & (jax.random.uniform(kf2, bits_u32.shape) < p2)
        pos2 = jax.random.randint(kb2, bits_u32.shape, 0, 32, dtype=jnp.uint32)
        mask2 = jnp.where(flip2, jnp.left_shift(jnp.uint32(1), pos2), jnp.uint32(0))
        out = jax.lax.bitwise_xor(out, mask2)
    return out


def inject_int32(acc: jax.Array, key: jax.Array, ber: jax.Array,
                 double_flip: bool = False, force_bit: int = -1) -> jax.Array:
    """Inject bit flips into an int32 accumulator tensor."""
    assert acc.dtype == jnp.int32, acc.dtype
    bits = jax.lax.bitcast_convert_type(acc, jnp.uint32)
    return jax.lax.bitcast_convert_type(
        _flip_words(bits, key, ber, double_flip, force_bit), jnp.int32)


def inject_f32(x: jax.Array, key: jax.Array, ber: jax.Array,
               double_flip: bool = False) -> jax.Array:
    """Bit flips on raw float32 words (un-quantized execution paths)."""
    assert x.dtype == jnp.float32, x.dtype
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    return jax.lax.bitcast_convert_type(
        _flip_words(bits, key, ber, double_flip), jnp.float32)


def inject_at(acc: jax.Array, flat_index: int, bit: int) -> jax.Array:
    """Deterministically flip one bit of one element (Sec 4 probes).

    ``flat_index`` addresses the flattened tensor; ``bit`` is 0 (LSB)..31.
    """
    bits = jax.lax.bitcast_convert_type(acc, jnp.uint32).reshape(-1)
    mask = jnp.zeros_like(bits).at[flat_index].set(jnp.uint32(1) << jnp.uint32(bit))
    out = jax.lax.bitwise_xor(bits, mask).reshape(acc.shape)
    if acc.dtype == jnp.int32:
        return jax.lax.bitcast_convert_type(out, jnp.int32)
    return jax.lax.bitcast_convert_type(out, acc.dtype)


def site_key(base: jax.Array, step, block: int, tensor_id: int = 0) -> jax.Array:
    """Fold a fault site identity into a PRNG key (reproducible injection)."""
    k = jax.random.fold_in(base, step)
    k = jax.random.fold_in(k, block)
    return jax.random.fold_in(k, tensor_id)


def expected_flips(shape, ber: float, bits: int = 32) -> float:
    """E[#flipped bits] for a tensor -- used by tests and the perf model."""
    n = 1
    for d in shape:
        n *= d
    return float(n) * bits * ber
