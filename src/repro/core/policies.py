"""Resilience classification policies (which work gets protected).

Sec 4 findings -> Sec 5.2 policy: the timestep/conditioning embeddings and
the first transformer block are error-*sensitive*; everything else is
error-*resilient*. Early denoising steps (default: first 2) are sensitive
regardless of block. These policies map a block's position in the network to
a resilience class consumed by ``core.dvfs.DvfsSchedule``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import dvfs


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Maps (block kind, depth index) -> dvfs resilience class."""

    protect_embeddings: bool = True
    protect_first_blocks: int = 1   # how many leading blocks stay nominal

    def classify(self, kind: str, layer_index: int) -> int:
        if kind in ("embed", "cond", "time_embed", "patch_embed", "text_embed",
                    "final", "head"):
            # Embedding layers have global, every-step influence (Sec 4.3);
            # the final projection maps straight to pixels/logits.
            return dvfs.CLASS_EMBED if self.protect_embeddings else dvfs.CLASS_BODY
        if layer_index < self.protect_first_blocks:
            return dvfs.CLASS_FIRST_BLOCK
        return dvfs.CLASS_BODY

    def class_vector(self, kinds: Sequence[str]) -> jnp.ndarray:
        """Vector of classes for a stack of blocks (index = depth)."""
        return jnp.asarray(
            [self.classify("block", i) for i, _ in enumerate(kinds)],
            dtype=jnp.int32)


PAPER_DEFAULT = ResiliencePolicy(protect_embeddings=True, protect_first_blocks=1)
UNPROTECTED = ResiliencePolicy(protect_embeddings=False, protect_first_blocks=0)


def sensitivity_score(lpips_deltas: np.ndarray) -> np.ndarray:
    """Normalize measured per-site quality deltas into [0, 1] sensitivities.

    Used by the characterization pipeline to *derive* a policy from an
    injection sweep instead of hand-picking (beyond-paper convenience).
    """
    d = np.maximum(lpips_deltas, 0.0)
    return d / (d.max() + 1e-12)


def derive_policy(block_scores: np.ndarray, embed_score: float,
                  quantile: float = 0.8) -> ResiliencePolicy:
    """Data-driven policy: protect blocks above the given score quantile."""
    thr = float(np.quantile(block_scores, quantile))
    n_lead = 0
    for s in block_scores:
        if s >= thr:
            n_lead += 1
        else:
            break
    return ResiliencePolicy(protect_embeddings=embed_score >= thr,
                            protect_first_blocks=max(n_lead, 1))
