"""Synthetic data pipelines (offline container; no downloads).

Two generators with deterministic, shardable, checkpointable state:

  * token streams for LM training (mixture of Zipf-distributed ids with
    local n-gram structure so loss actually decreases),
  * structured latent images for diffusion training: random multi-scale
    Gaussian blobs + frequency textures in [-1, 1], class-conditioned so a
    small DiT can visibly learn p(latent | class).

The loader yields per-host shards: ``host_batch = global_batch //
num_data_shards`` with the shard index folded into the PRNG key, so any
host can deterministically regenerate any step's batch -- which is what
makes data-pipeline state checkpointable as a single (step,) integer and
restartable after preemption (see checkpoint/manager.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    kind: str                    # "lm" | "latent" | "frames"
    vocab: int = 0
    seq_len: int = 0
    latent_size: int = 0
    latent_channels: int = 4
    num_classes: int = 10
    cond_dim: int = 0
    cond_tokens: int = 0
    encoder_seq: int = 0
    global_batch: int = 8
    seed: int = 0


def _zipf_tokens(key, shape, vocab: int) -> jax.Array:
    """Zipf-ish marginal with Markov structure: next ~ prev + noise."""
    k1, k2 = jax.random.split(key)
    u = jax.random.uniform(k1, shape, minval=1e-4, maxval=1.0)
    base = (vocab * u ** 2.5).astype(jnp.int32) % vocab
    drift = jax.random.randint(k2, shape, -3, 4)
    toks = jnp.cumsum(drift, axis=-1) % 17 + base
    return jnp.clip(toks, 0, vocab - 1)


def _latents(key, batch: int, size: int, ch: int, labels) -> jax.Array:
    """Class-structured blobs: center/scale/frequency keyed by label."""
    kb, kf, kp = jax.random.split(key, 3)
    yy, xx = jnp.meshgrid(jnp.linspace(-1, 1, size), jnp.linspace(-1, 1, size),
                          indexing="ij")
    ang = labels.astype(jnp.float32)[:, None, None] * 0.7
    cx = 0.5 * jnp.cos(ang)
    cy = 0.5 * jnp.sin(ang)
    blob = jnp.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2)
                     / (0.1 + 0.02 * labels.astype(jnp.float32)
                        )[:, None, None]))
    freq = 2.0 + labels.astype(jnp.float32)[:, None, None]
    tex = 0.3 * jnp.sin(freq * np.pi * xx)[..., None] * jnp.ones((1, 1, ch))
    noise = 0.05 * jax.random.normal(kp, (batch, size, size, ch))
    x = blob[..., None] * jnp.ones((1, 1, ch)) + tex + noise
    return jnp.clip(2.0 * x - 1.0, -1.0, 1.0).astype(jnp.float32)


def batch_at(cfg: DataConfig, step: int, shard: int = 0,
             num_shards: int = 1) -> Dict[str, jax.Array]:
    """Deterministically materialize the batch for (step, shard)."""
    assert cfg.global_batch % num_shards == 0
    b = cfg.global_batch // num_shards
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(cfg.seed), step), shard)
    if cfg.kind == "lm":
        return {"tokens": _zipf_tokens(key, (b, cfg.seq_len + 1), cfg.vocab)}
    if cfg.kind == "latent":
        kl, kc, kt = jax.random.split(key, 3)
        labels = jax.random.randint(kl, (b,), 0, cfg.num_classes)
        out = {"latents": _latents(kc, b, cfg.latent_size,
                                   cfg.latent_channels, labels),
               "labels": labels}
        if cfg.cond_tokens:
            out["text"] = 0.1 * jax.random.normal(
                kt, (b, cfg.cond_tokens, cfg.cond_dim))
        return out
    if cfg.kind == "frames":
        kf, kt = jax.random.split(key)
        return {"frames": 0.5 * jax.random.normal(
                    kf, (b, cfg.encoder_seq, cfg.cond_dim or cfg.vocab)),
                "tokens": _zipf_tokens(kt, (b, cfg.seq_len + 1), cfg.vocab)}
    raise ValueError(cfg.kind)


def iterate(cfg: DataConfig, start_step: int = 0, shard: int = 0,
            num_shards: int = 1) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield batch_at(cfg, step, shard, num_shards)
        step += 1


def for_model(model_cfg, global_batch: int, seq_len: int = 0,
              seed: int = 0) -> DataConfig:
    """DataConfig matching a ModelConfig's input contract."""
    fam = model_cfg.family
    if fam in ("dense", "moe", "ssm", "hybrid"):
        return DataConfig("lm", vocab=model_cfg.vocab, seq_len=seq_len,
                          global_batch=global_batch, seed=seed)
    if fam == "vlm":
        return DataConfig("lm", vocab=model_cfg.vocab, seq_len=seq_len,
                          global_batch=global_batch, seed=seed)
    if fam == "encdec":
        return DataConfig("frames", vocab=model_cfg.vocab, seq_len=seq_len,
                          encoder_seq=model_cfg.encoder_seq,
                          cond_dim=model_cfg.d_model,
                          global_batch=global_batch, seed=seed)
    if fam in ("dit", "unet"):
        return DataConfig("latent", latent_size=model_cfg.latent_size,
                          latent_channels=model_cfg.latent_channels,
                          num_classes=max(model_cfg.num_classes, 1),
                          cond_dim=model_cfg.cond_dim,
                          cond_tokens=model_cfg.cond_tokens,
                          global_batch=global_batch, seed=seed)
    raise ValueError(fam)
