"""TaylorSeer-style cache-based acceleration (paper Sec 6.6, Table 2).

From-reusing-to-forecasting [36]: instead of recomputing the denoiser at
every sampling step, compute it every ``interval`` steps and *forecast* the
skipped outputs with a Taylor expansion in step index built from finite
differences of the cached outputs (order <= 2 here, matching the paper's
"interval 3, cache order 2" configuration).

We cache at the model-output (eps) level -- the standard simplification of
feature-level TaylorSeer; its speedup accounting is identical (skipped steps
cost zero network FLOPs) and its quality behaviour is what Table 2 needs.

DRIFT composes orthogonally: computed steps still run under the DVFS
schedule with rollback-ABFT; forecast steps execute no GEMMs at all (and
thus cannot fault).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TaylorSeerConfig:
    interval: int = 3
    order: int = 2
    enabled: bool = True


class TaylorState(NamedTuple):
    y: jax.Array        # last computed output
    dy: jax.Array       # first finite difference (per computed-step)
    d2y: jax.Array      # second finite difference
    n_computed: jax.Array


def init_state(shape, dtype=jnp.float32) -> TaylorState:
    z = jnp.zeros(shape, dtype)
    return TaylorState(z, z, z, jnp.int32(0))


def update_on_compute(state: TaylorState, y_new: jax.Array) -> TaylorState:
    """Refresh the Taylor table after a real model evaluation."""
    dy_new = y_new - state.y
    d2y_new = dy_new - state.dy
    n = state.n_computed
    dy_new = jnp.where(n >= 1, dy_new, jnp.zeros_like(dy_new))
    d2y_new = jnp.where(n >= 2, d2y_new, jnp.zeros_like(d2y_new))
    return TaylorState(y_new, dy_new, d2y_new, n + 1)


def forecast(state: TaylorState, k: jax.Array, interval: int,
             order: int = 2) -> jax.Array:
    """Predict the output k steps after the last computed one.

    Differences are per computed-step (spacing = interval), so the local
    coordinate is u = k / interval.
    """
    u = k.astype(jnp.float32) / interval
    y = state.y + u * state.dy
    if order >= 2:
        y = y + 0.5 * u * (u - 1.0) * state.d2y
    return y


def should_compute(step: jax.Array, cfg: TaylorSeerConfig) -> jax.Array:
    if not cfg.enabled:
        return jnp.asarray(True)
    return (step % cfg.interval) == 0


def speedup(num_steps: int, cfg: TaylorSeerConfig) -> float:
    """Analytical network-eval speedup (skipped steps are free)."""
    if not cfg.enabled:
        return 1.0
    computed = (num_steps + cfg.interval - 1) // cfg.interval
    return num_steps / computed
