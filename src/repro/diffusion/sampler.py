"""The DRIFT sampling loop: DDIM scan with fine-grained DVFS, rollback-ABFT
checkpointing, BER monitoring, and optional TaylorSeer caching.

This is the paper's end-to-end system (Fig 8): one lax.scan over denoising
steps whose carry holds (latents, rollback checkpoint stores, BER-monitor
state, TaylorSeer table). Per step:

  1. the DVFS schedule chooses the BER per resilience class
     (nominal for the first ``nominal_steps`` and for embedding GEMMs),
  2. the model runs with fault injection + ABFT + tile rollback
     (ExecContext inside the model),
  3. every ``interval`` steps the checkpoint stores refresh ("offload"),
  4. the BER monitor folds the step's detected-error count into its
     estimate (Sec 5.1 feedback loop),
  5. DDIM updates the latents.

Works for DiT/PixArt (scanned or unrolled blocks) and the SD1.5 UNet (flat
checkpoint store derived by eval_shape).

Two execution shapes share one step function:

  * ``sample`` / ``make_sampler()`` -- the whole chain as ONE ``lax.scan``
    (a single XLA while-loop, no host round-trips),
  * ``sample_stream`` / ``make_sampler(stream_window=k)`` -- the same scan
    chunked into windows of ``k`` steps, surfacing the carry's latents
    between windows as ``StreamEvent`` previews. The per-step math is
    identical, so the streamed chain's final latents are bit-identical to
    the one-shot scan (the serving tests assert this).

The carry layout, checkpoint-offload semantics, and the shard-aware
``make_sampler(mesh=...)`` contract are documented in ``docs/sampler.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dvfs as dvfs_lib
from repro.core import quant as quant_lib
from repro.core.exec_ctx import DriftSystemConfig, ExecContext
from repro.diffusion import schedule as sched_lib
from repro.diffusion import taylorseer as ts_lib
from repro.models import dit as dit_lib
from repro.models import unet as unet_lib
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    num_sample_steps: int = 50
    num_train_steps: int = 1000
    drift: DriftSystemConfig = dataclasses.field(
        default_factory=lambda: DriftSystemConfig(mode="clean"))
    schedule: Optional[dvfs_lib.DvfsSchedule] = None   # None -> error-free
    taylorseer: ts_lib.TaylorSeerConfig = dataclasses.field(
        default_factory=lambda: ts_lib.TaylorSeerConfig(enabled=False))
    # Resilience-aware precision plan (core.quant.PRECISION_PLANS): the
    # default "int8" plan is a strict no-op (no extra op in the trace), so
    # pre-plan samplers are bit-identical. Narrowed plans fake-quantize
    # the denoiser output on resilient timesteps (step >= protect_steps)
    # -- the output-level simplification of layer-wise mixed precision.
    precision: quant_lib.PrecisionPlan = quant_lib.DEFAULT_PLAN
    monitor_target_ber: float = 3e-3
    # Fig 6 block-level study: per-layer / embed BER multipliers
    layer_gate: Optional[Any] = None
    embed_gate: Optional[Any] = None


class SampleOutput(NamedTuple):
    latents: jax.Array
    monitor: dvfs_lib.BerMonitorState
    total_corrected: jax.Array
    n_model_evals: jax.Array
    # Resilience heatmap: detected row errors per (denoising step, site),
    # shape (num_sample_steps, detection_rows(model_cfg)) int32 -- row 0 is
    # the embedding/conditioning GEMMs for DiT-family models, rows 1..L the
    # blocks; the UNet accumulates a single row. Batch-reduced (psum under
    # the sharded engine), and always computed in-trace, so recording it
    # never perturbs the latents. None for stub samplers that predate it.
    heatmap: Optional[jax.Array] = None


def detection_rows(model_cfg: ModelConfig) -> int:
    """Rows in the per-step detection heatmap: one per block plus one for
    the embedding/conditioning GEMMs (DiT family); the UNet's ExecContext
    accumulates a single scalar, so it gets one row."""
    if model_cfg.family == "unet":
        return 1
    return model_cfg.n_layers + 1


class StreamEvent(NamedTuple):
    """Intermediate preview from a streaming sampler: the carry's latents
    after ``step`` completed denoising steps (1-based, < num_sample_steps --
    the final state arrives as the terminating ``SampleOutput``, never as a
    ``StreamEvent``)."""
    step: int
    latents: jax.Array


def _model_eval(model_cfg: ModelConfig, params, latents, t, cond, text,
                drift_inputs, gates=(None, None)):
    """One denoiser evaluation, optionally DRIFT-protected."""
    scfg, key, step_idx, ber_by_class, stores, have_ckpt = drift_inputs
    if scfg.mode == "clean":
        # quantized error-free baseline == drift path at BER 0 (same GEMMs,
        # detections provably empty); reuse the store plumbing.
        scfg = dataclasses.replace(scfg, mode="drift")
        ber_by_class = jnp.zeros_like(ber_by_class)
    zero_rows = jnp.zeros((detection_rows(model_cfg),), jnp.int32)
    if scfg.mode == "float_clean":
        if model_cfg.family == "unet":
            return unet_lib.forward(model_cfg, params, latents, t, text), \
                stores, jnp.int32(0), jnp.int32(0), zero_rows
        eps, _, _ = dit_lib.forward(model_cfg, params, latents, t, cond,
                                    text=text)
        return eps, stores, jnp.int32(0), jnp.int32(0), zero_rows

    if model_cfg.family == "unet":
        ctx = ExecContext(scfg, key=key, step=step_idx,
                          ber_by_class=ber_by_class, state_in=stores,
                          have_ckpt=have_ckpt)
        eps = unet_lib.forward(model_cfg, params, latents, t, text, ctx=ctx)
        new_stores = ctx.state_out if ctx.state_out else stores
        detected = ctx.stats["detected_row_errors"]
        return eps, new_stores, ctx.stats["corrected_elems"], detected, \
            jnp.asarray(detected, jnp.int32)[None]

    embed_store, block_store = stores
    ds = dit_lib.DriftState(cfg=scfg, key=key, step=step_idx,
                            ber_by_class=ber_by_class,
                            embed_store=embed_store,
                            block_store=block_store, have_ckpt=have_ckpt,
                            layer_gate=gates[0], embed_gate=gates[1])
    eps, new_ds, stats = dit_lib.forward(model_cfg, params, latents, t, cond,
                                         text=text, drift=ds)
    corrected = stats.get("corrected_elems", jnp.int32(0))
    detected = stats.get("detected_row_errors", jnp.int32(0))
    det_blocks = stats.get("detected_per_block", zero_rows)
    # Modes that never write checkpoints (faulty / zeroing / recompute
    # baselines) return empty stores; keep the carry structure stable.
    new_embed = new_ds.embed_store if new_ds.embed_store else embed_store
    new_block = (new_ds.block_store
                 if jax.tree_util.tree_leaves(new_ds.block_store)
                 else block_store)
    return eps, (new_embed, new_block), corrected, detected, det_blocks


def init_stores(model_cfg: ModelConfig, params, latents, t, cond, text,
                scfg: DriftSystemConfig):
    """Zero checkpoint stores with the right structure (eval_shape, no run)."""
    if scfg.mode == "float_clean":
        return ()
    if model_cfg.family == "unet":
        def probe():
            ctx = ExecContext(dataclasses.replace(scfg, mode="drift"),
                              key=jax.random.PRNGKey(0), step=0,
                              ber_by_class=jnp.zeros(3), state_in={},
                              have_ckpt=False)
            unet_lib.forward(model_cfg, params, latents, t, text, ctx=ctx)
            return ctx.state_out
        spec = jax.eval_shape(probe)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    return dit_lib.drift_store_spec(model_cfg, latents.shape[0])


def _schedule_arrays(cfg: SamplerConfig):
    """(DDPM schedule, DDIM timesteps, next-timesteps, BER table) -- the
    trace-free per-run constants shared by one-shot and streamed sampling."""
    sched = sched_lib.DdpmSchedule.default(cfg.num_train_steps)
    ts = sched_lib.ddim_timesteps(cfg.num_train_steps, cfg.num_sample_steps)
    t_prev = np.concatenate([ts[1:], [-1]]).astype(np.int32)
    if cfg.schedule is not None:
        ber_table = cfg.schedule.ber_table
    else:
        ber_table = jnp.zeros((cfg.num_sample_steps, dvfs_lib.N_CLASSES))
    return sched, ts, t_prev, ber_table


def _init_carry(model_cfg: ModelConfig, params, latents0, cond, text,
                cfg: SamplerConfig, monitor0, ts):
    b = latents0.shape[0]
    t0 = jnp.full((b,), float(ts[0]), jnp.float32)
    stores0 = init_stores(model_cfg, params, latents0, t0, cond, text,
                          cfg.drift)
    taylor0 = ts_lib.init_state(latents0.shape)
    mon0 = monitor0 if monitor0 is not None else dvfs_lib.ber_monitor_init()
    return (latents0, stores0, taylor0, mon0, jnp.int32(0), jnp.int32(0))


def _make_step_fn(model_cfg: ModelConfig, cfg: SamplerConfig, sched,
                  ber_table, params, key, cond, text):
    """One denoising step of the sampling scan. Everything step-dependent
    (step index, timesteps) flows through the scan inputs, so the SAME step
    function drives both the one-shot full-length scan and the chunked
    streaming windows -- that is what makes the two paths bit-identical."""

    def step_fn(carry, inp):
        latents, stores, taylor, mon, corrected, nevals = carry
        i, t_now, t_nxt = inp
        b = latents.shape[0]
        tvec = jnp.full((b,), t_now, jnp.float32)
        ber_by_class = ber_table[jnp.minimum(i, ber_table.shape[0] - 1)]
        drift_inputs = (cfg.drift, jax.random.fold_in(key, i), i,
                        ber_by_class, stores, i > 0)

        def do_compute(_):
            eps, new_stores, corr, detected, det_blocks = _model_eval(
                model_cfg, params, latents, tvec, cond, text, drift_inputs,
                gates=(cfg.layer_gate, cfg.embed_gate))
            new_taylor = ts_lib.update_on_compute(taylor, eps)
            return (eps, new_stores, new_taylor, corr, detected, det_blocks,
                    jnp.int32(1))

        def do_forecast(_):
            k = i % cfg.taylorseer.interval
            eps = ts_lib.forecast(taylor, k, cfg.taylorseer.interval,
                                  cfg.taylorseer.order)
            return (eps, stores, taylor, jnp.int32(0), jnp.int32(0),
                    jnp.zeros((detection_rows(model_cfg),), jnp.int32),
                    jnp.int32(0))

        if cfg.taylorseer.enabled:
            eps, stores2, taylor2, corr, detected, det_blocks, ran = \
                jax.lax.cond(ts_lib.should_compute(i, cfg.taylorseer),
                             do_compute, do_forecast, operand=None)
        else:
            eps, stores2, taylor2, corr, detected, det_blocks, ran = \
                do_compute(None)

        if cfg.precision.narrowed:
            # Narrowed precision plan: fake-quantize the denoiser output on
            # resilient timesteps only; the first ``protect_steps`` steps
            # stay full-width (the same protection window the DVFS schedule
            # gives ``nominal_steps``). Python-gated, so the default plan
            # adds nothing to the trace.
            qeps = quant_lib.fake_quant(eps, cfg.precision.body_bits)
            eps = jnp.where(i >= cfg.precision.protect_steps, qeps, eps)

        n_words = max(int(np.prod(latents.shape)), 1)
        mon2 = dvfs_lib.ber_monitor_update(
            mon, detected, n_words, cfg.drift.abft.threshold_bit,
            cfg.monitor_target_ber)
        new_latents = sched.ddim_step(latents, eps, t_now, t_nxt)
        # The per-site detection vector rides the scan's ys slot: stacked
        # over steps it becomes the (steps, rows) resilience heatmap.
        return (new_latents, stores2, taylor2, mon2,
                corrected + corr, nevals + ran), det_blocks

    return step_fn


def _scan_xs(ts, t_prev):
    return (jnp.arange(len(ts), dtype=jnp.int32),
            jnp.asarray(ts), jnp.asarray(t_prev))


def sample(model_cfg: ModelConfig, params, key: jax.Array,
           latents0: jax.Array, cond, text,
           cfg: SamplerConfig,
           monitor0: Optional[dvfs_lib.BerMonitorState] = None
           ) -> SampleOutput:
    """Run the full denoising chain from Gaussian latents.

    ``monitor0`` seeds the runtime BER monitor; passing the previous batch's
    ``SampleOutput.monitor`` carries the Sec 5.1 feedback loop across batches
    (the serving engine does), while ``None`` starts from a fresh estimate.
    """
    sched, ts, t_prev, ber_table = _schedule_arrays(cfg)
    carry0 = _init_carry(model_cfg, params, latents0, cond, text, cfg,
                         monitor0, ts)
    step_fn = _make_step_fn(model_cfg, cfg, sched, ber_table, params, key,
                            cond, text)
    (latents, _, _, mon, corrected, nevals), heatmap = jax.lax.scan(
        step_fn, carry0, _scan_xs(ts, t_prev))
    return SampleOutput(latents, mon, corrected, nevals, heatmap)


def sample_stream(model_cfg: ModelConfig, params, key: jax.Array,
                  latents0: jax.Array, cond, text,
                  cfg: SamplerConfig,
                  monitor0: Optional[dvfs_lib.BerMonitorState] = None,
                  window: int = 1,
                  on_window: Optional[Callable[[int], None]] = None,
                  on_carry: Optional[Callable[[int, Tuple], None]] = None,
                  _window_runner: Optional[Callable] = None):
    """Generator form of :func:`sample`: the same denoising scan chunked
    into windows of ``window`` steps, yielding a :class:`StreamEvent`
    (completed-step count + current latents) after every window except the
    last, then the final :class:`SampleOutput` as the terminating item.

    The per-step computation is the one-shot scan's step function verbatim
    (all step-dependent state rides the scan inputs), so the final latents
    are bit-identical to ``sample``'s. Call with ``_window_runner`` from
    ``make_sampler(stream_window=...)`` to drive a pre-jitted window (the
    serving path); without it each window scans un-jitted (fine for tests
    and small smoke runs). ``on_window`` is a host-side tap fired with the
    completed-step count after every window (including the last) -- the
    serving telemetry counts stream windows with it; it never runs inside
    a trace, so it cannot perturb the computation. ``on_carry`` is the
    same tap handed the full scan carry as well (completed steps, carry)
    -- the checkpoint-offload store snapshots the carry's rollback stores
    through it (``repro.serving.offload``); like ``on_window`` it runs
    strictly host-side between windows, so enabling it cannot change the
    computed latents.
    """
    assert window >= 1, window
    sched, ts, t_prev, ber_table = _schedule_arrays(cfg)
    carry = _init_carry(model_cfg, params, latents0, cond, text, cfg,
                        monitor0, ts)
    xs = _scan_xs(ts, t_prev)
    n = len(ts)

    if _window_runner is None:
        def _window_runner(params, key, cond, text, carry, xs_slice):
            step_fn = _make_step_fn(model_cfg, cfg, sched, ber_table,
                                    params, key, cond, text)
            return jax.lax.scan(step_fn, carry, xs_slice)

    heat_chunks = []
    for start in range(0, n, window):
        xs_slice = tuple(x[start:start + window] for x in xs)
        carry, heat = _window_runner(params, key, cond, text, carry, xs_slice)
        heat_chunks.append(heat)
        done = min(start + window, n)
        if on_carry is not None:
            on_carry(done, carry)
        if on_window is not None:
            on_window(done)
        if done < n:
            yield StreamEvent(step=done, latents=carry[0])
    latents, _, _, mon, corrected, nevals = carry
    # Concatenating the windows' stacked ys reproduces the one-shot scan's
    # (steps, rows) heatmap exactly -- integer counts, no accumulation
    # order to differ on.
    heatmap = jnp.concatenate(heat_chunks, axis=0)
    yield SampleOutput(latents, mon, corrected, nevals, heatmap)


def make_sampler(model_cfg: ModelConfig, cfg: SamplerConfig,
                 on_trace: Optional[Callable[[], None]] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 stream_window: int = 0,
                 on_window: Optional[Callable[[int], None]] = None,
                 on_carry: Optional[Callable[[int, Tuple], None]] = None):
    """Build a reusable jitted sampling entry point for one configuration.

    Returns ``run(params, key, latents0, cond, text, monitor0)`` ->
    ``SampleOutput``. The model/sampler configs are closed over, so repeated
    calls with same-shaped arrays never retrace: this is the unit the serving
    engine caches per (arch, steps, mode, operating point, batch bucket).

    ``on_trace`` fires once per (re)trace -- a Python side effect that only
    runs while JAX is staging the function, so the serving tests use it as an
    exact compile counter.

    ``mesh`` makes the sampler shard-aware (the ``ShardedDriftServeEngine``
    path): the latents batch is pinned to the mesh's data axes with
    ``repro.distributed.sharding.batch_spec`` on entry and exit, and the
    scalar outputs (BER-monitor state, corrected-element / model-eval
    counts) are pinned to replicated -- the detected-error sums feeding the
    monitor are reduced over the batch-sharded dimension, so GSPMD lowers
    them to a cross-device psum and every device carries the same ladder
    state. ``mesh=None`` is the single-device path, byte-for-byte the old
    behavior.

    ``stream_window=k`` (k >= 1) returns a STREAMING entry point instead:
    calling it yields :class:`StreamEvent` previews every ``k`` denoising
    steps and terminates with the :class:`SampleOutput` (see
    :func:`sample_stream`). One window of ``k`` steps is jitted once and
    reused for every full window of every call; a trailing partial window
    (when ``k`` does not divide the step count) is a second, shorter trace
    -- so a streamed configuration costs at most two traces where the
    one-shot sampler costs one. The serving engine keys its compiled-sampler
    cache on the window size (``SamplerKey.stream``). ``on_window`` (only
    meaningful with ``stream_window``) fires host-side after each completed
    window with the done-step count -- the serving telemetry's stream tap.
    ``on_carry`` additionally hands that tap the scan carry itself: the
    async checkpoint-offload store (``repro.serving.offload``) commits the
    carry's rollback stores host-side through it, overlapped with the next
    window. Both hooks run outside any trace and cannot change the
    computation.
    """
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.distributed import sharding as shd

        replicated = NamedSharding(mesh, PartitionSpec())

        def _pin_batch(x):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, shd.batch_spec(x.shape, mesh)))

        def _pin_carry(carry):
            """Same placement contract as the one-shot wrapper, applied at
            window boundaries: latents on the data axes, monitor + scalar
            counters replicated; stores/taylor follow GSPMD propagation."""
            latents, stores, taylor, mon, corrected, nevals = carry
            pin_rep = lambda x: jax.lax.with_sharding_constraint(x,
                                                                 replicated)
            return (_pin_batch(latents), stores, taylor,
                    jax.tree.map(pin_rep, mon), pin_rep(corrected),
                    pin_rep(nevals))

    if stream_window:
        assert stream_window >= 1, stream_window
        sched, _, _, ber_table = _schedule_arrays(cfg)

        def _window(params, key, cond, text, carry, xs_slice):
            if on_trace is not None:
                on_trace()
            if mesh is not None:
                carry = _pin_carry(carry)
            step_fn = _make_step_fn(model_cfg, cfg, sched, ber_table,
                                    params, key, cond, text)
            carry, heat = jax.lax.scan(step_fn, carry, xs_slice)
            if mesh is not None:
                # Per-step detection rows are already batch-reduced sums,
                # so replicating them lowers to the same psum the monitor
                # state uses.
                carry = _pin_carry(carry)
                heat = jax.lax.with_sharding_constraint(heat, replicated)
            return carry, heat

        window_jit = jax.jit(_window)

        def _run_stream(params, key, latents0, cond, text, monitor0):
            return sample_stream(model_cfg, params, key, latents0, cond,
                                 text, cfg, monitor0=monitor0,
                                 window=stream_window, on_window=on_window,
                                 on_carry=on_carry,
                                 _window_runner=window_jit)
        return _run_stream

    def _run(params, key, latents0, cond, text, monitor0):
        if on_trace is not None:
            on_trace()
        if mesh is None:
            return sample(model_cfg, params, key, latents0, cond, text, cfg,
                          monitor0=monitor0)
        out = sample(model_cfg, params, key, _pin_batch(latents0), cond,
                     text, cfg, monitor0=monitor0)
        pin_rep = lambda x: jax.lax.with_sharding_constraint(x, replicated)
        return SampleOutput(
            latents=_pin_batch(out.latents),
            monitor=jax.tree.map(pin_rep, out.monitor),
            total_corrected=pin_rep(out.total_corrected),
            n_model_evals=pin_rep(out.n_model_evals),
            heatmap=pin_rep(out.heatmap))
    return jax.jit(_run)
