"""Diffusion noise schedules: DDPM forward process + DDIM sampling steps."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DdpmSchedule:
    betas: jax.Array            # (T,)
    alphas_cum: jax.Array       # (T,) cumulative prod of (1 - beta)
    num_steps: int

    @staticmethod
    def default(num_steps: int = 1000, beta_start: float = 1e-4,
                beta_end: float = 2e-2) -> "DdpmSchedule":
        betas = np.linspace(beta_start, beta_end, num_steps, dtype=np.float32)
        alphas_cum = np.cumprod(1.0 - betas)
        return DdpmSchedule(jnp.asarray(betas), jnp.asarray(alphas_cum),
                            num_steps)

    def q_sample(self, x0: jax.Array, t: jax.Array, eps: jax.Array
                 ) -> jax.Array:
        """Forward noising: x_t = sqrt(a_t) x0 + sqrt(1-a_t) eps. t: (B,)."""
        a = self.alphas_cum[t]
        sh = (-1,) + (1,) * (x0.ndim - 1)
        return (jnp.sqrt(a).reshape(sh) * x0
                + jnp.sqrt(1.0 - a).reshape(sh) * eps)

    def ddim_step(self, x_t: jax.Array, eps_pred: jax.Array, t, t_prev
                  ) -> jax.Array:
        """Deterministic DDIM update from step t to t_prev (eta=0)."""
        a_t = self.alphas_cum[jnp.maximum(t, 0)]
        a_p = jnp.where(t_prev >= 0, self.alphas_cum[jnp.maximum(t_prev, 0)],
                        jnp.float32(1.0))
        x0 = (x_t - jnp.sqrt(1.0 - a_t) * eps_pred) / jnp.sqrt(a_t)
        x0 = jnp.clip(x0, -4.0, 4.0)
        return jnp.sqrt(a_p) * x0 + jnp.sqrt(1.0 - a_p) * eps_pred


def ddim_timesteps(num_train_steps: int, num_sample_steps: int) -> np.ndarray:
    """Evenly spaced sampling timesteps, descending (e.g. 1000 -> 50)."""
    return np.linspace(num_train_steps - 1, 0, num_sample_steps
                       ).round().astype(np.int32)
