"""Elastic scaling + straggler mitigation policies.

This module holds the *decision logic* (pure, unit-testable); the actuation
is launch-level (re-create the mesh, restore-resharded from the checkpoint
manager). On thousands of nodes the failure model is: a host vanishes
(preemption/hardware), a host slows down (thermals, flaky HBM, network), or
a pod-link degrades.

  * ``plan_mesh``: given the surviving device count, pick the largest valid
    (pod, data, model) factorization that keeps the model axis intact
    (TP degree is fixed by memory), shrinking data parallelism first --
    restore-resharded then maps the old state onto the new mesh.
  * ``StragglerDetector``: per-host step-time EMA; a host is a straggler
    when its EMA exceeds median * threshold. Mitigation at this layer is
    deterministic data re-dispatch: the synthetic/deterministic pipeline
    lets any host regenerate any shard, so reassigning shards needs no data
    movement -- plus (documented) gradient-bucket overlap so a slow host
    only delays its last bucket, not the whole all-reduce.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


def plan_mesh(n_devices: int, model_parallel: int,
              chips_per_pod: int = 256
              ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (pod, data, model) grid for the surviving device count.

    The pod axis reflects PHYSICAL pods (256 chips each); partial pods fall
    back to one flat data axis (a degraded-but-running configuration)."""
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices cannot keep TP={model_parallel}")
    rest = n_devices // model_parallel
    pods = n_devices // chips_per_pod if n_devices % chips_per_pod == 0 else 1
    if pods > 1 and rest % pods == 0:
        return (pods, rest // pods, model_parallel), ("pod", "data", "model")
    return (rest, model_parallel), ("data", "model")


@dataclasses.dataclass
class StragglerDetector:
    threshold: float = 1.5
    decay: float = 0.8
    ema: Dict[int, float] = dataclasses.field(default_factory=dict)

    def update(self, host_times: Dict[int, float]) -> List[int]:
        """Feed per-host step times; returns current straggler host ids."""
        for h, t in host_times.items():
            self.ema[h] = (self.decay * self.ema.get(h, t)
                           + (1 - self.decay) * t)
        if len(self.ema) < 2:
            return []
        med = float(np.median(list(self.ema.values())))
        return [h for h, t in self.ema.items() if t > self.threshold * med]

    def reassign_shards(self, shards: Dict[int, int],
                        stragglers: List[int]) -> Dict[int, int]:
        """Move shards off stragglers onto the fastest hosts (deterministic
        pipeline => reassignment is just an index remap, no data motion)."""
        if not stragglers:
            return dict(shards)
        healthy = sorted([h for h in shards if h not in stragglers],
                         key=lambda h: self.ema.get(h, 0.0))
        out = dict(shards)
        for i, s in enumerate(stragglers):
            if healthy:
                out[s], out[healthy[i % len(healthy)]] = \
                    out[healthy[i % len(healthy)]], out[s]
        return out
