"""Activation sharding constraints (the GSPMD anchor points).

Without explicit activation constraints, sharding propagation is free to
resolve weight-vs-activation conflicts by REPLICATING activations -- e.g.
the FSDP-sharded embedding table (d_model on 'data') clashing with
batch-on-'data' token activations silently un-shards the batch for the
whole network (observed: per-device attention scores with the full global
batch). Production JAX frameworks pin activations with
``with_sharding_constraint`` at layer boundaries; this module is that
mechanism, behind a process-global policy so single-device tests/smoke
runs pay nothing.

Usage (launcher):
    constraints.set_policy(constraints.MeshPolicy(mesh))
    ... lower/compile under `with mesh:` ...
Models call ``constrain(x, "act")`` at anchor points.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

_POLICY: Optional["MeshPolicy"] = None


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


@dataclasses.dataclass
class MeshPolicy:
    mesh: Mesh
    # shard the embedding dim of activations on 'model' (sequence-parallel
    # style)? default off; the perf pass flips it per-cell.
    shard_act_dmodel: bool = False
    # treat EVERY mesh axis as data parallel (small models: replicate
    # weights, shard batch 1-per-chip; hillclimb #3)
    dp_over_all: bool = False

    @property
    def data_axes(self) -> Tuple[str, ...]:
        if self.dp_over_all:
            return tuple(self.mesh.axis_names)
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def dp(self):
        d = self.data_axes
        return d if len(d) > 1 else (d[0] if d else None)

    @property
    def dsize(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    def msize(self) -> int:
        return axis_size(self.mesh, "model")

    def spec(self, kind: str, shape: Tuple[int, ...]) -> Optional[P]:
        batch_ok = shape[0] % max(self.dsize, 1) == 0 and self.dsize > 1
        dp = self.dp if batch_ok else None
        last_model = "model" if self.shard_act_dmodel else None
        if kind == "act":        # (B, S, D) and friends
            mid = [None] * (len(shape) - 2)
            return P(dp, *mid, last_model)
        if kind == "logits":     # (B, S, V) -- vocab stays model-sharded
            mid = [None] * (len(shape) - 2)
            return P(dp, *mid, "model")
        if kind == "batch_only":
            return P(dp, *([None] * (len(shape) - 1)))
        if kind == "tokens2d":   # (T, d) flattened token streams (MoE)
            return P(dp, None)
        if kind == "slots2d":    # (E*C, d) expert-major flat slot space
            msize = axis_size(self.mesh, "model")
            if shape[0] % max(msize, 1) == 0 and msize > 1:
                return P("model", None)
            return None
        if kind == "w2d_model":  # (K, N) int8 weights: gathered over data,
            # output dim on model (the DRIFT quantized-GEMM layout)
            msize = axis_size(self.mesh, "model")
            if len(shape) == 2 and shape[1] % max(msize, 1) == 0 and msize > 1:
                return P(None, "model")
            return P(*([None] * len(shape)))
        if kind == "experts":    # (E, C, d) dispatched slots -- EP layout;
            # E on 'model' AND capacity on data: the expert GEMM is then
            # fully partitioned (E/m x C/d x d x f per device). E-only
            # sharding lets GSPMD replicate the einsum over the data axis
            # (measured 6.5x compute blowup; see EXPERIMENTS.md Perf #2).
            msize = axis_size(self.mesh, "model")
            cap_dp = (self.dp if len(shape) >= 2
                      and shape[1] % max(self.dsize, 1) == 0
                      and self.dsize > 1 else None)
            if shape[0] % max(msize, 1) == 0 and msize > 1:
                return P("model", cap_dp, None)
            return None
        return None


def set_policy(policy: Optional[MeshPolicy]) -> None:
    global _POLICY
    _POLICY = policy


def get_policy() -> Optional[MeshPolicy]:
    return _POLICY


def constrain(x: jax.Array, kind: str = "act") -> jax.Array:
    if _POLICY is None or not hasattr(x, "ndim") or x.ndim < 2:
        return x
    spec = _POLICY.spec(kind, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
