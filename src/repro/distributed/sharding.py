"""Per-architecture sharding rules for the (pod, data, model) mesh.

Conventions (MaxText-style FSDP + TP/EP):
  * ``model`` axis: tensor parallel -- attention heads / FFN hidden / vocab /
    MoE experts / SSM inner channels.
  * ``data`` axis (+ ``pod`` when present): data parallel for activations,
    FSDP ("zero-3") for weights and optimizer state -- every weight matrix is
    additionally sharded along its non-TP dimension, so even kimi-k2's
    ~2 TB of bf16 weights fit (~4 GB/chip at 512 ways).
  * Batch shards on ("pod", "data") when divisible; the 500k-decode cell
    (batch=1) replicates batch and shards the KV-cache/state sequence dim
    instead.
  * KV caches shard heads on ``model`` when kv_heads divides the axis, else
    the sequence dim (GQA kv=2 cases like glm4 would pad 8x otherwise).

Everything is expressed as PartitionSpec trees matched by parameter path,
consumed by pjit in launch/{dryrun,train}.py and by the sharded serving
engine (``repro.serving.sharded``), whose diffusion-side mapping is:
latents batch on ``data`` (``batch_spec``), DiT weights tensor-parallel on
``model`` per the rules below, BER-monitor state replicated
(``replicated``). See docs/serving.md for the full mesh/axis table.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


# --------------------------------------------------------------- params
_RULES = [
    # (path regex, spec builder(d_axes))  -- applied to the LAST dims;
    # stacked-layer leading L handled by padding None on the left.
    # NOTE: order matters -- expert-parallel MoE rules must precede the
    # generic w_gate/w_up/w_down rules.
    (r"moe/w_gate$",           lambda d: P("model", d, None)),  # (E, dm, f)
    (r"moe/w_up$",             lambda d: P("model", d, None)),
    (r"moe/w_down$",           lambda d: P("model", None, d)),
    (r"embed$",                lambda d: P("model", d)),        # (V, dm)
    (r"lm_head$",              lambda d: P(d, "model")),        # (dm, V)
    (r"(wq|wk|wv)$",           lambda d: P(d, "model")),
    (r"wo$",                   lambda d: P("model", d)),
    (r"(w_gate|w_up|mlp_w1|t_w1|t_w2|adaln_w|in_proj|patch_w|text_proj)$",
                               lambda d: P(d, "model")),
    (r"(w_down|mlp_w2|out_proj)$", lambda d: P("model", d)),
    (r"final_adaln_w$",        lambda d: P(d, "model")),
    (r"final_w$",              lambda d: P(d, None)),
    (r"router$",               lambda d: P(None, None)),        # tiny; repl
                                                                # avoids d-dim
                                                                # conflicts
    (r"conv_w$",               lambda d: P(None, "model")),     # (cw, cch)
    (r"(conv_b|norm_scale)$",  lambda d: P("model",)),
    (r"pos_embed|enc_pos",     lambda d: P(None, None)),
    (r"class_embed$",          lambda d: P(None, d)),
    (r"(conv1|conv2|skip|down|up|conv_in|conv_out)$",
                               lambda d: P(None, None, None, "model")),
    (r"temb_w$",               lambda d: P(d, "model")),
]


def _axes_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    return int(np.prod([axis_size(mesh, a) for a in names]))


def _fix_divisibility(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes from dims they do not divide (pjit requires exact
    divisibility for explicit arg shardings -- e.g. mamba2's vocab 50280 or
    hymba's in_proj 6482 are not multiples of 16)."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is not None and dim % _axes_size(mesh, entry) != 0:
            entry = None
        out.append(entry)
    return P(*out[: len(shape)])


def spec_for_param(path: str, shape, mesh: Mesh) -> P:
    ndim = len(shape)
    d = data_axes(mesh)
    d = d if len(d) > 1 else (d[0] if d else None)
    for pat, builder in _RULES:
        if re.search(pat, path):
            spec = builder(d)
            pad = ndim - len(spec)
            if pad < 0:   # param has fewer dims than the rule (e.g. bias)
                spec = P(*spec[-ndim:]) if ndim else P()
            else:
                spec = P(*([None] * pad + list(spec)))
            return _fix_divisibility(spec, shape, mesh)
    return P(*([None] * ndim))   # replicate (norms, scalars, biases)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def param_specs(tree: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree for a parameter pytree (incl. optimizer state)."""

    def one(path, leaf):
        p = _path_str(path)
        shape = tuple(leaf.shape) if hasattr(leaf, "shape") else ()
        return spec_for_param(p, shape, mesh)

    return jax.tree_util.tree_map_with_path(one, tree)


def shardings_for(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(tree, mesh))


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully-replicated sharding: every device holds the whole array (the
    serving engine's BER-monitor state and scalar counters)."""
    return NamedSharding(mesh, P())


def spec_str(spec: P) -> str:
    """Canonical short string for a PartitionSpec, e.g. ``"data,None,None"``
    -- hashable mesh-placement component of the serving ``SamplerKey``."""
    def one(entry):
        if isinstance(entry, tuple):
            return "+".join(str(a) for a in entry)
        return str(entry)
    return ",".join(one(e) for e in spec)


# --------------------------------------------------------------- batches
def batch_spec(shape: Tuple[int, ...], mesh: Mesh,
               seq_dim: Optional[int] = None) -> P:
    """Shard dim 0 (batch) over (pod, data) when divisible; else fall back
    to sharding ``seq_dim`` and replicating batch (the batch=1 long-decode
    cell)."""
    d = data_axes(mesh)
    dsize = int(np.prod([axis_size(mesh, a) for a in d]))
    spec = [None] * len(shape)
    if shape[0] % dsize == 0 and dsize > 1:
        spec[0] = d if len(d) > 1 else d[0]
    elif seq_dim is not None and shape[seq_dim] % dsize == 0:
        spec[seq_dim] = d if len(d) > 1 else d[0]
    return P(*spec)


def cache_spec(cfg: ModelConfig, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """(L, B, S, Hkv, hd) KV-cache sharding."""
    d = data_axes(mesh)
    dsize = int(np.prod([axis_size(mesh, a) for a in d]))
    msize = axis_size(mesh, "model")
    l_, b, s, hkv, hd = shape
    spec: list = [None, None, None, None, None]
    if b % dsize == 0 and dsize > 1:
        spec[1] = d if len(d) > 1 else d[0]
        if hkv % msize == 0:
            spec[3] = "model"
        else:
            spec[2] = "model"           # glm4/gemma2/kimi GQA: shard seq
    else:
        # batch=1 long-context: shard sequence over everything useful
        spec[2] = d if len(d) > 1 else d[0]
        if hkv % msize == 0:
            spec[3] = "model"
    return P(*spec)


def ssm_state_spec(cfg: ModelConfig, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """(L, B, G, Hg, N, P) SSD state: shard heads on model, batch on data."""
    d = data_axes(mesh)
    dsize = int(np.prod([axis_size(mesh, a) for a in d]))
    spec: list = [None] * len(shape)
    if len(shape) >= 2 and shape[1] % dsize == 0 and dsize > 1:
        spec[1] = d if len(d) > 1 else d[0]
    if len(shape) >= 4:
        msize = axis_size(mesh, "model")
        if shape[3] % msize == 0:
            spec[3] = "model"
    return P(*spec)


def logits_spec(mesh: Mesh) -> P:
    d = data_axes(mesh)
    return P(d if len(d) > 1 else (d[0] if d else None), None, "model")
