"""Hardware constants: the paper's accelerator and the TPU v5e roofline.

Paper accelerator (Sec 6.1): 64 systolic arrays (default 32x32, int8
multipliers + int32 accumulators), nominal 0.9 V / 2 GHz, HBM2 off-chip,
synthesized on a commercial 14nm PDK. Peak int8 throughput:
64 arrays x 32x32 MACs x 2 GHz x 2 ops = 262 Tops.

TPU v5e (the dry-run/roofline target given by the assignment):
197 TFLOP/s bf16 per chip, 819 GB/s HBM BW, ~50 GB/s/link ICI (about 100
GB/s bidirectional per axis neighbor on a 2-link torus axis; we use the
assignment's 50 GB/s per link figure).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperAccel:
    n_arrays: int = 64
    array_dim: int = 32
    freq_ghz: float = 2.0
    voltage: float = 0.9
    sram_bytes: int = 32 * 1024 * 1024
    dram_row_bytes: int = 2048          # HBM2 row buffer per pseudo-channel
    hbm_gbps: float = 450.0             # HBM2
    # energy constants (14nm-class, calibrated so DiT-XL-512 @50 DDIM steps
    # matches Table 1 baseline 6.02 J / 0.56 s -- see energy.py calibrate())
    e_mac_pj: float = 0.45              # int8 MAC at nominal V (incl. SRAM)
    e_dram_pj_per_byte: float = 25.0
    static_w: float = 8.0

    @property
    def peak_macs_per_s(self) -> float:
        return (self.n_arrays * self.array_dim ** 2 * self.freq_ghz * 1e9)


@dataclasses.dataclass(frozen=True)
class TpuV5e:
    peak_flops_bf16: float = 197e12
    hbm_bytes_per_s: float = 819e9
    ici_bytes_per_s_per_link: float = 50e9
    hbm_bytes: int = 16 * 1024 ** 3


PAPER_ACCEL = PaperAccel()
TPU_V5E = TpuV5e()
