"""SCALE-Sim-style analytical cycle model for tiled GEMM on systolic arrays.

Re-implementation of the output-stationary first-order model the paper uses
(SCALE-Sim [60]): an (a x a) array computes one (a x a) output tile per
(K + 2a - 2) cycles (pipeline fill + drain); tiles distribute over the 64
arrays; SRAM/DRAM traffic from the tiling loop order with weight reuse
across the M dimension.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.perfmodel.hw import PaperAccel


@dataclasses.dataclass(frozen=True)
class GemmStats:
    cycles: int
    macs: int
    utilization: float
    dram_read_bytes: int
    dram_write_bytes: int


def gemm(m: int, k: int, n: int, hw: PaperAccel,
         a_bytes: int = 1, b_bytes: int = 1, c_bytes: int = 4) -> GemmStats:
    """Cycle/traffic model for C[m,n] = A[m,k] @ B[k,n]."""
    a = hw.array_dim
    mt, nt = math.ceil(m / a), math.ceil(n / a)
    tile_cycles = k + 2 * a - 2
    waves = math.ceil(mt * nt / hw.n_arrays)
    cycles = waves * tile_cycles
    macs = m * k * n
    peak = hw.n_arrays * a * a * cycles
    util = macs / max(peak, 1)
    # weights stream once per column block; activations reread per col block
    # unless they fit SRAM (simple capacity check)
    a_total = m * k * a_bytes
    fits = a_total <= hw.sram_bytes // 2
    dram_read = k * n * b_bytes + (a_total if fits else a_total * nt)
    dram_write = m * n * c_bytes
    return GemmStats(cycles, macs, util, int(dram_read), int(dram_write))


def gemm_seconds(m: int, k: int, n: int, hw: PaperAccel,
                 freq_ghz: float | None = None) -> float:
    f = (freq_ghz or hw.freq_ghz) * 1e9
    return gemm(m, k, n, hw).cycles / f


def abft_overhead_ratio(m: int, k: int, n: int, hw: PaperAccel) -> float:
    """Extra MACs for the checksum lanes: one extra row + column per tile.

    Classic ABFT on an (a x a) tile adds (2a+1)/a^2 of the tile's MACs --
    6.35% at a=32, matching the paper's measured ~6.3% ABFT-wrapper power
    (comparator/monitor logic is noise at synthesis, Sec 6.2).
    """
    a = hw.array_dim
    return (2 * a + 1) / (a * a)
