"""Energy/latency model for DRIFT runs: Table 1, Figs 11-14 arithmetic.

Domain decomposition per generated sample (one voltage domain for the
accelerator die -- MACs, SRAM, memory controller/PHY all scale ~V^2; DRAM
*device* energy and leakage do not):

  E = MACs * e_mac * (V/V0)^2 * (1 + abft)        on-die compute + SRAM
    + DRAM_dev_bytes * e_dram * (1 + mem_ovh)     fixed (device) energy
    + P_static * T * (V/V0)                       leakage ~ V

  T = sum over computed steps of  t_nom * (emb + (1-emb) * f0/f)
      (compute-bound; checkpoint offload + recovery reads overlap, Sec 5.4)

Calibration (``calibrate()``): e_mac / e_dram / P_static / utilization are
fit once so the *nominal* DiT-XL-512 run reproduces Table 1's baseline
(6.02 J, 0.56 s) with the compute-dominant split of Fig 11(b)
(~92% die / 6% DRAM device / 2% leakage). Everything else -- the 36%
undervolt saving, the 1.7x overclock speedup, the <3% DRIFT memory
overhead, the DSE sweeps -- is then model OUTPUT, not fit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import dvfs as dvfs_lib
from repro.core.rollback import DEFAULT_INTERVAL
from repro.models.common import ModelConfig
from repro.perfmodel import flops as flops_lib
from repro.perfmodel import scalesim
from repro.perfmodel.hw import PAPER_ACCEL, PaperAccel


@dataclasses.dataclass(frozen=True)
class RunConfig:
    num_steps: int = 50
    nominal_steps: int = 2
    aggressive: dvfs_lib.OperatingPoint = dvfs_lib.UNDERVOLT
    abft_enabled: bool = True
    ckpt_interval: int = DEFAULT_INTERVAL
    embed_mac_fraction: float = 0.02     # embeds' share of per-step MACs
    taylorseer_interval: int = 0         # 0 = disabled
    # Operand width of the resilient body blocks on aggressive steps
    # (core.quant.PrecisionPlan.body_bits); 8 = the INT8 baseline, priced
    # (and computed) identically to the pre-precision-plan model. The
    # protected fraction (embeds/first block, first nominal_steps) always
    # runs at the baseline width, mirroring the DVFS schedule's protection.
    body_bits: int = 8
    recovery_tiles_per_step: float = 0.0  # from simulation stats
    repacked_layout: bool = True
    # Model evals of ``num_steps`` that were rollback replays (AR window
    # re-decodes). Replays run at the aggressive point like any resilient
    # step, so this splits the ledger's aggressive-compute charge into a
    # first-pass and a replay component without changing the total.
    replay_evals: int = 0


# The energy ledger: every joule run_cost prices lands in exactly one of
# these components, and ``ledger_total`` (a fixed left-to-right sum in this
# order) IS the canonical total -- ``energy_j`` and the legacy aggregate
# keys (e_die/e_dram/e_static/e_drift_mem) are derived from the components,
# never the other way around, so the ledger provably sums to the billed
# total bit for bit (run_cost and per_request_cost alike).
ENERGY_COMPONENTS = (
    "compute_nominal",     # protected steps at (V0, f0), ABFT included
    "compute_aggressive",  # resilient steps: V^2- and precision-scaled MACs
    "compute_replay",      # rollback-replay model evals (AR re-decodes)
    "dram_stream",         # weight/activation streaming per computed step
    "ckpt_refresh",        # rollback-checkpoint refresh writes (offload)
    "recovery",            # rollback recovery tile reads + row overhead
    "static",              # leakage over the run's latency, ~V
)


def ledger_total(breakdown: Dict[str, float]) -> float:
    """The canonical component sum: plain left-to-right addition in
    ``ENERGY_COMPONENTS`` order. Float addition is non-associative, so
    every place that turns a breakdown into a total MUST go through this
    one association -- that is what makes ``sum(components) == energy_j``
    an exact (bitwise) invariant rather than an approximate one."""
    total = 0.0
    for comp in ENERGY_COMPONENTS:
        total += breakdown[comp]
    return total


def _derive_totals(breakdown: Dict[str, float]) -> Dict[str, float]:
    """Aggregate keys recomputed from the (possibly scaled) components,
    each with its own fixed association."""
    return {
        "energy_j": ledger_total(breakdown),
        "e_die": (breakdown["compute_nominal"]
                  + breakdown["compute_aggressive"]
                  + breakdown["compute_replay"]),
        "e_dram": (breakdown["dram_stream"] + breakdown["ckpt_refresh"]
                   + breakdown["recovery"]),
        "e_static": breakdown["static"],
        "e_drift_mem": breakdown["ckpt_refresh"] + breakdown["recovery"],
    }


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    hw: PaperAccel = PAPER_ACCEL
    e_mac_pj: float = 0.12          # on-die energy per MAC (incl. SRAM)
    e_dram_pj_per_byte: float = 4.0  # DRAM device energy
    static_w: float = 0.2
    utilization: float = 0.25       # achieved/peak MACs (SCALE-Sim level)


def model_eval_macs(cfg: ModelConfig, batch: int = 1) -> float:
    return flops_lib.gemm_macs_per_model_eval(cfg, batch)


def dram_bytes_per_eval(cfg: ModelConfig, batch: int = 1) -> float:
    """Weights (int8) streamed once + activation spill traffic."""
    from repro.models import dit as dit_lib
    if cfg.family == "dit":
        n = dit_lib.param_count(cfg)
    else:
        n = model_eval_macs(cfg, 1) / max(cfg.latent_size ** 2, 1)
    return float(n) + 2.0 * activation_bytes(cfg, batch) * 0.25


def activation_bytes(cfg: ModelConfig, batch: int = 1) -> float:
    """Checkpointable GEMM-output volume per step (f32)."""
    if cfg.family == "dit":
        t = (cfg.latent_size // cfg.patch_size) ** 2
        d = cfg.d_model
        per_block = t * (4 * d + 2 * cfg.d_ff + d)
        return 4.0 * batch * cfg.n_layers * per_block
    if cfg.family == "unet":
        s, c = cfg.latent_size, cfg.unet_channels
        return 4.0 * batch * sum((s // 2 ** i) ** 2 * ch * 8
                                 for i, ch in enumerate(c))
    # LM decode step: the projection-GEMM outputs the statistical-ABFT
    # context checks (serving/ar.py) -- attn q/k/v/o plus the dense MLP.
    # SSM layers route no GEMMs through the protected path (0 bytes) and
    # MoE expert FFNs are unprotected, mirroring the coverage documented
    # in docs/servable.md.
    per_layer = 0.0
    if cfg.family != "ssm":
        per_layer += (cfg.n_heads * cfg.hd + 2 * cfg.kv_heads * cfg.hd
                      + cfg.d_model)
        if cfg.family != "moe":
            per_layer += 2.0 * cfg.d_ff + cfg.d_model
    return 4.0 * batch * cfg.n_layers * per_layer


def run_cost(cfg: ModelConfig, rc: RunConfig, batch: int = 1,
             em: EnergyModel = EnergyModel()) -> Dict[str, float]:
    """Energy (J) and latency (s) for one generated sample batch.

    Besides the aggregate keys, the result carries ``"breakdown"``: the
    per-component energy ledger (``ENERGY_COMPONENTS``). The components
    are the primary arithmetic -- ``energy_j`` is exactly
    ``ledger_total(breakdown)``, so component sums reconcile with the
    billed total bit for bit (tests/test_energy_slo.py asserts it across
    the whole configuration matrix).
    """
    hw = em.hw
    macs_step = model_eval_macs(cfg, batch)
    act_bytes = activation_bytes(cfg, batch)
    dram_step = dram_bytes_per_eval(cfg, batch)

    steps = list(range(rc.num_steps))
    if rc.taylorseer_interval > 1:
        computed = [s for s in steps if s % rc.taylorseer_interval == 0
                    or s < rc.nominal_steps]
    else:
        computed = steps
    n_nom = sum(1 for s in computed if s < rc.nominal_steps)
    n_agg = len(computed) - n_nom

    emb = rc.embed_mac_fraction
    abft = scalesim.abft_overhead_ratio(0, 0, 0, hw) if rc.abft_enabled else 0.0
    v0 = dvfs_lib.V_NOMINAL
    vf2 = (rc.aggressive.voltage / v0) ** 2
    e_mac = em.e_mac_pj * 1e-12

    # on-die energy (V^2-scaled for the aggressive fraction; narrowed
    # body operands additionally scale e_mac ~ (bits/8)^2 -- exactly 1.0
    # at the INT8 baseline, so a default precision plan prices identically)
    bscale_e = flops_lib.mac_bit_energy_scale(rc.body_bits)
    bscale_t = flops_lib.mac_bit_time_scale(rc.body_bits)
    e_die_nom = macs_step * e_mac * (1 + abft)
    e_die_agg = macs_step * e_mac * (1 + abft) \
        * (emb + (1 - emb) * vf2 * bscale_e)
    # replay evals are resilient-step re-runs: same aggressive pricing,
    # split out of the first-pass aggressive component for the ledger
    n_rep = min(max(int(rc.replay_evals), 0), n_agg)

    # DRAM device energy + DRIFT overheads (ckpt writes 1/n + recovery reads)
    ckpt_bytes = (len(computed) / max(rc.ckpt_interval, 1)) * act_bytes
    tiles = rc.recovery_tiles_per_step * len(computed)
    rows = tiles * (1.0 if rc.repacked_layout else hw.array_dim)
    recov_bytes = tiles * hw.array_dim ** 2 * 4 + rows * 64  # + row overhead
    e_byte = em.e_dram_pj_per_byte * 1e-12

    # latency: compute-bound, DVFS frequency scaling; narrowed body
    # operands stream faster through the systolic array (~ bits/8)
    t_nom = macs_step / (hw.peak_macs_per_s * em.utilization)
    f_ratio = hw.freq_ghz / rc.aggressive.freq_ghz
    t_agg = t_nom * (emb + (1 - emb) * f_ratio * bscale_t)
    latency = n_nom * t_nom + n_agg * t_agg

    breakdown = {
        "compute_nominal": n_nom * e_die_nom,
        "compute_aggressive": (n_agg - n_rep) * e_die_agg,
        "compute_replay": n_rep * e_die_agg,
        "dram_stream": len(computed) * dram_step * e_byte,
        "ckpt_refresh": ckpt_bytes * e_byte,
        "recovery": recov_bytes * e_byte,
        "static": em.static_w * latency * (rc.aggressive.voltage / v0),
    }
    out = _derive_totals(breakdown)
    out.update({
        "latency_s": latency,
        "abft_overhead": abft,
        "n_computed_steps": float(len(computed)),
        "breakdown": breakdown,
    })
    return out


def per_request_cost(cfg: ModelConfig, rc: RunConfig, batch: int,
                     n_live: int, em: EnergyModel = EnergyModel(),
                     cost: Optional[Dict[str, float]] = None
                     ) -> Dict[str, float]:
    """Attribute one batch-bucket run's cost evenly across its live requests.

    ``batch`` is the compiled bucket size, ``n_live`` the requests actually
    served by it. Padding slots burn real compute, so their energy lands on
    the live requests (the serving engine's bucketing overhead is visible in
    the per-request numbers instead of silently vanishing). Latency keys are
    returned unscaled. Pass ``cost`` (a prior ``run_cost`` result for the
    same configuration) to skip recomputing the model.

    Each ledger component is scaled by the per-request share and every
    energy aggregate -- ``energy_j`` included -- is re-derived from the
    scaled components with the same association as ``run_cost``, so the
    exact-sum invariant survives attribution: the per-request breakdown
    sums bitwise to the per-request ``energy_j``.
    """
    if cost is None:
        cost = run_cost(cfg, rc, batch=batch, em=em)
    share = 1.0 / max(n_live, 1)
    breakdown = {comp: cost["breakdown"][comp] * share
                 for comp in ENERGY_COMPONENTS}
    out = dict(cost)
    out.update(_derive_totals(breakdown))
    out["breakdown"] = breakdown
    return out


def baseline_rc(num_steps: int = 50) -> RunConfig:
    return RunConfig(num_steps=num_steps, nominal_steps=0,
                     aggressive=dvfs_lib.NOMINAL, abft_enabled=False,
                     ckpt_interval=10 ** 9, recovery_tiles_per_step=0.0)


def calibrate(target_e: float = 6.02, target_t: float = 0.56,
              die_frac: float = 0.92, dram_frac: float = 0.06,
              num_steps: int = 50) -> EnergyModel:
    """Fit the four constants to the Table 1 DiT-XL-512 nominal baseline."""
    from repro import configs
    cfg = configs.get_config("dit-xl-512")
    hw = PAPER_ACCEL
    macs = model_eval_macs(cfg, 1) * num_steps
    dram = dram_bytes_per_eval(cfg, 1) * num_steps
    util = macs / (hw.peak_macs_per_s * target_t)
    return EnergyModel(
        hw=hw,
        e_mac_pj=target_e * die_frac / macs * 1e12,
        e_dram_pj_per_byte=target_e * dram_frac / dram * 1e12,
        static_w=target_e * (1.0 - die_frac - dram_frac) / target_t,
        utilization=util,
    )
