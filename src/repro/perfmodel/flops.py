"""Analytical FLOPs/bytes accounting per (architecture x shape).

MODEL_FLOPS definitions used by the roofline (EXPERIMENTS.md):
  train:  6 * N_active * D        (fwd 2ND + bwd 4ND)
  prefill: 2 * N_active * D  + attention term
  decode: 2 * N_active * B   + attention-read term
plus explicit attention FLOPs (2 * 2 * S^2 * d per layer at train/prefill,
window-clipped for local layers), which the 6ND rule ignores.
"""
from __future__ import annotations

from typing import Dict

from repro.configs import shapes as shapes_lib
from repro.models import transformer as tf_lib
from repro.models import dit as dit_lib
from repro.models.common import ModelConfig


def active_params(cfg: ModelConfig) -> float:
    """Parameters touched per token (MoE: top-k + shared experts only)."""
    if cfg.family != "moe":
        n = tf_lib.param_count(cfg) if cfg.family not in ("dit", "unet") \
            else dit_lib.param_count(cfg)
        return float(n)
    d, f = cfg.d_model, cfg.d_ff
    per_layer = (d * cfg.n_heads * cfg.hd + 2 * d * cfg.kv_heads * cfg.hd
                 + cfg.n_heads * cfg.hd * d)
    per_layer += 3 * d * f * (cfg.top_k + cfg.n_shared_experts) + d * cfg.n_experts
    n = cfg.n_layers * per_layer + cfg.vocab * d
    if not cfg.tie_embeddings:
        n += cfg.vocab * d
    return float(n)


def _attn_flops_full(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Score + mix FLOPs over all layers, window-aware (causal halves it)."""
    total = 0.0
    if cfg.family != "ssm":
        for w in cfg.layer_windows():
            eff = seq if w == 0 else min(w, seq)
            # sum over query positions of attended length (causal avg)
            attended = seq * eff * (0.5 if w == 0 else 1.0)
            total += 2 * 2 * attended * cfg.n_heads * cfg.hd * batch
    if cfg.family in ("ssm", "hybrid"):
        total += cfg.n_layers * _ssd_flops(cfg, batch, seq)
    return total


def _ssd_flops(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Chunked SSD per layer: intra-chunk quadratic form + state recurrence.

    Per chunk of length Q: CB scores 2*Q^2*G*N, y_intra 2*Q^2*H*P,
    chunk state 2*Q*N*H*P, y_inter 2*Q*N*H*P. Decode (seq==1): one
    recurrence update 4*N*H*P.
    """
    ng, ns = cfg.ssm_groups, cfg.ssm_state
    nh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    if seq <= 1:
        return batch * 4.0 * ns * nh * hp
    q = min(cfg.ssm_chunk, seq)
    nc = -(-seq // q)
    per_chunk = (2.0 * q * q * ng * ns + 2.0 * q * q * nh * hp
                 + 4.0 * q * ns * nh * hp)
    return batch * nc * per_chunk


def cell_flops(cfg: ModelConfig, shape: shapes_lib.ShapeSpec) -> Dict[str, float]:
    """MODEL_FLOPS for one (arch, shape) cell."""
    n_act = active_params(cfg)
    if shape.kind == "train":
        d_tokens = shape.global_batch * shape.seq_len
        return {"model_flops": 6.0 * n_act * d_tokens
                + 3.0 * _attn_flops_full(cfg, shape.global_batch,
                                         shape.seq_len),
                "tokens": float(d_tokens)}
    if shape.kind == "prefill":
        d_tokens = shape.global_batch * shape.seq_len
        return {"model_flops": 2.0 * n_act * d_tokens
                + _attn_flops_full(cfg, shape.global_batch, shape.seq_len),
                "tokens": float(d_tokens)}
    if shape.kind == "decode":
        b = shape.global_batch
        attn = 0.0
        if cfg.family != "ssm":
            for w in cfg.layer_windows():
                eff = shape.seq_len if w == 0 else min(w, shape.seq_len)
                attn += 2 * 2 * eff * cfg.n_heads * cfg.hd * b
        if cfg.family in ("ssm", "hybrid"):
            attn += cfg.n_layers * _ssd_flops(cfg, b, 1)
        return {"model_flops": 2.0 * n_act * b + attn, "tokens": float(b)}
    if shape.kind in ("denoise_train", "sample"):
        t = (cfg.latent_size // cfg.patch_size) ** 2 if cfg.family == "dit" \
            else (cfg.latent_size ** 2)   # unet ~ per-pixel proxy
        d_tokens = shape.global_batch * t
        mult = 6.0 if shape.kind == "denoise_train" else 2.0
        extra = (_attn_flops_full(cfg, shape.global_batch, t)
                 if cfg.family == "dit" else 0.0)
        return {"model_flops": mult * active_params(cfg) * d_tokens
                + (mult / 2) * extra,
                "tokens": float(d_tokens)}
    raise ValueError(shape.kind)


def mac_bit_energy_scale(bits: int, base_bits: int = 8) -> float:
    """On-die energy per MAC at a narrowed operand width, relative to the
    INT8 baseline: multiplier area/energy grows with the product of operand
    widths, so e_mac ~ (bits/8)^2. Exactly 1.0 at the baseline width --
    the degenerate precision plan prices (and computes) identically to the
    pre-plan path."""
    return (bits / base_bits) ** 2


def mac_bit_time_scale(bits: int, base_bits: int = 8) -> float:
    """MAC time at a narrowed operand width relative to INT8: a
    weight-stationary systolic array streams ``bits``-wide operands, so
    throughput scales ~ 1/bits (int4 packs two ops where int8 packs one).
    Exactly 1.0 at the baseline width."""
    return bits / base_bits


#: nominal decode context length the per-token serving cost is quoted at
#: (KV reads grow with position; the engine charges a fixed mid-stream
#: context so batch cost stays affine in step count like diffusion).
DECODE_CONTEXT = 1024


def gemm_macs_per_model_eval(cfg: ModelConfig, batch: int = 1) -> float:
    """INT8 MACs for one model evaluation (the perf/energy model unit).

    For diffusion families one eval is a denoiser pass over the latent
    grid; for LM families one eval is ONE DECODE STEP (a token per
    sequence): weight MACs ~= active params, plus window-clipped KV
    attention reads at ``DECODE_CONTEXT``, plus the SSD recurrence for
    ssm/hybrid layers. This is the per-token cost the DeadlineScheduler's
    AR admission estimates multiply by the step count.
    """
    if cfg.family not in ("dit", "unet"):
        macs = active_params(cfg)
        attn = 0.0
        if cfg.family != "ssm":
            for w in cfg.layer_windows():
                eff = DECODE_CONTEXT if w == 0 else min(w, DECODE_CONTEXT)
                attn += 2.0 * eff * cfg.n_heads * cfg.hd
        if cfg.family in ("ssm", "hybrid"):
            attn += cfg.n_layers * _ssd_flops(cfg, 1, 1) / 2.0
        return batch * (macs + attn)
    if cfg.family == "dit":
        t = (cfg.latent_size // cfg.patch_size) ** 2
        d = cfg.d_model
        per_block = t * (4 * d * d + 2 * d * cfg.d_ff + 6 * d * d / t
                         + (4 * d * d if cfg.cond_tokens else 0))
        attn = 2 * t * t * d
        pdim = cfg.patch_size ** 2 * cfg.latent_channels
        embed = t * pdim * d * 2 + 256 * d + d * d
        return batch * (cfg.n_layers * (per_block + attn) + embed)
    if cfg.family == "unet":
        # conv-dominated; approximate via param sweep at latent res
        c = cfg.unet_channels
        s = cfg.latent_size
        total = 0.0
        res = s
        for i, ch in enumerate(c):
            cin = c[max(i - 1, 0)]
            total += res * res * (9 * cin * ch + 9 * ch * ch) * 2
            if i >= 1:
                total += res * res * ch * ch * 4 + res ** 4 * ch
            res //= 2
        return batch * 2.3 * total    # down+mid+up
    raise ValueError(cfg.family)
