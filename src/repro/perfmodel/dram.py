"""DRAM row-activation accounting: the Fig 10(b)/13(b) repacking study.

HBM reads operate at row-buffer granularity; recovering a (tm x tn) tile
under a conventional row-major activation layout touches one DRAM row per
matrix row in the tile (tm activations), while the repacked tile-contiguous
layout packs the whole tile into ceil(tile_bytes / row_bytes) rows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.perfmodel.hw import PAPER_ACCEL, PaperAccel


def rows_per_tile_rowmajor(tm: int, tn: int, n_cols: int,
                           elem_bytes: int = 4,
                           row_bytes: int = PAPER_ACCEL.dram_row_bytes) -> int:
    """Distinct DRAM rows touched recovering one tile, row-major layout."""
    matrix_row_bytes = n_cols * elem_bytes
    if matrix_row_bytes >= row_bytes:
        # each matrix row of the tile lives in its own DRAM row (or more)
        return tm * max(1, math.ceil(tn * elem_bytes / row_bytes))
    rows_per_dram_row = row_bytes // matrix_row_bytes
    return max(1, math.ceil(tm / rows_per_dram_row))


def rows_per_tile_repacked(tm: int, tn: int, elem_bytes: int = 4,
                           row_bytes: int = PAPER_ACCEL.dram_row_bytes) -> int:
    return max(1, math.ceil(tm * tn * elem_bytes / row_bytes))


def repack_speedup(tm: int, tn: int, n_cols: int, elem_bytes: int = 4,
                   row_bytes: int = PAPER_ACCEL.dram_row_bytes) -> float:
    """Row-activation reduction factor (Fig 13b; 23.4x-class for q_proj)."""
    return (rows_per_tile_rowmajor(tm, tn, n_cols, elem_bytes, row_bytes)
            / rows_per_tile_repacked(tm, tn, elem_bytes, row_bytes))


def recovery_report(n_flagged_tiles: float, tm: int, tn: int, n_cols: int,
                    hw: PaperAccel = PAPER_ACCEL) -> Dict[str, float]:
    """Latency/energy of one step's recovery reads, both layouts.

    Used to reproduce Sec 6.4's '"computation ~15us, retrieval 714ns ->
    fully overlapped"' claim shape: retrieval time = rows x tRC + bytes/BW.
    """
    t_rc_ns = 45.0
    rows_rm = n_flagged_tiles * rows_per_tile_rowmajor(tm, tn, n_cols)
    rows_rp = n_flagged_tiles * rows_per_tile_repacked(tm, tn)
    bytes_needed = n_flagged_tiles * tm * tn * 4
    bw = hw.hbm_gbps * 1e9
    return {
        "rows_rowmajor": rows_rm,
        "rows_repacked": rows_rp,
        "reduction": rows_rm / max(rows_rp, 1.0),
        "t_retrieval_rowmajor_us": (rows_rm * t_rc_ns) * 1e-3
            + bytes_needed / bw * 1e6,
        "t_retrieval_repacked_us": (rows_rp * t_rc_ns) * 1e-3
            + bytes_needed / bw * 1e6,
    }
