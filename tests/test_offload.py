"""Checkpoint-offload subsystem tests (repro.serving.offload).

The two acceptance bars from the PR:

* with faults disabled (and enabled -- the live store is untouched either
  way), finals are **bit-identical** between offload-enabled and
  offload-disabled engines, one-shot ``run()`` and ``run_stream()`` both
  (the 8-fake-device twin lives in tests/test_serving_sharded.py);
* a rollback restored from the offloaded store produces the **same
  corrected latents** as the inline-store path (``core.rollback``
  semantics, through the tile-contiguous pack/unpack round trip).

Plus the planner (Pareto membership, monotone pieces), the store's
double-buffer/commit/skip machinery on synthetic carries, "auto" interval
resolution through the engine and scheduler, the scheduler's stall-aware
projections, and the multi-engine /metrics aggregation.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import dvfs, rollback
from repro.core.exec_ctx import DriftSystemConfig
from repro.diffusion import sampler as sampler_lib
from repro.diffusion.sampler import SampleOutput
from repro.serving import (DeadlineScheduler, DriftServeEngine,
                           OffloadConfig, OffloadPlanner, OffloadStore,
                           PreviewEvent, TelemetryHTTPServer,
                           aggregate_metrics)
from repro.serving.offload import pareto_frontier
from repro.serving.offload import store as store_mod

ARCH, STEPS, BUCKET, N_REQ, INTERVAL = "dit-xl-512", 3, 2, 2, 2


def _fake_carry(stores, ema_ber=0.0):
    """Scan-carry shape the store's on_window tap reads: stores at [1],
    BER-monitor state at [3]."""
    mon = dvfs.BerMonitorState(jnp.float32(ema_ber), jnp.int32(0),
                               jnp.int32(1))
    return (None, stores, None, mon, None, None)


# ------------------------------------------------------- real engine runs
def _submit_all(eng):
    for i in range(N_REQ):
        eng.submit(steps=STEPS, mode="drift", op="undervolt", seed=i,
                   rollback_interval=INTERVAL)


@pytest.fixture(scope="module")
def baseline():
    """Offload-disabled engine: the bit-identity reference."""
    eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=BUCKET)
    _submit_all(eng)
    return eng, eng.run()


@pytest.fixture(scope="module")
def offloaded():
    """Offload-enabled engine over the same stream (one-shot run())."""
    eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=BUCKET,
                           offload=OffloadConfig())
    _submit_all(eng)
    return eng, eng.run()


@pytest.mark.slow
def test_offload_run_bit_identical(baseline, offloaded):
    """Acceptance bar: enabling async offload must not change one latent
    bit -- the host store is redundancy, the live store drives every
    correction. (Faults ARE injected here: drift mode at undervolt.)"""
    _, ref = baseline
    eng, res = offloaded
    assert len(res) == N_REQ
    for a, b in zip(ref, res):
        assert a.request_id == b.request_id
        assert np.array_equal(np.asarray(a.latents), np.asarray(b.latents))
        assert a.batch_corrected_elems == b.batch_corrected_elems
        assert a.n_model_evals == b.n_model_evals
    # ... and the offload actually happened: ceil(3 / 2) = 2 refreshes
    st = eng.offload_store.stats
    assert st.commits == 2 and st.bytes_offloaded > 0


@pytest.mark.slow
def test_offload_run_stream_bit_identical(baseline):
    """Same bar for the streaming path: previews + offload commits ride
    the same windows, finals stay bit-identical to the one-shot
    offload-free reference."""
    _, ref = baseline
    eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=BUCKET,
                           offload=OffloadConfig())
    _submit_all(eng)
    events = list(eng.run_stream(preview_interval=1))
    previews = [e for e in events if isinstance(e, PreviewEvent)]
    results = sorted((e for e in events if not isinstance(e, PreviewEvent)),
                     key=lambda r: r.request_id)
    assert len(previews) == (STEPS - 1) * N_REQ
    for a, b in zip(ref, results):
        assert np.array_equal(np.asarray(a.latents), np.asarray(b.latents))
    assert eng.offload_store.stats.commits == 2


@pytest.mark.slow
def test_offload_charges_stall_and_telemetry(baseline, offloaded):
    """The modeled residual refresh stall lands on the virtual clock and
    in the offload metric families; energy attribution is unchanged
    (refresh DRAM traffic was already priced by ckpt_interval)."""
    beng, bres = baseline
    oeng, ores = offloaded
    stall = oeng.offload_stall_s(ARCH, "undervolt", STEPS, INTERVAL)
    assert stall >= 0.0
    assert ores[0].latency_s == pytest.approx(bres[0].latency_s + stall)
    assert oeng.clock_s == pytest.approx(beng.clock_s + stall)
    assert ores[0].energy_j == pytest.approx(bres[0].energy_j)
    reg = oeng.telemetry.registry.expose()
    assert "drift_offload_commits_total 2" in reg
    assert "drift_offload_interval 2" in reg


@pytest.mark.slow
def test_restore_matches_live_carry_stores(baseline):
    """Drive the windowed sampler directly, snapshot the carry at every
    window through the offload tap, and check restore() returns the live
    stores bit-for-bit -- pack/unpack (tile-contiguous) is exact even for
    the DiT (embed dict, stacked block dict) pytree."""
    del baseline          # ordering only: reuse warm jax caches
    model_cfg = configs.get_config(ARCH, smoke=True)
    from repro.train import steps as steps_lib
    params = steps_lib.init_model_params(model_cfg, jax.random.PRNGKey(0))
    scfg = sampler_lib.SamplerConfig(
        num_sample_steps=STEPS,
        drift=DriftSystemConfig(
            mode="drift",
            rollback=rollback.RollbackConfig(interval=INTERVAL)))
    lat0 = jax.random.normal(jax.random.PRNGKey(1),
                             (1, model_cfg.latent_size,
                              model_cfg.latent_size,
                              model_cfg.latent_channels))
    cond = jnp.zeros((1,), jnp.int32)

    carries = []
    store = OffloadStore(OffloadConfig(async_commit=False, tile_m=8,
                                       tile_n=8))
    store.begin_batch(interval=INTERVAL, batch_index=0)
    for ev in sampler_lib.sample_stream(
            model_cfg, params, jax.random.PRNGKey(2), lat0, cond, None,
            scfg, window=INTERVAL,
            on_carry=lambda done, carry: (carries.append((done, carry)),
                                          store.on_window(done, carry))):
        final = ev
    assert isinstance(final, SampleOutput)
    assert store.finish_batch().commits == 2
    # last committed snapshot corresponds to the refresh at step 2, whose
    # live values were visible in the carry after the window ending there
    assert store.committed_step == 2
    restored = store.restore()
    live = carries[-1][1][1]             # stores of the final carry
    live_leaves = jax.tree.leaves(live)
    restored_leaves = jax.tree.leaves(restored)
    assert len(live_leaves) == len(restored_leaves) > 0
    for a, b in zip(live_leaves, restored_leaves):
        assert a.shape == b.shape and a.dtype == b.dtype
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------- rollback-correct regression
def test_rollback_correct_from_restored_store_regression():
    """core.rollback semantics: a correction masked from the restored
    (offloaded, repacked, round-tripped) checkpoint equals the inline
    store path bit-for-bit -- non-tile-aligned shapes included."""
    rng = np.random.default_rng(0)
    stores = {
        "q_proj": jnp.asarray(rng.standard_normal((37, 19)), jnp.float32),
        "mlp.w1": jnp.asarray(rng.standard_normal((64, 33)), jnp.float32),
    }
    store = OffloadStore(OffloadConfig(async_commit=False, tile_m=8,
                                       tile_n=8))
    store.begin_batch(interval=1, batch_index=0)
    store.on_window(1, _fake_carry(stores))
    restored = store.restore()
    for name, ckpt in stores.items():
        current = jnp.asarray(rng.standard_normal(ckpt.shape), jnp.float32)
        mask = jnp.asarray(rng.random(ckpt.shape) < 0.2)
        inline = rollback.correct(current, ckpt, mask, jnp.asarray(True))
        offl = rollback.correct(current, restored[name], mask,
                                jnp.asarray(True))
        assert np.array_equal(np.asarray(inline), np.asarray(offl))
        # sanity: the mask actually replaced something
        assert np.asarray(mask).sum() > 0


# --------------------------------------------------- store unit behavior
def test_store_commits_only_when_refresh_crossed():
    stores = {"w": jnp.ones((4, 4))}
    s = OffloadStore(OffloadConfig(async_commit=False))
    s.begin_batch(interval=4, batch_index=0)
    s.on_window(2, _fake_carry(stores))   # refresh step 0 in [0, 2)
    s.on_window(3, _fake_carry(stores))   # no refresh in [2, 3)
    s.on_window(6, _fake_carry(stores))   # refresh step 4 in [3, 6)
    assert s.stats.commits == 2
    assert s.committed_step == 4


def test_store_skips_commit_on_detection_spike():
    stores = {"w": jnp.ones((4, 4))}
    s = OffloadStore(OffloadConfig(async_commit=False, skip_spike_ratio=2.0,
                                   target_ber=1e-3))
    s.begin_batch(interval=1, batch_index=0)
    s.on_window(1, _fake_carry(stores, ema_ber=0.0))       # quiet: commit
    s.on_window(2, _fake_carry(stores, ema_ber=5e-3))      # spike: keep old
    st = s.finish_batch()
    assert st.commits == 1 and st.skipped == 1
    assert s.committed_step == 0          # the pre-spike snapshot survives


def test_store_async_commit_is_joined_and_restores():
    stores = {"w": jnp.arange(16.0).reshape(4, 4)}
    s = OffloadStore(OffloadConfig())     # async
    s.begin_batch(interval=1, batch_index=0)
    s.on_window(1, _fake_carry(stores))
    delta = s.finish_batch()              # joins the background thread
    assert delta.commits == 1 and delta.bytes_offloaded > 0
    r = s.restore()
    assert np.array_equal(np.asarray(r["w"]), np.asarray(stores["w"]))
    with pytest.raises(RuntimeError):
        OffloadStore().restore()          # nothing committed yet


def test_store_surfaces_background_commit_failure():
    """A failed pack/copy on the worker thread must not leave the engine
    believing the offload is healthy: the next join point re-raises."""
    s = OffloadStore(OffloadConfig())
    s.begin_batch(interval=1, batch_index=0)
    s.on_window(1, _fake_carry({"w": object()}))   # unpackable leaf
    with pytest.raises(RuntimeError, match="offload commit failed"):
        s.finish_batch()
    # the store recovers: a later good commit goes through
    s.begin_batch(interval=1, batch_index=1)
    s.on_window(1, _fake_carry({"w": jnp.ones((4, 4))}))
    assert s.finish_batch().commits == 1


def test_row_major_layout_costs_more_recovery_rows():
    from repro.serving.offload import recovery_rows
    shape = (256, 1152)
    rp = recovery_rows(shape, 32, 32, n_tiles=4, repacked=True)
    rm = recovery_rows(shape, 32, 32, n_tiles=4, repacked=False)
    assert rp < rm                        # the Fig 10(b) gap


# -------------------------------------------------------------- planner
def test_planner_chosen_interval_on_pareto_frontier():
    cfg = configs.get_config(ARCH)
    planner = OffloadPlanner()
    for rate in (1e-4, 0.3, 1.0):
        plans = planner.sweep(cfg, dvfs.UNDERVOLT, 50, 2, detect_rate=rate)
        chosen = planner.plan(cfg, dvfs.UNDERVOLT, 50, 2, detect_rate=rate)
        frontier = pareto_frontier(plans)
        assert any(p.interval == chosen.interval for p in frontier)
        # overlap strictly beats serialization whenever there is any
        # compute to hide behind
        assert all(p.stall_s < p.stall_serialized_s for p in plans)
    # refresh energy falls and staleness penalty rises with the interval
    plans = planner.sweep(cfg, dvfs.UNDERVOLT, 50, 2, detect_rate=1.0)
    by_interval = sorted(plans, key=lambda p: p.interval)
    for a, b in zip(by_interval, by_interval[1:]):
        assert b.refresh_energy_j <= a.refresh_energy_j
        assert b.rollback_penalty_j >= a.rollback_penalty_j


def test_planner_low_detection_rate_prefers_longer_intervals():
    """With nothing to roll back, refreshing often is pure waste."""
    cfg = configs.get_config(ARCH)
    planner = OffloadPlanner()
    quiet = planner.plan(cfg, dvfs.UNDERVOLT, 50, 2, detect_rate=1e-6)
    noisy = planner.plan(cfg, dvfs.UNDERVOLT, 50, 2, detect_rate=1.0)
    assert quiet.interval >= noisy.interval


# ------------------------------------------------ auto-interval plumbing
def fake_factory():
    """Trace-free sampler factory; yields like the windowed path when the
    key asks for streaming so the offload drain works against it."""
    def factory(key, model_cfg, scfg, on_trace):
        on_trace()

        def run(params, rng, latents, cond, text, monitor0):
            out = SampleOutput(latents, monitor0, jnp.int32(0),
                               jnp.int32(scfg.num_sample_steps))
            if key.stream:
                def gen():
                    yield out
                return gen()
            return out
        return run
    return factory


def _fake_engine(**kw):
    return DriftServeEngine(arch=ARCH, smoke=True, bucket=BUCKET,
                            sampler_factory=fake_factory(), **kw)


def test_auto_rollback_interval_resolves_once_per_config():
    eng = _fake_engine()
    for i in range(2):
        eng.submit(steps=4, mode="drift", op="undervolt", seed=i,
                   rollback_interval="auto")
    results = eng.run()
    assert len(results) == 2
    assert eng.stats.batches == 1         # both resolved identically
    planned = eng.auto_rollback_interval(ARCH, "undervolt", 4)
    assert isinstance(planned, int) and planned >= 1
    # memoized per (config, quantized detection rate): re-resolving at the
    # same telemetry state adds no entries -- but the key does carry the
    # rate, so adaptation CAN move the choice later
    n_memo = len(eng._interval_memo)
    assert eng.auto_rollback_interval(ARCH, "undervolt", 4) == planned
    assert len(eng._interval_memo) == n_memo


def test_auto_interval_lands_in_sampler_key():
    eng = _fake_engine()
    eng.submit(steps=4, mode="drift", op="undervolt", seed=0,
               rollback_interval="auto")
    mb = eng.batcher.next_batch(eng.queue, eng._resolve_op,
                                eng._resolve_interval)
    assert isinstance(mb.key.rollback_interval, int)
    assert mb.key.rollback_interval == \
        eng.auto_rollback_interval(ARCH, "undervolt", 4)


def test_request_validates_rollback_interval():
    eng = _fake_engine()
    with pytest.raises(ValueError):
        eng.submit(steps=4, mode="drift", op="undervolt", seed=0,
                   rollback_interval="sometimes")
    with pytest.raises(ValueError):
        eng.submit(steps=4, mode="drift", op="undervolt", seed=0,
                   rollback_interval=0)


def test_scheduler_prices_auto_interval_and_stall():
    """Admission must price (a) the planner-resolved interval in the
    learned-estimator key and (b) the offload residual stall in the
    perfmodel projection -- and an offload-free engine must project
    bit-identically to the pre-offload scheduler."""
    plain = DeadlineScheduler(_fake_engine())
    offl = DeadlineScheduler(_fake_engine(offload=OffloadConfig()))
    base = plain.batch_latency_s(ARCH, "undervolt", STEPS,
                                 rollback_interval=1)
    with_stall = offl.batch_latency_s(ARCH, "undervolt", STEPS,
                                      rollback_interval=1)
    stall = offl.engine.offload_stall_s(ARCH, "undervolt", STEPS, 1)
    assert with_stall == pytest.approx(base + stall)
    assert plain.engine.offload_stall_s(ARCH, "undervolt", STEPS, 1) == 0.0
    # "auto" interval resolves through the engine for discriminators
    adm = offl.submit(steps=STEPS, mode="drift", op="undervolt", seed=0,
                      rollback_interval="auto", deadline_s=1e9)
    assert adm.admitted


# ------------------------------------------- multi-engine /metrics wire
def test_aggregate_metrics_labels_every_series():
    engines = {}
    for name in ("a", "b"):
        eng = _fake_engine()
        eng.submit(steps=2, mode="drift", op="undervolt", seed=0)
        eng.run()
        engines[name] = eng
    text = aggregate_metrics(engines)
    for line in text.splitlines():
        if line.startswith("#") or not line.strip():
            continue
        assert 'engine="a"' in line or 'engine="b"' in line, line
    # families appear once, grouped (scrape-friendly): HELP precedes all
    # of a family's samples
    helps = [l for l in text.splitlines()
             if l.startswith("# HELP drift_batches_total")]
    assert len(helps) == 1
    assert 'drift_batches_total{engine="a",mode="drift",op="undervolt"} 1' \
        in text
    assert 'drift_batches_total{engine="b",mode="drift",op="undervolt"} 1' \
        in text


def test_http_metrics_endpoint_aggregates_engines():
    import urllib.request
    a, b = _fake_engine(), _fake_engine()
    for eng in (a, b):
        eng.submit(steps=2, mode="drift", op="undervolt", seed=0)
        eng.run()
    with TelemetryHTTPServer(a, engines={"left": a, "right": b}) as srv:
        with urllib.request.urlopen(f"{srv.url}/metrics", timeout=10) as r:
            payload = r.read().decode()
        with urllib.request.urlopen(f"{srv.url}/healthz", timeout=10) as r:
            import json
            health = json.loads(r.read().decode())
    assert 'engine="left"' in payload and 'engine="right"' in payload
    assert set(health["engines"]) == {"left", "right"}
    assert health["engines"]["left"]["batches"] == 1
    assert health["engines"]["right"]["batches"] == 1
