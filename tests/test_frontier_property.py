"""Property tests for Pareto-dominance pruning, run against BOTH pruners
the serving stack ships: the three-objective ``serving.frontier``
helpers (quality max, energy min, latency min) and the two-objective
``serving/offload/planner.pareto_frontier`` (energy min, stall min).

The two invariants every randomized cost table must satisfy:

1. no returned frontier point is dominated by another returned point;
2. every pruned point is dominated by some kept point.

Together they pin down the non-dominated set exactly (up to ties, which
both implementations keep), which is what makes the scheduler's
"search the pruned set" == "search the full enumeration" argument hold.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_stub import given, settings, st

from repro.serving import frontier
from repro.serving.offload import planner as offload_planner


def _points_from_seed(n, seed, levels):
    """Deterministic pseudo-random cost table. ``levels`` coarsens each
    axis so ties and duplicate cost vectors actually occur."""
    import random
    rng = random.Random(seed)
    pts = []
    for i in range(n):
        pts.append(frontier.FrontierPoint(
            op=f"op{i % 3}", steps=4 + i % 5, precision=f"p{i % 2}",
            taylorseer=bool(i % 2),
            quality=rng.randrange(levels) / levels,
            energy_j=float(rng.randrange(levels)),
            latency_s=float(rng.randrange(levels))))
    return pts


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 10_000),
       levels=st.integers(2, 6))
def test_frontier_pareto_properties(n, seed, levels):
    """Three-objective pruner: kept points mutually non-dominated, every
    pruned point dominated by a kept one."""
    pts = _points_from_seed(n, seed, levels)
    front = frontier.pareto_front(pts)
    assert front, "non-empty input must keep at least one point"
    for p in front:
        assert not any(frontier.dominates(q, p) for q in front)
    kept = set(map(id, front))
    for p in pts:
        if id(p) not in kept and p not in front:
            assert any(frontier.dominates(q, p) for q in front), p


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 30), seed=st.integers(0, 10_000))
def test_frontier_matches_bruteforce_nondominated(n, seed):
    """The pruned set IS the non-dominated set (ties kept): brute force
    over the full table agrees point-for-point."""
    pts = _points_from_seed(n, seed, levels=4)
    front = frontier.pareto_front(pts)
    brute = [p for p in pts
             if not any(frontier.dominates(q, p) for q in pts)]
    assert sorted(front, key=frontier.sort_key) \
        == sorted(brute, key=frontier.sort_key)


def _plans_from_seed(n, seed, levels):
    import random
    rng = random.Random(seed)
    plans = []
    for i in range(n):
        refresh = float(rng.randrange(levels))
        penalty = float(rng.randrange(levels))
        stall = float(rng.randrange(levels))
        plans.append(offload_planner.IntervalPlan(
            interval=i + 1, n_refreshes=1, refresh_s=0.0,
            stall_serialized_s=stall, stall_s=stall,
            refresh_energy_j=refresh, rollback_penalty_j=penalty,
            total_j=refresh + penalty))
    return plans


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 10_000),
       levels=st.integers(2, 6))
def test_offload_pareto_properties(n, seed, levels):
    """Two-objective (energy_j, stall_s) pruner obeys the same two
    invariants over randomized plan tables."""
    plans = _plans_from_seed(n, seed, levels)
    front = offload_planner.pareto_frontier(plans)
    assert front

    def dominates(a, b):
        return ((a.energy_j <= b.energy_j and a.stall_s <= b.stall_s)
                and (a.energy_j < b.energy_j or a.stall_s < b.stall_s))

    for p in front:
        assert not any(dominates(q, p) for q in front)
    kept = set(map(id, front))
    for p in plans:
        if id(p) not in kept:
            assert any(dominates(q, p) for q in front), p


def test_pareto_front_keeps_ties():
    """Duplicate cost vectors are ties, not mutual dominators: both
    survive (matching the offload planner's ties-kept contract)."""
    a = frontier.FrontierPoint("nominal", 10, "int8", False, 0.9, 1.0, 0.1)
    b = frontier.FrontierPoint("uv-safe", 10, "int8", False, 0.9, 1.0, 0.1)
    c = frontier.FrontierPoint("nominal", 8, "int8", False, 0.8, 2.0, 0.2)
    assert not frontier.dominates(a, b)
    assert not frontier.dominates(b, a)
    front = frontier.pareto_front([a, b, c])
    assert a in front and b in front and c not in front


def test_dominates_needs_strict_edge():
    """Equal on every axis is NOT dominance; one strict improvement is."""
    a = frontier.FrontierPoint("nominal", 10, "int8", False, 0.9, 1.0, 0.1)
    b = frontier.FrontierPoint("nominal", 10, "int8", False, 0.9, 1.0, 0.2)
    assert frontier.dominates(a, b)
    assert not frontier.dominates(b, a)
    assert not frontier.dominates(a, a)


def test_real_builder_frontier_is_nondominated():
    """The real priced enumeration (not synthetic): the memoized frontier
    equals the non-dominated subset of the full knob sweep."""
    from repro import configs
    builder = frontier.FrontierBuilder()
    cfg = configs.get_config("dit-xl-512")
    full = builder.enumerate(cfg, 10, 2)
    front = builder.frontier(cfg, 10, 2)
    brute = [p for p in full
             if not any(frontier.dominates(q, p) for q in full)]
    assert sorted(front, key=frontier.sort_key) \
        == sorted(brute, key=frontier.sort_key)
    # Memo hit returns the identical list object.
    assert builder.frontier(cfg, 10, 2) is front
