"""Property tests for the statistical-ABFT threshold (kernels/stat_abft.py).

The detection contract the AR serving path leans on (docs/servable.md):

* fault-free GEMMs NEVER trip the threshold -- the checksum residual of a
  clean product stays inside the calibrated rounding envelope for every
  dtype/shape combination (bounded false-positive rate; here: zero over
  the sampled space, by the gamma_K envelope's construction);
* an injected perturbation above ``min_detectable_magnitude`` (2x the
  row threshold) is ALWAYS detected, wherever the clean residual sits
  inside the envelope;
* perturbations far below the envelope sail through undetected -- that
  is the ReaLM point: decoding tolerates them, so detection (and the
  rollback replay it triggers) shouldn't fire.

Plus unit coverage for the quantized Pallas backend (exact INT32
checksums + magnitude cutoff) and the decode-loop execution context.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_stub import given, settings, st

from repro.core import dvfs
from repro.kernels import stat_abft


def _operands(m, k, n, dtype, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    w = jnp.asarray(rng.standard_normal((k, n)), dtype)
    return x, w


# ----------------------------------------------------------- float envelope
@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 9), k=st.integers(1, 96), n=st.integers(1, 96),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 5))
def test_clean_gemm_under_threshold(m, k, n, dtype, seed):
    """No false positives: the residual of a fault-free product stays
    inside the envelope for every shape/dtype sampled."""
    x, w = _operands(m, k, n, dtype, seed)
    y = x @ w
    flags = np.asarray(stat_abft.detect(x, w, y))
    assert not flags.any(), (
        f"clean GEMM flagged: residual "
        f"{np.asarray(stat_abft.residuals(x, w, y))} vs threshold "
        f"{np.asarray(stat_abft.threshold(x, w))}")


@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 9), k=st.integers(2, 96), n=st.integers(2, 96),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 5))
def test_flip_above_cutoff_detected(m, k, n, dtype, seed):
    """A single corrupted element whose magnitude clears the cutoff is
    detected in exactly its row, and nowhere else."""
    x, w = _operands(m, k, n, dtype, seed)
    y = x @ w
    rng = np.random.default_rng(seed + 1000)
    i, j = int(rng.integers(m)), int(rng.integers(n))
    delta = 2.0 * float(stat_abft.min_detectable_magnitude(x, w)[i])
    y_bad = y.astype(jnp.float32).at[i, j].add(delta)
    flags = np.asarray(stat_abft.detect(x, w, y_bad))
    assert flags[i], "above-cutoff corruption missed"
    assert flags.sum() == 1, "uncorrupted rows flagged"


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 9), k=st.integers(2, 96), n=st.integers(2, 96),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 5))
def test_flip_far_below_cutoff_tolerated(m, k, n, dtype, seed):
    """Perturbations an order of magnitude under the envelope don't fire
    detection -- small numerical noise must not trigger rollbacks."""
    x, w = _operands(m, k, n, dtype, seed)
    y = x @ w
    delta = 0.05 * float(stat_abft.threshold(x, w)[0])
    y_bad = y.astype(jnp.float32).at[0, 0].add(delta)
    assert not np.asarray(stat_abft.detect(x, w, y_bad))[0]


# -------------------------------------------------------- quantized backend
def test_quantized_backend_exact_and_thresholded():
    """threshold_mag=0 reproduces exact ABFT on the Pallas kernel; a
    threshold above the flip magnitude suppresses the detection."""
    rng = np.random.default_rng(0)
    aq = rng.integers(-16, 16, (16, 16)).astype(np.int8)
    bq = rng.integers(-16, 16, (16, 16)).astype(np.int8)
    flips = np.zeros((16, 16), np.uint32)
    c, det = stat_abft.stat_abft_matmul(aq, bq, flips, threshold_mag=0,
                                        bm=8, bn=8, bk=8, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(c), aq.astype(np.int32) @ bq.astype(np.int32))
    assert not np.asarray(det).any()

    flips[3, 5] = np.uint32(1) << 30          # high-bit accumulator flip
    _, det2 = stat_abft.stat_abft_matmul(aq, bq, flips, threshold_mag=0,
                                         bm=8, bn=8, bk=8, interpret=True)
    det2 = np.asarray(det2)                   # (M, n_col_tiles)
    assert det2[3, 0] and det2.sum() == 1

    _, det3 = stat_abft.stat_abft_matmul(aq, bq, flips,
                                         threshold_mag=2 ** 31 - 1,
                                         bm=8, bn=8, bk=8, interpret=True)
    assert not np.asarray(det3).any()


# ------------------------------------------------------- decode-loop context
def test_stat_abft_context_detects_injected_faults():
    """The serving execution context: BER 0 returns the clean product with
    zero detections; an aggressive BER on the same site key both perturbs
    the output and reports detections."""
    from repro.serving.ar import StatAbftContext
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    key = jax.random.PRNGKey(0)

    ctx0 = StatAbftContext(key, jnp.int32(0),
                           jnp.zeros((dvfs.N_CLASSES,)), detect=True)
    y0 = ctx0.matmul(x, w, name="attn.q", rclass=dvfs.CLASS_BODY)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(x @ w))
    assert float(ctx0.stats["detected_rows"]) == 0.0
    assert float(ctx0.stats["gemm_words"]) == 4 * 128

    ctx1 = StatAbftContext(key, jnp.int32(0),
                           jnp.full((dvfs.N_CLASSES,), 3e-2), detect=True)
    y1 = ctx1.matmul(x, w, name="attn.q", rclass=dvfs.CLASS_BODY)
    assert float(ctx1.stats["detected_rows"]) > 0
    assert not np.array_equal(np.asarray(y1), np.asarray(y0))
