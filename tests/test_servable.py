"""ServableModel protocol tests: registry totality, paradigm-irrelevant
request-field validation on both engines, and the autoregressive serving
path end-to-end (statistical ABFT detections + KV-window rollback through
the plain engine, the DeadlineScheduler, and the sharded engine).

The diffusion path's behavior is pinned elsewhere (test_serving*.py --
those suites ran against the pre-refactor engine and must stay green);
this module covers what the protocol added.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.serving import (PARADIGM_BY_FAMILY, UNSUPPORTED_FAMILIES,
                           DeadlineScheduler, DriftServeEngine,
                           ShardedDriftServeEngine, UnsupportedArchError,
                           paradigm_for)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

AR_ARCH = "olmo-1b"        # smallest dense smoke config
STEPS = 6


# ------------------------------------------------------------- registry
def test_family_partition_is_total():
    """Every config family resolves to exactly one paradigm or is
    explicitly unsupported -- a new config can't silently fall through."""
    assert not set(PARADIGM_BY_FAMILY) & set(UNSUPPORTED_FAMILIES)
    for arch in configs.list_archs():
        fam = configs.get_config(arch, smoke=True).family
        supported = fam in PARADIGM_BY_FAMILY
        assert supported != (fam in UNSUPPORTED_FAMILIES), (
            f"family {fam!r} ({arch}) must be in exactly one registry")
        if supported:
            assert paradigm_for(arch) == PARADIGM_BY_FAMILY[fam]
        else:
            with pytest.raises(UnsupportedArchError, match=arch):
                paradigm_for(arch)


def test_known_family_assignments():
    assert paradigm_for("dit-xl-512") == "diffusion"
    assert paradigm_for("sd15-unet") == "diffusion"
    assert paradigm_for("olmo-1b") == "autoregressive"
    assert paradigm_for("deepseek-moe-16b") == "autoregressive"
    assert paradigm_for("mamba2-370m") == "autoregressive"
    assert paradigm_for("hymba-1.5b") == "autoregressive"
    for arch in ("whisper-base", "internvl2-76b"):
        with pytest.raises(UnsupportedArchError):
            paradigm_for(arch)


# ------------------------------------------------- submit-time validation
def _check_submit_validation(eng):
    with pytest.raises(ValueError, match="taylorseer"):
        eng.submit(arch=AR_ARCH, steps=STEPS, mode="stat_abft",
                   taylorseer=True)
    with pytest.raises(ValueError, match="mode='drift'"):
        eng.submit(arch=AR_ARCH, steps=STEPS, mode="drift")
    # diffusion-only frontier knobs: reasoned rejections, not key errors
    with pytest.raises(ValueError, match="precision"):
        eng.submit(arch=AR_ARCH, steps=STEPS, mode="stat_abft",
                   precision="int8-body4")
    with pytest.raises(ValueError, match="frontier"):
        eng.submit(arch=AR_ARCH, steps=STEPS, mode="stat_abft",
                   energy_budget_j=1.0)
    with pytest.raises(ValueError, match="frontier"):
        eng.submit(arch=AR_ARCH, steps=STEPS, mode="stat_abft",
                   quality_floor=0.9)
    with pytest.raises(UnsupportedArchError, match="whisper-base"):
        eng.submit(arch="whisper-base", steps=STEPS, mode="clean")
    assert len(eng.queue) == 0          # nothing slipped into the queue


def test_ar_knob_validation_plain_engine():
    _check_submit_validation(DriftServeEngine(bucket=2))


@needs_mesh
def test_ar_knob_validation_sharded_engine():
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_serving_mesh(model_parallel=1,
                                      devices=jax.devices()[:2])
    _check_submit_validation(ShardedDriftServeEngine(mesh=mesh, bucket=2))


def test_ar_rejects_streaming():
    """run_stream previews are latent images -- AR requests must fail
    loudly, not yield garbage."""
    eng = DriftServeEngine(bucket=2)
    eng.submit(arch=AR_ARCH, steps=STEPS, mode="clean", op="nominal")
    with pytest.raises(ValueError, match="previews are latent images"):
        list(eng.run_stream(preview_interval=2))


# --------------------------------------------------- AR serving end-to-end
def test_ar_stat_abft_detects_and_rolls_back():
    """The acceptance-criterion run: an AR request through the shared
    engine, with injected faults detected by statistical ABFT and
    corrected via KV-cache window rollback (replayed tokens match the
    clean reference)."""
    eng = DriftServeEngine(bucket=2)
    eng.submit(arch=AR_ARCH, steps=STEPS, mode="stat_abft",
               op="undervolt", seed=0)
    eng.submit(arch=AR_ARCH, steps=STEPS, mode="clean",
               op="nominal", seed=1)
    res = {r.mode: r for r in eng.run()}
    assert set(res) == {"stat_abft", "clean"}

    prot = res["stat_abft"]
    assert prot.tokens is not None and len(prot.tokens) == STEPS
    assert prot.latents is None
    assert prot.ar_detections > 0, "undervolt BER produced no detections"
    assert prot.ar_rollbacks >= 1, "detections did not trigger rollback"
    assert prot.token_match_vs_clean == 1.0, (
        "rolled-back decode should match the clean reference")
    assert prot.n_model_evals > STEPS          # replays charged
    assert prot.energy_j > 0 and prot.latency_s > 0

    clean = res["clean"]
    assert clean.ar_detections == 0 and clean.ar_rollbacks == 0
    assert clean.token_match_vs_clean == 1.0
    assert clean.n_model_evals == STEPS

    # monitored mode fed the shared BER-monitor ladder
    assert int(eng.monitor.n_updates) > 0
    assert float(eng.monitor.ema_ber) > 0.0


def test_ar_and_diffusion_share_one_engine():
    """One engine, two paradigms: batches of each family serve through the
    same queue/cache/monitor without interfering."""
    eng = DriftServeEngine(bucket=2)
    eng.submit(arch="dit-xl-512", steps=3, mode="drift", op="undervolt",
               seed=0)
    eng.submit(arch=AR_ARCH, steps=4, mode="clean", op="nominal", seed=1)
    res = sorted(eng.run(), key=lambda r: r.request_id)
    assert len(res) == 2
    assert res[0].latents is not None and res[0].tokens is None
    assert res[1].tokens is not None and res[1].latents is None
    assert eng.stats.batches == 2


def test_ar_through_deadline_scheduler():
    """Admission control prices AR work per token (perfmodel LM branch)
    and the scheduled request serves through the same engine."""
    eng = DriftServeEngine(bucket=2)
    sched = DeadlineScheduler(eng)
    adm = sched.submit(arch=AR_ARCH, steps=STEPS, mode="stat_abft",
                       op="undervolt", priority="interactive", seed=3)
    assert adm.admitted
    res = sched.run()
    assert len(res) == 1
    assert res[0].tokens is not None and len(res[0].tokens) == STEPS
    assert res[0].ar_detections > 0
    # Frontier objectives on an AR request surface the servable's
    # reasoned rejection through the scheduler too (no diffusion
    # frontier is ever consulted for token decoding).
    with pytest.raises(ValueError, match="frontier"):
        sched.submit(arch=AR_ARCH, steps=STEPS, mode="stat_abft",
                     quality_floor=0.9)


@needs_mesh
def test_ar_sharded_engine_serves_and_detects():
    """The same AR configuration through a data-parallel mesh: detections
    are psum-reduced across shards and the run completes with rollback."""
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_serving_mesh(model_parallel=1,
                                      devices=jax.devices()[:2])
    eng = ShardedDriftServeEngine(mesh=mesh, bucket=2)
    eng.submit(arch=AR_ARCH, steps=STEPS, mode="stat_abft",
               op="undervolt", seed=0)
    res = eng.run()
    assert len(res) == 1
    assert res[0].ar_detections > 0 and res[0].ar_rollbacks >= 1
    assert res[0].token_match_vs_clean == 1.0
