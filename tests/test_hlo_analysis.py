"""Scan-aware HLO analyzer: verified against hand-countable programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H

DOT = 2 * 128 ** 3  # flops of one 128^3 matmul


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


A = jax.ShapeDtypeStruct((128, 128), jnp.float32)
W8 = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)


def test_single_dot():
    r = H.analyze(_hlo(lambda x, y: x @ y, A, A))
    assert r["flops"] == DOT


def test_scan_multiplies_by_trip_count():
    def f(x, w):
        y, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return y.sum()
    r = H.analyze(_hlo(f, A, W8))
    assert r["flops"] == 8 * DOT


def test_grad_scan_counts_both_loops():
    def f(x, w):
        y, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return y.sum()
    r = H.analyze(_hlo(jax.value_and_grad(f, argnums=(0, 1)), A, W8))
    assert r["flops"] == 24 * DOT     # 8 fwd + 16 bwd (dc, dw per layer)


def test_nested_scan():
    def f(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return ci @ wi, None
            ci, _ = jax.lax.scan(inner, c, jnp.arange(4))
            return ci, None
        y, _ = jax.lax.scan(outer, x, w)
        return y.sum()
    r = H.analyze(_hlo(f, A, W8))
    assert r["flops"] == 32 * DOT     # 8 outer x 4 inner


def test_bytes_counts_dot_traffic():
    r = H.analyze(_hlo(lambda x, y: x @ y, A, A))
    assert r["bytes"] >= 3 * 128 * 128 * 4   # two operands + result


def test_collectives_counted_with_trip_multiplier():
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (dry-run covers this path)")


def test_conv_flops():
    x = jax.ShapeDtypeStruct((1, 16, 16, 8), jnp.float32)
    k = jax.ShapeDtypeStruct((3, 3, 8, 16), jnp.float32)
    r = H.analyze(_hlo(
        lambda a, b: jax.lax.conv_general_dilated(
            a, b, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")), x, k))
    assert r["flops"] == 2 * 16 * 16 * 16 * (3 * 3 * 8)
