"""Sharding rules, checkpoint manager, elastic planning, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.distributed import elastic
from repro.distributed import sharding as shd
from repro.optim import compression


class FakeMesh:
    """Shape-only stand-in (tests run on 1 device; rules are pure)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH2 = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_param_rules_basic():
    assert shd.spec_for_param("embed", (262144, 5376), MESH2) == \
        P("model", "data")
    assert shd.spec_for_param("layers/attn/wq", (4096, 4096), MESH2) == \
        P("data", "model")
    assert shd.spec_for_param("layers/moe/w_gate", (384, 7168, 2048),
                              MESH3) == P("model", ("pod", "data"), None)
    assert shd.spec_for_param("layers/ln1/scale", (4096,), MESH2) == P(None)


def test_param_rules_divisibility_fallback():
    # mamba2 vocab 50280 is not divisible by 16 -> vocab axis dropped
    assert shd.spec_for_param("embed", (50280, 1024), MESH2) == \
        P(None, "data")
    # hymba in_proj second dim 6482 not divisible -> replicated on that dim
    assert shd.spec_for_param("layers/ssm/in_proj", (1600, 6482), MESH2) == \
        P("data", None)


def test_cache_spec_gqa_fallback():
    cfg = configs.get_config("glm4-9b")   # kv=2 < model axis 16
    spec = shd.cache_spec(cfg, (40, 128, 32768, 2, 128), MESH2)
    assert spec[3] is None and spec[2] == "model"   # seq-sharded instead
    cfg2 = configs.get_config("gemma3-27b")  # kv=16 divides
    spec2 = shd.cache_spec(cfg2, (62, 128, 32768, 16, 168), MESH2)
    assert spec2[3] == "model"


def test_batch_spec_batch1_fallback():
    spec = shd.batch_spec((1, 524288), MESH2, seq_dim=1)
    assert spec[0] is None and spec[1] == "data"


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    mgr.save(10, tree, extra={"data_step": 10})
    mgr.save(20, tree)
    got = mgr.restore_latest(tree)
    assert got is not None
    step, restored, extra = got
    assert step == 20
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_corruption_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=3)
    tree = {"a": jnp.arange(4.0)}
    mgr.save(1, tree)
    mgr.save(2, {"a": jnp.arange(4.0) * 2})
    # corrupt the newest checkpoint
    leaf = os.path.join(str(tmp_path), "step_00000002", "leaf_00000.npy")
    np.save(leaf, np.zeros(4))
    step, restored, _ = mgr.restore_latest(tree)
    assert step == 1


def test_checkpoint_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, {"a": jnp.zeros(2)})
    assert mgr.steps() == [3, 4]


def test_elastic_mesh_planning():
    assert elastic.plan_mesh(512, 16) == ((2, 16, 16),
                                          ("pod", "data", "model"))
    assert elastic.plan_mesh(256, 16) == ((16, 16), ("data", "model"))
    # losing a host: 480 devices, keep TP=16
    shape, axes = elastic.plan_mesh(480, 16)
    assert np.prod(shape) == 480
    with pytest.raises(ValueError):
        elastic.plan_mesh(100, 16)


def test_straggler_detector():
    det = elastic.StragglerDetector(threshold=1.5)
    for _ in range(5):
        bad = det.update({0: 1.0, 1: 1.0, 2: 1.0, 3: 5.0})
    assert bad == [3]
    shards = {0: 0, 1: 1, 2: 2, 3: 3}
    new = det.reassign_shards(shards, bad)
    assert new[3] != 3 and sorted(new.values()) == [0, 1, 2, 3]


def test_compression_error_feedback():
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (64, 64))}
    err = compression.init_error_buffer(g)
    total = jnp.zeros((64, 64))
    # accumulated dequantized gradients converge to true sum (EF property)
    for i in range(20):
        q, s, err = compression.compress(g, err)
        total = total + compression.decompress(q, s)["w"]
    rel = float(jnp.linalg.norm(total - 20 * g["w"])
                / jnp.linalg.norm(20 * g["w"]))
    assert rel < 0.01
