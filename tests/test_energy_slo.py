"""Energy-ledger + SLO-engine + trajectory-gate tests (the observatory).

Covers the PR 10 acceptance bar:

* **ledger exactness** -- for every priced cost the stack can produce
  (``run_cost`` and ``per_request_cost`` across archs, operating points,
  precision plans, ABFT on/off, TaylorSeer, replay evals, checkpoint
  intervals) the fixed-order component sum equals ``energy_j``
  **bitwise**, and the same invariant holds on real engine results
  (``RequestResult.energy_breakdown``) across ops x precision x
  offload on/off, diffusion and autoregressive (the 8-fake-device twin
  lives in test_serving_sharded.py);
* **SLO determinism** -- the tracker's burn rates are exact functions of
  the virtual clock (unit-pinned values; two identical engines produce
  identical ``/slo`` snapshots over the wire);
* **closing the loop** -- an energy-objective breach pins ``op="auto"``
  to the guardband floor, breaches edge-count into
  ``drift_slo_breaches_total``;
* **trajectory gate** -- tools/bench_history.py ingest/check mechanics:
  direction-aware tolerances, the zero-tolerance ledger residual, the
  fresh-history auto-pass, rolling retention, and ``--inject``.
"""
import importlib.util
import itertools
import json
import types
import urllib.request
from pathlib import Path

import pytest

from repro import configs
from repro.core import dvfs
from repro.perfmodel import energy
from repro.serving import (DriftServeEngine, EngineTelemetry, OffloadConfig,
                           serve_telemetry)
from repro.serving.telemetry import (ENERGY_COMPONENTS, GuardbandController,
                                     OBJECTIVES, SLOConfig, SLOTracker,
                                     verify_cost)
from repro.serving.telemetry.energy import EnergyLedger, ledger_total

ARCH = "dit-xl-512"
REPO = Path(__file__).resolve().parents[1]


def _load_bench_history():
    spec = importlib.util.spec_from_file_location(
        "bench_history", REPO / "tools" / "bench_history.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------- perfmodel ledger
def test_run_cost_ledger_exact_over_config_matrix():
    """The tentpole invariant at the source: every configuration the
    perfmodel can price reconciles component sum == energy_j bitwise."""
    em = energy.calibrate()
    checked = 0
    for arch, op, abft, ts, bits, interval in itertools.product(
            ("dit-xl-512", "sd15-unet", "olmo-1b"),
            (dvfs.NOMINAL, dvfs.UNDERVOLT, dvfs.OVERCLOCK),
            (True, False), (0, 3), (8, 4), (4, 10 ** 9)):
        cfg = configs.get_config(arch)
        rc = energy.RunConfig(num_steps=12, nominal_steps=2, aggressive=op,
                              abft_enabled=abft, taylorseer_interval=ts,
                              body_bits=bits, ckpt_interval=interval,
                              recovery_tiles_per_step=0.5)
        for batch in (1, 4):
            cost = energy.run_cost(cfg, rc, batch=batch, em=em)
            assert verify_cost(cost) == 0.0
            # aggregates are derived from the components, same association
            b = cost["breakdown"]
            assert cost["e_die"] == (b["compute_nominal"]
                                     + b["compute_aggressive"]
                                     + b["compute_replay"])
            assert cost["e_drift_mem"] == b["ckpt_refresh"] + b["recovery"]
            # attribution keeps the invariant for every live-count
            for n_live in (1, 2, batch):
                req = energy.per_request_cost(cfg, rc, batch=batch,
                                              n_live=n_live, em=em,
                                              cost=cost)
                assert verify_cost(req) == 0.0
                assert req["latency_s"] == cost["latency_s"]  # unscaled
            checked += 1
    assert checked == 3 * 3 * 2 * 2 * 2 * 2 * 2   # configs x batch sizes


def test_replay_evals_split_conserves_total():
    """Splitting aggressive compute into first-pass + replay relabels
    joules, it does not mint them; replay counts clamp to the resilient
    step count."""
    cfg = configs.get_config("olmo-1b")
    base = dict(num_steps=8, nominal_steps=1, aggressive=dvfs.UNDERVOLT)
    plain = energy.run_cost(cfg, energy.RunConfig(**base))
    for evals in (1, 3, 10 ** 6):
        split = energy.run_cost(
            cfg, energy.RunConfig(replay_evals=evals, **base))
        assert verify_cost(split) == 0.0
        assert split["energy_j"] == pytest.approx(plain["energy_j"])
        assert (split["breakdown"]["compute_aggressive"]
                + split["breakdown"]["compute_replay"]) == pytest.approx(
                    plain["breakdown"]["compute_aggressive"])
        if evals >= 7:      # n_agg = 7 here: the clamp
            assert split["breakdown"]["compute_aggressive"] == 0.0
    assert plain["breakdown"]["compute_replay"] == 0.0


def test_negative_replay_evals_charge_nothing():
    cfg = configs.get_config("olmo-1b")
    cost = energy.run_cost(cfg, energy.RunConfig(num_steps=4,
                                                 replay_evals=-3))
    assert cost["breakdown"]["compute_replay"] == 0.0
    assert verify_cost(cost) == 0.0


# --------------------------------------------------------- engine ledger
def _drain_and_verify(eng, n=2, **fields):
    for seed in range(n):
        eng.submit(seed=seed, **fields)
    results = eng.run()
    assert results
    for res in results:
        assert res.energy_breakdown is not None
        assert set(res.energy_breakdown) == set(ENERGY_COMPONENTS)
        assert ledger_total(res.energy_breakdown) == res.energy_j  # bitwise
    return results


@pytest.mark.parametrize("op", ["undervolt", "overclock"])
@pytest.mark.parametrize("offload", [False, True])
def test_engine_results_ledger_exact(op, offload):
    """Engine-billed requests reconcile bitwise, offload store included
    (its commits charge the same ckpt bytes the perfmodel prices)."""
    eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=2,
                           offload=OffloadConfig() if offload else None)
    results = _drain_and_verify(eng, steps=4, mode="drift", op=op)
    comp = results[0].energy_breakdown
    assert comp["compute_aggressive"] > 0 and comp["static"] > 0
    assert comp["ckpt_refresh"] > 0        # drift mode refreshes ckpts
    ledger = eng.telemetry.ledger
    assert ledger.batches == eng.stats.batches
    assert ledger.ops() == (op,)
    assert ledger.requests == len(results)
    # the fleet counter series carry the same joules the ledger holds
    text = eng.telemetry.registry.expose()
    assert f'drift_energy_joules_total{{component="static",op="{op}"}}' \
        in text


def test_engine_precision_plan_ledger_exact():
    eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=1)
    _drain_and_verify(eng, n=1, steps=4, mode="drift", op="undervolt",
                      precision="int8-body4")


def test_ar_engine_ledger_exact_with_replay_component():
    """Autoregressive serving bills replays into compute_replay and still
    reconciles bitwise."""
    eng = DriftServeEngine(arch="olmo-1b", smoke=True, bucket=1)
    results = _drain_and_verify(eng, n=1, steps=6, mode="stat_abft",
                                op="undervolt")
    res = results[0]
    # evals = prefill + steps + replays; any replay evals must have been
    # billed to the replay component
    replays = res.n_model_evals - 1 - res.steps
    if replays > 0:
        assert res.energy_breakdown["compute_replay"] > 0.0


def test_energy_ledger_accumulator_queries():
    led = EnergyLedger()
    led.charge_batch("undervolt", {c: 0.0 for c in ENERGY_COMPONENTS}
                     | {"compute_aggressive": 3.0, "static": 1.0})
    led.charge_batch("nominal", {c: 0.0 for c in ENERGY_COMPONENTS}
                     | {"compute_nominal": 4.0})
    led.charge_request(2.0)
    led.charge_request(4.0)
    assert led.ops() == ("nominal", "undervolt")
    assert led.component_totals()["compute_aggressive"] == 3.0
    assert led.component_totals("nominal")["compute_nominal"] == 4.0
    assert led.shares("undervolt")["compute_aggressive"] == 0.75
    assert sum(led.shares().values()) == pytest.approx(1.0)
    assert led.energy_per_request_j() == 3.0
    assert EnergyLedger().shares() == {c: 0.0 for c in ENERGY_COMPONENTS}
    assert EnergyLedger().energy_per_request_j() == 0.0


# ------------------------------------------------------------ SLO engine
def _req(clock_s=0.0, deadline=None, missed=False, energy_j=1.0, wait=0.0):
    return types.SimpleNamespace(deadline_s=deadline, deadline_missed=missed,
                                 energy_j=energy_j, queue_wait_s=wait)


def test_slo_tracker_pins_exact_burn_rates():
    cfg = SLOConfig(energy_per_request_j=2.0, queue_wait_p99_s=0.5,
                    deadline_miss_rate=0.25, fast_window_s=1.0,
                    slow_window_s=10.0)
    t = SLOTracker(target_ber=1e-3, config=cfg)
    t.observe_batch(0.1, ema_ber=2e-3, monitored=True, results=[
        _req(deadline=0.05, missed=True, energy_j=4.0, wait=0.2),
        _req(deadline=None, energy_j=4.0, wait=0.1)])
    burns = t.burn_rates()
    # energy: mean 4.0 vs target 2.0 -> burn 2.0, both windows
    assert burns[("energy_per_request_j", "fast")] == 2.0
    assert burns[("energy_per_request_j", "slow")] == 2.0
    # deadline: 1 miss of 1 deadline-carrying request vs target 0.25
    assert burns[("deadline_miss_rate", "fast")] == 1.0 / 0.25
    # ber: window mean 2e-3 vs target 1e-3
    assert burns[("ber_detection_rate", "slow")] == 2.0
    # p99 queue wait (nearest rank over [0.1, 0.2]) vs 0.5
    assert burns[("queue_wait_p99_s", "fast")] == 0.2 / 0.5
    assert t.breached["energy_per_request_j"]
    assert t.energy_breached and t.any_breached
    assert "energy_per_request_j" in t.breached_objectives()


def test_slo_windows_evict_on_virtual_clock():
    cfg = SLOConfig(energy_per_request_j=2.0, fast_window_s=1.0,
                    slow_window_s=5.0)
    t = SLOTracker(target_ber=1e-3, config=cfg)
    t.observe_batch(0.0, 0.0, False, [_req(energy_j=8.0)])
    assert t.breached["energy_per_request_j"]
    # 2 virtual seconds later the spike left the fast window: slow still
    # burns but the multiwindow guard clears the breach
    t.observe_batch(2.0, 0.0, False, [_req(energy_j=1.0)])
    assert t.value("energy_per_request_j", cfg.fast_window_s) == 1.0
    assert t.value("energy_per_request_j", cfg.slow_window_s) == 4.5
    assert not t.breached["energy_per_request_j"]
    # past the slow horizon the spike is evicted entirely
    t.observe_batch(8.0, 0.0, False, [_req(energy_j=1.0)])
    assert t.value("energy_per_request_j", cfg.slow_window_s) == 1.0
    snap = t.snapshot()
    assert snap["batches"] == 3 and snap["clock_s"] == 8.0
    assert set(snap["objectives"]) == set(OBJECTIVES)


def test_slo_unknown_objective_raises():
    t = SLOTracker(target_ber=1e-3)
    with pytest.raises(KeyError):
        t.target("nope")
    with pytest.raises(KeyError):
        t.value("nope", 1.0)


def test_slo_snapshot_deterministic_across_engines_and_http():
    """Two identical engines serve the same stream: byte-identical /slo
    bodies (virtual-clock evaluation has no machine dependence)."""
    snaps = []
    for _ in range(2):
        eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=2)
        for seed in range(4):
            eng.submit(steps=3, mode="drift",
                       op="undervolt" if seed < 2 else "overclock",
                       seed=seed)
        eng.run()
        with serve_telemetry(eng, port=0) as server:
            body = urllib.request.urlopen(f"{server.url}/slo").read()
        snaps.append(body)
        assert json.loads(body) == json.loads(
            json.dumps(eng.telemetry.slo_snapshot()))
    assert snaps[0] == snaps[1]


def test_slo_disabled_telemetry_over_http():
    eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=1,
                           telemetry=EngineTelemetry(enabled=False))
    eng.submit(steps=2, mode="drift", op="undervolt", seed=0)
    eng.run()
    with serve_telemetry(eng, port=0) as server:
        body = json.load(urllib.request.urlopen(f"{server.url}/slo"))
    assert body == {"slo": "disabled"}


# ------------------------------------------------- closing the loop
def test_energy_breach_pins_clamp_to_guardband_floor():
    ctrl = GuardbandController(target_ber=1e-3)
    ctrl.guard_index = 1
    assert ctrl.clamp(0) == 1          # floor
    assert ctrl.clamp(3) == 3          # ladder above floor wins
    ctrl.set_energy_slo_breach(True)
    assert ctrl.clamp(3) == 1          # breach: floor is the ceiling too
    assert ctrl.clamp(0) == 1
    ctrl.set_energy_slo_breach(False)
    assert ctrl.clamp(3) == 3


def test_energy_breach_feeds_controller_and_edge_counts():
    """A hopeless energy target breaches on the first batch: the engine's
    controller learns it, "auto" resolves to the floor, and the breach
    counter counts the onset exactly once across repeated burning
    batches."""
    tele = EngineTelemetry(
        slo_config=SLOConfig(energy_per_request_j=1e-12))
    eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=1, telemetry=tele)
    eng.submit(steps=2, mode="drift", op="undervolt", seed=0)
    eng.run()
    assert tele.slo.energy_breached
    assert tele.controller.energy_slo_breached
    assert eng.auto_op_index() == tele.controller.guard_index
    edge = tele.registry.counter("drift_slo_breaches_total").labels(
        objective="energy_per_request_j")
    assert edge.value == 1.0
    eng.submit(steps=2, mode="drift", op="undervolt", seed=1)
    eng.run()
    assert edge.value == 1.0           # still burning: no new onset
    gauge = tele.registry.gauge("drift_slo_breached").labels(
        objective="energy_per_request_j")
    assert gauge.value == 1.0


# ------------------------------------------------------- trajectory gate
@pytest.fixture()
def bh():
    return _load_bench_history()


def test_bench_history_flatten_scalars_only(bh):
    out = {}
    bh._flatten("t", {"a": 1, "b": {"c": 2.5, "flag": True},
                      "s": "text", "l": [1, 2]}, out)
    assert out == {"t.a": 1.0, "t.b.c": 2.5}
    assert bh._tag("/x/BENCH_serving.json") == "serving"


def test_bench_history_ingest_and_rolling_retention(bh, tmp_path):
    (tmp_path / "BENCH_serving.json").write_text(
        json.dumps({"throughput_req_per_virtual_s": 20.0}))
    hist = tmp_path / "BENCH_history.json"
    for i in range(5):
        bh.ingest(str(tmp_path), str(hist), sha=f"sha{i}", keep=3)
    entries = bh.load_history(str(hist))
    assert [e["sha"] for e in entries] == ["sha2", "sha3", "sha4"]
    assert entries[-1]["metrics"] == {
        "serving.throughput_req_per_virtual_s": 20.0}


def test_bench_history_regression_directions(bh):
    base = [{"sha": "b", "metrics": {
        "serving.throughput_req_per_virtual_s": 20.0,
        "serving.queue_wait_p99_s": 0.4,
        "energy.energy_per_request_j": 1.0,
        "energy.ledger_residual_j": 0.0}} for _ in range(3)]

    def bad_metrics(**kw):
        m = dict(base[0]["metrics"])
        m.update(kw)
        return {r["metric"] for r in
                bh.regressions(base, {"sha": "c", "metrics": m})}

    assert bad_metrics() == set()
    # inside tolerance in the bad direction: no flag
    assert bad_metrics(**{
        "serving.throughput_req_per_virtual_s": 18.5}) == set()
    # beyond tolerance, bad direction
    assert bad_metrics(**{"serving.throughput_req_per_virtual_s": 15.0}) \
        == {"serving.throughput_req_per_virtual_s"}
    assert bad_metrics(**{"energy.energy_per_request_j": 1.2}) \
        == {"energy.energy_per_request_j"}
    # the good direction never flags, however large the move
    assert bad_metrics(**{
        "serving.throughput_req_per_virtual_s": 400.0,
        "energy.energy_per_request_j": 0.01}) == set()
    # zero-tolerance residual: any leak is a regression
    assert bad_metrics(**{"energy.ledger_residual_j": 1e-9}) \
        == {"energy.ledger_residual_j"}
    # metrics missing on either side are skipped, not flagged
    assert bh.regressions(base, {"sha": "c", "metrics": {}}) == []


def test_bench_history_check_min_baseline_and_inject(bh, tmp_path, capsys):
    (tmp_path / "BENCH_serving.json").write_text(
        json.dumps({"throughput_req_per_virtual_s": 20.0}))
    hist = str(tmp_path / "BENCH_history.json")
    # empty history and fresh (no-baseline) history both auto-pass
    assert bh.check(hist, 5, 1, {}) == 0
    bh.ingest(str(tmp_path), hist, sha="a")
    assert bh.check(hist, 5, 1, {}) == 0
    bh.ingest(str(tmp_path), hist, sha="b")
    assert bh.check(hist, 5, 1, {}) == 0
    # the gate fires on an injected throughput drop
    assert bh.check(hist, 5, 1,
                    {"serving.throughput_req_per_virtual_s": 0.5}) == 1
    assert "REGRESSION" in capsys.readouterr().out
    with pytest.raises(SystemExit):
        bh.check(hist, 5, 1, {"not.a.metric": 0.5})
    assert bh.self_test() == 0
