"""Flash-attention Pallas kernel vs the pure-jnp attention oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, mha_flash
from repro.models import attention as attn_ref


def _ref(q, k, v, causal):
    # (BH, S, D) oracle via models.attention.full_attention
    bh, s, d = q.shape
    q4 = q.reshape(bh, s, 1, d).transpose(0, 1, 2, 3)
    k4 = k.reshape(bh, s, 1, d)
    v4 = v.reshape(bh, s, 1, d)
    o = attn_ref.full_attention(q4, k4, v4, causal=causal, window=None)
    return o.reshape(bh, s, d)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("s,d,bq,bk", [(64, 32, 32, 32), (128, 64, 32, 64),
                                       (96, 16, 32, 32)])
def test_flash_matches_ref(causal, s, d, bq, bk):
    key = jax.random.PRNGKey(s + d)
    q = jax.random.normal(key, (2, s, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, s, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, s, d), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                          interpret=True)
    want = _ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 64, 32), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 32),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 32),
                          jnp.bfloat16)
    got = flash_attention(q, k, v, bq=32, bk=32, interpret=True)
    want = _ref(q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), False)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=3e-2, rtol=3e-2)


def test_mha_wrapper_shape():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 64, 4, 32))
    o = mha_flash(x, x, x, causal=False, bq=32, bk=32, interpret=True)
    assert o.shape == (2, 64, 4, 32)
    ref = attn_ref.full_attention(x, x, x, causal=False, window=None)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref), atol=2e-5)
