"""Unit + property tests for the DRIFT core (quant, fault, abft, dvfs,
rollback, exec context, baselines, repack, metrics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                         # deterministic local fallback
    from _hypothesis_stub import given, settings, st

from repro.core import (abft, baselines, dvfs, exec_ctx, fault, metrics,
                        policies, quant, repack, rollback)


# ---------------------------------------------------------------- quant
def test_quant_roundtrip_error_bound():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 64)) * 3.0
    qt = quant.quantize(x)
    err = jnp.abs(qt.dequantize() - x)
    assert float(err.max()) <= float(qt.scale) * 0.5 + 1e-6


def test_quant_per_channel_scales():
    x = jnp.stack([jnp.ones(8) * 0.01, jnp.ones(8) * 100.0], axis=1)
    qt = quant.quantize(x, axis=1)
    assert qt.scale.shape == (1, 2)
    np.testing.assert_allclose(np.asarray(qt.dequantize()), np.asarray(x),
                               rtol=0.02)


def test_int32_accumulator_headroom():
    # Largest assigned contraction (gemma3 d_ff=21504) must not saturate.
    assert quant.quant_error_bound(21504) < 2 ** 31


# ---------------------------------------------------------------- fault
def test_fault_rate_matches_ber():
    key = jax.random.PRNGKey(1)
    acc = jnp.zeros((512, 512), jnp.int32)
    ber = 1e-3
    out = fault.inject_int32(acc, key, jnp.float32(ber))
    flipped = int(jnp.sum(out != 0))
    expect = 512 * 512 * 32 * ber  # one flip per word approximation
    assert 0.7 * expect < flipped < 1.3 * expect


def test_fault_zero_ber_is_identity():
    key = jax.random.PRNGKey(1)
    acc = jax.random.randint(key, (64, 64), -10000, 10000, dtype=jnp.int32)
    out = fault.inject_int32(acc, key, jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(acc))


def test_inject_at_deterministic():
    acc = jnp.zeros((8, 8), jnp.int32)
    out = fault.inject_at(acc, flat_index=9, bit=14)
    assert int(out.reshape(-1)[9]) == 1 << 14
    assert int(jnp.sum(out != 0)) == 1


# ---------------------------------------------------------------- abft
@settings(max_examples=30, deadline=None)
@given(bit=st.integers(min_value=0, max_value=31),
       idx=st.integers(min_value=0, max_value=64 * 48 - 1))
def test_abft_detects_iff_above_threshold(bit, idx):
    key = jax.random.PRNGKey(bit)
    a = jax.random.normal(key, (64, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 48))
    aq, wq = quant.quantize(a), quant.quantize(w, axis=1)
    acc = quant.int32_matmul(aq.q, wq.q)
    accf = fault.inject_at(acc, idx, bit)
    rep = abft.detect_int(accf, aq.q, wq.q, abft.AbftConfig(threshold_bit=10))
    detected = bool(rep.n_row_err > 0) and bool(rep.n_col_err > 0)
    assert detected == (bit >= 10)
    if detected:
        assert bool(rep.row_flag[idx // 48]) and bool(rep.col_flag[idx % 48])


def test_abft_error_free_no_flags():
    key = jax.random.PRNGKey(2)
    a = jax.random.normal(key, (128, 256)) * 5
    w = jax.random.normal(jax.random.fold_in(key, 1), (256, 64)) * 5
    aq, wq = quant.quantize(a), quant.quantize(w, axis=1)
    acc = quant.int32_matmul(aq.q, wq.q)
    rep = abft.detect_int(acc, aq.q, wq.q, abft.AbftConfig(threshold_bit=0))
    # exact integer checksums: zero diff even at threshold bit 0
    assert int(rep.n_row_err) == 0 and int(rep.n_col_err) == 0
    assert int(jnp.abs(rep.row_diff).max()) == 0


def test_abft_bit31_flip_detected():
    """abs(INT32_MIN) overflow regression: delta=-2^31 must still flag."""
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (32, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 32))
    aq, wq = quant.quantize(a), quant.quantize(w, axis=1)
    acc = quant.int32_matmul(aq.q, wq.q)
    accf = fault.inject_at(acc, 5, 31)
    rep = abft.detect_int(accf, aq.q, wq.q, abft.AbftConfig(threshold_bit=10))
    assert int(rep.n_row_err) >= 1 and int(rep.n_col_err) >= 1


def test_tile_checksums_match_global():
    key = jax.random.PRNGKey(4)
    aq = jax.random.randint(key, (64, 96), -127, 128, dtype=jnp.int8)
    bq = jax.random.randint(jax.random.fold_in(key, 1), (96, 64),
                            -127, 128, dtype=jnp.int8)
    acc = quant.int32_matmul(aq, bq)
    cfg = abft.AbftConfig(tile_m=32, tile_n=32)
    rd, cd = abft.tile_checksum_diff(acc, aq, bq, cfg)
    assert int(jnp.abs(rd).max()) == 0 and int(jnp.abs(cd).max()) == 0


# ---------------------------------------------------------------- dvfs
def test_ber_anchor_points():
    assert dvfs.ber_of(dvfs.NOMINAL) < 1e-10
    assert abs(dvfs.ber_of(dvfs.UNDERVOLT) - 3e-3) < 1e-4
    assert abs(dvfs.ber_of(dvfs.OVERCLOCK) - 3e-3) < 1e-4


def test_ber_monotone_in_voltage():
    bers = [dvfs.ber_of(dvfs.OperatingPoint(v, 2.0))
            for v in [0.65, 0.7, 0.75, 0.8, 0.85, 0.9]]
    assert all(b1 >= b2 for b1, b2 in zip(bers, bers[1:]))


def test_fine_grained_schedule_protects():
    sched = dvfs.fine_grained_schedule(10, dvfs.UNDERVOLT, nominal_steps=2)
    t = np.asarray(sched.ber_table)
    assert (t[:2] == 0).all()                         # first steps nominal
    assert (t[:, dvfs.CLASS_EMBED] == 0).all()        # embeddings nominal
    assert (t[2:, dvfs.CLASS_BODY] > 0).all()         # body aggressive


def test_ber_monitor_walks_ladder():
    st_ = dvfs.ber_monitor_init()
    # consistently hot measurements walk the index up
    for _ in range(5):
        st_ = dvfs.ber_monitor_update(st_, jnp.int32(1000), 4096, 10, 1e-4)
    assert int(st_.op_index) > 0
    # sustained cold measurements eventually walk it back down (EMA decay)
    for _ in range(80):
        st_ = dvfs.ber_monitor_update(st_, jnp.int32(0), 4096, 10, 1e-4)
    assert int(st_.op_index) == 0


# ------------------------------------------------------------- rollback
def test_rollback_interval_semantics():
    assert bool(rollback.should_checkpoint(jnp.int32(0), 10))
    assert bool(rollback.should_checkpoint(jnp.int32(10), 10))
    assert not bool(rollback.should_checkpoint(jnp.int32(5), 10))


def test_rollback_correct_fallback_zeroes():
    cur = jnp.ones((4, 4))
    mask = jnp.zeros((4, 4), bool).at[1, 2].set(True)
    out = rollback.correct(cur, None, mask, jnp.asarray(False))
    assert float(out[1, 2]) == 0.0 and float(out[0, 0]) == 1.0


# ------------------------------------------------------------- exec ctx
@pytest.mark.parametrize("mode", ["clean", "faulty", "drift", "thundervolt",
                                  "approx_abft", "dmr", "stat_abft"])
def test_exec_ctx_modes_run(mode):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 48))
    w = jax.random.normal(jax.random.fold_in(key, 1), (48, 64))
    ctx = exec_ctx.ExecContext(
        exec_ctx.DriftSystemConfig(mode=mode), key=key, step=3,
        ber_by_class=jnp.array([0.0, 0.0, 1e-3]),
        state_in={"g": x @ w}, have_ckpt=True)
    y = ctx.matmul(x, w, name="g")
    assert y.shape == (64, 64)
    assert not bool(jnp.any(jnp.isnan(y)))


def test_exec_ctx_drift_beats_faulty():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 48))
    w = jax.random.normal(jax.random.fold_in(key, 1), (48, 64))
    clean = exec_ctx.ExecContext(
        exec_ctx.DriftSystemConfig(mode="clean")).matmul(x, w, name="g")
    errs = {}
    for mode in ["faulty", "drift"]:
        ctx = exec_ctx.ExecContext(
            exec_ctx.DriftSystemConfig(mode=mode), key=key, step=3,
            ber_by_class=jnp.array([0.0, 0.0, 3e-3]),
            state_in={"g": clean}, have_ckpt=True)
        errs[mode] = float(jnp.abs(ctx.matmul(x, w, name="g") - clean).max())
    assert errs["drift"] < errs["faulty"] * 1e-3


def test_exec_ctx_jit_and_scan_compatible():
    """The context must be usable inside jit with threaded state."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 32))
    cfg = exec_ctx.DriftSystemConfig(mode="drift")

    @jax.jit
    def step(carry, step_idx):
        state, = carry
        ctx = exec_ctx.ExecContext(cfg, key=key, step=step_idx,
                                   ber_by_class=jnp.array([0., 0., 1e-3]),
                                   state_in=state, have_ckpt=step_idx > 0)
        y = ctx.matmul(x, w, name="g")
        return (ctx.state_out,), y

    carry = ({"g": jnp.zeros((32, 32))},)
    carry, ys = jax.lax.scan(step, carry, jnp.arange(4))
    assert ys.shape == (4, 32, 32)
    assert not bool(jnp.any(jnp.isnan(ys)))


# ------------------------------------------------------------ baselines
def test_baseline_costs_ordering():
    """DMR must charge more recompute than StatABFT; DRIFT charges none."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 48))
    w = jax.random.normal(jax.random.fold_in(key, 1), (48, 64))
    costs = {}
    for mode in ["dmr", "stat_abft", "drift"]:
        ctx = exec_ctx.ExecContext(
            exec_ctx.DriftSystemConfig(mode=mode), key=key, step=3,
            ber_by_class=jnp.array([0.0, 0.0, 1e-3]),
            state_in={"g": x @ w}, have_ckpt=True)
        ctx.matmul(x, w, name="g")
        costs[mode] = float(ctx.stats["extra_compute_flops"])
    assert costs["dmr"] > costs["stat_abft"] > 0
    assert costs["drift"] == 0.0


# --------------------------------------------------------------- repack
@settings(max_examples=20, deadline=None)
@given(m=st.integers(min_value=1, max_value=70),
       n=st.integers(min_value=1, max_value=70),
       tm=st.sampled_from([8, 16, 32]),
       tn=st.sampled_from([8, 16, 32]))
def test_repack_roundtrip(m, n, tm, tn):
    x = jnp.arange(m * n, dtype=jnp.float32).reshape(m, n)
    xt = repack.repack(x, tm, tn)
    np.testing.assert_array_equal(np.asarray(repack.unpack(xt, (m, n), tm, tn)),
                                  np.asarray(x))


# -------------------------------------------------------------- metrics
def test_metrics_basics():
    key = jax.random.PRNGKey(0)
    img = jax.random.uniform(key, (2, 32, 32, 3)) * 2 - 1
    assert float(metrics.lpips_proxy(img, img)) == 0.0
    noisy = img + 0.5 * jax.random.normal(key, img.shape)
    d1 = float(metrics.lpips_proxy(img, img + 0.1 * jax.random.normal(key, img.shape)))
    d2 = float(metrics.lpips_proxy(img, noisy))
    assert d2 > d1 > 0.0
    assert float(metrics.psnr(img, img)) > 100
    assert float(metrics.ssim(img, img)) > 0.999


# ------------------------------------------------------------- policies
def test_policy_classification():
    pol = policies.PAPER_DEFAULT
    assert pol.classify("embed", 0) == dvfs.CLASS_EMBED
    assert pol.classify("block", 0) == dvfs.CLASS_FIRST_BLOCK
    assert pol.classify("block", 5) == dvfs.CLASS_BODY
