"""Sharded serving-engine tests: mesh placement, bit-comparability vs the
single-device engine, compile-once behavior per mesh config, and
BER-monitor ladder consistency across the mesh.

These need a multi-device jax runtime; CI provides one with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (must be set before
the first jax import, hence a separate job -- see .github/workflows/ci.yml).
On a single-device run everything mesh-shaped skips.
"""
import jax
import numpy as np
import pytest

from repro.launch import mesh as mesh_lib
from repro.serving import (DriftServeEngine, GenerationRequest, PreviewEvent,
                           RequestResult, ShardedDriftServeEngine,
                           make_engine, request_key)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

STEPS, BUCKET, N_REQ = 3, 4, 6   # 6 requests -> 2 batches, one padded slot


def submit_stream(eng):
    for i in range(N_REQ):
        eng.submit(steps=STEPS, mode="drift",
                   op="auto" if i >= 4 else "undervolt", seed=i)
    return eng.run()


def monitor_snapshot(eng):
    """Immutable copy of the post-stream monitor state: later tests may run
    more batches on the shared engines, so comparisons use this, not the
    live ``eng.monitor`` (keeps the module order-independent)."""
    return (int(eng.monitor.n_updates), int(eng.monitor.op_index),
            float(eng.monitor.ema_ber))


@pytest.fixture(scope="module")
def reference():
    """Single-device engine results for the shared request stream."""
    eng = DriftServeEngine(bucket=BUCKET)
    results = submit_stream(eng)
    return eng, results, monitor_snapshot(eng)


@pytest.fixture(scope="module")
def sharded_dp():
    """Data-parallel engine (4-way batch shard) over the same stream."""
    mesh = mesh_lib.make_serving_mesh(model_parallel=1,
                                      devices=jax.devices()[:BUCKET])
    eng = ShardedDriftServeEngine(mesh=mesh, bucket=BUCKET)
    results = submit_stream(eng)
    return eng, results, monitor_snapshot(eng)


@needs_mesh
def test_data_parallel_latents_bit_equal(reference, sharded_dp):
    """The tentpole acceptance bar: sharding one micro-batch over the data
    axis must not change a single bit of any request's latents."""
    _, ref, _ = reference
    _, shr, _ = sharded_dp
    assert len(shr) == N_REQ
    for a, b in zip(ref, shr):
        assert a.request_id == b.request_id and a.op == b.op
        assert np.array_equal(np.asarray(a.latents), np.asarray(b.latents))
        assert a.n_model_evals == b.n_model_evals


@needs_mesh
def test_monitor_ladder_consistent_across_mesh(reference, sharded_dp):
    """Detected-error counts are psum-reduced into a replicated monitor, so
    the sharded ladder walks in lockstep with the single-device one -- and
    the "auto" requests (seeds 4, 5) resolved against that shared state."""
    _, ref, ref_mon = reference
    _, shr, shr_mon = sharded_dp
    # (n_updates, op_index, ema_ber): batch-dim detection sums are integer
    # reductions, so even the EMA float is bit-equal
    assert shr_mon == ref_mon
    assert [r.op for r in shr][4:] == [r.op for r in ref][4:]
    assert [r.monitor_op_index for r in shr] == \
        [r.monitor_op_index for r in ref]


@needs_mesh
def test_no_recompiles_after_first_batch_per_mesh_config(sharded_dp):
    """Re-serving an already-compiled (config, mesh) must be pure cache
    hits. ("auto" requests are excluded: the ladder may have walked, and a
    new resolved op is a legitimately new configuration.)"""
    eng, _, _ = sharded_dp
    traces0, hits0 = eng.cache.traces, eng.cache.hits
    for i in range(BUCKET):
        eng.submit(steps=STEPS, mode="drift", op="undervolt", seed=i)
    eng.run()
    assert eng.cache.traces == traces0      # zero new jax traces
    assert eng.cache.hits > hits0


@needs_mesh
def test_tensor_parallel_mesh_close_to_reference(reference):
    """model axis > 1 re-associates GEMM reductions, so only closeness (not
    bit-equality) is guaranteed; quality metrics must hold up."""
    _, ref, _ = reference
    mesh = mesh_lib.make_serving_mesh(model_parallel=2)   # (4, 2) over 8
    eng = ShardedDriftServeEngine(mesh=mesh, bucket=BUCKET)
    shr = submit_stream(eng)
    for a, b in zip(ref, shr):
        np.testing.assert_allclose(np.asarray(a.latents),
                                   np.asarray(b.latents),
                                   atol=5e-3, rtol=5e-3)
        assert b.psnr_vs_clean_db > 20.0


@needs_mesh
def test_results_carry_sharded_latents(sharded_dp):
    _, shr, _ = sharded_dp
    for r in shr:
        lat = np.asarray(r.latents)
        assert lat.ndim == 3                      # (H, W, C), one sample
        assert np.all(np.abs(lat) <= 1.0)


def test_sampler_key_grows_mesh_component():
    """Pure key hygiene (no devices needed): engines on different meshes
    must never alias a compiled sampler."""
    req = GenerationRequest(request_id=0, steps=4, mode="drift",
                            op="undervolt")
    base = request_key(req, 4, "undervolt")
    k8 = request_key(req, 4, "undervolt",
                     extra={"mesh_shape": (("data", 8), ("model", 1)),
                            "batch_spec": "data,None,None,None"})
    k42 = request_key(req, 4, "undervolt",
                      extra={"mesh_shape": (("data", 4), ("model", 2)),
                             "batch_spec": "data,None,None,None"})
    assert base.mesh_shape == () and base.batch_spec == ""
    assert len({base, k8, k42}) == 3
    # mesh placement must survive the clean-reference key rewrite
    import dataclasses
    ck = dataclasses.replace(k8, mode="clean", op="")
    assert ck.mesh_shape == k8.mesh_shape


@needs_mesh
def test_streaming_bit_identical_on_sharded_engine(reference):
    """PR 3 acceptance: a streamed request on the 8-fake-device
    data-parallel engine yields >= 1 intermediate preview and finishes with
    latents bit-identical to the single-device NON-streaming reference --
    streaming and sharding each preserve bit-equality, so together they
    must too."""
    _, ref, _ = reference
    mesh = mesh_lib.make_serving_mesh(model_parallel=1,
                                      devices=jax.devices()[:BUCKET])
    eng = ShardedDriftServeEngine(mesh=mesh, bucket=BUCKET)
    for i in range(N_REQ):
        eng.submit(steps=STEPS, mode="drift",
                   op="auto" if i >= 4 else "undervolt", seed=i)
    events = list(eng.run_stream(preview_interval=1))
    previews = [e for e in events if isinstance(e, PreviewEvent)]
    results = sorted((e for e in events if isinstance(e, RequestResult)),
                     key=lambda r: r.request_id)
    # STEPS denoising steps, window 1 -> STEPS-1 previews per live request
    assert len(previews) == (STEPS - 1) * N_REQ
    assert all(p.step < STEPS for p in previews)
    assert len(results) == N_REQ
    for a, b in zip(ref, results):
        assert a.request_id == b.request_id and a.op == b.op
        assert np.array_equal(np.asarray(a.latents), np.asarray(b.latents))
        assert a.n_model_evals == b.n_model_evals
    # the shared monitor walked the same ladder through the windowed path
    assert [r.monitor_op_index for r in results] == \
        [r.monitor_op_index for r in ref]


@needs_mesh
def test_offload_bit_identical_on_sharded_engine(reference):
    """Offload acceptance twin (single-device version in
    tests/test_offload.py): async checkpoint offload on the 8-fake-device
    data-parallel engine -- shard-resident store leaves snapshotted
    host-side between windows, commit decisions driven by the replicated
    (psum-reduced) monitor -- must leave every request's final latents
    bit-identical to the single-device, offload-free reference."""
    from repro.serving import OffloadConfig

    _, ref, _ = reference
    mesh = mesh_lib.make_serving_mesh(model_parallel=1,
                                      devices=jax.devices()[:BUCKET])
    eng = ShardedDriftServeEngine(mesh=mesh, bucket=BUCKET,
                                  offload=OffloadConfig())
    shr = submit_stream(eng)
    assert len(shr) == N_REQ
    for a, b in zip(ref, shr):
        assert a.request_id == b.request_id and a.op == b.op
        assert np.array_equal(np.asarray(a.latents), np.asarray(b.latents))
    # the offload really ran: ceil(3 / 10) = 1 refresh per batch, 2 batches
    st = eng.offload_store.stats
    assert st.commits == 2 and st.bytes_offloaded > 0
    # a restore reassembles the sharded leaves with their shardings intact
    restored = eng.offload_store.restore()
    import jax as _jax
    for leaf in _jax.tree.leaves(restored):
        assert leaf.shape[0] >= 1          # materialized on device
    # monitor stayed replicated/lockstep through the offload windows
    assert [r.monitor_op_index for r in shr] == \
        [r.monitor_op_index for r in ref]


@needs_mesh
def test_make_engine_picks_sharded_on_multi_device():
    eng = make_engine(bucket=2)
    assert isinstance(eng, ShardedDriftServeEngine)
    assert eng.mesh.size == jax.device_count()


@needs_mesh
def test_empty_history_admission_bit_identical_on_mesh():
    """Telemetry-estimator fallback twin (single-device version in
    test_telemetry.py): on the 8-fake-device sharded engine with no
    served-batch history, admission decisions and clock projections are
    bit-identical to the perfmodel-only (telemetry-disabled) path."""
    from repro.serving import DeadlineScheduler, EngineTelemetry

    def plans(telemetry):
        eng = ShardedDriftServeEngine(bucket=BUCKET, telemetry=telemetry)
        sched = DeadlineScheduler(eng)
        lat = sched.batch_latency_s("dit-xl-512", "undervolt", STEPS)
        out = []
        for i, (dl, prio) in enumerate([(None, "background"),
                                        (5.0 * lat, "interactive"),
                                        (1.2 * lat, "standard"),
                                        (1e-7, "interactive")]):
            out.append(sched.submit(steps=STEPS, mode="drift",
                                    op="undervolt", priority=prio,
                                    deadline_s=dl, seed=i))
        return out

    with_telemetry = plans(None)                       # default: enabled
    without = plans(EngineTelemetry(enabled=False))
    assert with_telemetry == without   # frozen dataclasses, exact floats


@needs_mesh
def test_energy_ledger_exact_on_mesh(reference, sharded_dp):
    """The ledger invariant survives sharding: every request billed by
    either engine reconciles component sum == energy_j bitwise, and the
    two engines bill identical breakdowns (same perfmodel, same bucket)."""
    from repro.serving.telemetry.energy import ledger_total
    _, ref, _ = reference
    _, shr, _ = sharded_dp
    for results in (ref, shr):
        for r in results:
            assert r.energy_breakdown is not None
            assert ledger_total(r.energy_breakdown) == r.energy_j
    for a, b in zip(ref, shr):
        assert a.energy_breakdown == b.energy_breakdown
