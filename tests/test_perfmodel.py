"""Energy model calibration, DSE trends, DRAM repacking, cycle model."""
import numpy as np
import pytest

from repro import configs
from repro.core import dvfs
from repro.perfmodel import dram, energy, flops, scalesim
from repro.perfmodel.hw import PAPER_ACCEL, PaperAccel


def test_calibration_hits_table1_baseline():
    em = energy.calibrate()
    cfg = configs.get_config("dit-xl-512")
    base = energy.run_cost(cfg, energy.baseline_rc(50), em=em)
    assert abs(base["energy_j"] - 6.02) < 0.05
    assert abs(base["latency_s"] - 0.56) < 0.01


def test_undervolt_saving_in_paper_range():
    em = energy.calibrate()
    saves = []
    for arch, steps in [("dit-xl-512", 50), ("pixart-alpha", 20),
                        ("sd15-unet", 50)]:
        cfg = configs.get_config(arch)
        base = energy.run_cost(cfg, energy.baseline_rc(steps), em=em)
        uv = energy.run_cost(cfg, energy.RunConfig(
            num_steps=steps, aggressive=dvfs.UNDERVOLT,
            recovery_tiles_per_step=200), em=em)
        saves.append(1 - uv["energy_j"] / base["energy_j"])
    avg = float(np.mean(saves))
    assert 0.28 < avg < 0.40   # paper: 36% average


def test_overclock_speedup_in_paper_range():
    em = energy.calibrate()
    cfg = configs.get_config("dit-xl-512")
    base = energy.run_cost(cfg, energy.baseline_rc(50), em=em)
    oc = energy.run_cost(cfg, energy.RunConfig(
        num_steps=50, aggressive=dvfs.OVERCLOCK), em=em)
    speed = base["latency_s"] / oc["latency_s"]
    assert 1.6 < speed < 1.75   # paper: 1.7x


def test_drift_memory_overhead_below_3pct():
    em = energy.calibrate()
    cfg = configs.get_config("dit-xl-512")
    uv = energy.run_cost(cfg, energy.RunConfig(
        num_steps=50, aggressive=dvfs.UNDERVOLT,
        ckpt_interval=10, recovery_tiles_per_step=200), em=em)
    assert uv["e_drift_mem"] / uv["energy_j"] < 0.03   # Sec 6.2 claim


def test_abft_overhead_matches_paper():
    assert abs(scalesim.abft_overhead_ratio(0, 0, 0, PAPER_ACCEL)
               - 0.063) < 0.005


def test_ckpt_interval_tradeoff_monotone():
    em = energy.calibrate()
    cfg = configs.get_config("dit-xl-512")
    costs = [energy.run_cost(cfg, energy.RunConfig(
        num_steps=50, aggressive=dvfs.UNDERVOLT, ckpt_interval=n), em=em)
        ["e_drift_mem"] for n in [1, 2, 5, 10]]
    assert costs[0] > costs[1] > costs[2] > costs[3]   # Fig 14b rationale


def test_repack_reduction():
    red = dram.repack_speedup(32, 32, 1152)
    assert red >= 8.0   # paper: 23.4x at their row geometry


def test_recovery_overlappable():
    rep = dram.recovery_report(100, 32, 32, 1152)
    gemm_us = scalesim.gemm_seconds(1024, 1152, 1152, PAPER_ACCEL) * 1e6
    assert rep["t_retrieval_repacked_us"] < gemm_us   # Sec 6.4 claim


def test_scalesim_utilization_bounds():
    st = scalesim.gemm(1024, 1152, 1152, PAPER_ACCEL)
    assert 0.0 < st.utilization <= 1.0
    assert st.macs == 1024 * 1152 * 1152


def test_moe_active_params():
    cfg = configs.get_config("kimi-k2-1t-a32b")
    active = flops.active_params(cfg)
    from repro.models import transformer as tf_lib
    total = tf_lib.param_count(cfg)
    assert 25e9 < active < 40e9          # "a32b"
    assert 0.9e12 < total < 1.2e12       # "1t"


def test_cell_flops_decode_windowed():
    """Local-attention archs must count window-clipped decode FLOPs."""
    from repro.configs import shapes as shapes_lib
    g3 = configs.get_config("gemma3-27b")
    olmo = configs.get_config("olmo-1b")
    cell = shapes_lib.get_shape("decode_32k")
    f_g3 = flops.cell_flops(g3, cell)["model_flops"]
    # per-layer attended length: gemma3 mostly window=1024 << 32768
    full_attn = 2 * 2 * 32768 * g3.n_heads * g3.hd * 128 * g3.n_layers
    win_attn = f_g3 - 2 * flops.active_params(g3) * 128
    assert win_attn < 0.3 * full_attn
