"""Compute-optimal frontier tests: quality-proxy invariants, the
scheduler's frontier resolution proven optimal against brute force over
the FULL knob enumeration, and the degenerate-point bit-identity bar --
a frontier pick at (requested op, requested steps, baseline precision,
TaylorSeer off) serves latents bit-identical to the pre-frontier
as-requested path, one-shot and streamed, on both engines (the
8-fake-device sharded twin skips on a single-device run).

Scheduler-policy tests ride the fake sampler factory (admission and
frontier resolution are pure arithmetic); bit-identity runs the real
smoke DiT.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_stub import given, settings, st

from repro.core import dvfs
from repro.core import quant
from repro.diffusion.sampler import SampleOutput, StreamEvent
from repro.serving import (DeadlineScheduler, DriftServeEngine,
                           FrontierBuilder, RequestResult, SchedulerConfig,
                           ShardedDriftServeEngine, frontier)
from repro.serving.request import GenerationRequest

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

ARCH = "dit-xl-512"


def fake_factory(key, model_cfg, scfg, on_trace):
    """Echo-latents sampler stub (test_scheduler.py's), for policy tests
    that never need a real model."""
    on_trace()

    def output(latents, monitor0):
        mon = dvfs.BerMonitorState(monitor0.ema_ber, monitor0.op_index,
                                   monitor0.n_updates + 1)
        return SampleOutput(latents, mon, jnp.int32(0),
                            jnp.int32(scfg.num_sample_steps))

    if not key.stream:
        return lambda params, rng, latents, cond, text, monitor0: \
            output(latents, monitor0)

    def run_stream(params, rng, latents, cond, text, monitor0):
        for done in range(key.stream, scfg.num_sample_steps, key.stream):
            yield StreamEvent(step=done, latents=latents)
        yield output(latents, monitor0)
    return run_stream


def make_sched(bucket=2, **cfg_kw):
    eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=bucket,
                           sampler_factory=fake_factory)
    return DeadlineScheduler(eng, SchedulerConfig(**cfg_kw))


def brute_force_pick(sched, req, objective):
    """Argmin over the FULL unpruned knob enumeration (not the Pareto
    set) under the same constraints/tie-breaks the scheduler uses -- the
    ground truth its pruned-set search must match."""
    eng = sched.engine
    builder = sched.frontier_builder()
    full = builder.enumerate(eng._full_cfg(req.arch), req.steps,
                             eng.batcher.bucket, req.mode,
                             eng.resolve_interval(req))
    budget = None
    if req.deadline_s is not None:
        budget = req.deadline_s - sched.projected_wait_s(req)
    lat = {p: sched.frontier_latency_s(req, p) for p in full}
    ok = [p for p in full
          if (req.quality_floor is None
              or p.quality >= req.quality_floor - 1e-12)
          and (req.energy_budget_j is None
               or p.energy_j <= req.energy_budget_j + 1e-12)
          and (budget is None or lat[p] <= budget)]
    if not ok:
        return None
    keys = {
        "min-energy": lambda p: (p.energy_j, -p.quality, lat[p],
                                 frontier.sort_key(p)),
        "min-latency": lambda p: (lat[p], -p.quality, p.energy_j,
                                  frontier.sort_key(p)),
        "max-quality": lambda p: (-p.quality, p.energy_j, lat[p],
                                  frontier.sort_key(p)),
    }
    return min(ok, key=keys[objective])


# ---------------------------------------------------- quality invariants
@settings(max_examples=40, deadline=None)
@given(steps=st.integers(2, 20), requested=st.integers(20, 30),
       plan_name=st.sampled_from(list(quant.PRECISION_PLANS)),
       ts=st.sampled_from([False, True]),
       op_i=st.integers(0, len(frontier.FRONTIER_OPS) - 1))
def test_quality_monotone_in_steps(steps, requested, plan_name, ts, op_i):
    """Shrinking the step count never raises the proxy, whatever the
    other knobs (the TaylorSeer term's bounded gain can't outrun the
    step factor's loss)."""
    plan = quant.get_plan(plan_name)
    op = frontier.FRONTIER_OPS[op_i]
    q_hi = frontier.quality_proxy(steps, requested, plan, ts, op)
    q_lo = frontier.quality_proxy(steps - 1, requested, plan, ts, op)
    assert q_lo <= q_hi + 1e-12
    assert 0.0 < q_lo <= 1.0 and 0.0 < q_hi <= 1.0


@settings(max_examples=40, deadline=None)
@given(steps=st.integers(1, 20), requested=st.integers(20, 30),
       ts=st.sampled_from([False, True]),
       op_i=st.integers(0, len(frontier.FRONTIER_OPS) - 1))
def test_quality_monotone_in_precision(steps, requested, ts, op_i):
    """Narrowing the body precision at a fixed op never raises the
    proxy: int8 >= int8-body6 >= int8-body4."""
    op = frontier.FRONTIER_OPS[op_i]
    qs = [frontier.quality_proxy(steps, requested, quant.get_plan(n),
                                 ts, op)
          for n in ("int8", "int8-body6", "int8-body4")]
    assert qs[0] >= qs[1] >= qs[2]


def test_quality_one_only_as_requested():
    """The proxy is ~1.0 exactly for (requested steps, int8, TS off) at
    the BER-free nominal point, and strictly below for every single-knob
    degradation."""
    nominal = dvfs.NOMINAL
    base = frontier.quality_proxy(10, 10, quant.DEFAULT_PLAN, False,
                                  nominal)
    assert base == pytest.approx(1.0, abs=1e-6)
    assert frontier.quality_proxy(9, 10, quant.DEFAULT_PLAN, False,
                                  nominal) < base
    assert frontier.quality_proxy(10, 10, quant.get_plan("int8-body6"),
                                  False, nominal) < base
    assert frontier.quality_proxy(10, 10, quant.DEFAULT_PLAN, True,
                                  nominal) < base
    assert frontier.quality_proxy(10, 10, quant.DEFAULT_PLAN, False,
                                  dvfs.UNDERVOLT) < base


# ------------------------------------------- scheduler pick == brute force
def test_frontier_pick_is_min_energy_among_deadline_meeting():
    """Deadline + budget/floor objective: the scheduler's pick equals the
    argmin-energy deadline-meeting point of the FULL enumeration."""
    sched = make_sched()
    probe = GenerationRequest(request_id=-1, arch=ARCH, steps=10,
                              mode="drift", op="auto", deadline_s=2.0,
                              energy_budget_j=10.0)
    expect = brute_force_pick(sched, probe, "min-energy")
    assert expect is not None
    adm = sched.submit(steps=10, mode="drift", op="auto", deadline_s=2.0,
                       energy_budget_j=10.0)
    assert adm.action == "frontier"
    assert (adm.op, adm.steps, adm.precision, adm.taylorseer) \
        == expect.knobs()
    assert adm.projected_energy_j == pytest.approx(expect.energy_j)
    assert adm.quality == pytest.approx(expect.quality)
    assert sched.stats.frontier_selected == 1
    # The pick honors the deadline under the scheduler's own projection.
    assert adm.projected_total_s <= 2.0


def test_frontier_pick_is_min_latency_among_floor_meeting():
    """Quality floor without a deadline: argmin-latency among points at
    or above the floor."""
    sched = make_sched()
    probe = GenerationRequest(request_id=-1, arch=ARCH, steps=10,
                              mode="drift", op="auto", quality_floor=0.9)
    expect = brute_force_pick(sched, probe, "min-latency")
    assert expect is not None
    adm = sched.submit(steps=10, mode="drift", op="auto",
                       quality_floor=0.9)
    assert adm.action == "frontier"
    assert (adm.op, adm.steps, adm.precision, adm.taylorseer) \
        == expect.knobs()
    assert adm.quality >= 0.9


def test_frontier_pick_is_max_quality_within_budget():
    """Energy budget without a deadline: best quality the budget buys."""
    sched = make_sched()
    probe = GenerationRequest(request_id=-1, arch=ARCH, steps=10,
                              mode="drift", op="auto",
                              energy_budget_j=0.4)
    expect = brute_force_pick(sched, probe, "max-quality")
    assert expect is not None
    # the budget actually binds: the as-requested-ish corner is pricier
    assert any(p.energy_j > 0.4 for p in
               sched.frontier_builder().enumerate(
                   sched.engine._full_cfg(ARCH), 10, 2))
    adm = sched.submit(steps=10, mode="drift", op="auto",
                       energy_budget_j=0.4)
    assert adm.action == "frontier"
    assert (adm.op, adm.steps, adm.precision, adm.taylorseer) \
        == expect.knobs()
    assert adm.projected_energy_j <= 0.4 + 1e-12


def test_frontier_pick_brute_force_sweep():
    """Optimality across a grid of objectives/constraints, not one lucky
    corner: every admitted frontier pick matches brute force; every
    brute-force-infeasible case falls back to the ladder."""
    sched = make_sched()
    cases = [
        dict(deadline_s=d, energy_budget_j=b, quality_floor=f)
        for d in (None, 0.5, 1.0, 3.0)
        for b in (None, 0.3, 0.6, 5.0)
        for f in (None, 0.8, 0.95)
        if b is not None or f is not None
    ]
    for fields in cases:
        probe = GenerationRequest(request_id=-1, arch=ARCH, steps=8,
                                  mode="drift", op="auto", **fields)
        if fields["deadline_s"] is not None:
            objective = "min-energy"
        elif fields["quality_floor"] is not None:
            objective = "min-latency"
        else:
            objective = "max-quality"
        expect = brute_force_pick(sched, probe, objective)
        adm = sched.plan(probe)
        if expect is None:
            assert adm.action != "frontier", fields
        else:
            assert adm.action == "frontier", fields
            assert (adm.op, adm.steps, adm.precision, adm.taylorseer) \
                == expect.knobs(), fields


def test_empty_frontier_falls_back_to_reject_and_projected_miss():
    """Impossible deadline with a frontier objective: no qualifying
    point, so the PR 3 ladder decides -- reject by default, admitted as a
    projected miss with reject_hopeless=False."""
    sched = make_sched()
    adm = sched.submit(steps=10, mode="drift", op="auto",
                       deadline_s=1e-6, energy_budget_j=10.0)
    assert not adm.admitted and adm.action == "rejected"
    assert sched.stats.rejected == 1 and sched.stats.frontier_selected == 0

    lenient = make_sched(reject_hopeless=False)
    adm = lenient.submit(steps=10, mode="drift", op="auto",
                         deadline_s=1e-6, quality_floor=0.9)
    assert adm.admitted and adm.action == "projected-miss"


def test_unsatisfiable_floor_without_deadline_is_best_effort():
    """A floor above every point's quality (e.g. 1.0 with only lossy
    ladder ops enumerated at nonzero BER... use >max) degrades to the
    documented best-effort as-requested path, not a rejection."""
    sched = make_sched()
    points = sched.frontier_builder().frontier(
        sched.engine._full_cfg(ARCH), 10, 2)
    floor = max(p.quality for p in points)
    if floor >= 1.0:                      # pragma: no cover
        pytest.skip("every knob point is perfect; floor cannot exceed it")
    adm = sched.submit(steps=10, mode="drift", op="undervolt",
                       quality_floor=1.0)
    assert adm.admitted and adm.action == "as-requested"
    assert adm.op == "undervolt" and adm.steps == 10


def test_deadline_only_requests_never_touch_frontier():
    """No energy_budget_j / quality_floor: the PR 3 ladder runs
    unchanged (as-requested here), and the request's own precision/
    taylorseer knobs survive admission."""
    sched = make_sched()
    adm = sched.submit(steps=10, mode="drift", op="undervolt",
                       deadline_s=100.0, taylorseer=True,
                       precision="int8-body6")
    assert adm.action == "as-requested"
    assert sched.stats.frontier_selected == 0
    req = sched.engine.queue.pending()[0]
    assert req.taylorseer is True and req.precision == "int8-body6"


def test_frontier_memoized_across_submissions():
    """Repeat submissions of one configuration reuse the memoized
    frontier (auto_rollback_interval-style): the builder's memo holds one
    entry, not one per request."""
    sched = make_sched()
    for seed in range(4):
        sched.submit(steps=10, mode="drift", op="auto", seed=seed,
                     quality_floor=0.9)
    assert len(sched.frontier_builder()._memo) == 1
    assert sched.stats.frontier_selected == 4


# -------------------------------------------------- submit-time validation
def test_budget_and_floor_validation():
    """Nonsensical objectives fail loudly at submit time, on the bare
    engine and through the scheduler, and never touch the queue."""
    eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=2,
                           sampler_factory=fake_factory)
    sched = DeadlineScheduler(eng)
    for bad in (dict(energy_budget_j=0.0), dict(energy_budget_j=-1.0),
                dict(quality_floor=0.0), dict(quality_floor=-0.5),
                dict(quality_floor=1.5), dict(precision="fp4"),
                dict(precision="")):
        with pytest.raises(ValueError):
            eng.submit(steps=10, mode="drift", **bad)
        with pytest.raises(ValueError):
            sched.submit(steps=10, mode="drift", **bad)
    assert len(eng.queue) == 0
    # boundary values that must be accepted
    eng.submit(steps=10, mode="drift", quality_floor=1.0,
               energy_budget_j=1e-9)
    assert len(eng.queue) == 1


# ------------------------------------------------- degenerate bit-identity
def _degenerate_pair(eng_a, eng_b, stream=False):
    """Submit the as-requested baseline on ``eng_a`` and the same request
    through a frontier-resolving scheduler on ``eng_b`` with a quality
    floor only the (nominal op, full steps, int8, TS off) corner meets;
    returns (baseline results, frontier results, admission)."""
    sched = DeadlineScheduler(eng_b)
    eng_a.submit(steps=6, mode="drift", op="nominal", seed=0)
    adm = sched.submit(steps=6, mode="drift", op="nominal", seed=0,
                       quality_floor=0.99)
    assert adm.action == "frontier"
    assert (adm.op, adm.steps, adm.precision, adm.taylorseer) \
        == ("nominal", 6, "int8", False)
    if not stream:
        return eng_a.run(), sched.run(), adm
    ev_a = list(eng_a.run_stream(preview_interval=2))
    ev_b = list(sched.run_stream(preview_interval=2))
    return ev_a, ev_b, adm


def _assert_results_identical(res_a, res_b):
    assert len(res_a) == len(res_b)
    for a, b in zip(res_a, res_b):
        if not isinstance(a, RequestResult):        # PreviewEvent
            assert type(a) is type(b) and a.step == b.step
            assert np.array_equal(np.asarray(a.latents),
                                  np.asarray(b.latents))
            continue
        assert np.array_equal(np.asarray(a.latents),
                              np.asarray(b.latents)), \
            "frontier degenerate point must be bit-identical"
        assert (a.op, a.steps, a.precision, a.taylorseer) \
            == (b.op, b.steps, b.precision, b.taylorseer)
        assert a.energy_j == pytest.approx(b.energy_j)
        assert a.latency_s == pytest.approx(b.latency_s)


@pytest.mark.slow
def test_degenerate_frontier_point_bit_identical_single_device():
    """Real smoke DiT: the frontier's full-fidelity corner serves the
    exact bytes of the pre-frontier as-requested path, one-shot AND
    streamed."""
    mk = lambda: DriftServeEngine(arch=ARCH, smoke=True, bucket=1)
    res_a, res_b, _ = _degenerate_pair(mk(), mk())
    _assert_results_identical(res_a, res_b)
    ev_a, ev_b, _ = _degenerate_pair(mk(), mk(), stream=True)
    assert any(not isinstance(e, RequestResult) for e in ev_a)
    _assert_results_identical(ev_a, ev_b)


@needs_mesh
@pytest.mark.slow
def test_degenerate_frontier_point_bit_identical_sharded():
    """The 8-fake-device twin of the bit-identity bar."""
    from repro.launch import mesh as mesh_lib

    def mk():
        mesh = mesh_lib.make_serving_mesh(model_parallel=1)
        return ShardedDriftServeEngine(mesh=mesh, arch=ARCH, smoke=True,
                                       bucket=1)

    res_a, res_b, _ = _degenerate_pair(mk(), mk())
    _assert_results_identical(res_a, res_b)
    ev_a, ev_b, _ = _degenerate_pair(mk(), mk(), stream=True)
    _assert_results_identical(ev_a, ev_b)


@pytest.mark.slow
def test_narrowed_precision_gets_its_own_trace_and_cheaper_bill():
    """A narrowed-precision request compiles its own sampler (SamplerKey
    carries the plan) and is billed less energy than the int8 twin; the
    clean reference stays full-width so quality metrics remain
    comparable."""
    eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=1)
    eng.submit(steps=6, mode="drift", op="undervolt", seed=0)
    eng.submit(steps=6, mode="drift", op="undervolt", seed=0,
               precision="int8-body4")
    results = eng.run()
    # 2 drift configs + 1 shared clean reference
    assert eng.cache.traces == 3
    base, narrow = results
    assert base.precision == "int8" and narrow.precision == "int8-body4"
    assert narrow.energy_j < base.energy_j
    assert narrow.latency_s < base.latency_s
