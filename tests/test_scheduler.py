"""Deadline-aware scheduler + streaming tests.

Covers the PR 3 acceptance bar:

* a deadline-constrained request demonstrably receives a different
  (operating point, step budget) assignment than a background request;
* a streamed request yields >= 1 intermediate preview and its final
  latents are bit-identical to the non-streaming path (single-device here;
  the 8-fake-device sharded twin lives in test_serving_sharded.py);
* starvation / deadline-miss accounting;
* RequestQueue edge cases (empty peek, mixed-config take_matching limits).

Scheduler-logic tests ride the fake sampler factory (no jit, no model) so
admission arithmetic, priority formation, and clock bookkeeping run in
milliseconds; the streaming-equivalence tests run the real smoke DiT.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dvfs
from repro.diffusion.sampler import SampleOutput, StreamEvent
from repro.serving import (DeadlineScheduler, DriftServeEngine,
                           PreviewEvent, RequestResult, SchedulerConfig)
from repro.serving.request import GenerationRequest, RequestQueue


def fake_factory(key, model_cfg, scfg, on_trace):
    """Echo-latents sampler stub; handles both one-shot and streamed keys
    (key.stream > 0 returns a generator, like the real make_sampler)."""
    on_trace()

    def output(latents, monitor0):
        mon = dvfs.BerMonitorState(monitor0.ema_ber, monitor0.op_index,
                                   monitor0.n_updates + 1)
        return SampleOutput(latents, mon, jnp.int32(0),
                            jnp.int32(scfg.num_sample_steps))

    if not key.stream:
        return lambda params, rng, latents, cond, text, monitor0: \
            output(latents, monitor0)

    def run_stream(params, rng, latents, cond, text, monitor0):
        n = scfg.num_sample_steps
        for done in range(key.stream, n, key.stream):
            yield StreamEvent(step=done, latents=latents)
        yield output(latents, monitor0)
    return run_stream


def make_engine(bucket=2, **kw):
    return DriftServeEngine(arch="dit-xl-512", smoke=True, bucket=bucket,
                            sampler_factory=fake_factory, **kw)


# ------------------------------------------------------- admission policy
def test_deadline_vs_background_assignments_differ():
    """THE acceptance test: same submitted configuration, but the
    deadline-constrained request is escalated/trimmed while the background
    request keeps the energy-saving assignment."""
    eng = make_engine(bucket=1)
    sched = DeadlineScheduler(eng)
    bg = sched.submit(steps=10, mode="drift", op="undervolt",
                      priority="background", seed=0)
    assert bg.admitted and bg.action == "as-requested"
    assert (bg.op, bg.steps) == ("undervolt", 10)

    # a deadline even (overclock, 10 steps) cannot meet, but a trimmed
    # step count can: force the joint (op, step_budget) policy to use both
    # knobs at once
    lat_oc_full = sched.batch_latency_s("dit-xl-512", "overclock", 10)
    lat_oc_min = sched.batch_latency_s("dit-xl-512", "overclock",
                                       sched.cfg.min_steps)
    deadline = (lat_oc_full + lat_oc_min) / 2
    ur = sched.submit(steps=10, mode="drift", op="undervolt",
                      priority="interactive", deadline_s=deadline, seed=1)
    assert ur.admitted and ur.action == "trimmed-steps"
    assert ur.op == "overclock" and ur.steps < 10
    assert (ur.op, ur.steps) != (bg.op, bg.steps)

    # the assignment flows through to the served results
    results = {r.request_id: r for r in sched.run()}
    assert results[bg.request_id].op == "undervolt"
    assert results[bg.request_id].steps == 10
    assert results[ur.request_id].op == "overclock"
    assert results[ur.request_id].steps == ur.steps
    assert not results[ur.request_id].deadline_missed


def test_op_escalation_without_trimming():
    eng = make_engine(bucket=1)
    sched = DeadlineScheduler(eng)
    lat_uv = sched.batch_latency_s("dit-xl-512", "undervolt", 10)
    lat_oc = sched.batch_latency_s("dit-xl-512", "overclock", 10)
    assert lat_oc < lat_uv          # overclock is the speed mode
    adm = sched.submit(steps=10, mode="drift", op="undervolt",
                       deadline_s=(lat_oc + lat_uv) / 2, seed=0)
    assert adm.action == "escalated-op"
    assert adm.op == "overclock" and adm.steps == 10


def test_hopeless_deadline_rejected_and_never_enqueued():
    eng = make_engine(bucket=1)
    sched = DeadlineScheduler(eng)
    adm = sched.submit(steps=10, mode="drift", op="undervolt",
                       deadline_s=1e-6, seed=0)
    assert not adm.admitted and adm.action == "rejected"
    assert adm.request_id == -1 and "deadline" in adm.reason
    assert len(eng.queue) == 0
    assert sched.stats.rejected == 1 and sched.stats.admitted == 0


def test_reject_hopeless_false_admits_projected_miss():
    eng = make_engine(bucket=1)
    sched = DeadlineScheduler(eng, SchedulerConfig(reject_hopeless=False))
    adm = sched.submit(steps=10, mode="drift", op="undervolt",
                       deadline_s=1e-6, seed=0)
    assert adm.admitted and adm.action == "projected-miss"
    assert adm.steps == sched.cfg.min_steps
    (res,) = sched.run()
    assert res.deadline_missed
    assert eng.stats.deadline_misses == 1


def test_step_budget_caps_even_without_deadline():
    eng = make_engine(bucket=1)
    sched = DeadlineScheduler(eng)
    adm = sched.submit(steps=10, step_budget=5, mode="drift",
                       op="undervolt", seed=0)
    assert adm.admitted and adm.steps == 5
    (res,) = sched.run()
    assert res.steps == 5
    # the bare engine honors step_budget too (no scheduler needed)
    eng2 = make_engine(bucket=1)
    eng2.submit(steps=10, step_budget=3, mode="drift", op="undervolt",
                seed=0)
    assert eng2.queue.peek().steps == 3


def test_backlog_projection_counts_only_higher_urgency():
    eng = make_engine(bucket=1)
    sched = DeadlineScheduler(eng)
    # queue three standard-priority requests
    for i in range(3):
        sched.submit(steps=10, mode="drift", op="undervolt", seed=i)
    lat = sched.batch_latency_s("dit-xl-512", "undervolt", 10)
    # an interactive newcomer outranks all of them: zero projected wait
    probe_hi = GenerationRequest(request_id=-1, priority="interactive",
                                 steps=10, op="undervolt")
    assert sched.projected_wait_s(probe_hi) == 0.0
    # a standard newcomer waits behind all three (FIFO tie-break)
    probe_std = GenerationRequest(request_id=-1, priority="standard",
                                  steps=10, op="undervolt")
    assert sched.projected_wait_s(probe_std) == pytest.approx(3 * lat)


# --------------------------------------------------- priority formation
def test_interactive_batches_form_before_earlier_background():
    eng = make_engine(bucket=2)
    sched = DeadlineScheduler(eng)
    ids = {}
    for i, prio in enumerate(["background", "background", "interactive",
                              "interactive"]):
        adm = sched.submit(steps=4, mode="drift", op="undervolt",
                           priority=prio, seed=i)
        ids[adm.request_id] = prio
    results = {r.request_id: r for r in sched.run()}
    inter = [r for r in results.values() if r.priority == "interactive"]
    backg = [r for r in results.values() if r.priority == "background"]
    # interactive bucket ran first despite later submission...
    assert all(i.batch_index < b.batch_index for i in inter for b in backg)
    # ...and background still completed (no starvation in a drain)
    assert len(backg) == 2
    assert all(b.completed_at_s > i.completed_at_s
               for i in inter for b in backg)


def test_earlier_deadline_wins_within_a_priority_class():
    eng = make_engine(bucket=1)
    sched = DeadlineScheduler(eng)
    # both standard, generous deadlines; a2's is earlier
    lat = sched.batch_latency_s("dit-xl-512", "undervolt", 4)
    a1 = sched.submit(steps=4, mode="drift", op="undervolt",
                      deadline_s=50 * lat, seed=0)
    a2 = sched.submit(steps=4, mode="drift", op="undervolt",
                      deadline_s=10 * lat, seed=1)
    results = {r.request_id: r for r in sched.run()}
    assert results[a2.request_id].batch_index \
        < results[a1.request_id].batch_index


def test_aging_promotes_starved_background_work():
    eng = make_engine(bucket=1)
    age = 1e-4
    sched = DeadlineScheduler(eng, SchedulerConfig(age_s=age))
    bg = sched.submit(steps=4, mode="drift", op="undervolt",
                      priority="background", seed=0)
    hi1 = sched.submit(steps=4, mode="drift", op="undervolt",
                       priority="interactive", seed=1)
    # serve one batch: the interactive request wins it, and the clock
    # advance pushes the waiting background request past age_s
    mb = eng.batcher.next_batch(eng.queue, eng._resolve_op)
    first = eng._run_batch(mb)
    assert first[0].request_id == hi1.request_id
    assert eng.clock_s - 0.0 >= age
    # now an even newer interactive request arrives -- but the aged
    # background request outranks it at formation time
    sched.submit(steps=4, mode="drift", op="undervolt",
                 priority="interactive", seed=2)
    mb2 = eng.batcher.next_batch(eng.queue, eng._resolve_op)
    assert [r.request_id for r in mb2.requests] == [bg.request_id]


def test_uniform_priorities_degenerate_to_fifo():
    """Scheduler wrapped around an all-standard stream must batch exactly
    like the bare FIFO engine (launchers can wrap unconditionally)."""
    plain = make_engine(bucket=2)
    for i in range(4):
        plain.submit(steps=4, mode="drift", op="undervolt", seed=i)
    wrapped = make_engine(bucket=2)
    sched = DeadlineScheduler(wrapped)
    for i in range(4):
        sched.submit(steps=4, mode="drift", op="undervolt", seed=i)
    ref = [(r.request_id, r.batch_index) for r in plain.run()]
    got = [(r.request_id, r.batch_index) for r in sched.run()]
    assert ref == got


# ------------------------------------------------------ queue edge cases
def test_empty_queue_peek_and_pending():
    q = RequestQueue()
    assert q.peek() is None
    assert q.pending() == ()
    assert len(q) == 0
    assert q.take_matching("anything", lambda r: r.op, limit=3) == []


def test_take_matching_respects_limit_across_mixed_configs():
    q = RequestQueue()
    for op in ["undervolt", "overclock", "undervolt", "overclock",
               "undervolt"]:
        q.submit(op=op)
    taken = q.take_matching("undervolt", lambda r: r.op, limit=2)
    assert [r.request_id for r in taken] == [0, 2]      # FIFO among matches
    # the un-taken match and both non-matches kept their relative order
    assert [r.request_id for r in q.pending()] == [1, 3, 4]
    # limit larger than remaining matches drains them all
    taken = q.take_matching("overclock", lambda r: r.op, limit=99)
    assert [r.request_id for r in taken] == [1, 3]
    assert [r.request_id for r in q.pending()] == [4]


def test_pending_is_a_snapshot():
    q = RequestQueue()
    q.submit(op="undervolt")
    snap = q.pending()
    q.take_matching("undervolt", lambda r: r.op, limit=1)
    assert len(snap) == 1 and len(q) == 0


def test_request_field_validation():
    with pytest.raises(ValueError):
        GenerationRequest(request_id=0, priority="vip")
    with pytest.raises(ValueError):
        GenerationRequest(request_id=0, deadline_s=0.0)
    with pytest.raises(ValueError):
        GenerationRequest(request_id=0, step_budget=0)
    req = GenerationRequest(request_id=0, deadline_s=2.0, submitted_at_s=1.0)
    assert req.absolute_deadline_s == 3.0
    assert GenerationRequest(request_id=0).absolute_deadline_s is None


# --------------------------------------------- deadline-miss bookkeeping
def test_deadline_miss_accounting_on_bare_engine():
    """The bare engine (no admission control) still stamps misses: two
    same-config requests with a deadline only the first batch can meet."""
    eng = make_engine(bucket=1)
    eng.submit(steps=10, mode="drift", op="undervolt", seed=0,
               deadline_s=1.0)
    (probe,) = eng.run()
    lat = probe.latency_s
    # deadline fits one batch but not two: the second request (same
    # config, so it lands in the later bucket) must miss
    eng.submit(steps=10, mode="drift", op="undervolt", seed=1,
               deadline_s=1.5 * lat)
    eng.submit(steps=10, mode="drift", op="undervolt", seed=2,
               deadline_s=1.5 * lat)
    results = eng.run()
    assert [r.deadline_missed for r in results] == [False, True]
    assert eng.stats.deadline_misses == 1
    missed = results[1]
    assert missed.completed_at_s > missed.deadline_s + probe.latency_s
    assert missed.queue_wait_s == pytest.approx(lat)


def test_result_records_carry_scheduling_fields():
    eng = make_engine(bucket=1)
    eng.submit(steps=4, mode="drift", op="undervolt", seed=0,
               priority="interactive", deadline_s=5.0)
    (res,) = eng.run()
    assert res.priority == "interactive"
    assert res.deadline_s == 5.0
    assert res.completed_at_s == pytest.approx(res.latency_s)
    assert not res.deadline_missed


# ----------------------------------------------------- streaming (fakes)
def test_run_stream_yields_previews_then_results():
    eng = make_engine(bucket=2)
    for i in range(2):
        eng.submit(steps=6, mode="drift", op="undervolt", seed=i)
    events = list(eng.run_stream(preview_interval=2))
    previews = [e for e in events if isinstance(e, PreviewEvent)]
    results = [e for e in events if isinstance(e, RequestResult)]
    # 6 steps, window 2 -> previews at steps 2 and 4, per live request
    assert [(p.step, p.request_id) for p in previews] == \
        [(2, 0), (2, 1), (4, 0), (4, 1)]
    assert all(p.total_steps == 6 for p in previews)
    assert sorted(r.request_id for r in results) == [0, 1]
    assert eng.stats.preview_events == 4
    # previews of a batch strictly precede its results
    assert max(events.index(p) for p in previews) \
        < min(events.index(r) for r in results)


def test_streamed_key_gets_own_cache_slot_and_clean_ref_is_shared():
    eng = make_engine(bucket=1)
    eng.submit(steps=4, mode="drift", op="undervolt", seed=0)
    list(eng.run_stream(preview_interval=2))
    eng.submit(steps=4, mode="drift", op="undervolt", seed=0)
    eng.run()
    keys = list(eng.cache._fns)
    streams = sorted(k.stream for k in keys)
    # streamed drift fn, one-shot drift fn, one-shot clean ref
    assert streams == [0, 0, 2]
    # the clean reference batch was computed once and shared across paths
    assert eng.stats.clean_samples_computed == 1
    assert eng.stats.clean_sample_hits == 1


# ------------------------------------------------- streaming (real model)
@pytest.mark.slow
def test_streaming_bit_identical_to_one_shot_single_device():
    """Acceptance: streamed final latents == one-shot latents, bit for bit,
    with >= 1 intermediate preview, on the single-device engine."""
    steps, bucket = 4, 2
    ref_eng = DriftServeEngine(arch="dit-xl-512", smoke=True, bucket=bucket)
    for i in range(2):
        ref_eng.submit(steps=steps, mode="drift", op="undervolt", seed=i)
    ref = ref_eng.run()

    str_eng = DriftServeEngine(arch="dit-xl-512", smoke=True, bucket=bucket)
    for i in range(2):
        str_eng.submit(steps=steps, mode="drift", op="undervolt", seed=i)
    events = list(str_eng.run_stream(preview_interval=2))
    previews = [e for e in events if isinstance(e, PreviewEvent)]
    results = sorted((e for e in events if isinstance(e, RequestResult)),
                     key=lambda r: r.request_id)

    assert len(previews) >= 1
    assert all(p.step < steps for p in previews)
    for a, b in zip(ref, results):
        assert a.request_id == b.request_id
        assert np.array_equal(np.asarray(a.latents), np.asarray(b.latents))
        assert a.n_model_evals == b.n_model_evals
        assert a.psnr_vs_clean_db == pytest.approx(b.psnr_vs_clean_db)
    # previews differ from the final image (they are intermediate states)
    p0 = next(p for p in previews if p.request_id == 0)
    assert not np.array_equal(np.asarray(p0.latents),
                              np.asarray(results[0].latents))
    # monitor feedback carried identically through the windowed path
    assert int(str_eng.monitor.n_updates) == int(ref_eng.monitor.n_updates)
    assert float(str_eng.monitor.ema_ber) == \
        pytest.approx(float(ref_eng.monitor.ema_ber))


@pytest.mark.slow
def test_streaming_through_scheduler_cli_shape():
    """Streaming + scheduler compose: a deadline'd interactive request and
    a background request, streamed, both produce previews and results."""
    eng = DriftServeEngine(arch="dit-xl-512", smoke=True, bucket=1)
    sched = DeadlineScheduler(eng)
    lat_full = sched.batch_latency_s("dit-xl-512", "overclock", 6)
    hi = sched.submit(steps=6, mode="drift", op="undervolt",
                      priority="interactive", deadline_s=lat_full * 1.1,
                      seed=0)
    bg = sched.submit(steps=6, mode="drift", op="undervolt",
                      priority="background", seed=1)
    assert hi.op == "overclock" and bg.op == "undervolt"
    events = list(sched.run_stream(preview_interval=3))
    results = {e.request_id: e for e in events
               if isinstance(e, RequestResult)}
    previews = [e for e in events if isinstance(e, PreviewEvent)]
    assert {p.request_id for p in previews} == {hi.request_id,
                                                bg.request_id}
    # interactive request's batch ran (and streamed) first
    assert previews[0].request_id == hi.request_id
    assert not results[hi.request_id].deadline_missed
    assert results[hi.request_id].op == "overclock"
    assert results[bg.request_id].op == "undervolt"


# ------------------------------------------------------------- help sync
def test_serve_cli_help_enumerates_ladder_and_flags():
    """Tier-1 twin of tools/check_help_sync.py for the importable CLI."""
    from repro.launch import serve as serve_cli
    text = serve_cli.build_parser().format_help()
    for p in dvfs.OP_LADDER:
        assert p.name in text, f"--help lost ladder point {p.name}"
    for flag in ("--priority", "--deadline", "--step-budget", "--stream",
                 "--op", "--metrics-port", "--no-telemetry"):
        assert flag in text, f"--help lost {flag}"
