"""Sampler + schedule + TaylorSeer behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import dvfs
from repro.core.exec_ctx import DriftSystemConfig
from repro.diffusion import sampler as sampler_lib
from repro.diffusion import schedule as sched_lib
from repro.diffusion import taylorseer as ts_lib
from repro.train import steps as steps_lib


@pytest.fixture(scope="module")
def dit_setup():
    cfg = configs.get_config("dit-xl-512", smoke=True)
    key = jax.random.PRNGKey(0)
    params = steps_lib.init_model_params(cfg, key)
    params["blocks"]["adaln_w"] = 0.1 * jax.random.normal(
        jax.random.fold_in(key, 1), params["blocks"]["adaln_w"].shape)
    params["final_w"] = 0.2 * jax.random.normal(
        jax.random.fold_in(key, 2), params["final_w"].shape)
    lat0 = jax.random.normal(jax.random.fold_in(key, 3), (2, 8, 8, 4))
    cond = jnp.array([1, 2])
    return cfg, params, lat0, cond


def _run(dit_setup, mode, schedule=None, ts=False, n=6):
    cfg, params, lat0, cond = dit_setup
    scfg = sampler_lib.SamplerConfig(
        num_sample_steps=n, drift=DriftSystemConfig(mode=mode),
        schedule=schedule,
        taylorseer=ts_lib.TaylorSeerConfig(interval=3, order=2, enabled=ts))
    return sampler_lib.sample(cfg, params, jax.random.PRNGKey(9), lat0,
                              cond, None, scfg)


def test_schedule_q_sample_consistency():
    s = sched_lib.DdpmSchedule.default(1000)
    x0 = jnp.ones((2, 4, 4, 1))
    eps = jnp.zeros_like(x0)
    xt = s.q_sample(x0, jnp.array([0, 999]), eps)
    # early t keeps most signal; final t keeps almost none
    assert float(xt[0].mean()) > 0.9 * float(x0.mean())
    assert float(xt[1].mean()) < 0.1 * float(x0.mean())


def test_ddim_step_identity_when_perfect():
    """If eps_pred equals the true noise, DDIM recovers x0 at t_prev=-1."""
    s = sched_lib.DdpmSchedule.default(100)
    key = jax.random.PRNGKey(0)
    x0 = jnp.clip(jax.random.normal(key, (2, 4, 4, 1)), -1, 1)
    eps = jax.random.normal(jax.random.fold_in(key, 1), x0.shape)
    t = jnp.int32(50)
    xt = s.q_sample(x0, jnp.array([50, 50]), eps)
    x0_hat = s.ddim_step(xt, eps, t, jnp.int32(-1))
    np.testing.assert_allclose(np.asarray(x0_hat), np.asarray(x0),
                               atol=1e-4)


def test_sampler_deterministic(dit_setup):
    a = _run(dit_setup, "clean")
    b = _run(dit_setup, "clean")
    np.testing.assert_array_equal(np.asarray(a.latents),
                                  np.asarray(b.latents))


def test_drift_beats_faulty(dit_setup):
    sched = dvfs.fine_grained_schedule(6, dvfs.UNDERVOLT, nominal_steps=2)
    clean = _run(dit_setup, "clean")
    faulty = _run(dit_setup, "faulty", sched)
    drift = _run(dit_setup, "drift", sched)
    e_f = float(jnp.abs(faulty.latents - clean.latents).mean())
    e_d = float(jnp.abs(drift.latents - clean.latents).mean())
    assert e_d < e_f
    assert int(drift.total_corrected) > 0


def test_taylorseer_skips_evals(dit_setup):
    out = _run(dit_setup, "clean", ts=True)
    assert int(out.n_model_evals) == 2          # steps 0, 3 of 6
    full = _run(dit_setup, "clean", ts=False)
    assert int(full.n_model_evals) == 6


def test_taylorseer_forecast_linear():
    st = ts_lib.init_state((4,))
    st = ts_lib.update_on_compute(st, jnp.array([0.0, 0.0, 0.0, 0.0]))
    st = ts_lib.update_on_compute(st, jnp.array([3.0, 3.0, 3.0, 3.0]))
    pred = ts_lib.forecast(st, jnp.int32(3), interval=3, order=1)
    np.testing.assert_allclose(np.asarray(pred), 6.0, atol=1e-6)


def test_monitor_sees_errors(dit_setup):
    sched = dvfs.uniform_schedule(6, dvfs.UNDERVOLT)
    out = _run(dit_setup, "drift", sched)
    assert float(out.monitor.ema_ber) > 0.0
