"""Per-architecture smoke tests: reduced config of the SAME family, one
forward + one train step on CPU, output shapes + finiteness. The FULL
configs are exercised only by the dry-run (ShapeDtypeStruct, no alloc)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import shapes as shapes_lib
from repro.data import synthetic
from repro.models import transformer as tf_lib
from repro.optim.adamw import OptimConfig
from repro.train import steps as steps_lib

ARCHS = list(configs.ALL_ARCHS)


def _batch_for(cfg, batch=2, seq=16):
    dcfg = synthetic.for_model(cfg, global_batch=batch, seq_len=seq)
    b = synthetic.batch_at(dcfg, step=0)
    if cfg.family == "vlm":
        b["vis_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(1), (batch, cfg.vis_tokens, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    ocfg = OptimConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = steps_lib.init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    train_step = steps_lib.make_train_step(cfg, ocfg)
    state2, metrics = jax.jit(train_step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    # params actually changed
    def delta(a, b):
        return float(jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max())
    deltas = jax.tree.map(delta, state.params, state2.params)
    assert max(jax.tree_util.tree_leaves(deltas)) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if a not in shapes_lib.DIFFUSION_ARCHS])
def test_smoke_serve_path(arch):
    cfg = configs.get_config(arch, smoke=True)
    params = steps_lib.init_model_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, batch=2, seq=8)
    if cfg.family == "encdec":
        from repro.models import encdec
        mem = encdec.encode(cfg, params, batch["frames"])
        cache = encdec.init_decode_cache(cfg, params, mem, max_seq=12)
        logits, cache = encdec.decode_step(cfg, params, cache,
                                           batch["tokens"][:, :1])
        assert logits.shape == (2, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        return
    toks = batch["tokens"][:, :8]
    vis = batch.get("vis_embeds")
    logits, cache = tf_lib.prefill(cfg, params, toks,
                                   max_seq=12 + cfg.vis_tokens,
                                   vis_embeds=vis)
    dec, cache, _ = tf_lib.decode_step(cfg, params, cache, toks[:, -1:])
    assert dec.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(dec)).all()


@pytest.mark.parametrize("arch", shapes_lib.DIFFUSION_ARCHS)
def test_smoke_denoise_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    params = steps_lib.init_model_params(cfg, jax.random.PRNGKey(0))
    denoise = steps_lib.make_denoise_step(cfg)
    lat = jax.random.normal(jax.random.PRNGKey(1),
                            (2, cfg.latent_size, cfg.latent_size,
                             cfg.latent_channels))
    if cfg.cond_tokens:
        cond = jax.random.normal(jax.random.PRNGKey(2),
                                 (2, cfg.cond_tokens, cfg.cond_dim))
    else:
        cond = jnp.array([1, 2])
    out = jax.jit(denoise)(params, lat, jnp.int32(500), cond)
    assert out.shape == lat.shape
    assert np.isfinite(np.asarray(out)).all()


def test_full_configs_construct_and_count():
    """FULL configs build (no alloc) and hit the expected parameter scale."""
    expected = {
        "gemma3-27b": (20e9, 40e9),
        "gemma2-9b": (8e9, 12e9),
        "olmo-1b": (0.9e9, 1.6e9),
        "glm4-9b": (8e9, 12e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "deepseek-moe-16b": (13e9, 20e9),
        "mamba2-370m": (0.3e9, 0.45e9),
        "hymba-1.5b": (1.2e9, 2.2e9),
        "internvl2-76b": (60e9, 85e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = configs.get_config(arch)
        n = tf_lib.param_count(cfg)
        assert lo < n < hi, (arch, f"{n:.3e}")


def test_cells_matrix():
    cells = {a: shapes_lib.cells_for(a) for a in configs.ALL_ARCHS}
    n_lm = sum(len(v) for a, v in cells.items()
               if a not in shapes_lib.DIFFUSION_ARCHS)
    # 10 archs x (3 or 4): 4 long-context archs get the 4th cell
    assert n_lm == 10 * 3 + 4
    for a in ("olmo-1b", "glm4-9b", "kimi-k2-1t-a32b"):
        assert "long_500k" in shapes_lib.skipped_cells(a)
    assert "long_500k" in shapes_lib.cells_for("mamba2-370m")
