"""Perf-path equivalence tests: windowed mixed decode, microbatched train
step, SSD chunked-vs-recurrent, constraints no-op off-mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.models import mamba2
from repro.models import transformer as tf
from repro.models.common import ModelConfig
from repro.optim.adamw import OptimConfig
from repro.train import steps as steps_lib

GEMMA_LIKE = ModelConfig(
    name="t", family="dense", n_layers=8, d_model=32, n_heads=4,
    n_kv_heads=2, d_ff=64, vocab=64,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window=4, dtype=jnp.float32)


def test_mixed_decode_equivalence():
    """Hillclimb #1 safety: ring-buffer windowed decode == masked full
    decode, including ring wraparound (decode well past the window)."""
    cfg = GEMMA_LIKE
    assert tf.supports_mixed_decode(cfg)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    _, full_c = tf.prefill(cfg, params, toks[:, :6], max_seq=20)
    mixed_c = tf.mixed_from_full(cfg, full_c)
    for i in range(6, 16):
        lf, full_c, _ = tf.decode_step(cfg, params, full_c, toks[:, i:i + 1])
        lm, mixed_c = tf.decode_step_mixed(cfg, params, mixed_c,
                                           toks[:, i:i + 1])
        assert float(jnp.abs(lf - lm).max()) < 1e-3, i


def test_mixed_decode_alternating_pattern():
    cfg = ModelConfig(name="g2", family="dense", n_layers=5, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                      attn_pattern=("local", "global"), window=4,
                      dtype=jnp.float32)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, 64)
    _, full_c = tf.prefill(cfg, params, toks[:, :5], max_seq=16)
    mixed_c = tf.mixed_from_full(cfg, full_c)
    for i in range(5, 12):
        lf, full_c, _ = tf.decode_step(cfg, params, full_c, toks[:, i:i + 1])
        lm, mixed_c = tf.decode_step_mixed(cfg, params, mixed_c,
                                           toks[:, i:i + 1])
        assert float(jnp.abs(lf - lm).max()) < 1e-3, i


def test_microbatched_train_step_matches_single_shot():
    """Gradient accumulation must produce the same update (linearity)."""
    cfg = ModelConfig(name="m", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab=64,
                      dtype=jnp.float32)
    ocfg = OptimConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = steps_lib.init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    dcfg = synthetic.for_model(cfg, global_batch=8, seq_len=16)
    batch = synthetic.batch_at(dcfg, 0)
    s1, m1 = jax.jit(steps_lib.make_train_step(cfg, ocfg, 1))(state, batch)
    s4, m4 = jax.jit(steps_lib.make_train_step(cfg, ocfg, 4))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-4)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     s1.params, s4.params)
    assert max(jax.tree_util.tree_leaves(d)) < 5e-5


def test_ssd_long_sequence_chunking():
    """Chunk-boundary correctness at S not divisible by the chunk."""
    cfg = ModelConfig(name="s", family="ssm", n_layers=1, d_model=32,
                      vocab=64, ssm_state=8, ssm_expand=2, ssm_head_dim=8,
                      ssm_chunk=5, dtype=jnp.float32)
    p = mamba2.init_ssm_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 13, 32))
    y_chunk, _ = mamba2.ssd_forward(cfg, p, x)
    st = mamba2.init_ssm_state(cfg, 1)
    ys = []
    for i in range(13):
        yi, st = mamba2.ssd_decode_step(cfg, p, x[:, i:i + 1], st)
        ys.append(yi)
    np.testing.assert_allclose(np.asarray(y_chunk),
                               np.asarray(jnp.concatenate(ys, 1)),
                               atol=1e-3)


def test_constraints_noop_without_policy():
    from repro.distributed import constraints
    constraints.set_policy(None)
    x = jnp.ones((4, 4))
    np.testing.assert_array_equal(np.asarray(constraints.constrain(x, "act")),
                                  np.asarray(x))


def test_moe_capacity_rounding_preserves_routing():
    """Slot-0 zero-scatter for dropped tokens must not corrupt expert 0."""
    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=16, vocab=32,
                      n_experts=4, top_k=2, capacity_factor=8.0,
                      dtype=jnp.float32)
    from repro.models import moe
    p = moe.init_moe_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    y, aux = moe.moe_ffn(cfg, p, x)
    assert y.shape == (8, 16)
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0
