"""Telemetry subsystem tests: metrics registry/exposition, learned latency
estimates with perfmodel fallback, the adaptive BER guardband, and the
HTTP/SSE front-end.

Covers the PR 4 acceptance bar:

* with telemetry enabled and history populated, scheduler admission uses
  the learned estimates -- an observed-latency divergence from the
  perfmodel demonstrably flips the admission decision; with no history,
  decisions and projections are bit-identical to the perfmodel-only path
  (the 8-fake-device twin lives in test_serving_sharded.py);
* an injected detection-count spike lowers the auto ladder's
  aggressiveness within one adaptation window, then recovers after quiet
  windows, while the compiled-sampler cache stays within its trace
  budget;
* the SSE endpoint delivers the same PreviewEvent sequence as the
  in-process generator, and final latents stay bit-identical to the
  non-streaming path (digest-compared here with the fake sampler; the
  real-model twin is marked slow).

Scheduler/controller logic rides the fake sampler factory (no jit, no
model); the HTTP tests run a real ThreadingHTTPServer on an ephemeral
port with stdlib urllib as the client.
"""
import json
import urllib.error
import urllib.request

import jax.numpy as jnp
import pytest

from repro.core import dvfs
from repro.diffusion.sampler import SampleOutput, StreamEvent
from repro.serving import (DeadlineScheduler, DriftServeEngine,
                           EngineTelemetry, GuardbandConfig,
                           GuardbandController, PreviewEvent, RequestResult,
                           SchedulerConfig, serve_telemetry)
from repro.serving.telemetry import (BatchObservation, LatencyEstimator,
                                     MetricsRegistry)
from repro.serving.telemetry.http import (latents_sha256, preview_wire,
                                          result_wire)

ARCH = "dit-xl-512"


def make_fake_factory(box=None):
    """Echo-latents sampler stub whose monitor EMA/corrected counts come
    from the mutable ``box`` -- the detection-spike injection point."""
    box = box if box is not None else {}

    def factory(key, model_cfg, scfg, on_trace):
        on_trace()

        def output(latents, monitor0):
            ema = box.get("ema", float(monitor0.ema_ber))
            mon = dvfs.BerMonitorState(jnp.float32(ema), monitor0.op_index,
                                       monitor0.n_updates + 1)
            return SampleOutput(latents, mon,
                                jnp.int32(box.get("corrected", 0)),
                                jnp.int32(scfg.num_sample_steps))

        if not key.stream:
            return lambda params, rng, latents, cond, text, monitor0: \
                output(latents, monitor0)

        def run_stream(params, rng, latents, cond, text, monitor0):
            for done in range(key.stream, scfg.num_sample_steps, key.stream):
                yield StreamEvent(step=done, latents=latents)
            yield output(latents, monitor0)
        return run_stream
    return factory


def make_engine(bucket=1, box=None, **kw):
    return DriftServeEngine(arch=ARCH, smoke=True, bucket=bucket,
                            sampler_factory=make_fake_factory(box), **kw)


# ------------------------------------------------------- metrics registry
def test_counter_gauge_histogram_exposition():
    reg = MetricsRegistry()
    c = reg.counter("t_requests_total", "requests", label_names=("op",))
    c.labels(op="undervolt").inc()
    c.labels(op="undervolt").inc(2)
    c.labels(op="overclock").inc()
    g = reg.gauge("t_clock_seconds", "clock")
    g.set(1.5)
    h = reg.histogram("t_latency_seconds", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    text = reg.expose()
    assert "# TYPE t_requests_total counter" in text
    assert 't_requests_total{op="undervolt"} 3' in text
    assert 't_requests_total{op="overclock"} 1' in text
    assert "# TYPE t_clock_seconds gauge" in text
    assert "t_clock_seconds 1.5" in text
    # cumulative buckets + sum/count
    assert 't_latency_seconds_bucket{le="0.1"} 1' in text
    assert 't_latency_seconds_bucket{le="1"} 2' in text
    assert 't_latency_seconds_bucket{le="+Inf"} 3' in text
    assert "t_latency_seconds_count 3" in text
    assert text.endswith("\n")
    # idempotent re-registration returns the same metric
    assert reg.counter("t_requests_total") is c
    with pytest.raises(AssertionError):
        reg.gauge("t_requests_total")


def test_histogram_percentile_and_label_validation():
    reg = MetricsRegistry()
    h = reg.histogram("t_wait_seconds", "wait")
    assert h.percentile(50) is None
    for v in range(1, 101):
        h.observe(float(v))
    assert h.percentile(50) == pytest.approx(50.0, abs=1.0)
    assert h.percentile(99) == pytest.approx(99.0, abs=1.0)
    c = reg.counter("t_labeled_total", "x", label_names=("a",))
    with pytest.raises(ValueError):
        c.labels(b="nope")


def test_estimator_window_eviction_keeps_sorted_view_consistent():
    est = LatencyEstimator(decay=1.0, window=3)
    for i, v in enumerate([10.0, 1.0, 2.0, 3.0, 4.0]):
        est.observe(obs(v, i=i))
    # only the last 3 observations remain: the early 10.0 outlier is gone
    assert est.percentile_s(ARCH, "undervolt", 10, 2, 100) == 4.0
    assert est.percentile_s(ARCH, "undervolt", 10, 2, 0) == 2.0


# ------------------------------------------------------- latency history
def test_estimator_empty_returns_none():
    est = LatencyEstimator()
    assert est.estimate_s(ARCH, "undervolt", 10, 2) is None
    assert est.n_observations(ARCH, "undervolt", 10, 2) == 0


def obs(latency, key=(ARCH, "undervolt", 10, 2), i=0):
    arch, op, steps, bucket = key
    return BatchObservation(arch=arch, op=op, steps=steps, bucket=bucket,
                            latency_s=latency, clock_s=0.0, batch_index=i)


def test_estimator_tracks_and_guards_with_percentile():
    est = LatencyEstimator(decay=0.5, conservative_percentile=90.0)
    for i, v in enumerate([1.0, 1.0, 1.0, 1.0]):
        est.observe(obs(v, i=i))
    assert est.estimate_s(ARCH, "undervolt", 10, 2) == pytest.approx(1.0)
    # one slow outlier: the percentile guard keeps the estimate conservative
    est.observe(obs(10.0, i=4))
    e = est.estimate_s(ARCH, "undervolt", 10, 2)
    assert e == pytest.approx(10.0)     # p90 of [1,1,1,1,10]
    # keys are isolated
    assert est.estimate_s(ARCH, "overclock", 10, 2) is None
    assert est.percentile_s(ARCH, "undervolt", 10, 2, 50) == 1.0


# -------------------------------------------- guardband controller (unit)
def test_guardband_state_machine_hysteresis():
    ctrl = GuardbandController(target_ber=1e-3,
                               config=GuardbandConfig(quiet_windows=2))
    # spike widens immediately
    assert ctrl.observe_batch(1.0, "undervolt") == "widen"
    assert ctrl.guard_index == 1
    # in-band holds and resets the quiet streak
    assert ctrl.observe_batch(1e-3, "undervolt") == "hold"
    # one quiet window is not enough (hysteresis)
    assert ctrl.observe_batch(0.0, "undervolt") == "quiet"
    assert ctrl.guard_index == 1
    # second consecutive quiet window re-tightens
    assert ctrl.observe_batch(0.0, "undervolt") == "tighten"
    assert ctrl.guard_index == 0
    # never below zero, never above the ladder top
    assert ctrl.observe_batch(0.0, "undervolt") == "quiet"
    assert ctrl.observe_batch(0.0, "undervolt") == "quiet"  # nothing to cut
    assert ctrl.guard_index == 0
    for _ in range(10):
        ctrl.observe_batch(1.0, "undervolt")
    assert ctrl.guard_index == len(dvfs.OP_LADDER) - 1
    assert ctrl.clamp(0) == ctrl.guard_index
    assert ctrl.clamp(ctrl.guard_index + 7) == ctrl.guard_index + 7
    assert ctrl.realized_ber["undervolt"] > 0.5


# -------------------------------- estimator fallback: bit-identical plans
def submit_plan_stream(sched):
    """A deterministic mix of deadline'd/priority'd submissions; returns
    the Admission records (including projections)."""
    lat = sched.batch_latency_s(ARCH, "undervolt", 10)
    plans = []
    for i, (dl, prio) in enumerate([(None, "background"),
                                    (5.0 * lat, "interactive"),
                                    (1.2 * lat, "standard"),
                                    (1e-6, "interactive")]):
        plans.append(sched.submit(steps=10, mode="drift", op="undervolt",
                                  priority=prio, deadline_s=dl, seed=i))
    return plans


def test_empty_history_bit_identical_to_perfmodel_only():
    """Satellite acceptance: with no served-batch history, admission
    decisions AND clock projections match the telemetry-free scheduler
    bit for bit (single-device; the 8-device twin lives in
    test_serving_sharded.py)."""
    sched_on = DeadlineScheduler(make_engine())
    sched_off = DeadlineScheduler(
        make_engine(telemetry=EngineTelemetry(enabled=False)))
    plans_on = submit_plan_stream(sched_on)
    plans_off = submit_plan_stream(sched_off)
    assert plans_on == plans_off       # frozen dataclasses, exact floats
    for a in plans_on:
        if a.projected_wait_s is not None:
            assert isinstance(a.projected_wait_s, float)
    # the engines then *serve* identically too
    res_on = {r.request_id: r for r in sched_on.run()}
    res_off = {r.request_id: r for r in sched_off.run()}
    assert sorted(res_on) == sorted(res_off)
    for rid in res_on:
        assert res_on[rid].completed_at_s == res_off[rid].completed_at_s
        assert res_on[rid].op == res_off[rid].op
        assert res_on[rid].steps == res_off[rid].steps


def test_use_learned_latency_false_pins_perfmodel():
    eng = make_engine()
    sched = DeadlineScheduler(eng, SchedulerConfig(use_learned_latency=False))
    lat = sched.batch_latency_s(ARCH, "undervolt", 10)
    eng.telemetry.estimator.observe(obs(100 * lat, key=(ARCH, "undervolt",
                                                        10, 1)))
    assert sched.batch_latency_s(ARCH, "undervolt", 10) == lat


# --------------------------------- learned estimates flip admission (THE
# acceptance test for the tentpole's estimator half)
def test_learned_divergence_flips_admission_decision():
    eng = make_engine(bucket=1)
    sched = DeadlineScheduler(eng)
    lat_uv = sched.batch_latency_s(ARCH, "undervolt", 10)

    # perfmodel says (undervolt, 10 steps) fits this deadline comfortably
    deadline = 1.5 * lat_uv
    before = sched.plan(probe(deadline))
    assert before.admitted and before.action == "as-requested"
    assert (before.op, before.steps) == ("undervolt", 10)

    # observed reality diverges: this configuration's batches measure 3x
    # the perfmodel price (per-request overheads the a-priori model never
    # saw). Feed the history the engine tap would have fed.
    for i in range(4):
        eng.telemetry.estimator.observe(
            obs(3.0 * lat_uv, key=(ARCH, "undervolt", 10, 1), i=i))
    learned = sched.batch_latency_s(ARCH, "undervolt", 10)
    assert learned == pytest.approx(3.0 * lat_uv)

    # same submission now flips: undervolt no longer fits, the scheduler
    # escalates to overclock (whose history is empty -> perfmodel price,
    # which fits)
    after = sched.plan(probe(deadline))
    assert after.admitted and after.action == "escalated-op"
    assert after.op == "overclock"
    assert (before.op, before.action) != (after.op, after.action)


def probe(deadline):
    from repro.serving import GenerationRequest
    return GenerationRequest(request_id=-1, arch=ARCH, steps=10,
                             mode="drift", op="undervolt",
                             deadline_s=deadline)


def test_clean_mode_history_does_not_contaminate_drift_estimates():
    """A clean-mode batch bills without ABFT/checkpoint overhead; its
    history must not be served as the learned estimate for a drift-mode
    request at the same (arch, op, steps, bucket)."""
    eng = make_engine(bucket=1)
    sched = DeadlineScheduler(eng)
    eng.submit(steps=10, mode="clean", op="nominal", seed=0)
    eng.run()
    # the clean batch was observed -- under its own mode key
    assert eng.telemetry.estimator.n_observations(
        ARCH, "nominal", 10, 1, mode="clean") == 1
    assert eng.telemetry.estimator.estimate_s(ARCH, "nominal", 10, 1) \
        is None                         # default = drift configuration
    # pricing a drift-mode nominal request falls back to the perfmodel
    sched.batch_latency_s(ARCH, "nominal", 10)
    text = eng.telemetry.registry.expose()
    assert 'drift_projection_source_total{source="learned"}' not in text
    assert 'drift_projection_source_total{source="perfmodel"}' in text


def test_engine_tap_populates_estimator_with_billed_latency():
    """The estimator learns exactly what the engine bills: after one
    served batch, the learned estimate equals the result's latency and
    admission runs on it (projection-source counter says 'learned')."""
    eng = make_engine(bucket=1)
    sched = DeadlineScheduler(eng)
    sched.submit(steps=10, mode="drift", op="undervolt", seed=0)
    (res,) = sched.run()
    est = eng.telemetry.estimator.estimate_s(ARCH, "undervolt", 10, 1)
    assert est == pytest.approx(res.latency_s)
    assert sched.batch_latency_s(ARCH, "undervolt", 10) == est
    reg_text = eng.telemetry.registry.expose()
    assert 'drift_projection_source_total{source="learned"}' in reg_text


# --------------------------------------- latency-memo key hygiene (fix)
def test_latency_memo_keys_on_operating_point_parameters():
    """The modeled-latency memo must key on resolved op *parameters*:
    after the ladder (or guardband) moves, pricing "auto" again must
    re-resolve instead of serving the first call's point."""
    eng = make_engine(telemetry=EngineTelemetry(enabled=False))
    sched = DeadlineScheduler(eng)
    assert eng.auto_op_name() == "undervolt"      # fresh monitor, index 0
    sched.batch_latency_s(ARCH, "auto", 10)
    keys0 = set(sched._latency_cache)
    assert all(isinstance(k[1], float) for k in keys0)   # voltage, not name
    volt0 = {k[1] for k in keys0}
    assert volt0 == {dvfs.UNDERVOLT.voltage}

    # ladder walks to nominal; "auto" now prices the nominal parameters
    eng.monitor = dvfs.BerMonitorState(eng.monitor.ema_ber,
                                       jnp.int32(len(dvfs.OP_LADDER) - 1),
                                       eng.monitor.n_updates)
    assert eng.auto_op_name() == "nominal"
    lat_auto = sched.batch_latency_s(ARCH, "auto", 10)
    assert lat_auto == sched.batch_latency_s(ARCH, "nominal", 10)
    volts = {k[1] for k in sched._latency_cache}
    assert volts == {dvfs.UNDERVOLT.voltage, dvfs.NOMINAL.voltage}
    # and no entry was ever keyed by the request-facing name
    assert not any(k[1] == "auto" for k in sched._latency_cache)


# ------------------------------------------- guardband loop (integration)
def test_detection_spike_widens_then_recovers_within_budget():
    """Acceptance: an injected detection-count spike lowers the auto
    ladder's aggressiveness within ONE adaptation window; after the quiet
    hysteresis it recovers; and the compiled-sampler cache stays within
    its trace budget (bounded by the ladder, not the batch count)."""
    box = {"ema": 0.0}
    eng = make_engine(
        bucket=1, box=box,
        telemetry=EngineTelemetry(
            guardband_config=GuardbandConfig(quiet_windows=2)))
    ctrl = eng.telemetry.controller

    def serve_auto(seed):
        eng.submit(steps=4, mode="drift", op="auto", seed=seed)
        return eng.run()[0]

    r0 = serve_auto(0)
    assert r0.op == "undervolt" and ctrl.guard_index == 0   # quiet start

    box["ema"] = 1.0                    # detection storm
    r1 = serve_auto(1)
    assert r1.op == "undervolt"         # the spike batch itself ran aggressive
    assert ctrl.guard_index == 1        # ...but the floor rose in one window
    # the very next auto request is already less aggressive
    box["ema"] = 0.0
    r2 = serve_auto(2)
    assert r2.op == "uv-mild"
    # quiet_windows=2 consecutive quiet windows re-tighten (r2's batch was
    # quiet window #1)
    r3 = serve_auto(3)
    assert ctrl.guard_index == 0
    r4 = serve_auto(4)
    assert r4.op == "undervolt"         # recovered
    assert ctrl.stats.widenings == 1 and ctrl.stats.tightenings == 1

    # trace budget: every distinct (op, steps) drift config + its clean
    # reference jits once; the guardband visited 2 ladder points, so
    # 2 drift traces + 1 clean trace -- bounded by the ladder length, not
    # the 5 batches served
    assert eng.cache.traces <= len(dvfs.OP_LADDER) + 1
    assert eng.cache.traces == 3
    text = eng.telemetry.registry.expose()
    assert "drift_guardband_widenings_total 1" in text
    assert "drift_guardband_tightenings_total 1" in text


def test_scheduler_prices_auto_through_guardband_floor():
    """Admission's cost estimate resolves "auto" through the same floored
    index the batcher will use -- no stale ladder point."""
    box = {"ema": 1.0}
    eng = make_engine(bucket=1, box=box)
    sched = DeadlineScheduler(eng)
    eng.submit(steps=4, mode="drift", op="auto", seed=0)
    eng.run()                           # widens the guardband to 1
    assert eng.telemetry.controller.guard_index == 1
    assert sched._concrete_op("auto") == "uv-mild"


def test_disabled_telemetry_is_inert():
    eng = make_engine(telemetry=EngineTelemetry(enabled=False))
    assert not eng.telemetry.enabled
    assert eng.telemetry.estimator is None
    assert eng.telemetry.controller is None
    eng.submit(steps=4, mode="drift", op="auto", seed=0)
    (res,) = eng.run()
    assert res.op == "undervolt"        # bare monitor resolution
    assert eng.telemetry.learned_latency_s(ARCH, "undervolt", 4, 1) is None
    assert eng.telemetry.clamp_ladder_index(2) == 2
    assert eng.telemetry.registry.expose() == "\n"


# ----------------------------------------------------- HTTP/SSE front-end
def fetch(url):
    # generous timeout: the SSE drain jits the streaming sampler inside
    # the handler (~10-15s per trace, much more on a loaded CI box)
    with urllib.request.urlopen(url, timeout=600) as resp:
        return resp.headers, resp.read().decode("utf-8")


def parse_sse(payload):
    events, kind = [], None
    for line in payload.splitlines():
        if line.startswith("event: "):
            kind = line[len("event: "):]
        elif line.startswith("data: "):
            events.append((kind, json.loads(line[len("data: "):])))
    return events


@pytest.fixture()
def served_engine():
    eng = make_engine(bucket=1)
    server = serve_telemetry(eng, port=0)
    yield eng, server
    server.close()


def test_healthz_and_metrics_endpoints(served_engine):
    eng, server = served_engine
    eng.submit(steps=4, mode="drift", op="undervolt", seed=0)
    eng.run()
    _, body = fetch(f"{server.url}/healthz")
    health = json.loads(body)
    assert health["status"] == "ok"
    assert health["batches"] == 1
    assert health["queue_depth"] == 0
    assert health["telemetry_enabled"] is True
    headers, text = fetch(f"{server.url}/metrics")
    assert headers["Content-Type"].startswith("text/plain")
    for series in ("drift_batches_total", "drift_requests_served_total",
                   "drift_clock_seconds", "drift_batch_latency_seconds"):
        assert series in text
    with pytest.raises(urllib.error.HTTPError) as exc:
        fetch(f"{server.url}/nope")
    assert exc.value.code == 404
    with pytest.raises(urllib.error.HTTPError) as exc:
        fetch(f"{server.url}/events?interval=zero")
    assert exc.value.code == 400
    # arbitrary window lengths are refused: each distinct interval would
    # compile its own streaming sampler, and an open endpoint must not
    # grow the compiled-fn cache without bound
    with pytest.raises(urllib.error.HTTPError) as exc:
        fetch(f"{server.url}/events?interval=63")
    assert exc.value.code == 400
    assert "not allowed" in exc.value.read().decode()


def test_sse_stream_matches_in_process_generator(served_engine):
    """Acceptance: the SSE endpoint delivers the same PreviewEvent
    sequence as the in-process generator, and the final latents (by
    digest) are bit-identical to the non-streaming run()."""
    eng, server = served_engine
    for i in range(2):
        eng.submit(steps=6, mode="drift", op="undervolt", seed=i)
    events = parse_sse(fetch(f"{server.url}/events?interval=2")[1])

    # twin A: in-process streaming generator on an identical engine
    twin = make_engine(bucket=1)
    for i in range(2):
        twin.submit(steps=6, mode="drift", op="undervolt", seed=i)
    expected = []
    for ev in twin.run_stream(preview_interval=2):
        if isinstance(ev, PreviewEvent):
            expected.append(("preview", preview_wire(ev)))
        else:
            expected.append(("result", result_wire(ev)))
    assert events[:-1] == expected      # same sequence, frame for frame
    assert events[-1] == ("end", {"served": 2, "previews": 4})

    # twin B: non-streaming run() -- finals bit-identical by digest
    ref = make_engine(bucket=1)
    for i in range(2):
        ref.submit(steps=6, mode="drift", op="undervolt", seed=i)
    ref_digests = {r.request_id: latents_sha256(r.latents)
                   for r in ref.run()}
    sse_results = {d["request_id"]: d["latents_sha256"]
                   for k, d in events if k == "result"}
    assert sse_results == ref_digests


def test_server_close_before_start_does_not_deadlock():
    from repro.serving import TelemetryHTTPServer
    srv = TelemetryHTTPServer(make_engine())
    srv.close()        # never started: must release the socket and return


def test_sse_empty_queue_sends_end_frame(served_engine):
    _, server = served_engine
    events = parse_sse(fetch(f"{server.url}/events")[1])
    assert events == [("end", {"served": 0, "previews": 0})]


def test_concurrent_drain_gets_503(served_engine):
    eng, server = served_engine
    eng.submit(steps=4, mode="drift", op="undervolt", seed=0)
    with server.engine_lock:            # simulate an in-flight drain
        with pytest.raises(urllib.error.HTTPError) as exc:
            fetch(f"{server.url}/events")
        assert exc.value.code == 503
    # lock released: the drain goes through now
    events = parse_sse(fetch(f"{server.url}/events")[1])
    assert events[-1][0] == "end" and events[-1][1]["served"] == 1


@pytest.mark.slow
def test_sse_bit_identity_real_model():
    """Real smoke DiT through the wire: >= 1 SSE preview and the SSE
    result digest equals the non-streaming run() latents digest."""
    steps = 4
    ref = DriftServeEngine(arch=ARCH, smoke=True, bucket=1)
    ref.submit(steps=steps, mode="drift", op="undervolt", seed=0)
    (ref_res,) = ref.run()

    eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=1)
    eng.submit(steps=steps, mode="drift", op="undervolt", seed=0)
    with serve_telemetry(eng, port=0) as server:
        events = parse_sse(fetch(f"{server.url}/events?interval=2")[1])
    kinds = [k for k, _ in events]
    assert kinds.count("preview") >= 1 and kinds.count("result") == 1
    (result,) = [d for k, d in events if k == "result"]
    assert result["latents_sha256"] == latents_sha256(ref_res.latents)
    # the sampler's stream-window tap fired once per jitted window
    windows = eng.telemetry.registry.counter("drift_stream_windows_total")
    assert windows.value == steps // 2


# ------------------------------------------------------ CLI wiring smoke
def test_serve_cli_builds_disabled_telemetry_engine():
    from repro.launch import serve as serve_cli
    args = serve_cli.build_parser().parse_args(
        ["--batch", "1", "--no-telemetry"])
    eng = serve_cli.build_engine(args)
    assert not eng.telemetry.enabled
    args = serve_cli.build_parser().parse_args(["--batch", "1"])
    assert serve_cli.build_engine(args).telemetry.enabled


# --------------------------------------------------------- clock skew
def test_clock_skew_gauge_reconciles_with_uptime_and_clock():
    """drift_clock_skew_ratio is computed from ONE shared wall sample
    with the uptime gauge, so the three gauges reconcile exactly:
    skew == clock / uptime, bitwise -- not merely approximately."""
    eng = make_engine(bucket=2)
    for seed in range(4):
        eng.submit(steps=6, mode="drift", op="undervolt", seed=seed)
    eng.run()
    reg = eng.telemetry.registry
    clock = reg.gauge("drift_clock_seconds").value
    uptime = reg.gauge("drift_engine_uptime_seconds").value
    skew = reg.gauge("drift_clock_skew_ratio").value
    assert clock == eng.clock_s > 0
    assert uptime > 0
    assert skew == clock / uptime
    # fake-device engines bill virtual seconds far faster than the wall
    # spends them, so the ratio is strictly positive
    assert skew > 0
