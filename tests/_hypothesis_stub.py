"""Minimal deterministic stand-in for `hypothesis` when it isn't installed.

The tier-1 suite uses a small slice of the hypothesis API (`given`,
`settings`, `st.integers`, `st.sampled_from`). When the real package is
available the test modules import it directly; otherwise they fall back to
this stub, which replays each property over a deterministic set of examples
(range corners plus seeded pseudo-random interior points, capped at the
test's `max_examples`). Install `hypothesis` (see requirements-dev.txt) for
real shrinking/fuzzing coverage.
"""
from __future__ import annotations

import functools
import inspect
import itertools
import random
import types

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, values):
        self.values = list(values)


def _integers(min_value, max_value):
    rng = random.Random(0x5EED ^ (min_value * 31) ^ max_value)
    span = max_value - min_value
    picks = [min_value, max_value, min_value + span // 2]
    picks += [min_value + rng.randrange(span + 1) for _ in range(4)]
    seen, vals = set(), []
    for v in picks:
        if v not in seen:
            seen.add(v)
            vals.append(v)
    return _Strategy(vals)


def _sampled_from(elements):
    return _Strategy(elements)


st = types.SimpleNamespace(integers=_integers, sampled_from=_sampled_from)


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
    def apply(fn):
        fn._stub_max_examples = max_examples
        return fn
    return apply


def given(**strategies):
    names = list(strategies)

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            combos = list(itertools.product(
                *(strategies[n].values for n in names)))
            cap = getattr(wrapper, "_stub_max_examples",
                          _DEFAULT_MAX_EXAMPLES)
            if len(combos) > cap:
                rng = random.Random(0xD21F7)
                interior = rng.sample(combos[1:-1], max(cap - 2, 0))
                combos = [combos[0]] + interior + [combos[-1]]
            for combo in combos:
                fn(*args, **dict(zip(names, combo)), **kwargs)

        # Hide the strategy-filled params from pytest's fixture resolution.
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for p in sig.parameters.values() if p.name not in strategies])
        del wrapper.__wrapped__
        return wrapper
    return deco
