"""Flight-recorder / decision-audit / resilience-heatmap tests.

The PR's acceptance bar:

* **zero perturbation** -- final latents are bit-identical with the
  recorder on and off, one-shot and streamed, on the plain engine (the
  8-fake-device twin lives at the bottom behind ``needs_mesh``);
* **span coverage** -- a streamed, monitored, offload-enabled request's
  trace contains a span for every jitted window, every offload commit,
  and the batch detect/finalize pair, with the scheduler's decision
  record attached; the AR paradigm records a replay span per KV-window
  rollback;
* **heatmap** -- ``RequestResult.detect_heatmap`` is present for
  monitored batches, streamed == one-shot, and the protected early
  timesteps carry no mass;
* the recorder itself: bounded ring, drop counting, disabled no-op; the
  Chrome exporter and ``/trace``/``/flight`` HTTP surfaces (404 paths
  included); SSE under two genuinely concurrent clients and one slow
  consumer; and docs/telemetry.md's catalog staying in sync with the
  registry (the tier-1 twin of tools/check_metrics_catalog.py).
"""
import json
import threading
import time
import urllib.error
import urllib.request
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.serving import (DeadlineScheduler, DriftServeEngine,
                           OffloadConfig, PreviewEvent, serve_telemetry)
from repro.serving.telemetry.http import latents_sha256
from repro.serving.trace import (FlightRecorder, SPAN_KINDS, bin_heatmap,
                                 request_tree, site_labels, summarize,
                                 to_chrome_trace)

ARCH = "dit-xl-512"
REPO = Path(__file__).resolve().parents[1]

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def kind_counts(tracer, request_id=None):
    counts = {}
    for s in tracer.spans(request_id):
        counts[s.kind] = counts.get(s.kind, 0) + 1
    return counts


def fetch(url):
    # generous timeout: SSE drains jit the streaming sampler in-handler
    with urllib.request.urlopen(url, timeout=600) as resp:
        return resp.headers, resp.read().decode("utf-8")


def parse_sse(payload):
    events, kind = [], None
    for line in payload.splitlines():
        if line.startswith("event: "):
            kind = line[len("event: "):]
        elif line.startswith("data: "):
            events.append((kind, json.loads(line[len("data: "):])))
    return events


# ---------------------------------------------------------- recorder core
def test_recorder_ring_buffer_bounds_and_drop_count():
    rec = FlightRecorder(capacity=4)
    for i in range(10):
        rec.record(f"s{i}", "window", request_ids=(i,))
    assert len(rec) == 4
    assert rec.recorded == 10 and rec.dropped == 6
    # newest-last snapshot keeps the most recent spans
    assert [s.name for s in rec.spans()] == ["s6", "s7", "s8", "s9"]
    assert rec.spans(request_id=3) == []
    assert [s.name for s in rec.spans(request_id=8)] == ["s8"]


def test_recorder_disabled_is_a_noop():
    rec = FlightRecorder(enabled=False)
    rec.on_submit(0, 0.0)
    rec.begin_batch(0, [0], 0.0)
    rec.on_compile(0.1)
    rec.on_window(2)
    rec.on_offload("commit", 0, 0.01, nbytes=8)
    rec.on_replay(0, 4)
    rec.finish_batch(1.0, detect_attrs={"heatmap": ((1,),)})
    assert len(rec) == 0 and rec.recorded == 0


def test_recorder_thread_safe_under_concurrent_records():
    # offload commits record from a background thread; pound the ring
    # from four threads and check the counters stay consistent
    rec = FlightRecorder(capacity=256)

    def pound(tid):
        for i in range(500):
            rec.record(f"t{tid}.{i}", "offload_commit", batch_index=tid)

    threads = [threading.Thread(target=pound, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert rec.recorded == 2000
    assert len(rec) == 256
    assert rec.dropped == 2000 - 256


def test_span_kinds_taxonomy_is_closed():
    # every engine/scheduler tap emits a kind from the documented taxonomy
    assert set(SPAN_KINDS) == {
        "submit", "admission", "queue_wait", "batch_assembly", "compile",
        "window", "offload_commit", "offload_restore", "replay", "detect",
        "finalize"}


# ------------------------------------------------------- heatmap plumbing
def test_bin_heatmap_and_site_labels():
    heat = np.zeros((8, 3), np.int32)
    heat[5, 1] = 4          # step 5, block0
    heat[7, 2] = 2          # step 7, block1
    binned = bin_heatmap(heat, n_bins=4)
    assert binned.shape == (3, 4)
    assert binned[1, 2] == 4 and binned[2, 3] == 2
    assert binned.sum() == heat.sum()
    # fewer steps than bins degrades to one bin per step
    assert bin_heatmap(np.ones((2, 1), np.int32), n_bins=4).shape == (1, 2)
    assert site_labels(1) == ("all",)
    assert site_labels(3) == ("embed", "block0", "block1")
    nested, labels = summarize(heat)
    assert labels == ("embed", "block0", "block1")
    assert nested == tuple(tuple(int(v) for v in row) for row in binned)
    assert summarize(None) == (None, None)


# -------------------------------------------- zero-perturbation + heatmap
def _drain(engine, stream=0):
    if not stream:
        return engine.run()
    results = [ev for ev in engine.run_stream(preview_interval=stream)
               if not isinstance(ev, PreviewEvent)]
    results.sort(key=lambda r: r.request_id)
    return results


def _engine(tracer=None, offload=False):
    return DriftServeEngine(
        arch=ARCH, smoke=True, bucket=1, tracer=tracer,
        offload=OffloadConfig() if offload else None)


def test_bit_identity_tracing_on_off_one_shot_and_streamed():
    """Acceptance: finals bit-identical with the recorder on vs off, for
    the one-shot AND the streamed path; heatmaps agree everywhere too."""
    digests, heatmaps = {}, {}
    for label, tracer, stream in (
            ("on/one-shot", None, 0),
            ("off/one-shot", FlightRecorder(enabled=False), 0),
            ("on/streamed", None, 2),
            ("off/streamed", FlightRecorder(enabled=False), 2)):
        eng = _engine(tracer=tracer)
        eng.submit(steps=4, mode="drift", op="undervolt", seed=0)
        (res,) = _drain(eng, stream=stream)
        digests[label] = latents_sha256(res.latents)
        heatmaps[label] = res.detect_heatmap
        if tracer is not None:
            assert len(eng.tracer) == 0    # disabled recorder stayed mute
    assert len(set(digests.values())) == 1, digests
    assert len(set(heatmaps.values())) == 1
    heat = heatmaps["on/one-shot"]
    assert heat is not None
    assert sum(map(sum, heat)) > 0         # undervolt at smoke BER detects
    # the engine protects the first nominal_steps (= 2 of 4) timesteps ->
    # the early half of every site's row is empty: the live Fig 5-6
    # structure, asserted on a real served sample
    n_bins = len(heat[0])
    assert all(sum(row[: n_bins // 2]) == 0 for row in heat)


def test_heatmap_engine_level_with_fewer_steps_than_bins():
    """A served request with steps < N_STEP_BINS degrades to one bin per
    step (no empty phantom bins), and the detect span's heatmap stays the
    result's heatmap."""
    from repro.serving.trace import N_STEP_BINS
    steps = N_STEP_BINS - 1
    eng = _engine()
    eng.submit(steps=steps, mode="drift", op="undervolt", seed=0)
    (res,) = _drain(eng)
    heat = res.detect_heatmap
    assert heat is not None
    assert all(len(row) == steps for row in heat)
    # the protected head (nominal_steps = 2) maps to the first two
    # per-step bins exactly -- no detections there by construction
    assert all(row[0] == row[1] == 0 for row in heat)
    (detect,) = [s for s in eng.tracer.spans() if s.kind == "detect"]
    assert detect.attrs["heatmap"] == heat


def test_recorder_offload_thread_racing_batch_lifecycle():
    """The offload store's background thread records commits while the
    engine thread opens/closes batches: with capacity headroom, nothing
    drops, every span lands exactly once, and batch-lifecycle spans stay
    one-per-batch."""
    n_batches, n_commits = 100, 400
    rec = FlightRecorder(capacity=8192)
    start = threading.Event()

    def offloader():
        start.wait()
        for i in range(n_commits):
            rec.on_offload("commit", i, 0.0, nbytes=64)

    t = threading.Thread(target=offloader)
    t.start()
    start.set()
    for b in range(n_batches):
        rec.begin_batch(b, [b], float(b))
        rec.on_window(2)
        rec.finish_batch(float(b) + 0.5)
    t.join()
    # queue_wait + batch_assembly + window + finalize per batch + commits
    assert rec.recorded == 4 * n_batches + n_commits
    assert rec.dropped == 0 and len(rec) == rec.recorded
    spans = rec.spans()
    by_kind = kind_counts(rec)
    assert by_kind == {"queue_wait": n_batches,
                       "batch_assembly": n_batches,
                       "window": n_batches,
                       "finalize": n_batches,
                       "offload_commit": n_commits}
    # no duplicated or lost commits: every step recorded exactly once
    commit_steps = sorted(s.attrs["step"] for s in spans
                          if s.kind == "offload_commit")
    assert commit_steps == list(range(n_commits))
    # batch-lifecycle spans are unique per batch index
    finals = [s.batch_index for s in spans if s.kind == "finalize"]
    assert sorted(finals) == list(range(n_batches))


def test_streamed_offloaded_span_coverage_with_decision_record():
    """Acceptance: a streamed, monitored, offload-enabled request's trace
    has spans for every window and commit plus the decision record."""
    eng = _engine(offload=True)
    sched = DeadlineScheduler(eng)
    window = 2
    adm = sched.submit(steps=6, mode="drift", op="undervolt", seed=0,
                       energy_budget_j=1e9)
    assert adm.admitted and adm.action == "frontier"
    results = _drain(eng, stream=window)
    assert len(results) == 1

    counts = kind_counts(eng.tracer, request_id=adm.request_id)
    assert counts.get("submit") == 1
    assert counts.get("admission") == 1
    assert counts.get("queue_wait") == 1
    assert counts.get("batch_assembly") == 1
    assert counts.get("compile", 0) >= 1   # drift trace (+ clean ref)
    assert counts.get("window") == -(-adm.steps // window)
    assert counts.get("offload_commit") == eng.offload_store.stats.commits
    assert eng.offload_store.stats.commits >= 1
    assert counts.get("detect") == 1 and counts.get("finalize") == 1

    tree = request_tree(eng.tracer.spans(), adm.request_id)
    dec = tree["decision"]
    assert dec["action"] == "frontier" and dec["admitted"]
    assert dec["frontier_points"] >= dec["frontier_ok"] >= 1
    assert len(dec["frontier_considered"]) == dec["frontier_points"]
    assert dec["chosen"].startswith(f"{dec['op']}/{dec['steps']}st/")
    # window spans carry contiguous step ranges covering the whole run
    windows = [s for s in eng.tracer.spans(adm.request_id)
               if s.kind == "window"]
    edges = [(s.attrs["from_step"], s.attrs["done_steps"]) for s in windows]
    assert edges[0][0] == 0 and edges[-1][1] == adm.steps
    assert all(a[1] == b[0] for a, b in zip(edges, edges[1:]))
    # the detect span carries the same heatmap the result reports
    (detect,) = [s for s in eng.tracer.spans(adm.request_id)
                 if s.kind == "detect"]
    assert detect.attrs["heatmap"] == results[0].detect_heatmap
    assert detect.attrs["blocks"] == results[0].detect_heatmap_blocks


def test_ar_replay_spans_and_token_heatmap():
    """The AR paradigm records a replay span per KV-window rollback and a
    single-site per-token-bin heatmap whose mass equals the detections."""
    eng = DriftServeEngine(arch="olmo-1b", smoke=True, bucket=2)
    for i in range(2):
        eng.submit(steps=8, mode="stat_abft", op="undervolt", seed=i)
    results = eng.run()
    counts = kind_counts(eng.tracer)
    # rollbacks are batch-level: every request in the bucket reports the
    # batch's count, one replay span each
    batch_rollbacks = results[0].ar_rollbacks
    assert batch_rollbacks >= 1            # undervolt at smoke BER rolls
    assert counts.get("replay", 0) == batch_rollbacks
    heat = results[0].detect_heatmap
    assert heat is not None
    assert results[0].detect_heatmap_blocks == ("all",)
    assert len(heat) == 1                  # one site row, binned tokens
    assert sum(heat[0]) == int(results[0].ar_detections) > 0


def test_rejected_decisions_recorded_without_request_id():
    eng = _engine()
    sched = DeadlineScheduler(eng)
    with pytest.raises(ValueError):
        sched.submit(steps=4, mode="not-a-mode", seed=0)
    adm = sched.submit(steps=8, mode="drift", op="undervolt", seed=1,
                       deadline_s=1e-9)
    assert not adm.admitted
    rejected = [s for s in eng.tracer.spans() if s.kind == "admission"
                and not s.attrs.get("admitted", True)]
    assert len(rejected) == 2
    assert all(s.request_ids == () for s in rejected)
    reasons = [s.attrs["reason"] for s in rejected]
    assert any(r.startswith("validation:") for r in reasons)
    rej = eng.telemetry.registry.counter("drift_scheduler_rejections_total",
                                         label_names=("reason",))
    assert rej.labels(reason="validation").value == 1
    assert rej.labels(reason="projected-miss").value == 1


# ------------------------------------------------------------- exporters
def _fake_trace():
    rec = FlightRecorder()
    rec.on_submit(7, 0.5, arch=ARCH)
    rec.begin_batch(3, [7], 1.0, n_live=1)
    rec.on_window(2)
    rec.finish_batch(1.5, latency_s=0.5)
    return rec


def test_chrome_trace_export_shape():
    rec = _fake_trace()
    doc = to_chrome_trace(rec.spans())
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    spans = [e for e in events if e["ph"] == "X"]
    names = {e["args"]["name"] for e in meta if e["name"] == "thread_name"}
    assert "scheduler/queue" in names and "batch 3" in names
    assert len(spans) == len(rec.spans())
    for e in spans:
        assert e["pid"] == 1 and e["dur"] >= 1.0
        assert "virtual_t0_s" in e["args"]
    submit = next(e for e in spans if e["cat"] == "submit")
    assert submit["tid"] == 0              # pre-batch track
    window = next(e for e in spans if e["cat"] == "window")
    assert window["tid"] == 4              # batch 3 -> tid 4
    json.dumps(doc)                        # wire-serializable


def test_request_tree_shape():
    rec = _fake_trace()
    tree = request_tree(rec.spans(), 7)
    assert tree["request_id"] == 7
    assert tree["n_spans"] == len(rec.spans(request_id=7)) == 5
    assert tree["decision"] is None        # no scheduler in this trace
    assert [s["kind"] for s in tree["spans"]] == \
        ["submit", "queue_wait", "batch_assembly", "window", "finalize"]
    empty = request_tree(rec.spans(), 99)
    assert empty["n_spans"] == 0 and empty["spans"] == []


# -------------------------------------------------- HTTP: /trace, /flight
@pytest.fixture()
def served_engine():
    eng = _engine()
    server = serve_telemetry(eng, port=0)
    yield eng, server
    server.close()


def test_trace_endpoint_200_and_flight(served_engine):
    eng, server = served_engine
    rid = eng.submit(steps=4, mode="drift", op="undervolt", seed=0)
    eng.run()
    headers, body = fetch(f"{server.url}/trace/{rid}")
    assert headers["Content-Type"].startswith("application/json")
    tree = json.loads(body)
    assert tree["request_id"] == rid and tree["n_spans"] >= 4
    kinds = {s["kind"] for s in tree["spans"]}
    assert {"submit", "batch_assembly", "detect", "finalize"} <= kinds
    doc = json.loads(fetch(f"{server.url}/flight")[1])
    assert len([e for e in doc["traceEvents"] if e.get("ph") == "X"]) \
        == len(eng.tracer)


def test_trace_endpoint_404_paths(served_engine):
    eng, server = served_engine
    # non-integer id
    with pytest.raises(urllib.error.HTTPError) as exc:
        fetch(f"{server.url}/trace/abc")
    assert exc.value.code == 404
    assert "bad request id" in exc.value.read().decode()
    # unknown id against an empty recorder
    with pytest.raises(urllib.error.HTTPError) as exc:
        fetch(f"{server.url}/trace/999")
    assert exc.value.code == 404
    assert "no trace" in exc.value.read().decode()


def test_trace_endpoint_404_when_recorder_disabled():
    eng = DriftServeEngine(arch=ARCH, smoke=True, bucket=1,
                           tracer=FlightRecorder(enabled=False))
    rid = eng.submit(steps=4, mode="drift", op="undervolt", seed=0)
    eng.run()
    with serve_telemetry(eng, port=0) as server:
        with pytest.raises(urllib.error.HTTPError) as exc:
            fetch(f"{server.url}/trace/{rid}")
        assert exc.value.code == 404
        # /flight still answers: an empty, well-formed trace
        doc = json.loads(fetch(f"{server.url}/flight")[1])
        assert [e["ph"] for e in doc["traceEvents"]] == ["M"]


# ------------------------------------------- HTTP: SSE under real clients
def test_two_concurrent_sse_clients_one_drains_other_503(served_engine):
    """Two genuinely concurrent /events clients: the first holds the
    drain for seconds (the handler jits in-line), the second must get a
    clean 503 -- never interleaved batches -- and a retry after the
    first finishes succeeds."""
    eng, server = served_engine
    for i in range(2):
        eng.submit(steps=4, mode="drift", op="undervolt", seed=i)
    first = {}

    def drain():
        first["events"] = parse_sse(fetch(f"{server.url}/events")[1])

    t = threading.Thread(target=drain)
    t.start()
    time.sleep(0.5)        # handler has the lock; the jit keeps it busy
    with pytest.raises(urllib.error.HTTPError) as exc:
        fetch(f"{server.url}/events")
    assert exc.value.code == 503
    t.join()
    # default interval 1: a 4-step request previews after steps 1-3 (the
    # final window yields the result instead), so 3 previews per request
    assert first["events"][-1] == ("end", {"served": 2, "previews": 6})
    # lock released: the loser's retry drains the (now empty) queue fine
    events = parse_sse(fetch(f"{server.url}/events")[1])
    assert events == [("end", {"served": 0, "previews": 0})]


def test_slow_sse_consumer_still_receives_every_frame(served_engine):
    """A consumer reading 32 bytes at a time with pauses: the drain
    completes engine-side and every frame still arrives intact."""
    eng, server = served_engine
    for i in range(2):
        eng.submit(steps=4, mode="drift", op="undervolt", seed=i)
    resp = urllib.request.urlopen(f"{server.url}/events?interval=2",
                                  timeout=600)
    chunks = []
    while True:
        chunk = resp.read(32)
        if not chunk:
            break
        chunks.append(chunk)
        time.sleep(0.002)
    events = parse_sse(b"".join(chunks).decode("utf-8"))
    kinds = [k for k, _ in events]
    assert kinds.count("result") == 2
    assert kinds.count("preview") == 2     # 2 requests x (4/K - 1) = 2
    assert events[-1] == ("end", {"served": 2, "previews": 2})
    assert eng.queue.pending() == ()


# ------------------------------------------------- nearest_rank hardening
def test_nearest_rank_empty_and_bounds():
    from repro.serving.telemetry.metrics import nearest_rank
    with pytest.raises(ValueError):
        nearest_rank([], 50)
    with pytest.raises(ValueError):
        nearest_rank([1.0], -1)
    with pytest.raises(ValueError):
        nearest_rank([1.0], 100.5)
    # single sample: every quantile is that sample
    assert nearest_rank([3.0], 0) == 3.0
    assert nearest_rank([3.0], 50) == 3.0
    assert nearest_rank([3.0], 100) == 3.0
    # endpoints clamp to the extremes
    data = [1.0, 2.0, 3.0, 4.0]
    assert nearest_rank(data, 0) == 1.0
    assert nearest_rank(data, 100) == 4.0
    assert nearest_rank(data, 50) in data


def test_histogram_empty_percentile_is_none():
    from repro.serving.telemetry.metrics import MetricsRegistry
    h = MetricsRegistry().histogram("t_seconds", "t")
    assert h.percentile(50) is None
    h.observe(2.5)
    assert h.percentile(0) == h.percentile(100) == 2.5


# --------------------------------------------------- metrics catalog twin
def test_metrics_catalog_covers_registry():
    """Tier-1 twin of tools/check_metrics_catalog.py: every registered
    metric family has a row in docs/telemetry.md."""
    import sys
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import check_metrics_catalog as cmc
    finally:
        sys.path.pop(0)
    doc = (REPO / "docs" / "telemetry.md").read_text(encoding="utf-8")
    names = cmc.registered_metric_names()
    assert len(names) >= 30
    assert cmc.missing_from_catalog(doc, names) == []
    # the satellite metrics are among them
    for name in ("drift_build_info", "drift_engine_uptime_seconds",
                 "drift_scheduler_rejections_total",
                 "drift_detect_heatmap_total"):
        assert name in names


def test_build_info_uptime_and_heatmap_metrics():
    from repro.version import __version__
    eng = _engine()
    text = eng.telemetry.registry.expose()
    assert f'version="{__version__}"' in text
    assert "drift_build_info" in text
    eng.submit(steps=4, mode="drift", op="undervolt", seed=0)
    (res,) = eng.run()
    text = eng.telemetry.registry.expose()
    assert "drift_engine_uptime_seconds" in text
    assert 'drift_detect_heatmap_total{block="block' in text
    # the counter's total equals the served heatmap's mass
    total = 0.0
    for line in text.splitlines():
        if line.startswith("drift_detect_heatmap_total{"):
            total += float(line.rsplit(" ", 1)[1])
    assert total == sum(map(sum, res.detect_heatmap)) > 0


# ------------------------------------------------------------------ mesh
@needs_mesh
def test_sharded_bit_identity_tracing_on_off():
    """8-fake-device twin: streamed + monitored on the mesh, recorder on
    vs off, finals and heatmaps bit-identical."""
    from repro.serving import make_engine

    def run(tracer):
        eng = make_engine(arch=ARCH, smoke=True, bucket=2, tracer=tracer)
        for i in range(2):
            eng.submit(steps=4, mode="drift", op="undervolt", seed=i)
        return eng, _drain(eng, stream=2)

    eng_on, res_on = run(None)
    eng_off, res_off = run(FlightRecorder(enabled=False))
    assert [latents_sha256(r.latents) for r in res_on] == \
        [latents_sha256(r.latents) for r in res_off]
    assert [r.detect_heatmap for r in res_on] == \
        [r.detect_heatmap for r in res_off]
    assert res_on[0].detect_heatmap is not None
    counts = kind_counts(eng_on.tracer)
    assert counts.get("window") == 2 and counts.get("detect") == 1
    assert len(eng_off.tracer) == 0
